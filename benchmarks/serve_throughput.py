"""Episodic serving throughput: tasks adapted/sec, queries/sec, state-store
hit-rate, p50/p99 latency, and the compile counter over a request stream.

Four comparisons:

* ``adapt_loop`` vs ``adapt_batch`` — per-task ``learner.adapt`` dispatches
  vs ONE vmapped ``adapt_batch`` over the same T tasks (the serving
  engine's adaptation path).
* ``query_loop`` vs ``query_batch`` — per-task ``predict`` dispatches vs
  ONE micro-batched ``predict_batch`` (the engine's per-step dispatch).
* ``engine_cold`` vs ``engine_warm`` — the full EpisodicServeEngine on a
  request stream of distinct users, then the SAME users again: warm
  traffic skips adaptation via the task-state store, the compile counters
  must not grow, and both rows report nearest-rank p50/p99 adapt latency
  (enqueue -> state ready) and query latency (enqueue -> first logit)
  from the engine's clock.
* ``fomaml_readapt`` vs ``fomaml_rehydrate`` — re-adapting a task whose
  state was evicted vs rehydrating it from the disk warm tier
  (checkpoint-serialized spill): fomaml is the expensive re-adapt tail
  (see table1_adaptation_cost.csv), exactly what the two-tier store
  avoids paying again.
* ``engine_int8_cold`` vs ``engine_cold`` — the same cold request stream
  through a ``serve_quant='int8'`` engine (frozen backbone in blockwise
  int8, dequantized lazily in-jit): compile counters must match the fp32
  engine and the ``param_bytes_resident`` column carries the measured
  resident weight bytes of each engine.
* ``engine_replicas{1,2,4}_{none,int8}`` — the replica-aware router
  (``repro.serve.replica``) on 4 EMULATED devices (fresh subprocess with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=4``, same pattern as
  benchmarks/dp_scaling.py): 1/2/4 replicas on disjoint
  ``make_replica_mesh`` device groups, weight-stationary per group, per
  serve_quant mode.  ``requests_per_step`` is admitted-request throughput
  per router step (each step steps every replica once — the replica-
  scaling acceptance row: ~2x from 1 -> 2 replicas) and
  ``wire_per_replica_bytes`` is ONE replica's per-step predict wire from
  ``collectives_report`` — it scales with the group's devices, not the
  deployment (4 replicas x 1 device: zero wire).

    PYTHONPATH=src python benchmarks/serve_throughput.py
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

sys.path.insert(0, __file__.rsplit("/", 1)[0])

REPLICA_DEVICES = 4


def _replica_worker() -> None:
    """Replica-scaling sweep, run in the 4-emulated-device subprocess:
    prints one ``REPLICA_ROWS <json>`` line the parent folds into the
    shared CSV."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.episodic_train import task_key
    from repro.core.lite import LiteSpec
    from repro.core.meta_learners import MetaLearnerConfig, make_learner
    from repro.core.set_encoder import SetEncoderConfig
    from repro.data.episodic import (EpisodicImageConfig, collate_task_batch,
                                     plan_buckets, sample_image_task)
    from repro.launch.mesh import make_replica_mesh
    from repro.models.conv_backbone import (ConvBackboneConfig,
                                            make_conv_backbone)
    from repro.roofline.analysis import score_serving_layout
    from repro.serve.episodic import EpisodicRequest
    from repro.serve.quant_params import dequantize_params, quantize_frozen
    from repro.serve.replica import ReplicatedServeEngine, uid_replica

    assert len(jax.devices()) == REPLICA_DEVICES, jax.devices()
    way, shot, query, image = 5, 4, 4, 12
    backbone = make_conv_backbone(ConvBackboneConfig(widths=(8,),
                                                     feature_dim=16))
    learner = make_learner(
        MetaLearnerConfig(kind="protonets", way=way), backbone,
        SetEncoderConfig(kind="conv", conv_blocks=1, conv_width=8,
                         task_dim=16))
    params = learner.init(jax.random.key(0))
    lite = LiteSpec(exact=True, chunk_size=32)
    cfg = EpisodicImageConfig(way=way, shot=shot, query_per_class=query,
                              image_size=image)
    buckets = plan_buckets([way * shot], max_buckets=1)
    n_req = 12

    # uids balanced across all of 1/2/4 replica homes (3 per 4-replica
    # home; 2 | 4 so the 2-replica split is even too) — the scaling rows
    # measure the router, not hash luck on a 12-request sample
    by_home = {r: [] for r in range(4)}
    u = 0
    while sum(len(v) for v in by_home.values()) < n_req:
        h = uid_replica(u, 4)
        if len(by_home[h]) < n_req // 4:
            by_home[h].append(u)
        u += 1
    uids = sorted(x for v in by_home.values() for x in v)

    def make_requests():
        return [EpisodicRequest(
            uid=u,
            support_x=np.asarray(
                (t := sample_image_task(jax.random.key(500 + u),
                                        cfg)).support_x),
            support_y=np.asarray(t.support_y),
            query_x=np.asarray(t.query_x), way=way) for u in uids]

    rows = []
    for replicas in (1, 2, 4):
        dpr = REPLICA_DEVICES // replicas
        meshes = make_replica_mesh(replicas, dpr)
        # one replica group's per-step predict wire (weight_stationary):
        # the group IS the collective domain, so this is what EACH
        # replica pays regardless of how many replicas exist
        probe = [sample_image_task(jax.random.key(i), cfg)
                 for i in range(2)]
        pbatch = collate_task_batch(probe, support_size=max(buckets),
                                    query_size=probe[0].query_x.shape[0])
        pkeys = jax.vmap(lambda i: task_key(jax.random.key(0), i))(
            jnp.arange(2))
        for quant in ("none", "int8"):
            sw = quantize_frozen(learner, params, quant)
            states = learner.adapt_batch(dequantize_params(sw), pbatch,
                                         pkeys, lite)
            wire = score_serving_layout(
                lambda w, st, qx: learner.predict_batch(
                    dequantize_params(w), st, qx),
                sw, (states, pbatch.query_x), meshes[0],
                "weight_stationary")["wire_bytes"]

            router = ReplicatedServeEngine(
                learner, params, replicas=replicas, meshes=meshes,
                serve_layout="weight_stationary", serve_quant=quant,
                lite=lite, n_slots=1, query_chunk=8,
                support_buckets=buckets, cache_capacity=n_req)
            reqs = make_requests()
            for r in reqs:
                router.submit(r)
            t0 = time.perf_counter()
            steps = 0
            while router.busy:
                router.step()
                steps += 1
            dt = time.perf_counter() - t0
            s = router.stats()
            assert s["tasks_adapted"] == n_req
            n_queries = sum(r.n_queries for r in reqs)
            rows.append(dict(
                mode=f"engine_replicas{replicas}_{quant}", tasks=n_req,
                replicas=replicas, devices_per_replica=dpr,
                requests_per_step=round(n_req / steps, 3),
                tasks_per_sec=round(n_req / dt, 1),
                queries_per_sec=round(n_queries / dt, 1),
                hit_rate=round(s["hit_rate"], 3),
                adapt_compiles=int(s["adapt_compiles"]),
                predict_compiles=int(s["predict_compiles"]),
                param_bytes_resident=int(s["param_bytes_resident"]),
                wire_per_replica_bytes=round(wire, 1),
                quarantined=0, rejections=0, deadline_abandoned=0))
    print("REPLICA_ROWS " + json.dumps(rows), flush=True)


if os.environ.get("SERVE_REPLICA_WORKER"):  # pragma: no cover - subprocess
    _replica_worker()
    sys.exit(0)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from common import emit, time_median  # noqa: E402

from repro.core.episodic import index_task_state, stack_task_states
from repro.core.episodic_train import task_key
from repro.core.lite import LiteSpec
from repro.core.meta_learners import MetaLearnerConfig, make_learner
from repro.core.set_encoder import SetEncoderConfig
from repro.data.episodic import (EpisodicImageConfig, collate_task_batch,
                                 plan_buckets, sample_image_task)
from repro.models.conv_backbone import ConvBackboneConfig, make_conv_backbone
from repro.serve.episodic import (EpisodicRequest, EpisodicServeEngine,
                                  WarmTaskStore, _pctl)


def _replica_rows() -> list:
    """Re-exec this file with 4 emulated devices (XLA_FLAGS must precede
    jax init) and collect the replica-scaling rows."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count="
                        f"{REPLICA_DEVICES}").strip()
    env["SERVE_REPLICA_WORKER"] = "1"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [str(__file__).rsplit("/", 2)[0] + "/src",
                    env.get("PYTHONPATH", "")] if p)
    r = subprocess.run([sys.executable, __file__], env=env,
                       capture_output=True, text=True)
    if r.returncode:
        raise RuntimeError(f"replica worker failed ({r.returncode}):\n"
                           f"{r.stderr[-3000:]}")
    for line in r.stdout.splitlines():
        if line.startswith("REPLICA_ROWS "):
            return json.loads(line[len("REPLICA_ROWS "):])
    raise RuntimeError("replica worker produced no REPLICA_ROWS line")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=8)
    ap.add_argument("--way", type=int, default=5)
    ap.add_argument("--shot", type=int, default=4)
    ap.add_argument("--query", type=int, default=4)
    ap.add_argument("--image-size", type=int, default=12)
    ap.add_argument("--iters", type=int, default=9)
    ap.add_argument("--engine-requests", type=int, default=12)
    args = ap.parse_args()

    backbone = make_conv_backbone(ConvBackboneConfig(widths=(8,),
                                                     feature_dim=16))
    learner = make_learner(
        MetaLearnerConfig(kind="protonets", way=args.way), backbone,
        SetEncoderConfig(kind="conv", conv_blocks=1, conv_width=8,
                         task_dim=16))
    params = learner.init(jax.random.key(0))
    lite = LiteSpec(exact=True, chunk_size=32)
    t_count = args.tasks

    cfg = EpisodicImageConfig(way=args.way, shot=args.shot,
                              query_per_class=args.query,
                              image_size=args.image_size)
    tasks = [sample_image_task(jax.random.key(100 + i), cfg)
             for i in range(t_count)]
    batch = collate_task_batch(tasks)
    key = jax.random.key(7)
    keys = jax.vmap(lambda i: task_key(key, i))(jnp.arange(t_count))
    n_q = int(batch.query_x.shape[1])

    def blank(r):
        return dict(mode=r["mode"], tasks=r.get("tasks", ""),
                    tasks_per_sec=r.get("tasks_per_sec", ""),
                    queries_per_sec=r.get("queries_per_sec", ""),
                    speedup=r.get("speedup", ""),
                    hit_rate=r.get("hit_rate", ""),
                    wall_us=r.get("wall_us", ""),
                    adapt_p50_us=r.get("adapt_p50_us", ""),
                    adapt_p99_us=r.get("adapt_p99_us", ""),
                    query_p50_us=r.get("query_p50_us", ""),
                    query_p99_us=r.get("query_p99_us", ""),
                    adapt_compiles=r.get("adapt_compiles", ""),
                    predict_compiles=r.get("predict_compiles", ""),
                    param_bytes_resident=r.get("param_bytes_resident", ""),
                    quarantined=r.get("quarantined", ""),
                    rejections=r.get("rejections", ""),
                    deadline_abandoned=r.get("deadline_abandoned", ""),
                    replicas=r.get("replicas", ""),
                    devices_per_replica=r.get("devices_per_replica", ""),
                    requests_per_step=r.get("requests_per_step", ""),
                    wire_per_replica_bytes=r.get("wire_per_replica_bytes",
                                                 ""))

    rows = []

    # -- adaptation: per-task loop vs one vmapped dispatch -------------------
    adapt_one = jax.jit(lambda p, sx, sy, k, m: learner.adapt(
        p, sx, sy, key=k, lite=lite, mask=m))

    def run_adapt_loop():
        sts = [adapt_one(params, batch.support_x[i], batch.support_y[i],
                         keys[i], batch.support_mask[i])
               for i in range(t_count)]
        jax.block_until_ready(sts)
        return sts

    adapt_b = jax.jit(lambda p, b, k: learner.adapt_batch(p, b, k, lite))

    def run_adapt_batch():
        return jax.block_until_ready(adapt_b(params, batch, keys))

    t_loop = time_median(run_adapt_loop, args.iters)
    t_batch = time_median(run_adapt_batch, args.iters)
    rows.append(blank(dict(mode="adapt_loop", tasks=t_count,
                           tasks_per_sec=round(t_count / t_loop, 1),
                           speedup=1.0)))
    rows.append(blank(dict(mode="adapt_batch", tasks=t_count,
                           tasks_per_sec=round(t_count / t_batch, 1),
                           speedup=round(t_loop / t_batch, 2))))

    # -- query scoring: per-task loop vs one micro-batched dispatch ----------
    states = run_adapt_batch()
    per_states = [index_task_state(states, i) for i in range(t_count)]
    pred_one = jax.jit(learner.predict)
    pred_b = jax.jit(learner.predict_batch)
    states_stacked = stack_task_states(per_states)

    def run_query_loop():
        out = [pred_one(params, per_states[i], batch.query_x[i])
               for i in range(t_count)]
        jax.block_until_ready(out)

    def run_query_batch():
        jax.block_until_ready(pred_b(params, states_stacked, batch.query_x))

    t_qloop = time_median(run_query_loop, args.iters)
    t_qbatch = time_median(run_query_batch, args.iters)
    rows.append(blank(dict(mode="query_loop", tasks=t_count,
                           queries_per_sec=round(t_count * n_q / t_qloop, 1),
                           speedup=1.0)))
    rows.append(blank(dict(mode="query_batch", tasks=t_count,
                           queries_per_sec=round(t_count * n_q / t_qbatch, 1),
                           speedup=round(t_qloop / t_qbatch, 2))))

    # -- full engine: cold stream, then the same users warm ------------------
    def make_requests():
        return [EpisodicRequest(uid=i, support_x=np.asarray(t.support_x),
                                support_y=np.asarray(t.support_y),
                                query_x=np.asarray(t.query_x), way=args.way)
                for i, t in enumerate(
                    sample_image_task(jax.random.key(500 + i), cfg)
                    for i in range(args.engine_requests))]

    buckets = plan_buckets([args.way * args.shot], max_buckets=1)
    engine = EpisodicServeEngine(learner, params, lite=lite, n_slots=4,
                                 query_chunk=8, support_buckets=buckets,
                                 cache_capacity=args.engine_requests)
    cold = make_requests()
    t0 = time.perf_counter()
    engine.run_to_completion(cold)
    dt_cold = time.perf_counter() - t0
    s_cold = engine.stats()

    warm = [EpisodicRequest(uid=r.uid, query_x=np.asarray(r.query_x),
                            way=args.way)
            for r in cold]                      # repeat visitors, no support
    t0 = time.perf_counter()
    engine.run_to_completion(warm)
    dt_warm = time.perf_counter() - t0
    s_warm = engine.stats()

    n_req = args.engine_requests
    n_queries = sum(r.n_queries for r in cold)

    def wave_pctls(reqs):
        """Per-wave nearest-rank percentiles from the request timestamps
        (the engine's cumulative stats would mix the waves)."""
        alat = [(r.t_adapt - r.t_enqueue) * 1e6 for r in reqs
                if r.t_adapt is not None]
        qlat = [(r.t_first_logit - r.t_enqueue) * 1e6 for r in reqs
                if r.t_first_logit is not None]
        return dict(adapt_p50_us=round(_pctl(alat, 50)),
                    adapt_p99_us=round(_pctl(alat, 99)),
                    query_p50_us=round(_pctl(qlat, 50)),
                    query_p99_us=round(_pctl(qlat, 99)))

    rows.append(blank(dict(
        mode="engine_cold", tasks=n_req,
        tasks_per_sec=round(s_cold["tasks_adapted"] / dt_cold, 1),
        queries_per_sec=round(n_queries / dt_cold, 1),
        hit_rate=round(s_cold["hit_rate"], 3),
        adapt_compiles=s_cold["adapt_compiles"],
        predict_compiles=s_cold["predict_compiles"],
        quarantined=int(s_cold["quarantined"]),
        rejections=int(s_cold["rejections"]),
        deadline_abandoned=int(s_cold["deadline_abandoned"]),
        param_bytes_resident=s_cold["param_bytes_resident"],
        **wave_pctls(cold))))
    rows.append(blank(dict(
        mode="engine_warm", tasks=n_req,
        queries_per_sec=round(n_queries / dt_warm, 1),
        speedup=round(dt_cold / dt_warm, 2),
        hit_rate=round(
            (s_warm["cache_hits"] - s_cold["cache_hits"]) /
            max(n_req, 1), 3),
        adapt_compiles=s_warm["adapt_compiles"],
        predict_compiles=s_warm["predict_compiles"],
        quarantined=int(s_warm["quarantined"]),
        rejections=int(s_warm["rejections"]),
        deadline_abandoned=int(s_warm["deadline_abandoned"]),
        param_bytes_resident=s_warm["param_bytes_resident"],
        **wave_pctls(warm))))

    # -- int8 weight-stationary serving vs fp32, same traffic ----------------
    # quantized frozen backbone (repro.serve.quant_params): same request
    # stream, same bucket plan — the rows compare throughput, compile
    # counters (must match the fp32 engine: identical dispatch paths), and
    # the measured resident parameter bytes.
    eng_q = EpisodicServeEngine(learner, params, lite=lite, n_slots=4,
                                query_chunk=8, support_buckets=buckets,
                                cache_capacity=args.engine_requests,
                                serve_quant="int8")
    cold_q = make_requests()
    t0 = time.perf_counter()
    eng_q.run_to_completion(cold_q)
    dt_q = time.perf_counter() - t0
    s_q = eng_q.stats()
    rows.append(blank(dict(
        mode="engine_int8_cold", tasks=n_req,
        tasks_per_sec=round(s_q["tasks_adapted"] / dt_q, 1),
        queries_per_sec=round(n_queries / dt_q, 1),
        speedup=round(dt_cold / dt_q, 2),
        hit_rate=round(s_q["hit_rate"], 3),
        adapt_compiles=s_q["adapt_compiles"],
        predict_compiles=s_q["predict_compiles"],
        quarantined=int(s_q["quarantined"]),
        rejections=int(s_q["rejections"]),
        deadline_abandoned=int(s_q["deadline_abandoned"]),
        param_bytes_resident=s_q["param_bytes_resident"],
        **wave_pctls(cold_q))))

    # -- warm-tier rehydrate vs re-adaptation (fomaml: the expensive tail) ---
    import tempfile

    fomaml = make_learner(
        MetaLearnerConfig(kind="fomaml", way=args.way, inner_steps=15),
        backbone,
        SetEncoderConfig(kind="conv", conv_blocks=1, conv_width=8,
                         task_dim=16))
    f_params = fomaml.init(jax.random.key(1))
    f_task = tasks[0]
    f_key = task_key(key, 0)
    adapt_j = jax.jit(lambda p, sx, sy, k: fomaml.adapt(
        p, sx, sy, key=k, lite=lite))
    st = jax.block_until_ready(
        adapt_j(f_params, f_task.support_x, f_task.support_y, f_key))
    with tempfile.TemporaryDirectory() as warm_dir:
        warm_store = WarmTaskStore(warm_dir)
        warm_store.put(0, st)
        t_readapt = time_median(lambda: jax.block_until_ready(
            adapt_j(f_params, f_task.support_x, f_task.support_y, f_key)),
            args.iters)
        t_rehydrate = time_median(lambda: jax.block_until_ready(
            warm_store.get(0)), args.iters)
    rows.append(blank(dict(mode="fomaml_readapt", tasks=1,
                           wall_us=round(1e6 * t_readapt), speedup=1.0)))
    rows.append(blank(dict(mode="fomaml_rehydrate", tasks=1,
                           wall_us=round(1e6 * t_rehydrate),
                           speedup=round(t_readapt / t_rehydrate, 2))))

    # -- replica scaling on 4 emulated devices (fresh subprocess) ------------
    rep_rows = [blank(r) for r in _replica_rows()]
    rows.extend(rep_rows)

    emit(rows, "serve_throughput")
    by_mode = {r["mode"]: r for r in rep_rows}
    r1 = by_mode["engine_replicas1_none"]
    r2 = by_mode["engine_replicas2_none"]
    print(f"# replica scaling (4 emulated devices): requests/step "
          f"{r1['requests_per_step']} -> {r2['requests_per_step']} "
          f"(x{r2['requests_per_step'] / r1['requests_per_step']:.2f} at 2 "
          f"replicas); per-replica predict wire "
          f"{r1['wire_per_replica_bytes']} B (4 dev) -> "
          f"{r2['wire_per_replica_bytes']} B (2-dev group) -> "
          f"{by_mode['engine_replicas4_none']['wire_per_replica_bytes']} B "
          f"(1-dev group)")
    print(f"# warm-tier rehydrate vs fomaml re-adapt: "
          f"{t_readapt / t_rehydrate:.2f}x cheaper "
          f"({1e6 * t_readapt:.0f} vs {1e6 * t_rehydrate:.0f} us)")
    print(f"# adapt_batch speedup over per-task adapt loop: "
          f"{t_loop / t_batch:.2f}x")
    print(f"# predict_batch speedup over per-task query loop: "
          f"{t_qloop / t_qbatch:.2f}x")
    print(f"# warm (cached) pass speedup over cold: "
          f"{dt_cold / dt_warm:.2f}x; compile counters flat: "
          f"{s_warm['adapt_compiles'] == s_cold['adapt_compiles']}")
    print(f"# int8 serving: resident weight bytes "
          f"{s_cold['param_bytes_resident']} -> "
          f"{s_q['param_bytes_resident']} "
          f"(frozen slice {s_cold['frozen_param_bytes_resident']} -> "
          f"{s_q['frozen_param_bytes_resident']}, "
          f"{s_cold['frozen_param_bytes_resident'] / max(s_q['frozen_param_bytes_resident'], 1):.2f}x); "
          f"compile counters match fp32: "
          f"{(s_q['adapt_compiles'], s_q['predict_compiles']) == (s_cold['adapt_compiles'], s_cold['predict_compiles'])}")


if __name__ == "__main__":
    main()
