"""Paper §D.4 memory claim: LITE's live-activation footprint scales with
|H| + chunk, not with N (the paper reports ~8 GB at H=40 vs ~16 GB full
at 84x84).  We measure compiled peak temp bytes of the meta-training step
via XLA's memory analysis as |H| varies at fixed N.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit
from repro.core.lite import LiteSpec
from repro.core.meta_learners import MetaLearnerConfig, make_learner
from repro.core.set_encoder import SetEncoderConfig
from repro.data.episodic import EpisodicImageConfig, sample_image_task
from repro.models.conv_backbone import ConvBackboneConfig, make_conv_backbone

H_VALUES = (4, 16, 64, 100)     # 100 == N -> exact
N = 100
CHUNK = 8


def run() -> list:
    bb = make_conv_backbone(ConvBackboneConfig(widths=(16, 32, 64),
                                               feature_dim=64))
    set_cfg = SetEncoderConfig(kind="conv", conv_blocks=3, conv_width=16,
                               task_dim=32)
    tcfg = EpisodicImageConfig(way=10, shot=10, query_per_class=4,
                               image_size=32)
    task = sample_image_task(jax.random.key(0), tcfg)
    lr = make_learner(MetaLearnerConfig(kind="simple_cnaps", way=10), bb, set_cfg)
    params = lr.init(jax.random.key(1))

    rows = []
    for h in H_VALUES:
        spec = LiteSpec(h=h, chunk_size=CHUNK if h < N else None)

        def loss(p, t, k):
            return lr.meta_loss(p, t, k, spec)[0]

        lowered = jax.jit(jax.grad(loss)).lower(params, task, jax.random.key(2))
        mem = lowered.compile().memory_analysis()
        rows.append(dict(
            h=h, mode=("exact" if h >= N else f"lite_chunk{CHUNK}"),
            peak_temp_bytes=int(mem.temp_size_in_bytes),
            argument_bytes=int(mem.argument_size_in_bytes),
        ))
    return rows


def main() -> None:
    emit(run(), "memory_vs_h")


if __name__ == "__main__":
    main()
