"""Paper §D.4 memory claim: LITE's live-activation footprint scales with
|H| + chunk, not with N (the paper reports ~8 GB at H=40 vs ~16 GB full
at 84x84).  We measure compiled peak temp bytes of the meta-training step
via XLA's memory analysis as |H| varies at fixed N — and, for each
subsampled point, the ``LiteSpec.compute_dtype='bfloat16'`` variant.

Two memory columns per row:
  * ``peak_temp_bytes`` — XLA memory analysis of THIS container's CPU
    lowering.  CAVEAT: XLA CPU up-converts bf16 convolutions/dots to fp32
    and materializes the converts, so the bf16 rows can come out LARGER
    here; on accelerators with native bf16 compute (TPU/GPU) the same HLO
    keeps the complement activations half-width.  (Same status as the
    flash-attention sweeps: CPU-verified logic, TPU-validated memory
    pending — see ROADMAP.)
  * ``chunk_live_bytes_model`` — backend-independent accounting of one
    no-grad complement chunk: the sum of every intermediate the chunk's
    encode produces, at the dtype the estimator actually requests.  This
    is the quantity LiteSpec.compute_dtype halves by construction, and
    the one that bounds live activations wherever the backend honors the
    dtype.

Plus the Simple CNAPs COVARIANCE-path columns (the kernel-dispatch win):
``cov_live_bytes_naive`` vs ``cov_live_bytes_fused`` account every
intermediate of the class-statistics reduction (per-class feature sums +
raw second moments) over one reduction batch — the complement chunk for
LITE rows, all N for exact rows.  The naive composite materializes the
per-example ``(B, F, F)`` outer tensor and its ``(B, C, F, F)``
class-expanded form; the fused dispatch path (the default since the
kernel-dispatch refactor) hops through ``(B, C, F)`` instead.  The
trailing ``cov_path_N*`` rows account the same reduction at serve/exact
batch sizes N in {256, 1000}, where the elimination is the difference
between O(N F^2 way) live bytes and O(N F way + F^2 way).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.lite import LiteSpec
from repro.core.meta_learners import MetaLearnerConfig, make_learner
from repro.core.set_encoder import SetEncoderConfig
from repro.data.episodic import EpisodicImageConfig, sample_image_task
from repro.kernels import dispatch
from repro.models.conv_backbone import ConvBackboneConfig, make_conv_backbone

H_VALUES = (4, 16, 64, 100)     # 100 == N -> exact
N = 100
# Throughput-oriented chunk: big enough that the no-grad complement is the
# binding memory term (the paper's large-N regime — its Algorithm 1 also
# microbatches the QUERY pass, so the query side is kept small here).
# This is the regime the mixed-precision complement targets.
CHUNK = 32


def run() -> list:
    bb = make_conv_backbone(ConvBackboneConfig(widths=(16, 32, 64),
                                               feature_dim=64))
    set_cfg = SetEncoderConfig(kind="conv", conv_blocks=3, conv_width=16,
                               task_dim=32)
    tcfg = EpisodicImageConfig(way=10, shot=10, query_per_class=1,
                               image_size=32)
    task = sample_image_task(jax.random.key(0), tcfg)
    lr = make_learner(MetaLearnerConfig(kind="simple_cnaps", way=10), bb, set_cfg)
    params = lr.init(jax.random.key(1))

    def chunk_live_bytes(dt) -> int:
        """Backend-independent bytes of every intermediate in one no-grad
        complement chunk's encode (backbone features at the estimator's
        requested dtype), from the jaxpr avals."""
        cd = jnp.dtype(dt) if dt else jnp.float32
        p = jax.tree.map(lambda a: a.astype(cd) if jnp.issubdtype(
            a.dtype, jnp.floating) else a, params["bb"])
        x = jnp.zeros((CHUNK, tcfg.image_size, tcfg.image_size,
                       tcfg.channels), cd)
        jaxpr = jax.make_jaxpr(lambda pp, xx: bb.features(pp, xx, None))(p, x)
        return int(sum(v.aval.size * v.aval.dtype.itemsize
                       for eqn in jaxpr.eqns for v in eqn.outvars))

    fdim = 64
    way = tcfg.way

    def cov_live_bytes(backend, b, dt=None) -> int:
        """Bytes of every intermediate in the Simple CNAPs class-statistics
        reduction over a batch of ``b`` features — the covariance path the
        kernel dispatch fuses.  ``naive`` materializes (b, F, F) outers
        and their (b, C, F, F) class expansion; ``ref`` hops through
        (b, C, F)."""
        cd = jnp.dtype(dt) if dt else jnp.float32
        feat = jnp.zeros((b, fdim), cd)
        oh = jnp.zeros((b, way), cd)

        def stats(f, o):
            return dict(
                feat=dispatch.segment_sum(f, o, accum_dtype=jnp.float32,
                                          backend=backend),
                outer=dispatch.class_second_moment(
                    f, o, accum_dtype=jnp.float32, backend=backend))

        jaxpr = jax.make_jaxpr(stats)(feat, oh)
        # convert_element_type outvars are excluded: XLA fuses the
        # cast into its consumer (the fp32-accumulating reduce), so no
        # such buffer is ever live — counting it would double-charge
        # the bf16 rows for a full-width copy of the naive outer tensor
        return int(sum(v.aval.size * v.aval.dtype.itemsize
                       for eqn in jaxpr.eqns for v in eqn.outvars
                       if eqn.primitive.name != "convert_element_type"))

    rows = []
    for h in H_VALUES:
        dtypes = (None,) if h >= N else (None, "bfloat16")
        for dt in dtypes:
            spec = LiteSpec(h=h, chunk_size=CHUNK if h < N else None,
                            compute_dtype=dt)

            def loss(p, t, k):
                return lr.meta_loss(p, t, k, spec)[0]

            lowered = jax.jit(jax.grad(loss)).lower(params, task,
                                                    jax.random.key(2))
            mem = lowered.compile().memory_analysis()
            stats_b = N if h >= N else CHUNK   # reduction batch: all-N
            rows.append(dict(                  # exact vs one chunk
                h=h, mode=("exact" if h >= N else f"lite_chunk{CHUNK}"),
                complement_dtype=(dt or "float32"),
                peak_temp_bytes=int(mem.temp_size_in_bytes),
                chunk_live_bytes_model=(0 if h >= N
                                        else chunk_live_bytes(dt)),
                cov_live_bytes_naive=cov_live_bytes("naive", stats_b, dt),
                cov_live_bytes_fused=cov_live_bytes("ref", stats_b, dt),
                argument_bytes=int(mem.argument_size_in_bytes),
            ))
    # serve/exact-scale covariance-path accounting: the (B, F, F)
    # elimination at the N the paper fights for (1000-image supports)
    for n in (256, 1000):
        rows.append(dict(
            h=0, mode=f"cov_path_N{n}", complement_dtype="float32",
            peak_temp_bytes=0, chunk_live_bytes_model=0,
            cov_live_bytes_naive=cov_live_bytes("naive", n),
            cov_live_bytes_fused=cov_live_bytes("ref", n),
            argument_bytes=0,
        ))
    return rows


def main() -> None:
    emit(run(), "memory_vs_h")


if __name__ == "__main__":
    main()
