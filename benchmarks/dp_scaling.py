"""Two-level DP scaling of the task-batched LITE engine (emulated hosts).

For each engine configuration — single-device, 1-D ``data`` mesh, and the
two-level ``(dcn, data)`` mesh with pmean / error-feedback-compressed /
gradient-accumulated cross-host reduction — this AOT-compiles the episodic
train step on 4 emulated CPU devices, accounts the per-step collective
wire bytes with :func:`repro.roofline.hlo.collectives_report` (the same
HLO walk the dry-run and the MoE wire-bytes regression guard use), and
measures steps/sec.

Emulation needs ``XLA_FLAGS=--xla_force_host_platform_device_count`` set
BEFORE jax initializes, so ``main()`` re-execs the measurement in a fresh
subprocess — the module stays registrable in ``benchmarks.run`` where jax
is already live.

    PYTHONPATH=src python benchmarks/dp_scaling.py
"""
from __future__ import annotations

import os
import subprocess
import sys

DEVICES = 4
TASKS = 8


def _worker() -> None:
    import jax
    import jax.numpy as jnp

    sys.path.insert(0, __file__.rsplit("/", 1)[0])
    from common import emit, time_median  # noqa: E402

    from repro.core.episodic_train import (init_ef_state,
                                           make_batched_meta_train_step)
    from repro.core.lite import LiteSpec
    from repro.core.meta_learners import MetaLearnerConfig, make_learner
    from repro.core.set_encoder import SetEncoderConfig
    from repro.data.episodic import (EpisodicImageConfig,
                                     sample_image_task_batch)
    from repro.launch.mesh import make_dp_mesh, make_two_level_dp_mesh
    from repro.models.conv_backbone import (ConvBackboneConfig,
                                            make_conv_backbone)
    from repro.optim import AdamWConfig, adamw_init
    from repro.roofline.hlo import collectives_report

    assert len(jax.devices()) == DEVICES, jax.devices()
    bb = make_conv_backbone(ConvBackboneConfig(widths=(8, 16),
                                               feature_dim=32))
    learner = make_learner(
        MetaLearnerConfig(kind="protonets", way=5), bb,
        SetEncoderConfig(kind="conv", conv_blocks=1, conv_width=8,
                         task_dim=16))
    params = learner.init(jax.random.key(0))
    adamw = AdamWConfig(weight_decay=0.0)
    spec = LiteSpec(h=4)
    tcfg = EpisodicImageConfig(way=5, shot=6, query_per_class=3,
                               image_size=12)
    batch = sample_image_task_batch(jax.random.key(3), tcfg, TASKS)
    key = jax.random.key(9)
    pbytes = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(params))

    configs = [
        dict(engine="single", mesh=None, kw={}),
        dict(engine="dp4", mesh=make_dp_mesh(4), kw={}),
        dict(engine="dcn1xdp4", mesh=make_two_level_dp_mesh(1, 4), kw={}),
        dict(engine="dcn2xdp2_pmean", mesh=make_two_level_dp_mesh(2, 2),
             kw={}),
        dict(engine="dcn2xdp2_compressed", mesh=make_two_level_dp_mesh(2, 2),
             kw=dict(grad_reduce="compressed")),
        dict(engine="dcn2xdp2_accum2", mesh=make_two_level_dp_mesh(2, 2),
             kw=dict(accum_steps=2)),
    ]

    rows = []
    for c in configs:
        step = make_batched_meta_train_step(learner, spec, adamw=adamw,
                                            mesh=c["mesh"], **c["kw"])
        opt = adamw_init(params, adamw)
        if c["kw"].get("grad_reduce") == "compressed":
            opt["ef"] = init_ef_state(params, 2)
        compiled = jax.jit(step).lower(params, opt, batch, key).compile()
        rep = collectives_report(compiled)

        def run(compiled=compiled, opt=opt):
            jax.block_until_ready(compiled(params, opt, batch, key))

        dt = time_median(run, 5)
        rows.append(dict(
            engine=c["engine"], devices=DEVICES, tasks_per_step=TASKS,
            param_bytes=pbytes,
            wire_bytes=round(rep["total_wire_bytes"], 1),
            wire_per_param=round(rep["total_wire_bytes"] / pbytes, 3),
            collective_count=int(rep["count"]),
            step_ms=round(1e3 * dt, 2),
            tasks_per_sec=round(TASKS / dt, 1),
        ))
    emit(rows, "dp_scaling")


def main() -> None:
    if os.environ.get("DP_SCALING_WORKER"):
        _worker()
        return
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={DEVICES}"
                        ).strip()
    env["DP_SCALING_WORKER"] = "1"
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in [str(__file__).rsplit("/", 2)[0] + "/src",
                     env.get("PYTHONPATH", "")] if p])
    r = subprocess.run([sys.executable, __file__], env=env)
    if r.returncode:
        raise RuntimeError(f"dp_scaling worker failed ({r.returncode})")


if __name__ == "__main__":
    main()
