"""Paper Fig. 4 / Tables D.7-D.8: gradient RMSE & bias vs |H| for LITE and
the sub-sampled small-task baseline, measured on the first conv layer of
Simple CNAPs' set encoder (10-way 10-shot, |D_S| = 100), plus a
ProtoNets full-gradient variant.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit
from repro.core.diagnostics import gradient_experiment
from repro.core.meta_learners import MetaLearnerConfig, make_learner
from repro.core.set_encoder import SetEncoderConfig
from repro.data.episodic import EpisodicImageConfig, sample_image_task
from repro.models.conv_backbone import ConvBackboneConfig, make_conv_backbone

H_VALUES = (10, 30, 50, 70, 90)
N_DRAWS = 10


def run() -> list:
    bb = make_conv_backbone(ConvBackboneConfig(widths=(8, 16), feature_dim=32))
    set_cfg = SetEncoderConfig(kind="conv", conv_blocks=2, conv_width=8,
                               task_dim=16)
    task = sample_image_task(jax.random.key(11), EpisodicImageConfig(
        way=10, shot=10, query_per_class=4, image_size=16))
    rows = []
    for kind, pf in (
        ("simple_cnaps", lambda p: p["enc"]["blocks"][0]["w"]),
        ("protonets", None),
    ):
        lr = make_learner(MetaLearnerConfig(kind=kind, way=10,
                                            film_init_std=0.1), bb, set_cfg)
        params = lr.init(jax.random.key(1))
        res = gradient_experiment(lr.meta_loss, params, task,
                                  h_values=H_VALUES, n_draws=N_DRAWS,
                                  key=jax.random.key(7),
                                  subsampled_estimator=True, param_filter=pf)
        for h in H_VALUES:
            rows.append(dict(
                model=kind, h=h,
                lite_rmse=f"{res['lite'][h]['rmse']:.4e}",
                lite_bias_mse=f"{res['lite'][h]['bias_mse']:.4e}",
                sub_rmse=f"{res['subsampled'][h]['rmse']:.4e}",
                sub_bias_mse=f"{res['subsampled'][h]['bias_mse']:.4e}",
            ))
    return rows


def main() -> None:
    emit(run(), "fig4_rmse")


if __name__ == "__main__":
    main()
