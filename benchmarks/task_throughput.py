"""Task-batched engine throughput: tasks/sec, batched vs per-task loop.

The paper's Algorithm 1 takes one optimizer step per task; the batched
engine (repro.core.episodic_train.make_batched_meta_train_step) vmaps the
meta-loss over a TaskBatch and takes one step per T tasks.  This reports
tasks/sec for the Python loop baseline and for the batched step at several
``tasks_per_step``, on whatever backend is available (CPU included).

    PYTHONPATH=src python benchmarks/task_throughput.py
"""
from __future__ import annotations

import argparse
import sys
import time

import jax

sys.path.insert(0, __file__.rsplit("/", 1)[0])
from common import emit  # noqa: E402

from repro.core.episodic_train import (make_batched_meta_train_step,
                                       make_meta_train_step, task_key)
from repro.core.lite import LiteSpec
from repro.core.meta_learners import MetaLearnerConfig, make_learner
from repro.core.set_encoder import SetEncoderConfig
from repro.data.episodic import EpisodicImageConfig, sample_image_task_batch
from repro.models.conv_backbone import ConvBackboneConfig, make_conv_backbone
from repro.optim import AdamWConfig, adamw_init


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks-per-step", type=int, nargs="+",
                    default=[1, 2, 4, 8])
    # Default workload: small tasks, where Algorithm 1's one-step-per-task
    # regime is dominated by per-task dispatch + optimizer overhead — the
    # cost the batched engine amortizes.  Scale the flags up to study the
    # compute-bound regime instead (on 2 CPU cores the batched advantage
    # shrinks toward 1x there; on parallel hardware it grows).
    ap.add_argument("--way", type=int, default=3)
    ap.add_argument("--image-size", type=int, default=6)
    ap.add_argument("--shot", type=int, default=2)
    ap.add_argument("--query", type=int, default=1)
    ap.add_argument("--h", type=int, default=2)
    ap.add_argument("--iters", type=int, default=9)
    args = ap.parse_args()

    backbone = make_conv_backbone(ConvBackboneConfig(widths=(4,),
                                                     feature_dim=8))
    learner = make_learner(
        MetaLearnerConfig(kind="protonets", way=args.way), backbone,
        SetEncoderConfig(kind="conv", conv_blocks=1, conv_width=4,
                         task_dim=8))
    params = learner.init(jax.random.key(0))
    spec = LiteSpec(h=args.h)
    adamw = AdamWConfig(weight_decay=0.0)
    opt = adamw_init(params, adamw)
    tcfg = EpisodicImageConfig(way=args.way, shot=args.shot,
                               query_per_class=args.query,
                               image_size=args.image_size)
    key = jax.random.key(7)

    def time_median(fn, iters: int) -> float:
        """median-of-N wall seconds (N runs after one warmup/compile)."""
        fn()
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return sorted(ts)[len(ts) // 2]

    # -- baseline: paper Algorithm 1, one jitted step per task, Python loop
    loop_step = jax.jit(make_meta_train_step(learner, spec, adamw=adamw))
    batch8 = sample_image_task_batch(jax.random.key(1), tcfg, 8)
    loop_tasks = [batch8.task(i) for i in range(8)]

    def run_loop():
        p, o = params, opt
        for i, t in enumerate(loop_tasks):
            p, o, m = loop_step(p, o, t, task_key(key, i))
        jax.block_until_ready(m["loss"])

    t_loop = time_median(run_loop, args.iters)
    loop_rate = len(loop_tasks) / t_loop
    rows = [dict(mode="loop", tasks_per_step=1,
                 step_us=round(1e6 * t_loop / len(loop_tasks)),
                 tasks_per_sec=round(loop_rate, 1), speedup=1.0)]

    # -- batched engine at several tasks_per_step
    step = jax.jit(make_batched_meta_train_step(learner, spec, adamw=adamw))
    for t in args.tasks_per_step:
        batch = sample_image_task_batch(jax.random.key(1), tcfg, t)

        def run_batched(b=batch):
            jax.block_until_ready(step(params, opt, b, key)[2]["loss"])

        t_b = time_median(run_batched, args.iters)
        rate = t / t_b
        rows.append(dict(mode="batched", tasks_per_step=t,
                         step_us=round(1e6 * t_b),
                         tasks_per_sec=round(rate, 1),
                         speedup=round(rate / loop_rate, 2)))

    emit(rows, "task_throughput")
    best = max(r["speedup"] for r in rows if r["mode"] == "batched")
    print(f"# batched best speedup over per-task loop: {best:.2f}x")


if __name__ == "__main__":
    main()
