"""Task-batched engine throughput: tasks/sec, batched vs per-task loop,
and the overlapped pipeline (prefetch + donation) vs the synchronous loop.

The paper's Algorithm 1 takes one optimizer step per task; the batched
engine (repro.core.episodic_train.make_batched_meta_train_step) vmaps the
meta-loss over a TaskBatch and takes one step per T tasks.  This reports
tasks/sec for the Python loop baseline and for the batched step at several
``tasks_per_step``, on whatever backend is available (CPU included).

The ``engine_*`` rows measure the FULL training engine at a paper-style
large-support workload — data generation + step + commit through
``repro.train.loop.train`` — the PR1 engine (synchronous loop, on-device
sampler serialized with the step) vs the PR2 overlapped engine
(host-side collation in a background ``Prefetcher``, donated state,
span syncs).

    PYTHONPATH=src python benchmarks/task_throughput.py
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, __file__.rsplit("/", 1)[0])
from common import emit, time_median  # noqa: E402

from repro.core.episodic_train import (make_batched_meta_train_step,
                                       make_meta_train_step, task_key)
from repro.core.lite import LiteSpec
from repro.core.meta_learners import MetaLearnerConfig, make_learner
from repro.core.set_encoder import SetEncoderConfig
from repro.data.episodic import (EpisodicImageConfig, HostEpisodicConfig,
                                 host_task_batch_at, sample_image_task_batch,
                                 task_batch_at)
from repro.models.conv_backbone import ConvBackboneConfig, make_conv_backbone
from repro.optim import AdamWConfig, adamw_init
from repro.train.loop import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks-per-step", type=int, nargs="+",
                    default=[1, 2, 4, 8])
    # Default workload: small tasks, where Algorithm 1's one-step-per-task
    # regime is dominated by per-task dispatch + optimizer overhead — the
    # cost the batched engine amortizes.  Scale the flags up to study the
    # compute-bound regime instead (on 2 CPU cores the batched advantage
    # shrinks toward 1x there; on parallel hardware it grows).
    ap.add_argument("--way", type=int, default=3)
    ap.add_argument("--image-size", type=int, default=6)
    ap.add_argument("--shot", type=int, default=2)
    ap.add_argument("--query", type=int, default=1)
    ap.add_argument("--h", type=int, default=2)
    ap.add_argument("--iters", type=int, default=9)
    ap.add_argument("--engine-tasks", type=int, default=8,
                    help="tasks_per_step for the engine_* pipeline rows")
    ap.add_argument("--engine-steps", type=int, default=40,
                    help="steps per engine_* measurement")
    ap.add_argument("--engine-image-size", type=int, default=16,
                    help="image size for the engine_* rows")
    ap.add_argument("--engine-way", type=int, default=5)
    ap.add_argument("--engine-shot", type=int, default=16,
                    help="support shots for the engine_* rows (large-N "
                         "regime: data generation heavy enough to be "
                         "worth overlapping)")
    ap.add_argument("--engine-prefetch", type=int, default=6)
    ap.add_argument("--engine-h", type=int, default=8,
                    help="LiteSpec.h for the engine_* rows (independent "
                         "of --h, which sizes the loop/batched rows)")
    args = ap.parse_args()

    backbone = make_conv_backbone(ConvBackboneConfig(widths=(4,),
                                                     feature_dim=8))
    learner = make_learner(
        MetaLearnerConfig(kind="protonets", way=args.way), backbone,
        SetEncoderConfig(kind="conv", conv_blocks=1, conv_width=4,
                         task_dim=8))
    params = learner.init(jax.random.key(0))
    spec = LiteSpec(h=args.h)
    adamw = AdamWConfig(weight_decay=0.0)
    opt = adamw_init(params, adamw)
    tcfg = EpisodicImageConfig(way=args.way, shot=args.shot,
                               query_per_class=args.query,
                               image_size=args.image_size)
    key = jax.random.key(7)

    # -- baseline: paper Algorithm 1, one jitted step per task, Python loop
    loop_step = jax.jit(make_meta_train_step(learner, spec, adamw=adamw))
    batch8 = sample_image_task_batch(jax.random.key(1), tcfg, 8)
    loop_tasks = [batch8.task(i) for i in range(8)]

    def run_loop():
        p, o = params, opt
        for i, t in enumerate(loop_tasks):
            p, o, m = loop_step(p, o, t, task_key(key, i))
        jax.block_until_ready(m["loss"])

    t_loop = time_median(run_loop, args.iters)
    loop_rate = len(loop_tasks) / t_loop
    rows = [dict(mode="loop", tasks_per_step=1,
                 step_us=round(1e6 * t_loop / len(loop_tasks)),
                 tasks_per_sec=round(loop_rate, 1), speedup=1.0)]

    # -- batched engine at several tasks_per_step
    step = jax.jit(make_batched_meta_train_step(learner, spec, adamw=adamw))
    for t in args.tasks_per_step:
        batch = sample_image_task_batch(jax.random.key(1), tcfg, t)

        def run_batched(b=batch):
            jax.block_until_ready(step(params, opt, b, key)[2]["loss"])

        t_b = time_median(run_batched, args.iters)
        rate = t / t_b
        rows.append(dict(mode="batched", tasks_per_step=t,
                         step_us=round(1e6 * t_b),
                         tasks_per_sec=round(rate, 1),
                         speedup=round(rate / loop_rate, 2)))

    # -- full engine at a paper-style large-support workload: the PR1
    # engine as it actually ran (train() synchronous loop, batch built by
    # the on-device jitted sampler each step, hard sync + metric
    # conversion every step) vs the PR2 overlapped engine (host-side
    # collation in a background Prefetcher, donated state, hard sync only
    # at span boundaries).  The speedup column for engine_* rows is vs
    # engine_sync.  NOTE: on a 2-core CPU container the win is bounded by
    # core conservation (the step's vmapped XLA program already keeps
    # both cores busy, so hiding the data path frees at most the
    # generation share of total core-work); expect ~1.1-1.2x here and
    # substantially more wherever the host has spare input-pipeline
    # cores relative to the accelerator.
    te = args.engine_tasks
    ecfg = dict(way=args.engine_way, shot=args.engine_shot,
                query_per_class=args.query,
                image_size=args.engine_image_size)
    dcfg = EpisodicImageConfig(**ecfg)
    hcfg = HostEpisodicConfig(augment=False, **ecfg)
    espec = LiteSpec(h=args.engine_h, chunk_size=8)
    data_key, step_key = jax.random.key(31), jax.random.key(37)

    def device_batch_at(s):
        return dict(tasks=task_batch_at(data_key, dcfg, te, s),
                    key=jax.random.fold_in(step_key, s))

    def host_batch_at(s):
        return dict(tasks=host_task_batch_at(31, hcfg, te, s),
                    key=jax.random.fold_in(step_key, s))

    elearner = make_learner(
        MetaLearnerConfig(kind="protonets", way=args.engine_way), backbone,
        SetEncoderConfig(kind="conv", conv_blocks=1, conv_width=4,
                         task_dim=8))
    eparams = elearner.init(jax.random.key(0))
    inner = make_batched_meta_train_step(elearner, espec, adamw=adamw)

    def train_step(state, batch):
        p, o, m = inner(state["params"], state["opt"], batch["tasks"],
                        batch["key"])
        return dict(params=p, opt=o), m

    def fresh_state():
        return dict(params=jax.tree.map(jnp.copy, eparams),
                    opt=adamw_init(eparams, adamw))

    n = args.engine_steps

    def median3(fn):
        return sorted(fn() for _ in range(3))[1]

    sync_rate = median3(lambda: train(
        fresh_state(), train_step, device_batch_at, n).throughput(te))
    # same host stream WITHOUT prefetch/donation — isolates the overlap
    # win from the device-sampler -> host-sampler source change
    host_sync_rate = median3(lambda: train(
        fresh_state(), train_step, host_batch_at, n).throughput(te))
    over_rate = median3(lambda: train(
        fresh_state(), train_step, host_batch_at, n,
        prefetch=args.engine_prefetch, donate=True).throughput(te))
    rows.append(dict(mode="engine_sync", tasks_per_step=te,
                     step_us=round(1e6 * te / sync_rate),
                     tasks_per_sec=round(sync_rate, 1), speedup=1.0))
    rows.append(dict(mode="engine_host_sync", tasks_per_step=te,
                     step_us=round(1e6 * te / host_sync_rate),
                     tasks_per_sec=round(host_sync_rate, 1),
                     speedup=round(host_sync_rate / sync_rate, 2)))
    rows.append(dict(mode="engine_prefetch_donate", tasks_per_step=te,
                     step_us=round(1e6 * te / over_rate),
                     tasks_per_sec=round(over_rate, 1),
                     speedup=round(over_rate / sync_rate, 2)))

    emit(rows, "task_throughput")
    best = max(r["speedup"] for r in rows if r["mode"] == "batched")
    print(f"# batched best speedup over per-task loop: {best:.2f}x")
    print(f"# overlapped engine speedup over PR1 sync engine at T={te}: "
          f"{over_rate / sync_rate:.2f}x "
          f"(overlap alone, same host stream: "
          f"{over_rate / host_sync_rate:.2f}x)")


if __name__ == "__main__":
    main()
