"""Shared benchmark plumbing: CSV emission + tiny timing helpers."""
from __future__ import annotations

import pathlib
import time
from typing import Callable, Iterable

import jax

RESULTS_DIR = pathlib.Path(__file__).resolve().parent / "results"


def emit(rows: Iterable[dict], name: str) -> None:
    rows = list(rows)
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    if not rows:
        return
    cols = list(rows[0].keys())
    lines = [",".join(cols)]
    for r in rows:
        lines.append(",".join(str(r[c]) for c in cols))
    out = RESULTS_DIR / f"{name}.csv"
    out.write_text("\n".join(lines) + "\n")
    print(f"# wrote {out}")
    print("\n".join(lines))


def time_median(fn: Callable, iters: int) -> float:
    """Median wall seconds over ``iters`` runs after one warmup/compile
    call.  The caller's ``fn`` must block on its own results."""
    fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time in microseconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return 1e6 * times[len(times) // 2]
