"""Benchmark orchestrator: one module per paper table/figure + kernel and
roofline reports.  ``PYTHONPATH=src python -m benchmarks.run``"""
from __future__ import annotations

import sys
import time
import traceback

MODULES = (
    "benchmarks.fig4_rmse",            # paper Fig. 4 / Tables D.7-D.8
    "benchmarks.table2_vary_h",        # paper Table 2 / D.4-D.6
    "benchmarks.table1_adaptation_cost",  # paper Table 1 adaptation cost
    "benchmarks.memory_vs_h",          # paper §D.4 memory-vs-|H| claim
    "benchmarks.serve_throughput",     # episodic serving engine throughput
    "benchmarks.kernel_bench",         # Pallas kernels vs jnp reference
    "benchmarks.dp_scaling",           # two-level DP engine wire bytes + rate
    "benchmarks.roofline_report",      # dry-run roofline table (§Roofline)
)


def main() -> None:
    failures = []
    for mod_name in MODULES:
        print(f"\n=== {mod_name} ===", flush=True)
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["main"])
            mod.main()
            print(f"# {mod_name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures.append(mod_name)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED benchmarks: {failures}")
        sys.exit(1)
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
