"""Roofline table (deliverable g): reads the dry-run JSON and emits the
per-cell three-term analysis as CSV + markdown."""
from __future__ import annotations

import pathlib

from benchmarks.common import RESULTS_DIR, emit
from repro.roofline.analysis import format_markdown, load_table

def _dryrun_path():
    for name in ("dryrun_opt.json", "dryrun.json"):
        p = RESULTS_DIR / name
        if p.exists():
            return p
    return RESULTS_DIR / "dryrun.json"


DRYRUN = _dryrun_path()


def run() -> list:
    rows = load_table(DRYRUN, mesh="single")
    out = []
    for r in rows:
        if "skipped" in r:
            out.append(dict(arch=r["arch"], shape=r["shape"],
                            t_compute_ms="", t_memory_ms="", t_coll_ms="",
                            bottleneck="skipped", useful="", frac=""))
            continue
        out.append(dict(
            arch=r["arch"], shape=r["shape"],
            t_compute_ms=f"{1e3*r['t_compute']:.3f}",
            t_memory_ms=f"{1e3*r['t_memory']:.3f}",
            t_coll_ms=f"{1e3*r['t_collective']:.3f}",
            bottleneck=r["bottleneck"],
            useful=f"{r['useful_ratio']:.3f}",
            frac=f"{r['roofline_fraction']:.4f}",
        ))
    md = format_markdown(rows)
    (RESULTS_DIR / "roofline.md").write_text(md + "\n")
    return out


def main() -> None:
    if not DRYRUN.exists():
        print("no dryrun.json — run `python -m repro.launch.dryrun` first")
        return
    emit(run(), "roofline")


if __name__ == "__main__":
    main()
