"""Roofline table (deliverable g): reads the dry-run JSON and emits the
per-cell three-term analysis as CSV + markdown.

Also emits ``serve_layouts.csv``: the serving-layout chooser's
per-(layout x batch-regime) wire/flops/bytes table for the episodic
predict step — every candidate in ``SERVING_LAYOUTS`` compiled on a
4-device emulated mesh and scored on its actual post-SPMD HLO, plus the
chooser's pick per regime.  Emulation needs
``XLA_FLAGS=--xla_force_host_platform_device_count`` set BEFORE jax
initializes, so that section re-execs itself in a fresh subprocess (same
pattern as ``benchmarks/dp_scaling.py``)."""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys

from benchmarks.common import RESULTS_DIR, emit
from repro.roofline.analysis import format_markdown, load_table

LAYOUT_DEVICES = 4

def _dryrun_path():
    for name in ("dryrun_opt.json", "dryrun.json"):
        p = RESULTS_DIR / name
        if p.exists():
            return p
    return RESULTS_DIR / "dryrun.json"


DRYRUN = _dryrun_path()


def run() -> list:
    rows = load_table(DRYRUN, mesh="single")
    out = []
    for r in rows:
        if "skipped" in r:
            out.append(dict(arch=r["arch"], shape=r["shape"],
                            t_compute_ms="", t_memory_ms="", t_coll_ms="",
                            bottleneck="skipped", useful="", frac=""))
            continue
        out.append(dict(
            arch=r["arch"], shape=r["shape"],
            t_compute_ms=f"{1e3*r['t_compute']:.3f}",
            t_memory_ms=f"{1e3*r['t_memory']:.3f}",
            t_coll_ms=f"{1e3*r['t_collective']:.3f}",
            bottleneck=r["bottleneck"],
            useful=f"{r['useful_ratio']:.3f}",
            frac=f"{r['roofline_fraction']:.4f}",
        ))
    md = format_markdown(rows)
    (RESULTS_DIR / "roofline.md").write_text(md + "\n")
    return out


def _serve_layouts_worker() -> None:
    """Runs inside the 4-fake-device subprocess: score every serving
    layout for the episodic predict step at two serving batch regimes."""
    import jax
    import jax.numpy as jnp

    from repro.core.episodic_train import task_key
    from repro.core.lite import LiteSpec
    from repro.core.meta_learners import MetaLearnerConfig, make_learner
    from repro.core.set_encoder import SetEncoderConfig
    from repro.data.episodic import (EpisodicImageConfig, collate_task_batch,
                                     sample_image_task)
    from repro.models.conv_backbone import (ConvBackboneConfig,
                                            make_conv_backbone)
    from repro.roofline.analysis import (SERVING_LAYOUTS,
                                         choose_serving_layout)
    from repro.serve.quant_params import dequantize_params, quantize_frozen

    bb = make_conv_backbone(ConvBackboneConfig(widths=(16, 32),
                                               feature_dim=64))
    lr = make_learner(
        MetaLearnerConfig(kind="protonets", way=5), bb,
        SetEncoderConfig(kind="conv", conv_blocks=2, conv_width=16,
                         task_dim=32))
    params = lr.init(jax.random.key(0))
    sw = quantize_frozen(lr, params, "int8")
    mesh = jax.make_mesh((LAYOUT_DEVICES,), ("serve",))
    lite = LiteSpec(exact=True, chunk_size=32)

    def predict_fn(w, st, qx):
        return lr.predict_batch(dequantize_params(w), st, qx)

    rows = []
    for regime, n_tasks in (("serve_small", 2), ("serve_large", 8)):
        cfg = EpisodicImageConfig(way=5, shot=4, query_per_class=4,
                                  image_size=12)
        tasks = [sample_image_task(jax.random.key(100 + i), cfg)
                 for i in range(n_tasks)]
        batch = collate_task_batch(tasks, support_size=32,
                                   query_size=tasks[0].query_x.shape[0])
        keys = jax.vmap(lambda i: task_key(jax.random.key(0), i))(
            jnp.arange(n_tasks))
        states = lr.adapt_batch(dequantize_params(sw), batch, keys, lite)
        pick = choose_serving_layout(predict_fn, sw,
                                     (states, batch.query_x), mesh)
        for lo in SERVING_LAYOUTS:
            r = pick["rows"][lo]
            rows.append(dict(
                regime=regime, tasks=n_tasks, layout=lo,
                wire_bytes=round(r["wire_bytes"]),
                collectives=round(r["collective_count"]),
                dot_flops=round(r["dot_flops"]),
                bytes_accessed=round(r["bytes_accessed"]),
                t_compute_us=f"{1e6 * r['t_compute']:.3f}",
                t_memory_us=f"{1e6 * r['t_memory']:.3f}",
                t_coll_us=f"{1e6 * r['t_collective']:.3f}",
                bottleneck=r["bottleneck"],
                chosen=int(lo == pick["choice"])))
        ws = pick["rows"]["weight_stationary"]["wire_bytes"]
        tr = pick["rows"]["training"]["wire_bytes"]
        print(f"# {regime}: chooser picked {pick['choice']}; "
              f"weight_stationary wire {ws:.0f} B vs training {tr:.0f} B "
              f"({tr / max(ws, 1):.1f}x less)")
    emit(rows, "serve_layouts")


def serve_layouts() -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count"
                        f"={LAYOUT_DEVICES}").strip()
    env["SERVE_LAYOUTS_WORKER"] = "1"
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in [str(pathlib.Path(__file__).resolve().parents[1] / "src"),
                     str(pathlib.Path(__file__).resolve().parents[1]),
                     env.get("PYTHONPATH", "")] if p])
    r = subprocess.run([sys.executable, __file__], env=env)
    if r.returncode:
        raise RuntimeError(f"serve_layouts worker failed ({r.returncode})")


def main() -> None:
    if os.environ.get("SERVE_LAYOUTS_WORKER"):
        _serve_layouts_worker()
        return
    if DRYRUN.exists():
        emit(run(), "roofline")
    else:
        print("no dryrun.json — run `python -m repro.launch.dryrun` first "
              "(skipping the dry-run roofline table)")
    serve_layouts()


if __name__ == "__main__":
    main()
