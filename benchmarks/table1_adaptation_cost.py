"""Paper Table 1 (test-time adaptation cost): MACs (via AOT cost
analysis), number of steps, and wall-clock per task for each learner
family — the paper's headline contrast between 1-forward meta-learners
and K-step fine-tuners.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.core.meta_learners import MetaLearnerConfig, make_learner
from repro.core.set_encoder import SetEncoderConfig
from repro.data.episodic import EpisodicImageConfig, sample_image_task
from repro.models.conv_backbone import ConvBackboneConfig, make_conv_backbone
from repro.roofline.hlo import xla_cost_analysis

LEARNERS = (
    ("protonets", "1F"),
    ("cnaps", "1F"),
    ("simple_cnaps", "1F"),
    ("fomaml", "15FB"),
    ("finetuner", "50FB"),
)


def run() -> list:
    bb = make_conv_backbone(ConvBackboneConfig(widths=(16, 32), feature_dim=64))
    set_cfg = SetEncoderConfig(kind="conv", conv_blocks=2, conv_width=16,
                               task_dim=32)
    task = sample_image_task(jax.random.key(0), EpisodicImageConfig(
        way=5, shot=10, query_per_class=4, image_size=32))
    rows = []
    for kind, steps in LEARNERS:
        inner = int(steps.rstrip("FB").rstrip("F") or 1)
        cfg = MetaLearnerConfig(kind=kind, way=5, inner_steps=inner)
        lr = make_learner(cfg, bb, set_cfg)
        params = lr.init(jax.random.key(1))

        adapt = jax.jit(lambda p, sx, sy: lr.adapt(p, sx, sy))
        lowered = adapt.lower(params, task.support_x, task.support_y)
        cost = xla_cost_analysis(lowered.compile())
        macs = float(cost.get("flops", 0.0)) / 2.0
        wall_us = time_call(adapt, params, task.support_x, task.support_y)
        rows.append(dict(model=kind, adapt_macs=f"{macs:.3e}",
                         steps=steps, wall_us=f"{wall_us:.0f}"))
    return rows


def main() -> None:
    emit(run(), "table1_adaptation_cost")


if __name__ == "__main__":
    main()
