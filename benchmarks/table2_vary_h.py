"""Paper Table 2 / D.4-D.6: classification accuracy vs |H| — LITE accuracy
is flat in |H| (unbiased estimator), while the naive small-task baseline
degrades at small |H|.  Synthetic episodic benchmark at CPU scale.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.lite import LiteSpec
from repro.core.meta_learners import MetaLearnerConfig, make_learner
from repro.core.set_encoder import SetEncoderConfig
from repro.data.episodic import EpisodicImageConfig, sample_image_task
from repro.models.conv_backbone import ConvBackboneConfig, make_conv_backbone
from repro.optim import clip_by_global_norm

H_VALUES = (5, 10, 25, 50)
TRAIN_STEPS = 60
EVAL_TASKS = 15


def _train_and_eval(kind: str, h: int, estimator, seed: int = 0) -> float:
    bb = make_conv_backbone(ConvBackboneConfig(widths=(8, 16), feature_dim=32))
    set_cfg = SetEncoderConfig(kind="conv", conv_blocks=2, conv_width=8,
                               task_dim=16)
    tcfg = EpisodicImageConfig(way=5, shot=10, query_per_class=4, image_size=16)
    lr = make_learner(MetaLearnerConfig(kind=kind, way=5), bb, set_cfg)
    params = lr.init(jax.random.key(seed))
    spec = LiteSpec(h=h)

    @jax.jit
    def step(p, t, k):
        _, g = jax.value_and_grad(
            lambda pp: lr.meta_loss(pp, t, k, spec, estimator=estimator)[0])(p)
        g, _ = clip_by_global_norm(g, 10.0)
        return jax.tree.map(lambda a, b: a - 1e-3 * b, p, g)

    k = jax.random.key(seed + 1)
    for i in range(TRAIN_STEPS):
        k, kt, kh = jax.random.split(k, 3)
        params = step(params, sample_image_task(kt, tcfg), kh)

    accs = []
    for i in range(EVAL_TASKS):
        t = sample_image_task(jax.random.fold_in(jax.random.key(99), i), tcfg)
        st = lr.adapt(params, t.support_x, t.support_y)
        pred = jnp.argmax(lr.predict(params, st, t.query_x), -1)
        accs.append(float(jnp.mean((pred == t.query_y).astype(jnp.float32))))
    return float(np.mean(accs))


def run() -> list:
    rows = []
    for kind in ("protonets",):
        for h in H_VALUES:
            acc_lite = _train_and_eval(kind, h, None)
            acc_sub = _train_and_eval(kind, h, "subsampled")
            rows.append(dict(model=kind, h=h,
                             lite_acc=f"{acc_lite:.3f}",
                             subsampled_acc=f"{acc_sub:.3f}"))
    return rows


def main() -> None:
    emit(run(), "table2_vary_h")


if __name__ == "__main__":
    main()
