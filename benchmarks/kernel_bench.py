"""Kernel micro-benchmarks: Pallas (interpret on CPU / Mosaic on TPU) vs
the jnp reference path, plus FLOP counts so TPU runs can report achieved
intensity.  On this CPU container most numbers check plumbing, not perf —
EXCEPT the episodic class-statistics rows: ``naive_us`` vs ``ref_us``
there is a real CPU-XLA comparison of the materializing outer-product
composite against the fused reassociated contraction
(repro.kernels.dispatch), the measured win behind the dispatch refactor.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.kernels import dispatch, ops, ref


def _episodic_rows(key) -> list:
    """Episodic-shape class-statistics + Mahalanobis-head rows: the naive
    (B, F, F)-materializing composite vs the fused ref contraction vs the
    Pallas kernel (interpret here; Mosaic on TPU)."""
    rows = []
    c = 10
    for n in (256, 1000):
        for f in (64, 256):
            x = jax.random.normal(key, (n, f), jnp.float32)
            y = jax.random.randint(jax.random.fold_in(key, 7), (n,), 0, c)
            oh = jax.nn.one_hot(y, c, dtype=jnp.float32)

            def stats(backend):
                @jax.jit
                def fn(x, oh):
                    return dict(
                        feat=dispatch.segment_sum(x, oh, backend=backend),
                        outer=dispatch.class_second_moment(
                            x, oh, backend=backend))
                return fn

            rows.append(dict(
                kernel="class_stats", shape=f"{n}x{f}x{c}",
                flops=2 * n * c * f * f,
                naive_us=f"{time_call(stats('naive'), x, oh):.0f}",
                ref_us=f"{time_call(stats('ref'), x, oh):.0f}",
                pallas_us=f"{time_call(stats('pallas'), x, oh):.0f}"))

    b, f = 512, 64
    q = jax.random.normal(key, (b, f))
    mu = jax.random.normal(jax.random.fold_in(key, 8), (c, f))
    a = jax.random.normal(jax.random.fold_in(key, 9), (c, f, f))
    sigma = jnp.einsum("cij,ckj->cik", a, a) + 1.0 * jnp.eye(f)
    chol = jax.vmap(jnp.linalg.cholesky)(sigma)

    def head(backend):
        return jax.jit(lambda q, mu, chol: dispatch.mahalanobis_head(
            q, mu, chol, backend=backend))

    # naive == ref for this op (the cho_solve composite has no
    # intermediate to fuse away), so there is no separate naive column
    rows.append(dict(
        kernel="mahalanobis_head", shape=f"{b}x{f}x{c}",
        flops=2 * b * c * f * f, naive_us="",
        ref_us=f"{time_call(head('ref'), q, mu, chol):.0f}",
        pallas_us=f"{time_call(head('pallas'), q, mu, chol):.0f}"))
    return rows


def run() -> list:
    key = jax.random.key(0)
    rows = []

    s, d = 512, 64
    q = jax.random.normal(key, (4, s, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (4, s, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (4, s, d), jnp.float32)
    ref_fa = jax.jit(lambda a, b, c: ref.attention_ref(a, b, c, causal=True))
    rows.append(dict(kernel="flash_attention", shape=f"4x{s}x{d}",
                     flops=4 * 2 * 2 * s * s * d, naive_us="",
                     ref_us=f"{time_call(ref_fa, q, k, v):.0f}",
                     pallas_us=f"{time_call(lambda a, b, c: ops.flash_attention(a, b, c), q, k, v):.0f}"))

    b, f, c = 512, 64, 10
    qq = jax.random.normal(key, (b, f))
    mu = jax.random.normal(jax.random.fold_in(key, 3), (c, f))
    a = jax.random.normal(jax.random.fold_in(key, 4), (c, f, f))
    sinv = jnp.einsum("cij,ckj->cik", a, a) + 0.1 * jnp.eye(f)
    rows.append(dict(kernel="mahalanobis", shape=f"{b}x{f}x{c}",
                     flops=2 * b * c * f * f, naive_us="",
                     ref_us=f"{time_call(jax.jit(ref.mahalanobis_ref), qq, mu, sinv):.0f}",
                     pallas_us=f"{time_call(ops.mahalanobis, qq, mu, sinv):.0f}"))

    x = jax.random.normal(key, (1024, 128))
    y = jax.random.randint(jax.random.fold_in(key, 5), (1024,), 0, 16)
    ref_sp = jax.jit(lambda a, b: ref.segment_pool_ref(a, b, 16))
    rows.append(dict(kernel="segment_pool", shape="1024x128x16",
                     flops=2 * 1024 * 128 * 16, naive_us="",
                     ref_us=f"{time_call(ref_sp, x, y):.0f}",
                     pallas_us=f"{time_call(lambda a, b: ops.segment_pool(a, b, 16), x, y):.0f}"))

    xx = jax.random.normal(key, (8, 128, 256), jnp.float32)
    ww = jax.random.normal(jax.random.fold_in(key, 6), (8, 256, 128), jnp.float32)
    rows.append(dict(kernel="gmm", shape="8x128x256x128",
                     flops=2 * 8 * 128 * 256 * 128, naive_us="",
                     ref_us=f"{time_call(jax.jit(ref.gmm_ref), xx, ww):.0f}",
                     pallas_us=f"{time_call(ops.gmm, xx, ww):.0f}"))

    rows.extend(_episodic_rows(key))
    return rows


def main() -> None:
    emit(run(), "kernel_bench")


if __name__ == "__main__":
    main()
