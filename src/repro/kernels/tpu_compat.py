"""Version tolerance for the Pallas TPU compiler-params dataclass.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; depending
on the installed jax exactly one of the two names exists.  Kernels import
``CompilerParams`` from here so they lower on either side of the rename.
"""
from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
