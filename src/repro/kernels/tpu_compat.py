"""Version tolerance for the Pallas TPU compiler-params dataclass, plus
the one shared backend-detection policy.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; depending
on the installed jax exactly one of the two names exists.  Kernels import
``CompilerParams`` from here so they lower on either side of the rename.
"""
from __future__ import annotations

import jax
import jax.experimental.pallas.tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def interpret_mode() -> bool:
    """True when Pallas kernels should run in interpret mode (any non-TPU
    backend).  The single policy shared by ops.py's wrappers and
    dispatch.py's backend resolution — keep them from drifting."""
    return jax.default_backend() != "tpu"
