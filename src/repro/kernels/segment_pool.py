"""Pallas TPU kernel for class-prototype / deep-set segment pooling:

    sums[c, f] = sum_b 1(y_b == c) x[b, f]

On TPU a scatter is serialized; the one-hot MATMUL form keeps it on the
MXU ((C, B_t) x (B_t, F_t) per tile, accumulated over the B grid axis).
This is the aggregation LITE subsamples (ProtoNets prototypes, CNAPs
class pooling, set-encoder sums).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.tpu_compat import CompilerParams


def _kernel(onehot_ref, x_ref, o_ref, *, block_b: int, n_rows: int):
    bi = pl.program_id(1)

    @pl.when(bi == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    oh = onehot_ref[...].astype(jnp.float32)          # (bb, C)
    x = x_ref[...].astype(jnp.float32)                # (bb, Ft)
    # zero OOB padding rows (may be NaN) — 0*NaN would poison the dot
    valid = (bi * block_b +
             jax.lax.broadcasted_iota(jnp.int32, (oh.shape[0], 1), 0)) < n_rows
    oh = jnp.where(valid, oh, 0.0)
    x = jnp.where(valid, x, 0.0)
    o_ref[...] += jax.lax.dot_general(
        oh, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)


def segment_pool(x: jnp.ndarray, labels: jnp.ndarray, num_classes: int, *,
                 block_b: int = 128, block_f: int = 256,
                 interpret: bool = False):
    """x: (B, F); labels: (B,) int32 -> (sums (C, F) f32, counts (C,) f32)."""
    import functools
    b, f = x.shape
    block_b = min(block_b, b)
    block_f = min(block_f, f)
    onehot = jax.nn.one_hot(labels, num_classes, dtype=jnp.float32)
    sums = pl.pallas_call(
        functools.partial(_kernel, block_b=block_b, n_rows=b),
        grid=(pl.cdiv(f, block_f), pl.cdiv(b, block_b)),
        in_specs=[
            pl.BlockSpec((block_b, num_classes), lambda fi, bi: (bi, 0)),
            pl.BlockSpec((block_b, block_f), lambda fi, bi: (bi, fi)),
        ],
        out_specs=pl.BlockSpec((num_classes, block_f), lambda fi, bi: (0, fi)),
        out_shape=jax.ShapeDtypeStruct((num_classes, f), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(onehot, x)
    return sums, jnp.sum(onehot, axis=0)
