"""Pallas TPU kernels for the episodic class-statistics family.

``segment_pool_weighted`` — class-prototype / deep-set segment pooling:

    sums[c, f] = sum_b w[b, c] x[b, f]

On TPU a scatter is serialized; the one-hot MATMUL form keeps it on the
MXU ((C, B_t) x (B_t, F_t) per tile, accumulated over the B grid axis).
``w`` is a *weighted* one-hot — collator masks and padded ``TaskBatch``
lanes fold into it as zero rows, so padding drops out natively.  This is
the aggregation LITE subsamples (ProtoNets prototypes, CNAPs class
pooling, set-encoder sums); ``segment_pool`` keeps the original
labels-based entry point on top of it.

``class_second_moment`` — the Simple CNAPs covariance statistic:

    out[c, i, j] = sum_b w[b, c] x[b, i] x[b, j]

computed per (class, F_i-tile, F_j-tile) grid cell as one MXU matmul
((F_i, B_t) x (B_t, F_j), the class weight folded into the left operand)
accumulated over the B grid axis — the per-example (B, F, F)
outer-product tensor is never formed, which is the whole point
(repro.kernels.dispatch routes the episodic hot path here).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.tpu_compat import CompilerParams


def _pool_kernel(w_ref, x_ref, o_ref, *, block_b: int, n_rows: int):
    bi = pl.program_id(1)

    @pl.when(bi == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[...].astype(jnp.float32)                # (bb, C)
    x = x_ref[...].astype(jnp.float32)                # (bb, Ft)
    # zero OOB padding rows (may be NaN) — 0*NaN would poison the dot
    valid = (bi * block_b +
             jax.lax.broadcasted_iota(jnp.int32, (w.shape[0], 1), 0)) < n_rows
    w = jnp.where(valid, w, 0.0)
    x = jnp.where(valid, x, 0.0)
    o_ref[...] += jax.lax.dot_general(
        w, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)


def segment_pool_weighted(x: jnp.ndarray, weights: jnp.ndarray, *,
                          block_b: int = 128, block_f: int = 256,
                          interpret: bool = False) -> jnp.ndarray:
    """x: (B, F); weights: (B, C) float (mask-folded one-hot) ->
    sums (C, F) float32."""
    b, f = x.shape
    c = weights.shape[1]
    block_b = min(block_b, b)
    block_f = min(block_f, f)
    return pl.pallas_call(
        functools.partial(_pool_kernel, block_b=block_b, n_rows=b),
        grid=(pl.cdiv(f, block_f), pl.cdiv(b, block_b)),
        in_specs=[
            pl.BlockSpec((block_b, c), lambda fi, bi: (bi, 0)),
            pl.BlockSpec((block_b, block_f), lambda fi, bi: (bi, fi)),
        ],
        out_specs=pl.BlockSpec((c, block_f), lambda fi, bi: (0, fi)),
        out_shape=jax.ShapeDtypeStruct((c, f), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(weights, x)


def segment_pool(x: jnp.ndarray, labels: jnp.ndarray, num_classes: int, *,
                 block_b: int = 128, block_f: int = 256,
                 interpret: bool = False):
    """x: (B, F); labels: (B,) int32 -> (sums (C, F) f32, counts (C,) f32)."""
    onehot = jax.nn.one_hot(labels, num_classes, dtype=jnp.float32)
    sums = segment_pool_weighted(x, onehot, block_b=block_b, block_f=block_f,
                                 interpret=interpret)
    return sums, jnp.sum(onehot, axis=0)


def _second_moment_kernel(w_ref, xi_ref, xj_ref, o_ref, *, block_b: int,
                          n_rows: int):
    bi = pl.program_id(3)

    @pl.when(bi == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[...].astype(jnp.float32)                # (bb, 1) — class ci
    xi = xi_ref[...].astype(jnp.float32)              # (bb, Ft_i)
    xj = xj_ref[...].astype(jnp.float32)              # (bb, Ft_j)
    valid = (bi * block_b +
             jax.lax.broadcasted_iota(jnp.int32, (w.shape[0], 1), 0)) < n_rows
    w = jnp.where(valid, w, 0.0)
    xi = jnp.where(valid, xi, 0.0)
    xj = jnp.where(valid, xj, 0.0)
    # (Ft_i, bb) x (bb, Ft_j) with the class weight folded into the left
    # operand: sum_b w[b] xi[b, i] xj[b, j]
    o_ref[0] += jax.lax.dot_general(
        xi * w, xj, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def class_second_moment(x: jnp.ndarray, weights: jnp.ndarray, *,
                        block_b: int = 128, block_f: int = 128,
                        interpret: bool = False) -> jnp.ndarray:
    """x: (B, F); weights: (B, C) float (mask-folded one-hot) ->
    out (C, F, F) float32 with out[c] = sum_b w[b, c] x[b] x[b]^T."""
    b, f = x.shape
    c = weights.shape[1]
    block_b = min(block_b, b)
    block_f = min(block_f, f)
    return pl.pallas_call(
        functools.partial(_second_moment_kernel, block_b=block_b, n_rows=b),
        grid=(c, pl.cdiv(f, block_f), pl.cdiv(f, block_f),
              pl.cdiv(b, block_b)),
        in_specs=[
            pl.BlockSpec((block_b, 1), lambda ci, fi, fj, bi: (bi, ci)),
            pl.BlockSpec((block_b, block_f), lambda ci, fi, fj, bi: (bi, fi)),
            pl.BlockSpec((block_b, block_f), lambda ci, fi, fj, bi: (bi, fj)),
        ],
        out_specs=pl.BlockSpec((1, block_f, block_f),
                               lambda ci, fi, fj, bi: (ci, fi, fj)),
        out_shape=jax.ShapeDtypeStruct((c, f, f), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(weights, x, x)
