"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships three layers:
  <name>.py  pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py     jit'd public wrappers (interpret=True off-TPU)
  ref.py     pure-jnp oracles (the allclose ground truth in tests)

Kernels: flash_attention (causal/window/softcap online-softmax),
mahalanobis (Simple CNAPs head), segment_pool (LITE's aggregation site as
a one-hot MXU matmul), ssd_scan (Mamba-2 intra-chunk), gmm (per-expert
grouped GEMM for the MoE dispatch).
"""
