"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships four layers:
  <name>.py    pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py       jit'd public wrappers (interpret=True off-TPU)
  ref.py       pure-jnp oracles (the allclose ground truth in tests)
  dispatch.py  backend policy (naive | ref | pallas | auto) + custom_vjp
               wrappers for the episodic hot path's aggregation sites —
               the layer train/serve code actually calls

Kernels: flash_attention (causal/window/softcap online-softmax),
mahalanobis (Simple CNAPs head), segment_pool / class_second_moment
(LITE's aggregation sites as one-hot MXU matmuls — weight-aware, so
padded TaskBatch lanes drop out natively), ssd_scan (Mamba-2
intra-chunk), gmm (per-expert grouped GEMM for the MoE dispatch),
int8_matmul (blocked int8 x f32 matmul with per-block scale applied
in-kernel and fp32 accumulation — the weight-stationary serving path's
native site for blockwise-quantized frozen weights; FORWARD-ONLY by
contract, no custom_vjp: serving never differentiates through it).
"""
