"""Pallas TPU kernel: blocked int8-weight x float-activation matmul for
the weight-stationary serving path.

    out[m, n] = sum_k x[m, k] * q[k, n] * scale[k, n // BLOCK]

``q``/``scale`` are the blockwise int8 form of ``repro.optim.quant``
(absmax per 128-wide block of the trailing dim), so the weight matrix is
never materialized in f32: each grid step loads an int8 (bk, 128) weight
tile plus its (bk, 1) scale column, applies the scale in-register, and
feeds the MXU with fp32 accumulation over the K grid axis.  The N tile is
pinned to ``BLOCK`` so one grid cell always covers exactly one scale
block — the per-block scale application the quantization scheme implies,
with no cross-block gather.

Serving-only contract: forward pass, no custom_vjp — the serve hot path
runs under stop_gradient (see ``repro.kernels.dispatch.int8_matmul``).
Padding is handled at the wrapper: M/K/N are zero-padded up to tile
multiples (zero int8 columns and zero activation rows contribute exactly
nothing), and the output is sliced back to (M, N).  Min int8 tile on TPU
is (32, 128); the padded K tile of 128 satisfies it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tpu_compat import CompilerParams
from repro.optim.quant import BLOCK


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def _int8_matmul_kernel(x_ref, q_ref, s_ref, o_ref):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)                    # (bm, bk)
    # per-block scale application: (bk, BLOCK) int8 tile * (bk, 1) scales
    w = q_ref[...].astype(jnp.float32) * s_ref[...]
    o_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)


def int8_matmul(x: jnp.ndarray, q: jnp.ndarray, scale: jnp.ndarray, *,
                block_m: int = 128, block_k: int = 128,
                interpret: bool = False) -> jnp.ndarray:
    """x: (M, K) float; q: (K, N) int8; scale: (K, ceil(N/BLOCK)) f32
    -> (M, N) float32 == x @ (q * scale-per-block)."""
    m, k = x.shape
    kq, n = q.shape
    assert kq == k, f"contraction mismatch: x K={k} vs q K={kq}"
    bm = min(block_m, _round_up(m, 8))
    bk = min(block_k, _round_up(k, BLOCK))
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, BLOCK)
    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    qp = jnp.pad(q, ((0, kp - k), (0, np_ - n)))
    sp = jnp.pad(scale, ((0, kp - k), (0, np_ // BLOCK - scale.shape[-1])))
    out = pl.pallas_call(
        _int8_matmul_kernel,
        grid=(mp // bm, np_ // BLOCK, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, BLOCK), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((bk, 1), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm, BLOCK), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xp, qp, sp)
    return out[:m, :n]
