"""Kernel dispatch for the episodic hot path: one policy, three backends.

Every support-set aggregation the meta-learners run — per-class feature
sums, the Simple CNAPs raw second moment E[x x^T], and the Mahalanobis
head — goes through the ops in this module instead of open-coded jnp.
Each op selects an implementation per *backend*:

  ``naive``   the literal pre-dispatch composite (per-example expansion,
              then a plain axis-0 reduce).  For the second moment this
              materializes the per-example ``(B, F, F)`` outer-product
              tensor — the memory bottleneck this subsystem exists to
              kill — so it survives only as the bit-exact legacy oracle
              the parity tests and benchmarks compare against.
  ``ref``     jnp, reassociated so XLA contracts over the example axis
              without the ``(B, F, F)`` intermediate (the second moment
              becomes ``"bc,bi,bj->cij"`` via a ``(B, C, F)`` hop —
              C = way << F).  This is the default, and the fast path on
              CPU/GPU.  For the first-order ops (plain segment sums, the
              cho_solve Mahalanobis head) there is no intermediate to
              kill, so ``ref`` keeps the ``naive`` formula and stays
              bit-exact with the pre-dispatch code; only the second
              moment is reassociated (same values to ~1e-5 fp32 — dot
              and reduce accumulate in different orders, so last-ulp
              bits legitimately differ; see the parity tests).
  ``pallas``  the Pallas kernels (repro.kernels.segment_pool one-hot MXU
              matmuls, repro.kernels.mahalanobis quadratic form), run in
              interpret mode off-TPU and lowered to Mosaic on TPU.  Each
              forward is wrapped in ``jax.custom_vjp`` with ref-math
              backwards, so the kernels are differentiable inside the
              LITE H-pass (the no-grad complement never calls the VJP).
  ``auto``    resolves to ``pallas`` on TPU, ``ref`` elsewhere.

Backend selection is *trace-time*: each op takes ``backend=None`` which
resolves against the module default (``set_default_backend`` /
``use_backend``).  Config plumbing: ``MetaTrainConfig.kernel_backend``
(bound by the episodic train-step adapter), the serving engine's
``kernel_backend`` argument (bound at engine construction), and
``--kernel-backend`` on both launchers.  Because the backend binds when
a function is lowered, a per-shape compile cache
(:class:`repro.train.pipeline.BucketedStepCache`) keyed on shapes alone
never recompiles when the ambient backend flips — switching backends on
a warm cache is a no-op by design (flat compile counters), and an engine
that wants a different backend is a new engine.

Weights everywhere are *mask-folded one-hots*: ``(B, C)`` float arrays
whose rows are zero for padded/invalid examples.  Zero-weight rows
contribute exactly nothing, which is what makes padded ``TaskBatch``
lanes work natively through every backend.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import int8_matmul as _im
from repro.kernels import mahalanobis as _md
from repro.kernels import segment_pool as _sp
from repro.kernels.tpu_compat import interpret_mode as _interpret
from repro.optim import quant as _quant

BACKENDS = ("naive", "ref", "pallas", "auto")

# ContextVar, not a module global: engines/steps built with different
# backends may trace concurrently from different threads (the serving
# engine and the prefetching train loop live in one process) — each
# thread/context resolves its own binding, so one engine's use_backend
# scope can never leak into another's lowering.
_default_backend: contextvars.ContextVar = contextvars.ContextVar(
    "repro_kernel_backend", default="ref")


def _check(backend: str) -> str:
    if backend not in BACKENDS:
        raise ValueError(f"unknown kernel backend {backend!r}; "
                         f"choose from {BACKENDS}")
    return backend


def set_default_backend(backend: str) -> None:
    """Set the current context's default backend (resolved at trace
    time).  Scoped per thread/context — prefer :func:`use_backend` for
    anything bounded."""
    _default_backend.set(_check(backend))


def get_default_backend() -> str:
    return _default_backend.get()


@contextlib.contextmanager
def use_backend(backend: Optional[str]):
    """Scoped default backend (None = leave the current default)."""
    token = None
    if backend is not None:
        token = _default_backend.set(_check(backend))
    try:
        yield
    finally:
        if token is not None:
            _default_backend.reset(token)


def resolve_backend(backend: Optional[str] = None) -> str:
    """None -> context default; ``auto`` -> pallas on TPU else ref."""
    b = _check(_default_backend.get() if backend is None else backend)
    if b == "auto":
        return "ref" if _interpret() else "pallas"
    return b


# ===========================================================================
# segment_sum: per-class weighted sums  S[c, ...] = sum_b w[b, c] e[b, ...]
# ===========================================================================


def _segment_sum_expand(e: jnp.ndarray, weights: jnp.ndarray,
                        accum_dtype) -> jnp.ndarray:
    """The pre-dispatch composite, bit-for-bit: expand to (B, C, ...) and
    reduce axis 0.  Weights are 0/1 (mask-folded one-hots), so any
    association of the elementwise products is exact — this formula is
    shared by ``naive`` and ``ref`` (no big intermediate to kill: the hop
    is (B, C, ...) with C = way)."""
    expanded = jnp.einsum("b...,bc->bc...", e, weights.astype(e.dtype))
    return jnp.sum(expanded, axis=0, dtype=accum_dtype)


@jax.custom_vjp
def _segment_sum_pallas(x: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """x: (B, K); weights: (B, C) -> (C, K) float32 via the one-hot-matmul
    segment_pool kernel (interpret off-TPU)."""
    return _sp.segment_pool_weighted(x, weights, interpret=_interpret())


def _segment_sum_pallas_fwd(x, weights):
    return _segment_sum_pallas(x, weights), (x, weights)


def _segment_sum_pallas_bwd(res, g):
    x, weights = res
    g = g.astype(jnp.float32)
    dx = jnp.einsum("bc,ck->bk", weights.astype(jnp.float32), g)
    dw = jnp.einsum("bk,ck->bc", x.astype(jnp.float32), g)
    return dx.astype(x.dtype), dw.astype(weights.dtype)


_segment_sum_pallas.defvjp(_segment_sum_pallas_fwd, _segment_sum_pallas_bwd)


def segment_sum(e: jnp.ndarray, weights: jnp.ndarray,
                accum_dtype=None, backend: Optional[str] = None
                ) -> jnp.ndarray:
    """Per-class weighted sum: ``out[c, ...] = sum_b weights[b, c] *
    e[b, ...]``.

    ``weights`` is a mask-folded one-hot (zero rows = padded lanes drop
    out natively).  ``accum_dtype`` upcasts the reduction (the fp32
    accumulator of the mixed-precision LITE complement).  ``naive`` and
    ``ref`` share the expand+reduce formula (bit-exact with the
    pre-dispatch code); ``pallas`` runs the MXU one-hot matmul under a
    ``custom_vjp`` with ref-math backward.
    """
    b = resolve_backend(backend)
    if b in ("naive", "ref"):
        return _segment_sum_expand(e, weights, accum_dtype)
    lead = e.shape[0]
    flat = e.reshape(lead, -1)
    out = _segment_sum_pallas(flat, weights)
    out = out.astype(accum_dtype or e.dtype)
    return out.reshape((weights.shape[1],) + e.shape[1:])


# ===========================================================================
# class_second_moment: S[c, i, j] = sum_b w[b, c] f[b, i] f[b, j]
# ===========================================================================


def _second_moment_naive(f, weights, accum_dtype):
    """Pre-dispatch composite: per-example outer products (B, F, F),
    expanded to (B, C, F, F), reduced over b.  The memory bottleneck —
    kept verbatim as the bit-exact oracle."""
    outer = jnp.einsum("bi,bj->bij", f, f)
    return _segment_sum_expand(outer, weights, accum_dtype)


def _second_moment_ref(f, weights, accum_dtype):
    """Reassociated ``"bc,bi,bj->cij"``: hop through (B, C, F) — C = way,
    so the intermediate is C/F the size of one (B, F, F) outer tensor —
    then contract the example axis on the MXU/GEMM.  Same math as naive;
    dot-vs-reduce accumulation orders differ, so bits may differ at the
    last ulp (fp32 ~1e-5 at N=1000)."""
    t = jnp.einsum("bc,bi->bci", weights.astype(f.dtype), f)
    return jnp.einsum("bci,bj->cij", t, f,
                      preferred_element_type=accum_dtype or f.dtype
                      ).astype(accum_dtype or f.dtype)


@jax.custom_vjp
def _second_moment_pallas(f: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    return _sp.class_second_moment(f, weights, interpret=_interpret())


def _second_moment_pallas_fwd(f, weights):
    return _second_moment_pallas(f, weights), (f, weights)


def _second_moment_pallas_bwd(res, g):
    f, weights = res
    f32, w32, g32 = (t.astype(jnp.float32) for t in (f, weights, g))
    gs = g32 + jnp.swapaxes(g32, -1, -2)
    # df[b, i] = sum_{c,j} w[b, c] (g[c, i, j] + g[c, j, i]) f[b, j]
    df = jnp.einsum("bc,cij,bj->bi", w32, gs, f32)
    # dw[b, c] = sum_{i,j} g[c, i, j] f[b, i] f[b, j]
    dw = jnp.einsum("bi,cij,bj->bc", f32, g32, f32)
    return df.astype(f.dtype), dw.astype(weights.dtype)


_second_moment_pallas.defvjp(_second_moment_pallas_fwd,
                             _second_moment_pallas_bwd)


def class_second_moment(f: jnp.ndarray, weights: jnp.ndarray,
                        accum_dtype=None, backend: Optional[str] = None
                        ) -> jnp.ndarray:
    """Per-class raw second moment ``out[c, i, j] = sum_b weights[b, c] *
    f[b, i] * f[b, j]`` — the Simple CNAPs covariance statistic — WITHOUT
    materializing the per-example ``(B, F, F)`` outer-product tensor
    (except on the ``naive`` oracle backend).

    f: (B, F); weights: (B, C) mask-folded one-hot -> (C, F, F).
    """
    b = resolve_backend(backend)
    if b == "naive":
        return _second_moment_naive(f, weights, accum_dtype)
    if b == "ref":
        return _second_moment_ref(f, weights, accum_dtype)
    out = _second_moment_pallas(f, weights)
    return out.astype(accum_dtype or f.dtype)


# ===========================================================================
# mahalanobis head: d2[b, c] = (q_b - mu_c)^T Sigma_c^{-1} (q_b - mu_c)
# ===========================================================================


def _mahalanobis_cho(qf, mu, chol):
    """Pre-dispatch composite (bit-exact): per-class triangular solves
    against the Cholesky factor."""
    diff = qf[:, None, :] - mu[None, :, :]                 # (B, C, F)
    sol = jax.vmap(
        lambda L, d: jax.scipy.linalg.cho_solve((L, True), d.T).T,
        in_axes=(0, 1), out_axes=1)(chol, diff)
    return jnp.sum(diff * sol, axis=-1)


@jax.custom_vjp
def _mahalanobis_pallas(q, mu, sinv):
    return _md.mahalanobis(q, mu, sinv, interpret=_interpret())


def _mahalanobis_pallas_fwd(q, mu, sinv):
    return _mahalanobis_pallas(q, mu, sinv), (q, mu, sinv)


def _mahalanobis_pallas_bwd(res, g):
    q, mu, sinv = res
    q32, mu32, s32, g32 = (t.astype(jnp.float32) for t in (q, mu, sinv, g))
    diff = q32[:, None, :] - mu32[None, :, :]              # (B, C, F)
    ssym = s32 + jnp.swapaxes(s32, -1, -2)
    t = jnp.einsum("cij,bcj->bci", ssym, diff)
    dq = jnp.einsum("bc,bci->bi", g32, t)
    dmu = -jnp.einsum("bc,bci->ci", g32, t)
    dsinv = jnp.einsum("bc,bci,bcj->cij", g32, diff, diff)
    return dq.astype(q.dtype), dmu.astype(mu.dtype), dsinv.astype(sinv.dtype)


_mahalanobis_pallas.defvjp(_mahalanobis_pallas_fwd, _mahalanobis_pallas_bwd)


def chol_inverse(chol: jnp.ndarray) -> jnp.ndarray:
    """Per-class covariance inverses from Cholesky factors:
    (C, F, F) lower -> (C, F, F) Sigma^{-1} via ``cho_solve(L, I)``.
    The pallas Mahalanobis head consumes this; adaptation computes it
    ONCE per task state (``state["sinv"]``) so serving's repeated query
    dispatches skip the O(C F^3) solves."""
    eye = jnp.eye(chol.shape[-1], dtype=chol.dtype)
    return jax.vmap(
        lambda L: jax.scipy.linalg.cho_solve((L, True), eye))(chol)


# ===========================================================================
# int8_matmul: out[m, n] = sum_k x[m, k] * q[k, n] * scale[k, n // BLOCK]
# ===========================================================================


def _int8_matmul_oracle(x2: jnp.ndarray, qs) -> jnp.ndarray:
    """Dequantize-then-dot: materialize the f32 weight and run a plain
    GEMM.  Shared by ``naive`` and ``ref`` (there is no cheaper
    association that avoids the f32 weight without a blocked kernel) —
    this is the bit-exact-within-reassociation oracle the pallas parity
    tests compare against."""
    w = _quant.dequantize(qs)
    return jnp.dot(x2.astype(jnp.float32), w,
                   preferred_element_type=jnp.float32)


def int8_matmul(x: jnp.ndarray, qs, backend: Optional[str] = None
                ) -> jnp.ndarray:
    """Weight-quantized matmul for the serving path: ``x @ W`` where W is
    stored in the blockwise int8 ``{q, scale, n}`` form of
    ``repro.optim.quant`` and is never materialized persistently in f32.

    x: (..., K) float; qs: quantized (K, N) weight -> (..., N) float32.

    FORWARD-ONLY contract — unlike the other sites there is no
    custom_vjp: serving runs under stop_gradient and quantized weights
    are frozen by definition, so a backward pass through this op is a
    bug, not a missing feature.  (``naive``/``ref`` remain differentiable
    as plain jnp by accident; ``pallas`` is not — do not rely on either.)

    ``naive``/``ref``: dequantize to f32, one GEMM (the oracle).
    ``pallas``: the blocked int8 kernel (``repro.kernels.int8_matmul``)
    — int8 tiles scaled in-register, fp32 accumulation, interpret mode
    off-TPU.  Leading batch dims are flattened around the 2-D kernel.
    """
    b = resolve_backend(backend)
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    if b in ("naive", "ref"):
        out = _int8_matmul_oracle(x2, qs)
    else:
        out = _im.int8_matmul(x2, qs["q"], qs["scale"],
                              interpret=_interpret())
    n = _quant.resolve_n(qs)
    return out.reshape(lead + (n,))


def mahalanobis_head(qf: jnp.ndarray, mu: jnp.ndarray, chol: jnp.ndarray,
                     backend: Optional[str] = None,
                     sinv: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Squared Mahalanobis distances of queries to class Gaussians given
    the Cholesky factors of the class covariances.

    qf: (B, F); mu: (C, F); chol: (C, F, F) lower -> (B, C).

    ``naive``/``ref``: per-class ``cho_solve`` (bit-exact with the
    pre-dispatch head; ``ref`` keeps the naive formula — there is no
    intermediate to kill, so there is no separate fused ref head).
    ``pallas``: the VMEM-resident quadratic-form kernel on the explicit
    per-class inverse, under a ``custom_vjp`` (gradients flow to
    ``chol`` through the inverse, and to q/mu/sinv through ref math).
    Pass ``sinv`` (:func:`chol_inverse`, precomputed at adaptation time
    and carried in the task state) to skip the per-call O(C F^3)
    inversion — serving's query dispatches hit this path; without it the
    inverse is recomputed here (the train path, one call per task).
    """
    b = resolve_backend(backend)
    if b in ("naive", "ref"):
        return _mahalanobis_cho(qf, mu, chol)
    if sinv is None:
        sinv = chol_inverse(chol)
    return _mahalanobis_pallas(qf, mu, sinv)
