"""Public jit'd wrappers for the Pallas kernels.

On non-TPU backends the kernels execute in interpret mode (Python
evaluation of the kernel body — bit-faithful semantics, no Mosaic); on
TPU the same code lowers to Mosaic.  Model code opts in via
``use_pallas_kernels`` config; the XLA/jnp path (ref semantics) is what
the SPMD dry-run lowers, so roofline FLOPs stay visible to the HLO
analyzer either way.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import gmm as _gmm
from repro.kernels import mahalanobis as _md
from repro.kernels import segment_pool as _sp
from repro.kernels import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None):
    """q, k, v: (BH, S, D)."""
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, interpret=_interpret())


def flash_attention_gqa(q, k, v, **kw):
    """q: (B, S, Hq, D); k/v: (B, S, Hkv, D) — GQA via group expansion."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    rep = hq // hkv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    o = flash_attention(fold(q), fold(k), fold(v), **kw)
    return o.reshape(b, hq, s, d).transpose(0, 2, 1, 3)


@jax.jit
def mahalanobis(q, mu, sinv):
    """q: (B, F); mu: (C, F); sinv: (C, F, F) -> (B, C)."""
    return _md.mahalanobis(q, mu, sinv, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("num_classes",))
def segment_pool(x, labels, num_classes: int):
    """x: (B, F); labels: (B,) -> (sums (C, F), counts (C,))."""
    return _sp.segment_pool(x, labels, num_classes, interpret=_interpret())


@jax.jit
def gmm(x, w):
    """Grouped per-expert matmul: (E, C, D) @ (E, D, F) -> (E, C, F)."""
    return _gmm.gmm(x, w, interpret=_interpret())


@jax.jit
def ssd_chunk(x, dt, A, B, C):
    """Intra-chunk SSD (see repro.kernels.ssd_scan)."""
    return _ssd.ssd_chunk(x, dt, A, B, C, interpret=_interpret())
