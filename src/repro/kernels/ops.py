"""Public jit'd wrappers for the Pallas kernels.

On non-TPU backends the kernels execute in interpret mode (Python
evaluation of the kernel body — bit-faithful semantics, no Mosaic); on
TPU the same code lowers to Mosaic.

How kernels reach model code — two layers:

* **The episodic hot path goes through ``repro.kernels.dispatch``**, not
  this module: the class-statistics reductions (per-class feature sums,
  Simple CNAPs second moments) and the Mahalanobis head are *dispatched*
  ops with a per-site backend policy (``naive`` legacy composite /
  ``ref`` fused jnp / ``pallas`` / ``auto``) selected via
  ``MetaTrainConfig.kernel_backend``, the serving engine's
  ``kernel_backend`` argument, or ``--kernel-backend`` on both
  launchers.  The Pallas forwards there are wrapped in ``custom_vjp``
  (ref-math backwards) so they are differentiable inside the LITE
  H-pass.  Wired sites: ProtoNets prototypes, CNAPs / Simple CNAPs class
  statistics and Mahalanobis head, through training
  (``make_batched_meta_train_step``), LITE-chunked serving
  (``repro.serve.episodic``), and the batched ``adapt_batch`` path.
  Status: ref is the default and fully validated; pallas is
  interpret-validated on CPU (parity + grad tests in
  tests/test_dispatch.py) with real-TPU Mosaic validation pending, same
  as the flash-attention sweeps.

* **This module** keeps the raw jit'd wrappers (LM-side kernels and
  direct use: flash_attention, gmm, ssd_chunk, plus the class-statistics
  kernels for benchmarks/tests).  The XLA/jnp path (ref semantics) is
  what the SPMD dry-run lowers, so roofline FLOPs stay visible to the
  HLO analyzer either way.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import gmm as _gmm
from repro.kernels import mahalanobis as _md
from repro.kernels import segment_pool as _sp
from repro.kernels import ssd_scan as _ssd
from repro.kernels.tpu_compat import interpret_mode as _interpret


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None):
    """q, k, v: (BH, S, D)."""
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, interpret=_interpret())


def flash_attention_gqa(q, k, v, **kw):
    """q: (B, S, Hq, D); k/v: (B, S, Hkv, D) — GQA via group expansion."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    rep = hq // hkv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    o = flash_attention(fold(q), fold(k), fold(v), **kw)
    return o.reshape(b, hq, s, d).transpose(0, 2, 1, 3)


@jax.jit
def mahalanobis(q, mu, sinv):
    """q: (B, F); mu: (C, F); sinv: (C, F, F) -> (B, C)."""
    return _md.mahalanobis(q, mu, sinv, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("num_classes",))
def segment_pool(x, labels, num_classes: int):
    """x: (B, F); labels: (B,) -> (sums (C, F), counts (C,))."""
    return _sp.segment_pool(x, labels, num_classes, interpret=_interpret())


@jax.jit
def segment_pool_weighted(x, weights):
    """x: (B, F); weights: (B, C) mask-folded one-hot -> sums (C, F).
    Padded/invalid rows are zero-weight rows — the TaskBatch-native form
    the dispatch layer uses."""
    return _sp.segment_pool_weighted(x, weights, interpret=_interpret())


@jax.jit
def class_second_moment(x, weights):
    """x: (B, F); weights: (B, C) -> (C, F, F) per-class raw second
    moments sum_b w[b,c] x_b x_b^T, computed without materializing the
    per-example (B, F, F) outer tensor."""
    return _sp.class_second_moment(x, weights, interpret=_interpret())


@jax.jit
def gmm(x, w):
    """Grouped per-expert matmul: (E, C, D) @ (E, D, F) -> (E, C, F)."""
    return _gmm.gmm(x, w, interpret=_interpret())


@jax.jit
def ssd_chunk(x, dt, A, B, C):
    """Intra-chunk SSD (see repro.kernels.ssd_scan)."""
    return _ssd.ssd_chunk(x, dt, A, B, C, interpret=_interpret())
