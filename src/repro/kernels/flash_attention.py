"""Pallas TPU flash attention (block-tiled online softmax).

TPU-native adaptation of the memory-efficient attention idea: q tiles are
VMEM-resident and MXU-aligned (block_q x head_dim, multiples of 128 at
production shapes); the k/v sequence streams through the LAST grid axis
('arbitrary' semantics -> sequential revisits of the same output block),
with the running max / denominator kept in VMEM scratch between visits.
Supports the pool's attention variants: causal, sliding-window (gemma2
local layers), and logit softcap.

Validated on CPU in interpret mode against ref.attention_ref; the TPU
path is identical code through pl.pallas_call.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.tpu_compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            block_q: int, block_k: int, causal: bool,
            window: Optional[int], softcap: Optional[float], scale: float,
            seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                    # (bq, d)
    k = k_ref[0].astype(jnp.float32)                    # (bk, d)
    v = v_ref[0].astype(jnp.float32)                    # (bk, d)

    # zero sequence-padding rows of k/v: OOB block padding may be NaN and
    # 0 * NaN inside the dots would poison valid rows
    kv_valid = (ki * block_k +
                jax.lax.broadcasted_iota(jnp.int32, (k.shape[0], 1), 0)) < seq_len
    k = jnp.where(kv_valid, k, 0.0)
    v = jnp.where(kv_valid, v, 0.0)
    q = jnp.where(jnp.isfinite(q), q, 0.0)              # q padding rows

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < seq_len
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                  # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # explicit zero under the mask: padded k/v blocks may contain NaN and
    # 0 * NaN would poison the accumulator
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = corr * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc = corr * acc_scr[...] + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    # written every visit (last one wins) — avoids relying on output-buffer
    # persistence semantics across revisits
    o_ref[0] = (acc / jnp.maximum(l_new, 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q, k, v: (BH, S, D) — batch*heads flattened (GQA groups expanded by
    the ops wrapper).  Returns (BH, S, D)."""
    bh, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    nq = pl.cdiv(s, block_q)
    nk = pl.cdiv(s, block_k)
    scale = d ** -0.5

    kern = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, causal=causal,
        window=window, softcap=softcap, scale=scale, seq_len=s)

    return pl.pallas_call(
        kern,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
