"""Pure-jnp oracles for every kernel in this package (the ground truth the
shape/dtype sweeps in tests/test_kernels.py assert against)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=None, softcap=None):
    """q,k,v: (BH, S, D)."""
    s = q.shape[1]
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (q.shape[-1] ** -0.5)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def mahalanobis_ref(q, mu, sinv):
    """q: (B, F); mu: (C, F); sinv: (C, F, F) -> d2 (B, C)."""
    diff = q[:, None, :].astype(jnp.float32) - mu[None].astype(jnp.float32)
    return jnp.einsum("bcf,cfg,bcg->bc", diff, sinv.astype(jnp.float32), diff)


def segment_pool_ref(x, labels, num_classes):
    """x: (B, F); labels: (B,) -> (sums (C, F), counts (C,))."""
    onehot = jax.nn.one_hot(labels, num_classes, dtype=jnp.float32)
    sums = jnp.einsum("bc,bf->cf", onehot, x.astype(jnp.float32))
    return sums, jnp.sum(onehot, axis=0)


def ssd_chunk_ref(x, dt, A, B, C):
    """Intra-chunk SSD terms for ONE chunk (the Pallas kernel's unit).

    x: (Q, H, P); dt: (Q, H); A: (H,); B, C: (Q, H, N)
    Returns (y_diag (Q, H, P), state (H, P, N), chunk_decay (H,),
             state_decay (Q, H)) — everything the inter-chunk jnp
    recurrence needs.
    """
    f32 = jnp.float32
    x, dt, A, B, C = (t.astype(f32) for t in (x, dt, A, B, C))
    dA = dt * A[None, :]                          # (Q, H)
    dA_cum = jnp.cumsum(dA, axis=0)
    q = x.shape[0]
    seg = dA_cum[:, None, :] - dA_cum[None, :, :]  # (Q, Q, H) l - s
    mask = jnp.arange(q)[:, None] >= jnp.arange(q)[None, :]
    L = jnp.where(mask[..., None], jnp.exp(seg), 0.0)
    CB = jnp.einsum("lhn,shn->lsh", C, B)
    y_diag = jnp.einsum("lsh,sh,shp->lhp", CB * L, dt, x)
    decay_states = jnp.exp(dA_cum[-1:, :] - dA_cum)          # (Q, H)
    state = jnp.einsum("qhn,qh,qhp->hpn", B, decay_states * dt, x)
    return y_diag, state, jnp.exp(dA_cum[-1]), jnp.exp(dA_cum)


def gmm_ref(x, w):
    """Grouped (per-expert) matmul: x (E, C, D), w (E, D, F) -> (E, C, F)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
