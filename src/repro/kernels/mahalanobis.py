"""Pallas TPU kernel for Simple CNAPs' Mahalanobis head (paper §3.1):

    d2[b, c] = (x_b - mu_c)^T Sinv_c (x_b - mu_c)

The per-class inverse covariance (F, F) tile and the query tile (block_b,
F) are VMEM-resident; the quadratic form runs as two MXU matmuls per
(class, query-block) grid cell.  F is the backbone feature width (64-512
across configs) so a full (F, F) tile fits VMEM comfortably.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(q_ref, mu_ref, sinv_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)            # (bb, F)
    mu = mu_ref[0].astype(jnp.float32)            # (F,)
    sinv = sinv_ref[0].astype(jnp.float32)        # (F, F)
    diff = q - mu[None, :]
    t = jax.lax.dot(diff, sinv, preferred_element_type=jnp.float32)
    o_ref[:, 0] = jnp.sum(t * diff, axis=1)


def mahalanobis(q: jnp.ndarray, mu: jnp.ndarray, sinv: jnp.ndarray, *,
                block_b: int = 128, interpret: bool = False) -> jnp.ndarray:
    """q: (B, F); mu: (C, F); sinv: (C, F, F) -> (B, C) squared distances."""
    b, f = q.shape
    c = mu.shape[0]
    block_b = min(block_b, b)
    nb = pl.cdiv(b, block_b)

    return pl.pallas_call(
        _kernel,
        grid=(c, nb),
        in_specs=[
            pl.BlockSpec((block_b, f), lambda ci, bi: (bi, 0)),
            pl.BlockSpec((1, f), lambda ci, bi: (ci, 0)),
            pl.BlockSpec((1, f, f), lambda ci, bi: (ci, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda ci, bi: (bi, ci)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=interpret,
    )(q, mu, sinv)
