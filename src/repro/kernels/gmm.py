"""Pallas TPU grouped matmul (per-expert GEMM) for the MoE dispatch path:

    out[e] = x[e] @ w[e]        x: (E, C, D), w: (E, D, F)

One expert's (block_c x block_d) x (block_d x block_f) tiles per grid
cell, accumulating over the D axis in VMEM scratch — the megablox-style
building block behind the dropless MoE layer (repro.models.moe runs the
jnp einsum on the dry-run path; this kernel is the TPU hot-spot form).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.tpu_compat import CompilerParams


def _kernel(x_ref, w_ref, o_ref, acc_ref):
    di = pl.program_id(3)
    nd = pl.num_programs(3)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                   # (bc, bd)
    w = w_ref[0]                                   # (bd, bf)
    acc_ref[...] += jax.lax.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(di == nd - 1)
    def _finish():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def gmm(x: jnp.ndarray, w: jnp.ndarray, *, block_c: int = 128,
        block_d: int = 512, block_f: int = 256,
        interpret: bool = False) -> jnp.ndarray:
    """x: (E, C, D) bf16/f32; w: (E, D, F) -> (E, C, F) in x.dtype."""
    e, c, d = x.shape
    f = w.shape[-1]
    block_c = min(block_c, c)
    block_d = min(block_d, d)
    block_f = min(block_f, f)

    return pl.pallas_call(
        _kernel,
        grid=(e, pl.cdiv(c, block_c), pl.cdiv(f, block_f), pl.cdiv(d, block_d)),
        in_specs=[
            pl.BlockSpec((1, block_c, block_d), lambda ei, ci, fi, di: (ei, ci, di)),
            pl.BlockSpec((1, block_d, block_f), lambda ei, ci, fi, di: (ei, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda ei, ci, fi, di: (ei, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w)
