"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk computation.

Per (batch*head, chunk) grid cell, with the chunk's (Q, P) inputs and
(Q, N) B/C projections VMEM-resident, computes the dense (MXU) part of
SSD:

    y_diag = (C B^T o L) diag(dt) X          (Q x Q semiseparable matmul)
    state  = B^T diag(decay * dt) X          (chunk's contribution)

The O(n_chunks) inter-chunk recurrence (tiny (P, N) states) stays in jnp
(``repro.models.mamba2.ssd_chunked``) — it is sequential and bandwidth-
trivial; the FLOPs live here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, cd_ref, sd_ref):
    f32 = jnp.float32
    x = x_ref[0].astype(f32)          # (Q, P)
    dt = dt_ref[0].astype(f32)        # (Q, 1) -> (Q,)
    a = a_ref[0, 0]                   # scalar A for this head
    b = b_ref[0].astype(f32)          # (Q, N)
    c = c_ref[0].astype(f32)          # (Q, N)
    q = x.shape[0]

    dtv = dt[:, 0]
    dA = dtv * a                      # (Q,)
    dA_cum = jnp.cumsum(dA)
    seg = dA_cum[:, None] - dA_cum[None, :]
    mask = (jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >=
            jax.lax.broadcasted_iota(jnp.int32, (q, q), 1))
    L = jnp.where(mask, jnp.exp(seg), 0.0)

    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=f32)      # (Q, Q)
    y = jax.lax.dot(cb * L * dtv[None, :], x,
                    preferred_element_type=f32)               # (Q, P)
    decay = jnp.exp(dA_cum[-1] - dA_cum)                       # (Q,)
    st = jax.lax.dot_general(b, x * (decay * dtv)[:, None],
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=f32)      # (N, P)

    y_ref[0] = y.astype(y_ref.dtype)
    st_ref[0] = jnp.transpose(st).astype(st_ref.dtype)         # (P, N)
    cd_ref[0, 0] = jnp.exp(dA_cum[-1])
    sd_ref[0] = jnp.exp(dA_cum)[:, None].astype(sd_ref.dtype)


def ssd_chunk(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
              B: jnp.ndarray, C: jnp.ndarray, *, interpret: bool = False):
    """Batched intra-chunk SSD.

    x: (G, Q, P); dt: (G, Q); A: (G,); B, C: (G, Q, N) where G = batch *
    heads * n_chunks flattened by the ops wrapper.
    Returns (y_diag (G, Q, P), states (G, P, N), chunk_decay (G,),
             state_decay (G, Q)).
    """
    g, q, p = x.shape
    n = B.shape[-1]
    dt2 = dt[..., None]                                       # (G, Q, 1)
    a2 = A[:, None]                                           # (G, 1)

    y, st, cd, sd = pl.pallas_call(
        _kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, q, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, q, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, n), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, p, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, q, 1), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, q, p), jnp.float32),
            jax.ShapeDtypeStruct((g, p, n), jnp.float32),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
            jax.ShapeDtypeStruct((g, q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt2, a2, B, C)
    return y, st, cd[:, 0], sd[..., 0]
