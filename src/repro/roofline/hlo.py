"""Loop-aware post-SPMD HLO analysis for the three-term roofline.

Why not ``compiled.cost_analysis()`` alone?  XLA's cost analysis visits
each instruction ONCE — a ``lax.scan`` over 80 layers reports 1/80th of the
real FLOPs (verified empirically in this repo).  And it reports no
collective traffic at all.  This module re-derives all three roofline
inputs from ``compiled.as_text()`` with while-loop trip-count weighting:

  * dot_flops        2 * result_elems * contraction_size per dot,
                     weighted by enclosing loop trip counts.
  * bytes_accessed   operand+result bytes of every top-level instruction
                     in non-fusion computations (fusion internals touch no
                     HBM; the fusion call site is what counts), weighted.
  * collectives      per-kind ring wire bytes per chip, weighted:
                         all-reduce        2(n-1)/n * result
                         all-gather        (n-1)/n  * result
                         reduce-scatter    (n-1)    * result
                         all-to-all        (n-1)/n  * result
                         collective-permute            result

Trip counts come from each while's condition computation (max scalar-int
compare constant — exact for lax.scan-lowered loops).  Shapes in
``compiled.as_text()`` are per-partition, so everything here is per-chip.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_COMP_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-_]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-_]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-_]+),\s*body=%?([\w\.\-_]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w\.\-_]+)")
_CONST_RE = re.compile(r"[su]\d+\[\]\s*constant\((\d+)\)")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_EXPL_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-_]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

# instructions that move no HBM data
_FREE_OPS = {"parameter", "tuple", "get-tuple-element", "bitcast", "constant",
             "after-all", "partition-id", "replica-id", "iota", "reshape",
             # control flow: carried state is aliased, not copied — counting
             # the full carry x trip-count would overcount by O(layers)
             "while", "conditional", "call"}

# ops that touch only the sliced/updated REGION of their big operand
# (XLA aliases the buffer): count 2x the touched bytes, not the operand.
#   dynamic-slice / gather: touched = result
#   dynamic-update-slice: touched = the update operand (index 1)
#   scatter: touched = the updates operand (index 2)
_REGION_OPS = {"dynamic-slice": None, "gather": None,
               "dynamic-update-slice": 1, "scatter": 2}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _shapes_in(text: str) -> List[Tuple[str, str]]:
    return _SHAPE_RE.findall(text)


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _EXPL_GROUPS_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def _wire_bytes(kind: str, result_bytes: float, n: int) -> float:
    if kind == "collective-permute":
        return float(result_bytes)
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n * result_bytes
    if kind == "all-gather":
        return (n - 1) / n * result_bytes
    if kind == "reduce-scatter":
        return float(n - 1) * result_bytes
    return (n - 1) / n * result_bytes      # all-to-all


class _Instr:
    __slots__ = ("name", "opcode", "result_shapes", "operands", "line")

    def __init__(self, name, opcode, result_shapes, operands, line):
        self.name = name
        self.opcode = opcode
        self.result_shapes = result_shapes      # [(dtype, dims_str), ...]
        self.operands = operands                # [%names]
        self.line = line


def _split_instr(rhs: str):
    """rhs = everything after '%name = '.  Returns (result_txt, opcode,
    operand_txt, attrs) or None.  Handles tuple results containing
    '/*index=N*/' comments by matching the tuple's closing paren."""
    rhs = rhs.lstrip()
    if rhs.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        result_txt, rest = rhs[: end + 1], rhs[end + 1:]
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        result_txt, rest = rhs[:sp], rhs[sp:]
    om = _OPCODE_RE.match(rest)
    if not om:
        return None
    opcode = om.group(1)
    call = rest[om.end():]
    depth = 1
    end = len(call)
    for i, ch in enumerate(call):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return result_txt, opcode, call[:end], call[end:]


def _parse(hlo_text: str):
    comps: Dict[str, List[_Instr]] = defaultdict(list)
    calls: Dict[str, set] = defaultdict(set)
    fusion_children: set = set()
    while_edges: List[Tuple[str, str]] = []     # (cond, body)
    trip_counts: Dict[str, int] = {}            # body -> known trip count
    max_const: Dict[str, int] = defaultdict(int)
    comp = "__toplevel__"
    for line in hlo_text.splitlines():
        h = _COMP_RE.match(line)
        if h and "{" in line and "=" not in line.split("(")[0]:
            comp = h.group(1)
            continue
        for m in _CONST_RE.finditer(line):
            max_const[comp] = max(max_const[comp], int(m.group(1)))
        nm = _NAME_RE.match(line)
        if not nm:
            continue
        parts = _split_instr(nm.group(2))
        if parts is None:
            continue
        result_txt, opcode, operand_txt, attrs = parts
        operands = _OPERAND_RE.findall(operand_txt)
        comps[comp].append(_Instr(nm.group(1), opcode, _shapes_in(result_txt),
                                  operands, line))
        if opcode == "while":
            w = _WHILE_RE.search(attrs)
            if w:
                while_edges.append((w.group(1), w.group(2)))
                t = _TRIP_RE.search(attrs)
                if t:
                    trip_counts[w.group(2)] = int(t.group(1))
        for cm in _CALLS_RE.finditer(attrs):
            calls[comp].add(cm.group(1))
            if opcode == "fusion" or "to_apply" in attrs:
                fusion_children.add(cm.group(1))
    return comps, calls, fusion_children, while_edges, max_const, trip_counts


def _trip_count(cond: str, calls, max_const) -> int:
    """Fallback when backend_config lacks known_trip_count: max scalar-int
    constant over the condition computation's transitive call closure."""
    seen, stack, best = set(), [cond], 1
    while stack:
        c = stack.pop()
        if c in seen:
            continue
        seen.add(c)
        best = max(best, max_const.get(c, 1))
        stack.extend(calls.get(c, ()))
    return best


def _multiplicities(comps, calls, while_edges, max_const,
                    trip_counts) -> Dict[str, float]:
    body_trip = {body: trip_counts.get(body) or
                 _trip_count(cond, calls, max_const)
                 for cond, body in while_edges}
    mult: Dict[str, float] = defaultdict(float)
    called = set()
    for cs in calls.values():
        called |= cs
    entries = [c for c in comps if c not in called] or ["__toplevel__"]
    for e in entries:
        mult[e] = 1.0
    for _ in range(64):                         # nesting depth bound
        changed = False
        for parent, children in calls.items():
            if mult[parent] <= 0:
                continue
            for ch in children:
                m = mult[parent] * body_trip.get(ch, 1)
                if m > mult[ch]:
                    mult[ch] = m
                    changed = True
        if not changed:
            break
    return mult


def analyze(hlo_text: str) -> Dict:
    """Full loop-aware analysis of optimized HLO text (see module doc)."""
    comps, calls, fusion_children, while_edges, max_const, trips = _parse(hlo_text)
    mult = _multiplicities(comps, calls, while_edges, max_const, trips)

    # symbol table: instruction name -> result shapes (for dot operands)
    symtab: Dict[str, List[Tuple[str, str]]] = {}
    for instrs in comps.values():
        for ins in instrs:
            symtab[ins.name] = ins.result_shapes

    dot_flops = 0.0
    bytes_accessed = 0.0
    coll: Dict[str, Dict[str, float]] = defaultdict(
        lambda: dict(result_bytes=0.0, wire_bytes=0.0, count=0.0, max_group=1))

    body_trips = {body: trips.get(body) or _trip_count(cond, calls, max_const)
                  for cond, body in while_edges}

    def _trip_adjusted(shapes, trip: int) -> int:
        """Scan-over-layers pattern: a tensor whose LEADING dim equals the
        enclosing while's trip count is per-iteration-sliced/updated
        (stacked weights, stacked KV caches) — one iteration touches
        1/trip of it, and XLA aliases the buffer in place."""
        total = 0
        for d, dims in shapes:
            b = _shape_bytes(d, dims)
            if trip > 1 and dims:
                lead = int(dims.split(",")[0])
                if lead == trip:
                    b //= trip
            total += b
        return total

    def operand_bytes(op_name: str, trip: int) -> int:
        return _trip_adjusted(symtab.get(op_name, ()), trip)

    for comp, instrs in comps.items():
        if comp in fusion_children:
            continue                       # fusion internals touch no HBM
        m = max(mult.get(comp, 1.0), 1.0)
        trip = body_trips.get(comp, 1)
        for ins in instrs:
            rbytes = _trip_adjusted(ins.result_shapes, trip)
            if ins.opcode in _REGION_OPS:
                opnd_idx = _REGION_OPS[ins.opcode]
                if opnd_idx is None:
                    touched = rbytes
                else:
                    touched = 0
                    if opnd_idx < len(ins.operands):
                        touched = operand_bytes(ins.operands[opnd_idx], trip)
                bytes_accessed += m * 2 * touched
            elif ins.opcode not in _FREE_OPS:
                obytes = sum(operand_bytes(op, trip) for op in ins.operands)
                bytes_accessed += m * (rbytes + obytes)
            if ins.opcode == "dot":
                cm = _CONTRACT_RE.search(ins.line)
                contract = 1
                if cm and ins.operands:
                    lhs_shapes = symtab.get(ins.operands[0], ())
                    if lhs_shapes:
                        dims = lhs_shapes[0][1].split(",") if lhs_shapes[0][1] else []
                        for idx in (cm.group(1).split(",") if cm.group(1) else []):
                            i = int(idx)
                            if i < len(dims):
                                contract *= int(dims[i])
                relems = 1
                if ins.result_shapes:
                    d0 = ins.result_shapes[0][1]
                    for d in (d0.split(",") if d0 else []):
                        relems *= int(d)
                dot_flops += m * 2.0 * relems * contract
            elif ins.opcode in ("convolution",):
                # rare in this codebase (vision smoke only); approximate via
                # result elems * operand-1 elems / spatial — skip, warn big
                pass
            op_base = ins.opcode.replace("-start", "")
            if op_base in COLLECTIVES and not ins.opcode.endswith("-done"):
                n = _group_size(ins.line)
                rec = coll[op_base]
                rec["result_bytes"] += m * rbytes
                rec["wire_bytes"] += m * _wire_bytes(op_base, rbytes, n)
                rec["count"] += m
                rec["max_group"] = max(rec["max_group"], n)

    return dict(dot_flops=dot_flops, bytes_accessed=bytes_accessed,
                collectives=dict(coll))


def collective_bytes(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Back-compat wrapper: just the collectives part of analyze()."""
    return analyze(hlo_text)["collectives"]


def xla_cost_analysis(compiled) -> Dict:
    """Drift-tolerant ``compiled.cost_analysis()``.

    Across jax versions ``cost_analysis()`` has returned a plain dict, a
    per-device LIST of dicts, or ``None`` — the raw call un-crashed three
    separate benchmarks before the callers learned to normalize it, each
    with its own copy of the fix.  This is the one shared shim: always a
    plain dict (device 0's entry on list-returning versions, ``{}`` when
    the analysis is absent).  Remember its numbers are loop-NAIVE (see
    module doc) — use :func:`analyze` for roofline inputs; this exists for
    cross-checks and the MACs-style accounting the benchmarks print.  The
    ``raw-cost-analysis`` lint rule rejects bare call sites outside this
    module."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})


def collectives_report(compiled_or_text) -> Dict:
    """Per-step collective wire bytes of a compiled executable.

    Accepts a ``jax`` compiled object (anything with ``as_text()`` — the
    result of ``jit(f).lower(...).compile()``) or raw optimized-HLO text,
    and returns::

        {"per_kind": {kind: {result_bytes, wire_bytes, count, max_group}},
         "total_wire_bytes": float,       # sum over kinds, per chip
         "count": float}                  # total collective launches

    Wire bytes are ring-corrected per chip (see module doc), so
    ``total_wire_bytes / link_bandwidth`` is the step's collective
    time bound.  This is the same walk the dry-run records and the
    shard_map-vs-GSPMD wire-bytes regression guard assert on; the
    two-level DP engine's tests and ``benchmarks/dp_scaling.py`` use it to
    account the cross-host gradient-reduction traffic per train step."""
    text = compiled_or_text if isinstance(compiled_or_text, str) \
        else compiled_or_text.as_text()
    per_kind = collective_bytes(text)
    return dict(
        per_kind=per_kind,
        total_wire_bytes=sum(r["wire_bytes"] for r in per_kind.values()),
        count=sum(r["count"] for r in per_kind.values()),
    )


def total_collective_seconds(per_kind: Dict[str, Dict[str, float]],
                             link_bw: float) -> float:
    """Wire bytes are already ring-corrected; just divide by link bandwidth."""
    return sum(rec["wire_bytes"] for rec in per_kind.values()) / link_bw
