"""TPU v5e hardware constants (the TARGET device; container runs CPU)."""

PEAK_FLOPS_BF16 = 197e12        # per chip, bf16
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW_PER_LINK = 50e9          # bytes/s per link (~)
HBM_BYTES = 16 * 1024**3        # 16 GiB per chip
CHIPS_PER_POD = 256

# effective wire-bytes multiplier per collective kind for ring algorithms
# on n participants: all-reduce moves 2(n-1)/n x data, all-gather /
# reduce-scatter (n-1)/n x, all-to-all (n-1)/n x, permute 1x.
# n is large (16..512) so (n-1)/n ~ 1.
COLLECTIVE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}
