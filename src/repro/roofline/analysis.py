"""Three-term roofline from dry-run records (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell:
    T_compute = dot_FLOPs_per_chip / peak_FLOPs
    T_memory  = bytes_per_chip / HBM_bw
    T_coll    = ring wire bytes_per_chip / ICI_link_bw
    bottleneck = argmax of the three
    MODEL_FLOPS = 6 N_active D   (train; 2 N_active D for inference pass)
    useful ratio = MODEL_FLOPS_per_chip / dot_FLOPs_per_chip
    roofline fraction = T_ideal / T_bound,  T_ideal = MODEL_FLOPS/(chips*peak)

The fraction answers "how close would a perfectly-overlapped execution of
this compiled program run to the hardware bound set by its own dominant
term" — the score §Perf iterates on.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, Optional

import jax

from repro.configs.base import SHAPES_BY_NAME, ModelConfig
from repro.configs.registry import get_config
from repro.launch.specs import abstract_params_for
from repro.roofline.constants import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16


def param_counts(cfg: ModelConfig) -> Dict[str, float]:
    """(total, active) parameter counts from the abstract param tree.
    Expert banks (3D+ leaves under 'ffn' with leading E) count at
    (top_k + n_shared)/E toward active."""
    params = abstract_params_for(cfg)
    total = 0.0
    active = 0.0
    embed = 0.0

    def visit(path, leaf):
        nonlocal total, active, embed
        p = "/".join(str(getattr(x, "key", getattr(x, "idx", x))) for x in path)
        n = 1.0
        for d in leaf.shape:
            n *= d
        total += n
        name = p.split("/")[-1]
        if name in ("embed", "lm_head"):
            embed += n
            return                      # embeddings excluded from 6ND flops
        if cfg.moe is not None and "ffn" in p and len(leaf.shape) >= 3 \
                and leaf.shape[-3] == cfg.moe.n_experts:
            active += n * cfg.moe.top_k / cfg.moe.n_experts
        else:
            active += n

    jax.tree_util.tree_map_with_path(visit, params)
    return dict(total=total, active=active, embed=embed)


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    """Global MODEL_FLOPS for one step of this cell."""
    s = SHAPES_BY_NAME[shape_name]
    counts = param_counts(cfg)
    n_active = counts["active"]
    if s.kind == "train":
        tokens = s.global_batch * s.seq_len
        return 6.0 * n_active * tokens
    if s.kind == "prefill":
        tokens = s.global_batch * s.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * s.global_batch


def analyze_cell(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    cfg = get_config(rec["arch"])
    chips = rec["chips"]
    t_compute = rec["flops_per_device"] / PEAK_FLOPS_BF16
    t_memory = rec["bytes_per_device"] / HBM_BW
    wire = sum(k["wire_bytes"] for k in rec["collectives"].values())
    t_coll = wire / ICI_BW_PER_LINK
    terms = dict(compute=t_compute, memory=t_memory, collective=t_coll)
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, rec["shape"])
    t_ideal = mf / (chips * PEAK_FLOPS_BF16)
    t_bound = max(terms.values())
    useful = mf / chips / max(rec["flops_per_device"], 1.0)
    return dict(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"], chips=chips,
        t_compute=t_compute, t_memory=t_memory, t_collective=t_coll,
        bottleneck=bottleneck,
        model_flops=mf, useful_ratio=useful,
        roofline_fraction=t_ideal / max(t_bound, 1e-30),
        state_bytes_per_device=rec.get("state_bytes_per_device", 0),
        hbm_headroom_gib=16.0 - rec.get("state_bytes_per_device", 0) / 2**30,
    )


def load_table(path: str | pathlib.Path, mesh: str = "single"):
    recs = json.loads(pathlib.Path(path).read_text())
    rows = []
    for key, rec in sorted(recs.items()):
        if rec.get("mesh") != mesh:
            continue
        if rec.get("status") == "skipped":
            rows.append(dict(arch=rec["arch"], shape=rec["shape"],
                             mesh=mesh, skipped=rec["reason"][:60]))
            continue
        out = analyze_cell(rec)
        if out:
            rows.append(out)
    return rows


def format_markdown(rows) -> str:
    hdr = ("| arch | shape | T_comp (ms) | T_mem (ms) | T_coll (ms) | "
           "bottleneck | useful | roofline frac | state GiB/chip |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {1e3*r['t_compute']:.2f} | "
            f"{1e3*r['t_memory']:.2f} | {1e3*r['t_collective']:.2f} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | "
            f"{r['state_bytes_per_device']/2**30:.2f} |")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Serving layout chooser (weight-stationary int8 serving path)
#
# Serving flips training's traffic balance: batches are small (a handful of
# episodes) while the frozen backbone is the big tensor, so the training
# layout — weights sharded on their LARGEST dim and all-gathered each step
# (ZeRO-style), batch sharded on the leading dim — pays full-weight wire
# every step for activation savings it no longer needs.  The serving
# candidates below are scored on the COMPILED program (collectives_report +
# loop-aware HLO walk, same machinery as the dry-run roofline), not on a
# paper model, so the chooser's pick reflects what XLA actually emits.
# ---------------------------------------------------------------------------

SERVING_LAYOUTS = ("training", "weight_stationary", "replicated")


def _largest_divisible_dim(shape, n: int) -> int:
    """Index of the largest dim divisible by n, or -1."""
    best, best_d = -1, 0
    for i, d in enumerate(shape):
        if d % n == 0 and d > best_d:
            best, best_d = i, d
    return best


def _weight_leaf_spec(leaf, layout: str, axis: str, n: int):
    """PartitionSpec for one serving-weight leaf under a named layout.

    training: every leaf sharded on its largest divisible dim (the
        ZeRO-ish weight-gathered placement the train step uses) — weights
        are all-gathered into each step.
    weight_stationary: 2-D matmul weights sharded on the CONTRACTING dim
        (dim 0), everything else replicated — each chip keeps its weight
        shard resident and the per-step wire carries only the (small at
        serving batch sizes) partial-sum reductions of activations.
    replicated: P() everywhere — the zero-wire single-chip counterfactual.
    """
    P = jax.sharding.PartitionSpec
    shape = getattr(leaf, "shape", None)
    if shape is None or len(shape) == 0 or n <= 1:
        return P()
    if layout == "replicated":
        return P()
    if layout == "weight_stationary":
        if len(shape) == 2 and shape[0] % n == 0:
            return P(axis, None)
        return P()
    if layout == "training":
        i = _largest_divisible_dim(shape, n)
        if i < 0:
            return P()
        spec = [None] * len(shape)
        spec[i] = axis
        return P(*spec)
    raise ValueError(f"unknown serving layout {layout!r}; "
                     f"choose from {SERVING_LAYOUTS}")


def _batch_leaf_spec(leaf, layout: str, axis: str, n: int):
    """Batch operands: training shards the leading dim (data parallel);
    the serving layouts keep the batch replicated (it is small — the whole
    point of weight-stationary placement)."""
    P = jax.sharding.PartitionSpec
    shape = getattr(leaf, "shape", None)
    if (layout == "training" and shape and len(shape) >= 1
            and n > 1 and shape[0] % n == 0):
        return P(axis)
    return P()


def serving_shardings(tree, mesh, layout: str):
    """NamedSharding pytree for a serving-weights tree under ``layout``.

    Works on a raw params tree or a ``ServingWeights`` pytree — quantized
    ``{q, scale, n}`` dicts are plain subtrees, so q/scale each get a spec
    from their own shape (scale rides along replicated or sharded on its
    blocks dim as divisibility allows)."""
    axis = mesh.axis_names[0]
    n = mesh.devices.size
    return jax.tree.map(
        lambda leaf: jax.sharding.NamedSharding(
            mesh, _weight_leaf_spec(leaf, layout, axis, n)),
        tree)


def batch_shardings(tree, mesh, layout: str):
    """NamedSharding pytree for non-weight step operands (episodes, keys)."""
    axis = mesh.axis_names[0]
    n = mesh.devices.size
    return jax.tree.map(
        lambda leaf: jax.sharding.NamedSharding(
            mesh, _batch_leaf_spec(leaf, layout, axis, n)),
        tree)


def score_serving_layout(fn, weights, args, mesh, layout: str) -> Dict:
    """Compile ``fn(weights, *args)`` under ``layout`` and score it with
    the three-term roofline over the ACTUAL post-SPMD HLO."""
    from repro.roofline.hlo import analyze, collectives_report
    in_sh = (serving_shardings(weights, mesh, layout),) + tuple(
        batch_shardings(a, mesh, layout) for a in args)
    compiled = jax.jit(fn, in_shardings=in_sh).lower(weights, *args).compile()
    text = compiled.as_text()
    rep = collectives_report(text)
    hlo = analyze(text)
    terms = dict(compute=hlo["dot_flops"] / PEAK_FLOPS_BF16,
                 memory=hlo["bytes_accessed"] / HBM_BW,
                 collective=rep["total_wire_bytes"] / ICI_BW_PER_LINK)
    return dict(
        layout=layout,
        wire_bytes=rep["total_wire_bytes"],
        collective_count=rep["count"],
        dot_flops=hlo["dot_flops"],
        bytes_accessed=hlo["bytes_accessed"],
        t_compute=terms["compute"], t_memory=terms["memory"],
        t_collective=terms["collective"],
        bottleneck=max(terms, key=terms.get),
        score=max(terms.values()),
    )


def choose_serving_layout(fn, weights, args, mesh,
                          layouts=SERVING_LAYOUTS) -> Dict:
    """Pick the serving weight layout by compiling every candidate.

    fn: the jittable step, called as ``fn(weights, *args)`` (e.g. the
        engine's predict dispatch over a representative serving batch).
    Returns ``{"choice": name, "rows": {layout: score_row}}`` where each
    row is :func:`score_serving_layout`'s output.  The winner minimizes
    the max roofline term (the compiled program's time bound); ties break
    toward the earlier entry in ``layouts``.  ``replicated`` is scored as
    the zero-wire counterfactual but the wire GUARD the tests assert is
    weight_stationary-vs-training: the chosen weight-stationary layout
    must move strictly fewer wire bytes per step than the training layout
    at serving batch sizes."""
    rows = {lo: score_serving_layout(fn, weights, args, mesh, lo)
            for lo in layouts}
    choice = min(layouts, key=lambda lo: rows[lo]["score"])
    return dict(choice=choice, rows=rows)


def choose_replica_serving_layout(fn, weights, args, replica_meshes,
                                  layouts=SERVING_LAYOUTS) -> Dict:
    """Layout choice for a multi-replica deployment: score on ONE replica
    group and apply the winner to all of them.

    The replica groups from ``make_replica_mesh`` are congruent — same
    device count, same axis, same (replicated) weights — so the compiled
    program, and therefore the roofline score, is identical on every
    group; scoring ``replica_meshes[0]`` prices them all.  The scoring is
    correctly SUBGROUP-scoped by construction: the candidate is compiled
    on the group's own mesh, so every collective the score charges for is
    intra-group wire — exactly what the deployment pays per replica, with
    zero inter-group terms (there is no axis spanning two groups to
    communicate over).  Returns :func:`choose_serving_layout`'s dict plus
    ``per_replica_wire_bytes`` (== the winning row's wire bytes: the
    per-step wire EACH replica pays, not a deployment total)."""
    if not replica_meshes:
        raise ValueError("replica_meshes must be non-empty")
    out = choose_serving_layout(fn, weights, args, replica_meshes[0],
                                layouts=layouts)
    out["per_replica_wire_bytes"] = out["rows"][out["choice"]]["wire_bytes"]
    return out
