"""Three-term roofline from dry-run records (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell:
    T_compute = dot_FLOPs_per_chip / peak_FLOPs
    T_memory  = bytes_per_chip / HBM_bw
    T_coll    = ring wire bytes_per_chip / ICI_link_bw
    bottleneck = argmax of the three
    MODEL_FLOPS = 6 N_active D   (train; 2 N_active D for inference pass)
    useful ratio = MODEL_FLOPS_per_chip / dot_FLOPs_per_chip
    roofline fraction = T_ideal / T_bound,  T_ideal = MODEL_FLOPS/(chips*peak)

The fraction answers "how close would a perfectly-overlapped execution of
this compiled program run to the hardware bound set by its own dominant
term" — the score §Perf iterates on.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, Optional

import jax

from repro.configs.base import SHAPES_BY_NAME, ModelConfig
from repro.configs.registry import get_config
from repro.launch.specs import abstract_params_for
from repro.roofline.constants import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16


def param_counts(cfg: ModelConfig) -> Dict[str, float]:
    """(total, active) parameter counts from the abstract param tree.
    Expert banks (3D+ leaves under 'ffn' with leading E) count at
    (top_k + n_shared)/E toward active."""
    params = abstract_params_for(cfg)
    total = 0.0
    active = 0.0
    embed = 0.0

    def visit(path, leaf):
        nonlocal total, active, embed
        p = "/".join(str(getattr(x, "key", getattr(x, "idx", x))) for x in path)
        n = 1.0
        for d in leaf.shape:
            n *= d
        total += n
        name = p.split("/")[-1]
        if name in ("embed", "lm_head"):
            embed += n
            return                      # embeddings excluded from 6ND flops
        if cfg.moe is not None and "ffn" in p and len(leaf.shape) >= 3 \
                and leaf.shape[-3] == cfg.moe.n_experts:
            active += n * cfg.moe.top_k / cfg.moe.n_experts
        else:
            active += n

    jax.tree_util.tree_map_with_path(visit, params)
    return dict(total=total, active=active, embed=embed)


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    """Global MODEL_FLOPS for one step of this cell."""
    s = SHAPES_BY_NAME[shape_name]
    counts = param_counts(cfg)
    n_active = counts["active"]
    if s.kind == "train":
        tokens = s.global_batch * s.seq_len
        return 6.0 * n_active * tokens
    if s.kind == "prefill":
        tokens = s.global_batch * s.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * s.global_batch


def analyze_cell(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    cfg = get_config(rec["arch"])
    chips = rec["chips"]
    t_compute = rec["flops_per_device"] / PEAK_FLOPS_BF16
    t_memory = rec["bytes_per_device"] / HBM_BW
    wire = sum(k["wire_bytes"] for k in rec["collectives"].values())
    t_coll = wire / ICI_BW_PER_LINK
    terms = dict(compute=t_compute, memory=t_memory, collective=t_coll)
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, rec["shape"])
    t_ideal = mf / (chips * PEAK_FLOPS_BF16)
    t_bound = max(terms.values())
    useful = mf / chips / max(rec["flops_per_device"], 1.0)
    return dict(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"], chips=chips,
        t_compute=t_compute, t_memory=t_memory, t_collective=t_coll,
        bottleneck=bottleneck,
        model_flops=mf, useful_ratio=useful,
        roofline_fraction=t_ideal / max(t_bound, 1e-30),
        state_bytes_per_device=rec.get("state_bytes_per_device", 0),
        hbm_headroom_gib=16.0 - rec.get("state_bytes_per_device", 0) / 2**30,
    )


def load_table(path: str | pathlib.Path, mesh: str = "single"):
    recs = json.loads(pathlib.Path(path).read_text())
    rows = []
    for key, rec in sorted(recs.items()):
        if rec.get("mesh") != mesh:
            continue
        if rec.get("status") == "skipped":
            rows.append(dict(arch=rec["arch"], shape=rec["shape"],
                             mesh=mesh, skipped=rec["reason"][:60]))
            continue
        out = analyze_cell(rec)
        if out:
            rows.append(out)
    return rows


def format_markdown(rows) -> str:
    hdr = ("| arch | shape | T_comp (ms) | T_mem (ms) | T_coll (ms) | "
           "bottleneck | useful | roofline frac | state GiB/chip |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {1e3*r['t_compute']:.2f} | "
            f"{1e3*r['t_memory']:.2f} | {1e3*r['t_collective']:.2f} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | "
            f"{r['state_bytes_per_device']/2**30:.2f} |")
    return "\n".join(lines)
