"""Deterministic fault-injection plans.

A :class:`FaultPlan` is the fault analogue of the serving harness's
``FakeClock``: a seeded, fully deterministic schedule of faults that the
fault-tolerant components accept by injection (``train(fault_plan=...)``,
``CheckpointManager(fault_plan=...)``, ``WarmTaskStore(fault_plan=...)``,
``EpisodicServeEngine(fault_plan=...)``).  Every failure mode the stack
claims to survive is expressed as a :class:`FaultSpec` trigger
``(site, at, kind)`` so the failure reproduces bit-for-bit in a test —
no monkeypatching, no flaky timing, no real signals.

Sites (the injection points wired through the stack):

==========================  ================================================
``data.nan``                poison the step's batch with NaNs (every float
                            leaf) — drives the non-finite-gradient guard
``data.transient``          raise :class:`TransientDataError` from
                            ``batch_at`` — drives prefetcher/loop retry
``train.preempt``           graceful preemption at a step: the loop flushes
                            a checkpoint and raises ``PreemptedError``
``train.straggler``         make a step slow by ``payload`` seconds
                            (advances an injectable clock; no real sleep
                            under a FakeClock)
``ckpt.pre_commit``         kill (raise :class:`InjectedKill`) after the
                            checkpoint tmp write, before the COMMIT marker
``ckpt.pre_replace``        kill after COMMIT, before the atomic
                            ``os.replace`` publish
``warm.corrupt``            truncate a just-spilled warm-tier npz to
                            ``payload`` bytes (crash-mid-put residue /
                            bit-rot) — drives quarantine + re-adapt
``warm.vanish``             remove the warm directory before a spill
                            (tmpfs cleanup) — drives L1-only degradation
``replica.dead``            a serving replica group dies mid-run (host
                            loss / device failure): the replica router
                            quarantines the group and re-routes its
                            unfinished uids to the surviving replicas —
                            warm-tier state rehydrates bit-exactly where
                            it had spilled, the rest re-adapts cold
==========================  ================================================

``at`` is the site's natural index — the step for training sites, the task
uid for warm-tier sites, the replica index for ``replica.dead`` (``None``
matches any index).  ``count`` bounds how
many times a spec fires: a transient error with ``count=2`` fails twice and
then heals, which is exactly what a bounded-retry test needs.  Every firing
is recorded in ``plan.fired`` for assertions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np

# canonical site names (plain strings everywhere; these constants are the
# documented vocabulary)
DATA_NAN = "data.nan"
DATA_TRANSIENT = "data.transient"
TRAIN_PREEMPT = "train.preempt"
TRAIN_STRAGGLER = "train.straggler"
CKPT_PRE_COMMIT = "ckpt.pre_commit"
CKPT_PRE_REPLACE = "ckpt.pre_replace"
WARM_CORRUPT = "warm.corrupt"
WARM_VANISH = "warm.vanish"
REPLICA_DEAD = "replica.dead"

ALL_SITES = (DATA_NAN, DATA_TRANSIENT, TRAIN_PREEMPT, TRAIN_STRAGGLER,
             CKPT_PRE_COMMIT, CKPT_PRE_REPLACE, WARM_CORRUPT, WARM_VANISH,
             REPLICA_DEAD)

# The site registry: every FaultSpec.site must be one of these (validated
# at construction), and every injection point must name its site via the
# constants above — the `fault-site-registry` lint rule rejects raw string
# literals at fire()/FaultSpec call sites, so the registry and the wired
# sites can never drift apart silently.
FAULT_SITES = frozenset(ALL_SITES)


class TransientDataError(RuntimeError):
    """A retryable data-source failure (the injected stand-in for a flaky
    loader / filesystem / network read)."""


class InjectedKill(RuntimeError):
    """Simulated process death at a precise point (e.g. between a
    checkpoint's tmp write and its atomic publish).  Tests catch it where
    a real kill would end the process; everything already on disk is
    exactly what a real crash would leave behind."""


@dataclasses.dataclass
class FaultSpec:
    """One trigger: fire ``kind`` at ``site`` when the site's index equals
    ``at`` (``None`` = any index), at most ``count`` times."""

    site: str
    at: Optional[int] = None
    kind: str = "error"
    payload: Any = None
    count: int = 1
    remaining: int = dataclasses.field(default=-1)

    def __post_init__(self):
        if self.site not in FAULT_SITES:
            raise ValueError(
                f"unknown fault site {self.site!r}: every site must be "
                f"declared in the repro/faults/plan.py registry "
                f"(FAULT_SITES) and referenced via its constant — known "
                f"sites: {sorted(FAULT_SITES)}")
        if self.remaining < 0:
            self.remaining = self.count


class FaultPlan:
    """A deterministic schedule of :class:`FaultSpec` triggers.

    ``fire(site, at)`` returns the first matching spec with firings left
    (decrementing it) or ``None`` — the single primitive every injection
    point calls.  ``fired`` records ``(site, at, kind)`` per firing so
    tests assert exactly which faults happened, in order.
    """

    def __init__(self, specs: Sequence[FaultSpec] = ()):
        self.specs: List[FaultSpec] = list(specs)
        self.fired: List[Tuple[str, Optional[int], str]] = []

    @classmethod
    def single(cls, site: str, at: Optional[int] = None, kind: str = "error",
               payload: Any = None, count: int = 1) -> "FaultPlan":
        return cls([FaultSpec(site=site, at=at, kind=kind, payload=payload,
                              count=count)])

    @classmethod
    def seeded(cls, seed: int, site: str, num_steps: int, rate: float,
               kind: str = "error", payload: Any = None,
               count: int = 1) -> "FaultPlan":
        """Seeded random plan: each step in ``range(num_steps)`` gets a
        trigger with probability ``rate`` — the same seed always yields the
        same schedule (``np.random.default_rng(seed)``), so a soak test is
        as repeatable as a hand-written one."""
        rng = np.random.default_rng(seed)
        steps = np.nonzero(rng.random(num_steps) < rate)[0]
        return cls([FaultSpec(site=site, at=int(s), kind=kind,
                              payload=payload, count=count) for s in steps])

    def extend(self, other: "FaultPlan") -> "FaultPlan":
        """Merge another plan's specs into this one (shared ``fired`` log)."""
        self.specs.extend(other.specs)
        return self

    def fire(self, site: str, at: Optional[int] = None) -> Optional[FaultSpec]:
        for spec in self.specs:
            if spec.site != site or spec.remaining <= 0:
                continue
            if spec.at is not None and at is not None and spec.at != at:
                continue
            spec.remaining -= 1
            self.fired.append((site, at, spec.kind))
            return spec
        return None

    def fired_count(self, site: Optional[str] = None) -> int:
        if site is None:
            return len(self.fired)
        return sum(1 for s, _, _ in self.fired if s == site)

    # -- batch-stream injection ---------------------------------------------

    def wrap_batch_at(self, batch_at: Callable[[int], Any]
                      ) -> Callable[[int], Any]:
        """Wrap a deterministic ``batch_at(step)`` stream with the data
        sites: ``data.transient`` raises (each call re-fires, so a retry
        consumes one firing per attempt and a ``count``-bounded spec heals
        after ``count`` failures), ``data.nan`` poisons every float leaf of
        the produced batch with NaN (the injected stand-in for a corrupt
        record — the non-finite guard must catch the resulting gradients)."""
        import jax
        import jax.numpy as jnp

        def wrapped(step: int):
            if self.fire(DATA_TRANSIENT, step) is not None:
                raise TransientDataError(
                    f"injected transient data-source failure at step {step}")
            batch = batch_at(step)
            if self.fire(DATA_NAN, step) is not None:
                def poison(a):
                    if hasattr(a, "dtype") and \
                            jnp.issubdtype(a.dtype, jnp.inexact):
                        return jnp.full_like(a, jnp.nan)
                    return a
                batch = jax.tree.map(poison, batch)
            return batch

        return wrapped


def advance_clock(clock: Callable[[], float], dt: float) -> None:
    """Make ``dt`` seconds pass on an injectable clock: a test FakeClock
    (anything with ``.advance``) advances virtually — no real sleep — while
    a wall clock sleeps for real (the launcher path)."""
    if hasattr(clock, "advance"):
        clock.advance(dt)
    else:
        import time
        # lint: allow(clock-discipline): the wall-clock half of the
        # injectable-clock contract itself — launchers land here, tests
        # always inject a FakeClock and never reach this branch
        time.sleep(dt)
