"""Fault-injection + fault-tolerance subsystem (the robustness layer).

Two halves:

* :mod:`repro.faults.plan` — seeded, deterministic fault *injection*: a
  :class:`FaultPlan` of ``(site, at, kind)`` triggers that the train loop,
  ``Prefetcher``, ``CheckpointManager``, ``WarmTaskStore``, and
  ``EpisodicServeEngine`` accept the same way the serving tests inject a
  ``FakeClock``.  Every survivable failure mode has a repeatable test.
* The *tolerance* lives in the components themselves: the non-finite
  gradient guard + divergence rollback in the train step/loop, bounded
  retry in the prefetcher, crash-consistent checkpoints, warm-tier
  checksums + quarantine, bounded-queue backpressure and deadline
  abandonment in the serve engine.  See ROADMAP.md "Fault-tolerance
  contract" for which faults are survivable at which layer and which
  counters report them.

:class:`PreemptionSignal` is the production half of the graceful-preemption
path: the launcher installs it on SIGTERM, the loop flushes a checkpoint
and exits resumable (same code path a ``train.preempt`` fault triggers).
"""
from __future__ import annotations

import signal as _signal
from typing import Optional, Sequence

from repro.faults.plan import (ALL_SITES, CKPT_PRE_COMMIT, CKPT_PRE_REPLACE,
                               DATA_NAN, DATA_TRANSIENT, FAULT_SITES,
                               REPLICA_DEAD, TRAIN_PREEMPT, TRAIN_STRAGGLER,
                               WARM_CORRUPT, WARM_VANISH, FaultPlan,
                               FaultSpec, InjectedKill, TransientDataError,
                               advance_clock)

__all__ = [
    "ALL_SITES", "CKPT_PRE_COMMIT", "CKPT_PRE_REPLACE", "DATA_NAN",
    "DATA_TRANSIENT", "FAULT_SITES", "REPLICA_DEAD", "TRAIN_PREEMPT",
    "TRAIN_STRAGGLER", "WARM_CORRUPT", "WARM_VANISH", "FaultPlan",
    "FaultSpec", "InjectedKill", "TransientDataError", "advance_clock",
    "PreemptionSignal",
]


class PreemptionSignal:
    """Cooperative preemption flag for the training loop.

    The loop polls ``requested`` at each step boundary; once set it flushes
    a checkpoint at the current step and raises ``PreemptedError`` —
    nonzero-but-resumable, and resume is bit-exact because the step is a
    pure function of (state, batch) and ``batch_at`` is pure in the step.

    ``install()`` registers the flag on real signals (SIGTERM by default —
    what a preemptible/budgeted scheduler sends); tests just call
    ``request()`` directly or let a ``train.preempt`` fault fire."""

    def __init__(self):
        self.requested = False

    def request(self, *_args) -> None:
        self.requested = True

    def install(self, signals: Optional[Sequence[int]] = None
                ) -> "PreemptionSignal":
        for sig in (signals if signals is not None else (_signal.SIGTERM,)):
            _signal.signal(sig, self.request)
        return self
