"""Pure-function training step: (state, batch) -> (state, metrics).

The whole step — loss, backward, clip, schedule, AdamW — is one jitted
SPMD program; restart-exactness (fault tolerance) falls out of purity.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import dispatch
from repro.models.registry import get_api
from repro.optim import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm
from repro.optim.schedules import cosine_schedule

State = Dict[str, Any]


def make_init_state(cfg: ModelConfig, adamw_cfg: AdamWConfig) -> Callable:
    api = get_api(cfg)

    def init_state(key) -> State:
        params = api.init(key, cfg)
        if cfg.param_dtype != "float32":
            dt = jnp.dtype(cfg.param_dtype)
            params = jax.tree.map(
                lambda p: p.astype(dt) if jnp.issubdtype(p.dtype, jnp.floating) else p,
                params)
        return dict(params=params, opt=adamw_init(params, adamw_cfg))

    return init_state


def make_train_step(cfg: ModelConfig, adamw_cfg: AdamWConfig,
                    schedule: Callable | None = None,
                    max_grad_norm: float = 1.0,
                    skip_nonfinite: bool = True) -> Callable:
    """``skip_nonfinite`` (default on): a NaN/inf gradient suppresses the
    update via a fused ``where``-select — params and opt state come out
    bit-identical to the inputs, ``metrics['nonfinite']`` is 1.0, and the
    loop's consecutive-skip budget decides when that means divergence."""
    api = get_api(cfg)
    if schedule is None:
        schedule = functools.partial(cosine_schedule, peak=3e-4,
                                     warmup_steps=2000, total_steps=100000)

    def train_step(state: State, batch: Dict) -> Tuple[State, Dict]:
        def loss_fn(params):
            return api.loss(params, batch, cfg)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"])
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = schedule(state["opt"]["count"])
        new_params, new_opt = adamw_update(state["params"], grads,
                                           state["opt"], lr, adamw_cfg)
        out_metrics = dict(loss=loss, grad_norm=gnorm, lr=lr, **metrics)
        if skip_nonfinite:
            from repro.core.episodic_train import _tree_all_finite
            ok = _tree_all_finite(grads)
            pick = lambda n, o: jnp.where(ok, n, o)  # noqa: E731
            new_params = jax.tree.map(pick, new_params, state["params"])
            new_opt = jax.tree.map(pick, new_opt, state["opt"])
            out_metrics["nonfinite"] = (~ok).astype(jnp.float32)
        return dict(params=new_params, opt=new_opt), out_metrics

    return train_step


def make_eval_step(cfg: ModelConfig) -> Callable:
    api = get_api(cfg)

    def eval_step(params, batch):
        loss, metrics = api.loss(params, batch, cfg)
        return dict(loss=loss, **metrics)

    return eval_step


# -- episodic (meta-training) adapters --------------------------------------
# Bridge the task-batched LITE engine to the same (state, batch) pure-step
# interface the fault-tolerant loop drives, so meta-training inherits
# checkpoint/resume/straggler handling unchanged.  ``batch`` is
# ``dict(tasks=TaskBatch, key=prng_key)`` — both produced deterministically
# from the step index by the data side (repro.data.episodic.task_batch_at).


def make_episodic_init_state(learner, adamw_cfg: AdamWConfig,
                             meta_cfg=None) -> Callable:
    """``meta_cfg`` with ``grad_reduce='compressed'`` adds the per-DCN-shard
    error-feedback residual to the optimizer state (``opt['ef']``), so
    checkpoints carry it and compressed-reduction restarts stay exact."""
    from repro.core.episodic_train import init_ef_state
    from repro.optim import adamw_init

    def init_state(key) -> State:
        params = learner.init(key)
        opt = adamw_init(params, adamw_cfg)
        if meta_cfg is not None and meta_cfg.grad_reduce == "compressed":
            opt["ef"] = init_ef_state(params, meta_cfg.dcn_shards)
        return dict(params=params, opt=opt)

    return init_state


def make_episodic_train_step(learner, lite, meta_cfg,
                             adamw_cfg: AdamWConfig = None,
                             mesh=None, dp_axis: str = "data",
                             dcn_axis: str = "dcn") -> Callable:
    """meta_cfg: repro.configs.base.MetaTrainConfig (tasks_per_step is the
    data side's concern; ``dp_shards>1`` or ``dcn_shards>1`` requires
    ``mesh`` — a 1-D ``make_dp_mesh`` or a two-level
    ``make_two_level_dp_mesh`` respectively).  A configured
    ``meta_cfg.schedule`` replaces the constant lr with a per-step lr
    keyed on the optimizer update count."""
    from repro.core.episodic_train import make_batched_meta_train_step
    from repro.optim.schedules import schedule_for

    adamw_cfg = adamw_cfg or AdamWConfig(weight_decay=0.0)
    needs_mesh = meta_cfg.dp_shards > 1 or meta_cfg.dcn_shards > 1 \
        or meta_cfg.grad_reduce == "compressed"
    if needs_mesh and mesh is None:
        raise ValueError(f"dp_shards={meta_cfg.dp_shards} / "
                         f"dcn_shards={meta_cfg.dcn_shards} / "
                         f"grad_reduce={meta_cfg.grad_reduce!r} requires a "
                         f"mesh (repro.launch.mesh.make_dp_mesh or "
                         f"make_two_level_dp_mesh)")
    inner = make_batched_meta_train_step(
        learner, lite, adamw=adamw_cfg, lr=meta_cfg.lr,
        max_grad_norm=meta_cfg.max_grad_norm,
        schedule=schedule_for(meta_cfg.schedule, meta_cfg.lr,
                              meta_cfg.warmup_steps, meta_cfg.total_steps),
        mesh=mesh if needs_mesh else None, dp_axis=dp_axis,
        dcn_axis=dcn_axis, grad_reduce=meta_cfg.grad_reduce,
        accum_steps=meta_cfg.accum_steps,
        skip_nonfinite=meta_cfg.skip_nonfinite)

    def train_step(state: State, batch: Dict) -> Tuple[State, Dict]:
        # the configured kernel backend is bound HERE, at trace time:
        # jit retraces per shape, and each trace resolves the config's
        # backend regardless of the ambient dispatch default
        with dispatch.use_backend(meta_cfg.kernel_backend):
            params, opt, metrics = inner(state["params"], state["opt"],
                                         batch["tasks"], batch["key"])
        return dict(params=params, opt=opt), metrics

    return train_step


def adamw_for(cfg: ModelConfig) -> AdamWConfig:
    return AdamWConfig(state_dtype=cfg.opt_state_dtype)
