"""Pure-function training step: (state, batch) -> (state, metrics).

The whole step — loss, backward, clip, schedule, AdamW — is one jitted
SPMD program; restart-exactness (fault tolerance) falls out of purity.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.registry import get_api
from repro.optim import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm
from repro.optim.schedules import cosine_schedule

State = Dict[str, Any]


def make_init_state(cfg: ModelConfig, adamw_cfg: AdamWConfig) -> Callable:
    api = get_api(cfg)

    def init_state(key) -> State:
        params = api.init(key, cfg)
        if cfg.param_dtype != "float32":
            dt = jnp.dtype(cfg.param_dtype)
            params = jax.tree.map(
                lambda p: p.astype(dt) if jnp.issubdtype(p.dtype, jnp.floating) else p,
                params)
        return dict(params=params, opt=adamw_init(params, adamw_cfg))

    return init_state


def make_train_step(cfg: ModelConfig, adamw_cfg: AdamWConfig,
                    schedule: Callable | None = None,
                    max_grad_norm: float = 1.0) -> Callable:
    api = get_api(cfg)
    if schedule is None:
        schedule = functools.partial(cosine_schedule, peak=3e-4,
                                     warmup_steps=2000, total_steps=100000)

    def train_step(state: State, batch: Dict) -> Tuple[State, Dict]:
        def loss_fn(params):
            return api.loss(params, batch, cfg)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"])
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = schedule(state["opt"]["count"])
        new_params, new_opt = adamw_update(state["params"], grads,
                                           state["opt"], lr, adamw_cfg)
        out_metrics = dict(loss=loss, grad_norm=gnorm, lr=lr, **metrics)
        return dict(params=new_params, opt=new_opt), out_metrics

    return train_step


def make_eval_step(cfg: ModelConfig) -> Callable:
    api = get_api(cfg)

    def eval_step(params, batch):
        loss, metrics = api.loss(params, batch, cfg)
        return dict(loss=loss, **metrics)

    return eval_step


def adamw_for(cfg: ModelConfig) -> AdamWConfig:
    return AdamWConfig(state_dtype=cfg.opt_state_dtype)
