"""Checkpoint manager: atomic per-step directories, keep-N retention,
auto-resume from the latest COMMITTED step.

Format: one ``state.npz`` per step directory (path-keyed flat pytree;
bfloat16 leaves stored as uint16 views with a dtype sidecar — numpy has no
bf16) plus ``meta.json``.  A ``COMMIT`` marker written after fsync makes
partially-written checkpoints (killed mid-save, the preemption test does
exactly this) invisible to resume.

Restore takes an abstract template (``jax.eval_shape`` of the init) so the
pytree structure, dtypes, and shardings are re-imposed — restart is
bit-exact because the train step is a pure function of (state, batch).
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_BF16 = "bfloat16"


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out[_path_str(path)] = np.asarray(leaf)
    return out


class CheckpointManager:
    def __init__(self, directory: str | pathlib.Path, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: PyTree, extra: Optional[Dict] = None) -> pathlib.Path:
        final = self.dir / f"step_{step:010d}"
        tmp = self.dir / f".tmp_step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        flat = _flatten(state)
        dtypes = {}
        arrays = {}
        for k, v in flat.items():
            if v.dtype == jnp.bfloat16:
                dtypes[k] = _BF16
                arrays[k] = v.view(np.uint16)
            else:
                dtypes[k] = str(v.dtype)
                arrays[k] = v
        with open(tmp / "state.npz", "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())
        meta = dict(step=step, dtypes=dtypes, extra=extra or {})
        (tmp / "meta.json").write_text(json.dumps(meta))
        (tmp / "COMMIT").write_text("ok")
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)           # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self):
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if (p / "COMMIT").exists():
                out.append(int(p.name.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: PyTree,
                shardings: Optional[PyTree] = None) -> Tuple[PyTree, Dict]:
        d = self.dir / f"step_{step:010d}"
        if not (d / "COMMIT").exists():
            raise FileNotFoundError(f"no committed checkpoint at step {step}")
        meta = json.loads((d / "meta.json").read_text())
        data = np.load(d / "state.npz")

        leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(template)
        flat_shard = (jax.tree.leaves(shardings) if shardings is not None
                      else [None] * len(leaves_with_path))
        out = []
        for (path, leaf), sh in zip(leaves_with_path, flat_shard):
            k = _path_str(path)
            arr = data[k]
            if meta["dtypes"].get(k) == _BF16:
                arr = arr.view(jnp.bfloat16)
            arr = arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jnp.asarray(arr))
        return jax.tree.unflatten(treedef, out), meta["extra"]

    def restore_latest(self, template: PyTree,
                       shardings: Optional[PyTree] = None):
        step = self.latest_step()
        if step is None:
            return None
        state, extra = self.restore(step, template, shardings)
        return step, state, extra
