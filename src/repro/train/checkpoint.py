"""Checkpoint manager: atomic per-step directories, keep-N retention,
auto-resume from the latest COMMITTED step.

Format: one ``state.npz`` per step directory (path-keyed flat pytree;
bfloat16 leaves stored as uint16 views with a dtype sidecar — numpy has no
bf16) plus ``meta.json``.  A ``COMMIT`` marker written after fsync makes
partially-written checkpoints (killed mid-save, the preemption test does
exactly this) invisible to resume.

Restore takes an abstract template (``jax.eval_shape`` of the init) so the
pytree structure, dtypes, and shardings are re-imposed — restart is
bit-exact because the train step is a pure function of (state, batch).

The flatten/encode machinery is also exported standalone
(:func:`save_array_tree` / :func:`load_array_tree`: one self-describing
npz per pytree) — the serving tier's warm task-state store spills evicted
adapted states through it, so a rehydrated state is bit-exact to the
originally adapted one by the same argument as restart exactness.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_BF16 = "bfloat16"


class ChecksumError(RuntimeError):
    """Stored content checksum does not match the bytes on disk — the file
    was corrupted after its atomic publish (bit-rot, partial overwrite).
    The warm task-state tier quarantines on this."""


def _tree_crc32(arrays: Dict[str, np.ndarray], dtypes: Dict[str, str]) -> int:
    """CRC32 over the encoded leaves (sorted key order) + the dtype
    sidecar: a cheap whole-content checksum, stable across writes of the
    same pytree."""
    crc = zlib.crc32(json.dumps(dtypes, sort_keys=True).encode())
    for k in sorted(arrays):
        crc = zlib.crc32(k.encode(), crc)
        crc = zlib.crc32(np.ascontiguousarray(arrays[k]).tobytes(), crc)
    return crc & 0xFFFFFFFF


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out[_path_str(path)] = np.asarray(leaf)
    return out


def encode_array_tree(tree: PyTree) -> Tuple[Dict[str, np.ndarray],
                                             Dict[str, str]]:
    """Path-keyed flat numpy arrays plus a dtype sidecar (bfloat16 leaves
    stored as uint16 views — numpy has no bf16).  The shared encode half of
    every on-disk pytree in this repo: step checkpoints (meta.json carries
    the sidecar) and the serving warm tier (the sidecar rides inside the
    npz, see :func:`save_array_tree`)."""
    flat = _flatten(tree)
    arrays: Dict[str, np.ndarray] = {}
    dtypes: Dict[str, str] = {}
    for k, v in flat.items():
        if v.dtype == jnp.bfloat16:
            dtypes[k] = _BF16
            arrays[k] = v.view(np.uint16)
        else:
            dtypes[k] = str(v.dtype)
            arrays[k] = v
    return arrays, dtypes


def _decode_array(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    return arr.view(jnp.bfloat16) if dtype_str == _BF16 else arr


def save_array_tree(file, tree: PyTree) -> None:
    """One self-describing npz: path-keyed leaves + a ``__dtypes__`` json
    member + a ``__crc32__`` whole-content checksum, fsynced before return.
    Atomicity (tmp + ``os.replace``) is the caller's job.  Values roundtrip
    bit-exactly through :func:`load_array_tree` (fp arrays are stored
    verbatim; bf16 via uint16 views)."""
    arrays, dtypes = encode_array_tree(tree)
    crc = _tree_crc32(arrays, dtypes)
    # lint: allow(atomic-publish): atomicity is this function's documented
    # caller contract — CheckpointManager.save always hands in a tmp path
    # and publishes with os.replace after the COMMIT marker
    with open(file, "wb") as f:
        np.savez(f, __dtypes__=np.asarray(json.dumps(dtypes)),
                 __crc32__=np.uint32(crc), **arrays)
        f.flush()
        os.fsync(f.fileno())


def load_array_tree(file, template: PyTree, verify: bool = False) -> PyTree:
    """Rebuild a :func:`save_array_tree` npz against an abstract template
    (``jax.eval_shape``-style): structure and dtypes are re-imposed from
    the template, bit-exact for matching dtypes — the same contract as
    :meth:`CheckpointManager.restore`.

    ``verify=True`` recomputes the whole-content checksum against the
    stored ``__crc32__`` and raises :class:`ChecksumError` on mismatch
    (files written before checksums existed, lacking the member, pass) —
    the warm task-state tier loads with this on and quarantines on any
    failure.  Truncated/zero-byte files fail earlier, inside ``np.load``'s
    zip parsing."""
    data = np.load(file)
    dtypes = json.loads(str(data["__dtypes__"]))
    if verify and "__crc32__" in data.files:
        arrays = {k: data[k] for k in data.files
                  if k not in ("__dtypes__", "__crc32__")}
        crc = _tree_crc32(arrays, dtypes)
        stored = int(data["__crc32__"])
        if crc != stored:
            raise ChecksumError(
                f"{file}: content crc32 {crc:#010x} != stored "
                f"{stored:#010x} — corrupted after publish")
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, leaf in leaves_with_path:
        k = _path_str(path)
        arr = _decode_array(data[k], dtypes.get(k, ""))
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out)


class CheckpointManager:
    def __init__(self, directory: str | pathlib.Path, keep: int = 3,
                 fault_plan=None):
        """``fault_plan`` (:class:`repro.faults.FaultPlan`) injects
        simulated kills at the two crash-consistency-critical points in
        ``save`` — sites ``ckpt.pre_commit`` / ``ckpt.pre_replace`` — so
        tests prove a death mid-save leaves the previous committed
        checkpoint restorable and a later save recovers the directory."""
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._fault_plan = fault_plan

    def _maybe_kill(self, site: str, step: int) -> None:
        if self._fault_plan is not None and \
                self._fault_plan.fire(site, step) is not None:
            from repro.faults.plan import InjectedKill
            raise InjectedKill(f"killed at {site} while saving step {step}")

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: PyTree, extra: Optional[Dict] = None) -> pathlib.Path:
        final = self.dir / f"step_{step:010d}"
        tmp = self.dir / f".tmp_step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        arrays, dtypes = encode_array_tree(state)
        with open(tmp / "state.npz", "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())
        meta = dict(step=step, dtypes=dtypes, extra=extra or {})
        (tmp / "meta.json").write_text(json.dumps(meta))
        from repro.faults.plan import CKPT_PRE_COMMIT, CKPT_PRE_REPLACE
        self._maybe_kill(CKPT_PRE_COMMIT, step)
        (tmp / "COMMIT").write_text("ok")
        self._maybe_kill(CKPT_PRE_REPLACE, step)
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)           # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self):
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if (p / "COMMIT").exists():
                out.append(int(p.name.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: PyTree,
                shardings: Optional[PyTree] = None) -> Tuple[PyTree, Dict]:
        d = self.dir / f"step_{step:010d}"
        if not (d / "COMMIT").exists():
            raise FileNotFoundError(f"no committed checkpoint at step {step}")
        meta = json.loads((d / "meta.json").read_text())
        data = np.load(d / "state.npz")

        leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(template)
        flat_shard = (jax.tree.leaves(shardings) if shardings is not None
                      else [None] * len(leaves_with_path))
        out = []
        for (path, leaf), sh in zip(leaves_with_path, flat_shard):
            k = _path_str(path)
            arr = _decode_array(data[k], meta["dtypes"].get(k, ""))
            arr = arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jnp.asarray(arr))
        return jax.tree.unflatten(treedef, out), meta["extra"]

    def restore_latest(self, template: PyTree,
                       shardings: Optional[PyTree] = None):
        step = self.latest_step()
        if step is None:
            return None
        state, extra = self.restore(step, template, shardings)
        return step, state, extra
