"""Throughput subsystem for the task-batched LITE engine.

Two pieces, both pure plumbing around the deterministic ``batch_at(step)``
contract the fault-tolerant loop already relies on:

* :class:`Prefetcher` — a double-buffered background-thread host->device
  pipeline.  A worker thread materializes ``batch_at(step)`` for steps in
  order and pushes device-committed batches into a bounded queue, so
  collation + H2D transfer overlap with the device compute of the
  previous step.  The consumer side is strictly sequential (``get(step)``
  asserts the step index), which is what keeps bit-exact checkpoint
  resume trivially true: the thread is just a lookahead evaluator of the
  same pure function the synchronous loop would call.

* :class:`BucketedStepCache` — a per-padded-shape AOT-compiled train-step
  cache.  Ragged task streams collated against a planned bucket set
  (:func:`repro.data.episodic.plan_buckets`) produce a small closed set of
  shapes; this cache compiles one executable per shape key (optionally
  with params/opt-state buffer donation) and exposes ``compile_count`` so
  tests and monitors can assert the compile rate stays flat.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Optional

import jax

PyTree = Any

_DONE = object()        # worker finished the requested range
_FAILED = object()      # worker raised; error in Prefetcher._err


class Prefetcher:
    """Background lookahead over a deterministic ``batch_at(step)`` stream.

    ``depth`` bounds how many batches may be in flight (2 = classic double
    buffering: one being consumed, one being built).  Batches are
    ``jax.device_put`` from the worker thread, so the transfer itself also
    overlaps compute.  A failing ``batch_at`` is retried up to ``retries``
    times with exponential backoff (``backoff_s * 2**attempt``; 0 = no
    wait, which is what deterministic tests use) — transient data-source
    faults heal in place and the delivered stream is unchanged; only an
    error that survives every retry is re-raised from ``get``.
    ``retries_used`` counts the retries actually spent (surfaced in
    ``TrainResult.data_retries``).  Always ``close()`` (the training loop
    does so in a ``finally``) so a preempted run doesn't leak the thread.
    """

    def __init__(self, batch_at: Callable[[int], PyTree], start: int,
                 stop: int, depth: int = 2, to_device: bool = True,
                 put: Optional[Callable[[PyTree], PyTree]] = None,
                 retries: int = 0, backoff_s: float = 0.05):
        """``put`` overrides the default ``jax.device_put`` — pass a
        sharded transfer (e.g. ``device_put`` with a ``NamedSharding``
        over the task axis) so batches land in the mesh layout the
        sharded step consumes, instead of on device 0 with a resharding
        copy inside the step dispatch."""
        if depth < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {depth}")
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop_evt = threading.Event()
        self._err: Optional[BaseException] = None
        self._next = start
        self._batch_at = batch_at
        self._to_device = to_device
        self._device_put = put if put is not None else jax.device_put
        self._retries = retries
        self._backoff_s = backoff_s
        self.retries_used = 0
        self._thread = threading.Thread(
            target=self._worker, args=(start, stop), daemon=True,
            name="batch-prefetcher")
        self._thread.start()

    def _put(self, item) -> bool:
        while not self._stop_evt.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _fetch(self, s: int) -> PyTree:
        """``batch_at(s)`` with bounded exponential-backoff retry — the
        tolerance half of the ``data.transient`` fault site.  The wait uses
        the stop event so ``close()`` interrupts a backoff immediately."""
        delay = self._backoff_s
        for attempt in range(self._retries + 1):
            try:
                return self._batch_at(s)
            except Exception:
                if attempt == self._retries or self._stop_evt.is_set():
                    raise
                self.retries_used += 1
                if delay > 0:
                    self._stop_evt.wait(delay)
                    delay *= 2

    def _worker(self, start: int, stop: int) -> None:
        try:
            for s in range(start, stop):
                if self._stop_evt.is_set():
                    return
                batch = self._fetch(s)
                if self._to_device:
                    batch = self._device_put(batch)
                if not self._put((s, batch)):
                    return
            self._put(_DONE)
        except BaseException as e:  # noqa: BLE001 — delivered via get()
            self._err = e
            self._put(_FAILED)

    def get(self, step: int) -> PyTree:
        """Next batch; blocks until the worker has it.  Strictly sequential
        — the loop must consume exactly the steps the prefetcher was built
        for, in order."""
        if step != self._next:
            raise ValueError(f"prefetcher is sequential: expected step "
                             f"{self._next}, got {step}")
        while True:
            try:
                item = self._q.get(timeout=0.1)
                break
            except queue.Empty:
                if self._err is not None:
                    raise self._err
                if not self._thread.is_alive():
                    raise RuntimeError("prefetcher thread died without "
                                       "delivering a batch")
        if item is _FAILED:
            raise self._err
        if item is _DONE:
            raise ValueError(f"prefetcher exhausted before step {step}")
        s, batch = item
        assert s == step, (s, step)
        self._next += 1
        return batch

    def close(self) -> None:
        self._stop_evt.set()
        # unblock a worker stuck on a full queue
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)


def _aval_key(args) -> tuple:
    """Hashable key: pytree structure (static fields included) + the
    shape/dtype of every array leaf — exactly what XLA specializes on."""
    leaves, treedef = jax.tree.flatten(args)
    return (treedef,
            tuple((tuple(getattr(l, "shape", ())),
                   str(getattr(l, "dtype", type(l).__name__)))
                  for l in leaves))


class BucketedStepCache:
    """Per-shape AOT-compiled cache for a train-step-like callable.

    ``jax.jit`` already retraces per shape; what the cache adds is (a) an
    exact, inspectable ``compile_count`` (a flat counter across a ragged
    stream is the bucketing policy working), (b) explicit lowering so the
    compile happens at a known point, and (c) optional buffer donation of
    the leading ``donate_argnums`` arguments (params/opt state for the
    task-batched step signature ``(params, opt_state, batch, key)``).
    """

    def __init__(self, step_fn: Callable, donate: bool = False,
                 donate_argnums: tuple = (0, 1)):
        self._jit = jax.jit(step_fn,
                            donate_argnums=donate_argnums if donate else ())
        self._compiled: Dict[tuple, Callable] = {}

    @property
    def compile_count(self) -> int:
        return len(self._compiled)

    def __call__(self, *args):
        key = _aval_key(args)
        fn = self._compiled.get(key)
        if fn is None:
            fn = self._jit.lower(*args).compile()
            self._compiled[key] = fn
        return fn(*args)
