"""Fault-tolerant training loop.

Design for 1000+ nodes (DESIGN.md §5):
  * the step is a pure jitted function of (state, batch); the data
    pipeline is a pure function of (config, step)  =>  restart from any
    committed checkpoint is bit-exact (tested by killing mid-run);
  * checkpoints are atomic + keep-N (repro.train.checkpoint);
  * a straggler monitor tracks per-step wall time EWMA and flags outliers
    (on a multi-host deployment the controller would re-slice around the
    slow host; here the signal is logged and surfaced in TrainResult);
  * preemption is injected via an optional hook for tests (the loop
    raises exactly as a SIGTERM handler would).

Throughput engine (PR2):
  * ``prefetch=k`` overlaps host-side ``batch_at(step)`` collation and
    H2D transfer with device compute via a background
    :class:`repro.train.pipeline.Prefetcher`, and the loop stops
    hard-syncing every step — it only blocks on ``log_every``/checkpoint
    boundaries (plus the first and last step), letting the runtime queue
    dispatches ahead.  Determinism is untouched: the prefetcher evaluates
    the same pure ``batch_at`` stream in order, so resume stays bit-exact.
  * ``donate=True`` donates the state argument to the jitted step
    (``donate_argnums=0``): params and optimizer state update in place
    instead of being copied each step.  The caller's initial ``state``
    buffers are consumed by the first step — thread the returned
    ``TrainResult.state``, never the original.
  * step-time accounting is per COMMITTED step: between hard syncs the
    loop measures wall-clock for the whole span and attributes the
    average to each step in it, so ``TrainResult.throughput()`` reports
    real tasks/sec, not per-dispatch latency (which under async dispatch
    would be a meaningless few microseconds).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.train.checkpoint import CheckpointManager
from repro.train.pipeline import Prefetcher

PyTree = Any


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time tracker; flags steps slower than ratio x the EWMA."""

    alpha: float = 0.1
    ratio: float = 3.0
    ewma: Optional[float] = None
    flagged: List[int] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = dt > self.ratio * self.ewma
        if slow:
            self.flagged.append(step)
        # slow steps do not poison the EWMA
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * min(dt, self.ratio * self.ewma)
        return slow


@dataclasses.dataclass
class TrainResult:
    state: PyTree
    step: int
    metrics_history: List[Dict]
    straggler_steps: List[int]
    resumed_from: Optional[int]
    step_times: List[float] = dataclasses.field(default_factory=list)

    def throughput(self, items_per_step: int = 1, skip: int = 1) -> float:
        """items/sec over the run, excluding the first ``skip`` (compile)
        steps — the task-batched launcher reports tasks/sec with this.

        ``step_times[i]`` is wall-clock per COMMITTED step: under async
        dispatch (``train(prefetch=...)``) the loop only syncs at span
        boundaries and spreads the measured span time uniformly over its
        steps, so this ratio reflects end-to-end throughput rather than
        dispatch latency.  The first step is always its own span (hard
        sync), so ``skip=1`` cleanly drops compile time."""
        times = self.step_times[skip:] or self.step_times
        if not times:
            return 0.0
        return items_per_step * len(times) / sum(times)


def train(state: PyTree,
          train_step: Callable,
          batch_at: Callable[[int], Dict],
          num_steps: int,
          *,
          ckpt: Optional[CheckpointManager] = None,
          ckpt_every: int = 50,
          state_template: Optional[PyTree] = None,
          preemption_hook: Optional[Callable[[int], None]] = None,
          log_every: int = 0,
          prefetch: int = 0,
          donate: bool = False,
          batch_put: Optional[Callable] = None,
          max_span: int = 64) -> TrainResult:
    """Run (and resume) training.  ``batch_at(step)`` must be deterministic
    in ``step`` — together with checkpointed state that is what makes
    restarts exact.

    ``prefetch > 0`` builds batches on a background thread ``prefetch``
    steps ahead and switches the loop to async dispatch: hard sync only on
    log/checkpoint boundaries, bounded by ``max_span`` so dispatch
    run-ahead (queued executions + their pinned batch buffers + pending
    metrics) can never grow with ``num_steps``.  Within a span the
    straggler monitor only sees the span-average step time — a single
    slow step inside a long span is smeared out; shorten ``log_every`` /
    ``max_span`` where per-step straggler attribution matters.
    ``donate=True`` donates the state to the jitted step so params/opt
    state update in place — the caller's input ``state`` is consumed by
    the first step.  ``batch_put`` overrides the prefetcher's H2D
    transfer (e.g. a sharded ``device_put`` matching a two-level mesh
    layout)."""
    start = 0
    resumed_from = None
    if ckpt is not None and state_template is not None:
        restored = ckpt.restore_latest(state_template)
        if restored is not None:
            start, state, _ = restored
            resumed_from = start
    step_fn = jax.jit(train_step, donate_argnums=(0,) if donate else ())
    monitor = StragglerMonitor()
    history: List[Dict] = []
    step_times: List[float] = []

    source = batch_at
    pf = None
    if prefetch > 0 and start < num_steps:
        pf = Prefetcher(batch_at, start, num_steps, depth=prefetch,
                        put=batch_put)
        source = pf.get
    try:
        pending: List[Dict] = []      # dispatched, not yet committed
        span_t0: Optional[float] = None
        span_start = start
        for step in range(start, num_steps):
            if preemption_hook is not None:
                preemption_hook(step)    # may raise (simulated SIGTERM)
            if span_t0 is None:
                span_t0 = time.time()
                span_start = step
            state, metrics = step_fn(state, source(step))
            pending.append(metrics)
            # In sync mode every step is a span; async mode syncs only on
            # the first step (isolates compile), log/ckpt boundaries, and
            # the final step.
            sync = (prefetch == 0 or step == start or step == num_steps - 1
                    or (log_every and step % log_every == 0)
                    or (ckpt is not None and (step + 1) % ckpt_every == 0)
                    or len(pending) >= max(max_span, 1))
            if sync:
                jax.block_until_ready(jax.tree.leaves(state)[0])
                per = (time.time() - span_t0) / (step - span_start + 1)
                for s in range(span_start, step + 1):
                    step_times.append(per)
                    monitor.observe(s, per)
                history.extend({k: float(v) for k, v in m.items()}
                               for m in pending)
                pending.clear()
                span_t0 = None
                if log_every and step % log_every == 0:
                    print(f"step {step}: {history[-1]}", flush=True)
            if ckpt is not None and (step + 1) % ckpt_every == 0:
                ckpt.save(step + 1, state)
    finally:
        if pf is not None:
            pf.close()

    if ckpt is not None:
        ckpt.save(num_steps, state)
    return TrainResult(state=state, step=num_steps, metrics_history=history,
                       straggler_steps=monitor.flagged,
                       resumed_from=resumed_from, step_times=step_times)
