"""Fault-tolerant training loop.

Design for 1000+ nodes (DESIGN.md §5):
  * the step is a pure jitted function of (state, batch); the data
    pipeline is a pure function of (config, step)  =>  restart from any
    committed checkpoint is bit-exact (tested by killing mid-run);
  * checkpoints are atomic + keep-N (repro.train.checkpoint);
  * a straggler monitor tracks per-step wall time EWMA and flags outliers
    (on a multi-host deployment the controller would re-slice around the
    slow host; here the signal is logged and surfaced in TrainResult);
  * preemption is injected via an optional hook for tests (the loop
    raises exactly as a SIGTERM handler would).

Throughput engine (PR2):
  * ``prefetch=k`` overlaps host-side ``batch_at(step)`` collation and
    H2D transfer with device compute via a background
    :class:`repro.train.pipeline.Prefetcher`, and the loop stops
    hard-syncing every step — it only blocks on ``log_every``/checkpoint
    boundaries (plus the first and last step), letting the runtime queue
    dispatches ahead.  Determinism is untouched: the prefetcher evaluates
    the same pure ``batch_at`` stream in order, so resume stays bit-exact.
  * ``donate=True`` donates the state argument to the jitted step
    (``donate_argnums=0``): params and optimizer state update in place
    instead of being copied each step.  The caller's initial ``state``
    buffers are consumed by the first step — thread the returned
    ``TrainResult.state``, never the original.
  * step-time accounting is per COMMITTED step: between hard syncs the
    loop measures wall-clock for the whole span and attributes the
    average to each step in it, so ``TrainResult.throughput()`` reports
    real tasks/sec, not per-dispatch latency (which under async dispatch
    would be a meaningless few microseconds).

Fault tolerance (PR7) — every path is drivable deterministically by a
:class:`repro.faults.FaultPlan` and has a paired test:

  * **non-finite updates**: steps built with ``skip_nonfinite`` report
    ``metrics['nonfinite']``; a skipped step leaves state bit-identical.
    The loop counts CONSECUTIVE skips (``TrainResult.nonfinite_steps``
    lists them all) and more than ``max_nonfinite`` in a row is treated
    as divergence: restore the latest committed checkpoint and replay
    (at most ``max_rollbacks`` times), else raise
    :class:`DivergenceError`.  Replay reuses the already-jitted step —
    no recompile — and, because ``batch_at`` is pure in the step, a
    replay past a since-healed data fault is bit-exact with a run that
    never faulted.
  * **transient data faults**: ``batch_at`` failures retry with bounded
    exponential backoff (``data_retries`` / ``data_backoff_s``) — in the
    prefetcher's worker for ``prefetch>0``, inline here for sync mode.
    Retries spent are surfaced as ``TrainResult.data_retries``; only an
    error outliving every retry propagates.
  * **graceful preemption**: a :class:`repro.faults.PreemptionSignal`
    (``preempt=``, set by a real SIGTERM or by a ``train.preempt``
    fault) is polled every step boundary: the loop flushes a checkpoint
    at the current step and raises :class:`PreemptedError` — nonzero but
    resumable, and the resumed run is bit-exact with an uninterrupted
    one.
  * **injectable clock**: all timing reads ``clock()`` (default
    ``time.time``); an injected ``train.straggler`` fault advances the
    clock by its payload, so straggler detection is testable with a
    FakeClock and zero real sleeps.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.train.checkpoint import CheckpointManager
from repro.train.pipeline import Prefetcher

PyTree = Any


class PreemptedError(RuntimeError):
    """Graceful preemption: a checkpoint at ``step`` was flushed before
    raising, so rerunning the same command resumes bit-exactly.  Launchers
    exit nonzero-but-resumable (75, EX_TEMPFAIL) on this."""

    def __init__(self, step: int, flushed: bool):
        self.step = step
        self.flushed = flushed
        where = f"checkpoint flushed at step {step}" if flushed else \
            "no checkpoint manager — progress since start is lost"
        super().__init__(f"preempted at step {step} ({where})")


class DivergenceError(RuntimeError):
    """More than ``max_nonfinite`` consecutive non-finite (skipped) steps
    and no rollback budget/checkpoint left to recover with."""


class _Diverged(Exception):
    """Internal: consecutive-skip budget exceeded at ``step`` — caught by
    the rollback driver in :func:`train`."""

    def __init__(self, step: int):
        self.step = step


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time tracker; flags steps slower than ratio x the EWMA."""

    alpha: float = 0.1
    ratio: float = 3.0
    ewma: Optional[float] = None
    flagged: List[int] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = dt > self.ratio * self.ewma
        if slow:
            self.flagged.append(step)
        # slow steps do not poison the EWMA
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * min(dt, self.ratio * self.ewma)
        return slow


@dataclasses.dataclass
class TrainResult:
    state: PyTree
    step: int
    metrics_history: List[Dict]
    straggler_steps: List[int]
    resumed_from: Optional[int]
    step_times: List[float] = dataclasses.field(default_factory=list)
    nonfinite_steps: List[int] = dataclasses.field(default_factory=list)
    rollbacks: int = 0
    data_retries: int = 0

    def throughput(self, items_per_step: int = 1, skip: int = 1) -> float:
        """items/sec over the run, excluding the first ``skip`` (compile)
        steps — the task-batched launcher reports tasks/sec with this.

        ``step_times[i]`` is wall-clock per COMMITTED step: under async
        dispatch (``train(prefetch=...)``) the loop only syncs at span
        boundaries and spreads the measured span time uniformly over its
        steps, so this ratio reflects end-to-end throughput rather than
        dispatch latency.  The first step is always its own span (hard
        sync), so ``skip=1`` cleanly drops compile time."""
        times = self.step_times[skip:] or self.step_times
        if not times:
            return 0.0
        return items_per_step * len(times) / sum(times)


def train(state: PyTree,
          train_step: Callable,
          batch_at: Callable[[int], Dict],
          num_steps: int,
          *,
          ckpt: Optional[CheckpointManager] = None,
          ckpt_every: int = 50,
          state_template: Optional[PyTree] = None,
          preemption_hook: Optional[Callable[[int], None]] = None,
          log_every: int = 0,
          prefetch: int = 0,
          donate: bool = False,
          batch_put: Optional[Callable] = None,
          max_span: int = 64,
          fault_plan=None,
          preempt=None,
          max_nonfinite: int = 8,
          max_rollbacks: int = 1,
          data_retries: int = 2,
          data_backoff_s: float = 0.05,
          clock: Optional[Callable[[], float]] = None) -> TrainResult:
    """Run (and resume) training.  ``batch_at(step)`` must be deterministic
    in ``step`` — together with checkpointed state that is what makes
    restarts exact.

    ``prefetch > 0`` builds batches on a background thread ``prefetch``
    steps ahead and switches the loop to async dispatch: hard sync only on
    log/checkpoint boundaries, bounded by ``max_span`` so dispatch
    run-ahead (queued executions + their pinned batch buffers + pending
    metrics) can never grow with ``num_steps``.  Within a span the
    straggler monitor only sees the span-average step time — a single
    slow step inside a long span is smeared out, and non-finite skips are
    only DETECTED at span commits; shorten ``log_every`` / ``max_span``
    where per-step attribution matters.  ``donate=True`` donates the
    state to the jitted step so params/opt state update in place — the
    caller's input ``state`` is consumed by the first step.  ``batch_put``
    overrides the prefetcher's H2D transfer (e.g. a sharded
    ``device_put`` matching a two-level mesh layout).

    Fault-tolerance knobs (see module docstring): ``fault_plan`` injects
    deterministic faults at the documented sites; ``preempt`` is a
    :class:`repro.faults.PreemptionSignal` polled each step;
    ``max_nonfinite`` bounds consecutive skipped steps before a rollback
    (``max_rollbacks`` of them, needing ``ckpt`` + ``state_template``)
    or :class:`DivergenceError`; ``data_retries``/``data_backoff_s``
    bound the transient-data retry; ``clock`` overrides ``time.time``
    for all timing (tests pass a FakeClock)."""
    from repro.faults.plan import (TRAIN_PREEMPT, TRAIN_STRAGGLER,
                                   advance_clock)

    _clock = clock if clock is not None else time.time
    if fault_plan is not None:
        batch_at = fault_plan.wrap_batch_at(batch_at)

    start = 0
    resumed_from = None
    if ckpt is not None and state_template is not None:
        restored = ckpt.restore_latest(state_template)
        if restored is not None:
            start, state, _ = restored
            resumed_from = start
    base_start = start
    step_fn = jax.jit(train_step, donate_argnums=(0,) if donate else ())
    monitor = StragglerMonitor()
    history: List[Dict] = []
    step_times: List[float] = []
    nonfinite_steps: List[int] = []
    consecutive_nonfinite = 0
    rollbacks_done = 0
    retries_spent = 0

    def fetch_sync(s: int):
        """Sync-mode ``batch_at`` with the same bounded-backoff retry the
        prefetcher applies in its worker.  Backoff passes through
        ``advance_clock`` so a FakeClock makes it instant and
        deterministic."""
        nonlocal retries_spent
        delay = data_backoff_s
        for attempt in range(data_retries + 1):
            try:
                return batch_at(s)
            except Exception:
                if attempt == data_retries:
                    raise
                retries_spent += 1
                if delay > 0:
                    advance_clock(_clock, delay)
                    delay *= 2

    def run_from(attempt_start: int, state: PyTree) -> PyTree:
        """One attempt: steps [attempt_start, num_steps) on the shared
        jitted step.  Raises :class:`_Diverged` when the consecutive-skip
        budget blows; the driver below rolls back and calls again."""
        nonlocal consecutive_nonfinite, retries_spent
        pf = None
        source = fetch_sync
        if prefetch > 0 and attempt_start < num_steps:
            pf = Prefetcher(batch_at, attempt_start, num_steps,
                            depth=prefetch, put=batch_put,
                            retries=data_retries, backoff_s=data_backoff_s)
            source = pf.get
        try:
            pending: List[tuple] = []    # (step, metrics) dispatched, uncommitted
            span_t0: Optional[float] = None
            span_start = attempt_start
            for step in range(attempt_start, num_steps):
                if preemption_hook is not None:
                    preemption_hook(step)    # may raise (simulated SIGTERM)
                preempted = preempt is not None and preempt.requested
                if fault_plan is not None and \
                        fault_plan.fire(TRAIN_PREEMPT, step) is not None:
                    preempted = True
                if preempted:
                    # state reflects completion through step-1: flush a
                    # checkpoint AT step so the rerun resumes right here.
                    if ckpt is not None:
                        ckpt.save(step, state)
                    raise PreemptedError(step, flushed=ckpt is not None)
                if span_t0 is None:
                    span_t0 = _clock()
                    span_start = step
                state, metrics = step_fn(state, source(step))
                if fault_plan is not None:
                    spec = fault_plan.fire(TRAIN_STRAGGLER, step)
                    if spec is not None:
                        advance_clock(_clock, float(spec.payload or 1.0))
                pending.append((step, metrics))
                # In sync mode every step is a span; async mode syncs only
                # on the first step (isolates compile), log/ckpt
                # boundaries, and the final step.
                sync = (prefetch == 0 or step == attempt_start
                        or step == num_steps - 1
                        or (log_every and step % log_every == 0)
                        or (ckpt is not None and (step + 1) % ckpt_every == 0)
                        or len(pending) >= max(max_span, 1))
                if sync:
                    jax.block_until_ready(jax.tree.leaves(state)[0])
                    per = (_clock() - span_t0) / (step - span_start + 1)
                    diverged_at = None
                    for s, m in pending:
                        step_times.append(per)
                        monitor.observe(s, per)
                        fm = {k: float(v) for k, v in m.items()}
                        history.append(fm)
                        if fm.get("nonfinite", 0.0) >= 0.5:
                            nonfinite_steps.append(s)
                            consecutive_nonfinite += 1
                            if consecutive_nonfinite > max_nonfinite and \
                                    diverged_at is None:
                                diverged_at = s
                        else:
                            consecutive_nonfinite = 0
                    pending.clear()
                    span_t0 = None
                    if diverged_at is not None:
                        raise _Diverged(diverged_at)
                    if log_every and step % log_every == 0:
                        print(f"step {step}: {history[-1]}", flush=True)
                if ckpt is not None and (step + 1) % ckpt_every == 0:
                    ckpt.save(step + 1, state)
            return state
        finally:
            if pf is not None:
                retries_spent += pf.retries_used
                pf.close()

    attempt_start = start
    while True:
        try:
            state = run_from(attempt_start, state)
            break
        except _Diverged as d:
            can_roll = (ckpt is not None and state_template is not None
                        and rollbacks_done < max_rollbacks)
            restored = ckpt.restore_latest(state_template) if can_roll else None
            if restored is None:
                raise DivergenceError(
                    f"{consecutive_nonfinite} consecutive non-finite steps "
                    f"(> max_nonfinite={max_nonfinite}) ending at step "
                    f"{d.step}; rollbacks used {rollbacks_done}/"
                    f"{max_rollbacks}" + (
                        "" if ckpt is not None and state_template is not None
                        else " and no checkpoint manager/template to roll "
                             "back with")) from None
            r, state, _ = restored
            rollbacks_done += 1
            consecutive_nonfinite = 0
            # rewind bookkeeping to the restore point; the replayed steps
            # re-commit their entries so the final result is contiguous.
            del history[r - base_start:]
            del step_times[r - base_start:]
            nonfinite_steps[:] = [s for s in nonfinite_steps if s < r]
            monitor.flagged[:] = [s for s in monitor.flagged if s < r]
            print(f"divergence at step {d.step}: rolled back to committed "
                  f"checkpoint at step {r} "
                  f"(rollback {rollbacks_done}/{max_rollbacks})", flush=True)
            attempt_start = r

    if ckpt is not None:
        ckpt.save(num_steps, state)
    return TrainResult(state=state, step=num_steps, metrics_history=history,
                       straggler_steps=monitor.flagged,
                       resumed_from=resumed_from, step_times=step_times,
                       nonfinite_steps=nonfinite_steps,
                       rollbacks=rollbacks_done, data_retries=retries_spent)
