"""Fault-tolerant training loop.

Design for 1000+ nodes (DESIGN.md §5):
  * the step is a pure jitted function of (state, batch); the data
    pipeline is a pure function of (config, step)  =>  restart from any
    committed checkpoint is bit-exact (tested by killing mid-run);
  * checkpoints are atomic + keep-N (repro.train.checkpoint);
  * a straggler monitor tracks per-step wall time EWMA and flags outliers
    (on a multi-host deployment the controller would re-slice around the
    slow host; here the signal is logged and surfaced in TrainResult);
  * preemption is injected via an optional hook for tests (the loop
    raises exactly as a SIGTERM handler would).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.train.checkpoint import CheckpointManager

PyTree = Any


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time tracker; flags steps slower than ratio x the EWMA."""

    alpha: float = 0.1
    ratio: float = 3.0
    ewma: Optional[float] = None
    flagged: List[int] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = dt > self.ratio * self.ewma
        if slow:
            self.flagged.append(step)
        # slow steps do not poison the EWMA
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * min(dt, self.ratio * self.ewma)
        return slow


@dataclasses.dataclass
class TrainResult:
    state: PyTree
    step: int
    metrics_history: List[Dict]
    straggler_steps: List[int]
    resumed_from: Optional[int]
    step_times: List[float] = dataclasses.field(default_factory=list)

    def throughput(self, items_per_step: int = 1, skip: int = 1) -> float:
        """items/sec over the run, excluding the first ``skip`` (compile)
        steps — the task-batched launcher reports tasks/sec with this."""
        times = self.step_times[skip:] or self.step_times
        if not times:
            return 0.0
        return items_per_step * len(times) / sum(times)


def train(state: PyTree,
          train_step: Callable,
          batch_at: Callable[[int], Dict],
          num_steps: int,
          *,
          ckpt: Optional[CheckpointManager] = None,
          ckpt_every: int = 50,
          state_template: Optional[PyTree] = None,
          preemption_hook: Optional[Callable[[int], None]] = None,
          log_every: int = 0) -> TrainResult:
    """Run (and resume) training.  ``batch_at(step)`` must be deterministic
    in ``step`` — together with checkpointed state that is what makes
    restarts exact."""
    start = 0
    resumed_from = None
    if ckpt is not None and state_template is not None:
        restored = ckpt.restore_latest(state_template)
        if restored is not None:
            start, state, _ = restored
            resumed_from = start
    step_fn = jax.jit(train_step)
    monitor = StragglerMonitor()
    history: List[Dict] = []
    step_times: List[float] = []

    for step in range(start, num_steps):
        if preemption_hook is not None:
            preemption_hook(step)        # may raise (simulated SIGTERM)
        t0 = time.time()
        state, metrics = step_fn(state, batch_at(step))
        jax.block_until_ready(jax.tree.leaves(state)[0])
        dt = time.time() - t0
        step_times.append(dt)
        monitor.observe(step, dt)
        if log_every and (step % log_every == 0):
            m = {k: float(v) for k, v in metrics.items()}
            print(f"step {step}: {m}", flush=True)
        history.append({k: float(v) for k, v in metrics.items()})
        if ckpt is not None and (step + 1) % ckpt_every == 0:
            ckpt.save(step + 1, state)

    if ckpt is not None:
        ckpt.save(num_steps, state)
    return TrainResult(state=state, step=num_steps, metrics_history=history,
                       straggler_steps=monitor.flagged,
                       resumed_from=resumed_from, step_times=step_times)
