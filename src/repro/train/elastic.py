"""Elastic scaling: rebuild the mesh for a changed device count and
reshard a checkpointed state onto the new topology.

Mesh builders are pure functions of device count, and checkpoints are
topology-free (plain host arrays), so elasticity reduces to:

    state_np  = gather(state)                  # topology-free
    new_mesh  = choose_mesh(len(live_devices))
    new_state = shard(state_np, new_specs(new_mesh))

The round-trip 8 -> 4 -> 8 devices is covered by tests/test_elastic.py.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

PyTree = Any


def choose_mesh_shape(n_devices: int, model_parallel: int = 1) -> Tuple[int, int]:
    """(data, model) for the live device count; model axis capped at the
    configured TP degree, remainder goes to data."""
    model = 1
    for cand in range(min(model_parallel, n_devices), 0, -1):
        if n_devices % cand == 0:
            model = cand
            break
    return n_devices // model, model


def gather_state(state: PyTree) -> PyTree:
    """Device state -> host numpy (topology-free)."""
    return jax.tree.map(lambda x: np.asarray(x), state)


def reshard(state_np: PyTree, specs: PyTree, mesh) -> PyTree:
    """Host state -> device state under a (new) mesh + spec tree."""
    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))
    return jax.tree.map(
        put, state_np, specs,
        is_leaf=lambda x: isinstance(x, np.ndarray))


def elastic_transition(state: PyTree, old_mesh, new_mesh, specs_for):
    """Full transition: gather off old topology, reshard to new.
    ``specs_for(mesh, abstract_state)`` returns the spec tree."""
    host = gather_state(state)
    abstract = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), host)
    specs = specs_for(new_mesh, abstract)
    return reshard(host, specs, new_mesh)
