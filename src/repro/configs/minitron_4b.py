"""minitron-4b — pruned Nemotron [arXiv:2407.14679].

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="transformer",
    n_layers=32,
    d_model=3072,
    d_ff=9216,
    vocab=256000,
    max_seq=131072,
    attention=AttentionConfig(kind="gqa", n_heads=24, n_kv_heads=8,
                              head_dim=128, rope_theta=10000.0),
    loss_chunk=512,
)

SMOKE = ModelConfig(
    name="minitron-smoke",
    family="transformer",
    n_layers=2, d_model=64, d_ff=192, vocab=256, max_seq=512,
    attention=AttentionConfig(kind="gqa", n_heads=8, n_kv_heads=2, head_dim=16),
    remat_policy="none",
)
