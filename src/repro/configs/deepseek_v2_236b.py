"""deepseek-v2-236b — MLA + MoE [arXiv:2405.04434].

60L d_model=5120 128H, MLA kv_lora=512 (q_lora=1536, qk_nope=128,
qk_rope=64, v_head=128), expert d_ff=1536, 2 shared + 160 routed top-6,
vocab=102400.
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="transformer",
    n_layers=60,
    d_model=5120,
    d_ff=12288,                      # dense d_ff (kept for record; layers are MoE)
    vocab=102400,
    max_seq=131072,
    attention=AttentionConfig(
        kind="mla", n_heads=128, n_kv_heads=128, head_dim=128,
        q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
        v_head_dim=128, rope_theta=10000.0),
    moe=MoEConfig(n_experts=160, top_k=6, n_shared=2, d_ff=1536,
                  capacity_factor=1.25),
    param_dtype="bfloat16",
    opt_state_dtype="bfloat16",
    loss_chunk=512,
)

SMOKE = ModelConfig(
    name="deepseek-v2-smoke",
    family="transformer",
    n_layers=2, d_model=64, d_ff=128, vocab=256, max_seq=512,
    attention=AttentionConfig(kind="mla", n_heads=4, n_kv_heads=4, head_dim=16,
                              q_lora_rank=32, kv_lora_rank=32, qk_nope_dim=16,
                              qk_rope_dim=8, v_head_dim=16),
    moe=MoEConfig(n_experts=8, top_k=2, n_shared=2, d_ff=64),
    remat_policy="none",
)
