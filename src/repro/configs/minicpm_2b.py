"""minicpm-2b — WSD schedule, llama-like with mup-style scaling
[arXiv:2404.06395].

40L d_model=2304 36H (MHA) d_ff=5760 vocab=122753.  Carries the paper's
scaling knobs: embed x12 (scale_emb), residual x(1.4/sqrt(40)), logits
x(1/(2304/256)).  The WSD LR schedule lives in repro.optim.schedules.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="transformer",
    n_layers=40,
    d_model=2304,
    d_ff=5760,
    vocab=122753,
    max_seq=131072,
    attention=AttentionConfig(kind="gqa", n_heads=36, n_kv_heads=36,
                              head_dim=64, rope_theta=10000.0),
    tie_embeddings=True,
    embed_scale=12.0,
    residual_scale=1.4 / (40 ** 0.5),
    logit_scale=256.0 / 2304.0,
    loss_chunk=512,
)

SMOKE = ModelConfig(
    name="minicpm-smoke",
    family="transformer",
    n_layers=2, d_model=64, d_ff=128, vocab=250, max_seq=512,
    attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=4, head_dim=16),
    tie_embeddings=True, embed_scale=12.0,
    residual_scale=1.4 / (2 ** 0.5), logit_scale=0.25,
    remat_policy="none",
)
