"""Assigned-architecture registry: id -> (full CONFIG, reduced SMOKE).

``--arch <id>`` everywhere (launcher, dry-run, benchmarks) resolves here.
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.configs import (
    deepseek_v2_236b,
    gemma2_2b,
    kimi_k2_1t_a32b,
    mamba2_780m,
    minicpm_2b,
    minitron_4b,
    phi_3_vision_4_2b,
    qwen2_72b,
    whisper_base,
    zamba2_7b,
)
from repro.configs.base import ModelConfig

_MODULES = {
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b,
    "deepseek-v2-236b": deepseek_v2_236b,
    "phi-3-vision-4.2b": phi_3_vision_4_2b,
    "mamba2-780m": mamba2_780m,
    "minicpm-2b": minicpm_2b,
    "minitron-4b": minitron_4b,
    "qwen2-72b": qwen2_72b,
    "gemma2-2b": gemma2_2b,
    "zamba2-7b": zamba2_7b,
    "whisper-base": whisper_base,
}

ARCH_IDS = tuple(_MODULES)

# Archs whose decode path is sub-quadratic in context (run long_500k).
LONG_CONTEXT_OK = ("mamba2-780m", "zamba2-7b")


def get_config(arch: str) -> ModelConfig:
    return _MODULES[arch].CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].SMOKE


def all_configs() -> Dict[str, Tuple[ModelConfig, ModelConfig]]:
    return {k: (m.CONFIG, m.SMOKE) for k, m in _MODULES.items()}


def cell_supported(arch: str, shape_name: str) -> Tuple[bool, str]:
    """Is (arch x shape) a runnable dry-run cell? Returns (ok, reason)."""
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_OK:
        return False, ("full-attention decode at 524288 ctx is O(S) mem / "
                       "O(S^2) aggregate — sub-quadratic archs only "
                       "(see DESIGN.md long_500k applicability)")
    return True, ""
