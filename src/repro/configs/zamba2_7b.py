"""zamba2-7b — Mamba2 trunk + shared attention blocks [arXiv:2411.15242].

81L d_model=3584 32H (MHA at the shared block) d_ff=14336 ssm_state=64.
Depth layout: 13 x (5 mamba + shared attn) + 3 tail mamba (=81 positions,
hybrid_attn_every=6).  Runs long_500k: mamba state is O(1); the 13 shared
KV caches are O(S) memory but O(S) — not O(S^2) — per decoded token.
"""
from repro.configs.base import AttentionConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    d_ff=14336,
    vocab=32000,
    max_seq=1 << 20,
    attention=AttentionConfig(kind="gqa", n_heads=32, n_kv_heads=32,
                              head_dim=112, rope_theta=10000.0),
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk_size=256),
    hybrid_attn_every=6,
    tie_embeddings=True,
    loss_chunk=512,
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    family="hybrid",
    n_layers=7, d_model=64, d_ff=128, vocab=256, max_seq=2048,
    attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=4, head_dim=16),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1,
                  chunk_size=32),
    hybrid_attn_every=3,
    tie_embeddings=True,
    remat_policy="none",
)
