"""qwen2-72b — GQA with QKV bias [arXiv:2407.10671].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="transformer",
    n_layers=80,
    d_model=8192,
    d_ff=29568,
    vocab=152064,
    max_seq=131072,
    attention=AttentionConfig(kind="gqa", n_heads=64, n_kv_heads=8,
                              head_dim=128, qkv_bias=True,
                              rope_theta=1000000.0),
    param_dtype="bfloat16",
    opt_state_dtype="bfloat16",
    loss_chunk=512,
)

SMOKE = ModelConfig(
    name="qwen2-smoke",
    family="transformer",
    n_layers=2, d_model=64, d_ff=224, vocab=256, max_seq=512,
    attention=AttentionConfig(kind="gqa", n_heads=8, n_kv_heads=2, head_dim=16,
                              qkv_bias=True),
    remat_policy="none",
)
