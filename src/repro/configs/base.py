"""Config dataclasses for every architecture family in the pool.

One frozen dataclass tree per model; configs are pure data (hashable,
jit-static-friendly).  The 10 assigned architectures each get a module in
this package exporting ``CONFIG``; ``repro.configs.registry`` maps ids to
them and to reduced smoke-test variants.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    """Attention sub-config. kind='gqa' covers MHA (n_kv_heads == n_heads)
    and GQA; kind='mla' is DeepSeek-style Multi-head Latent Attention."""

    kind: str = "gqa"                # "gqa" | "mla"
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 64
    qkv_bias: bool = False           # qwen2
    attn_softcap: Optional[float] = None  # gemma2: 50.0
    rope_theta: float = 10000.0
    # MLA-only fields (DeepSeek-V2):
    q_lora_rank: int = 0             # 0 -> dense q projection
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    n_shared: int = 0                # always-on shared experts
    d_ff: int = 2048                 # per-expert hidden width
    capacity_factor: float = 1.25
    router_softcap: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD block config."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2                  # d_inner = expand * d_model
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Unified model config covering all assigned families.

    family:
      'transformer'  decoder-only LM (dense or MoE FFN, GQA or MLA attn)
      'mamba2'       pure SSM LM
      'hybrid'       zamba2: mamba2 trunk + shared attention block
      'encdec'       whisper: transformer encoder-decoder
    """

    name: str = "model"
    family: str = "transformer"
    n_layers: int = 2
    d_model: int = 256
    d_ff: int = 1024                  # dense FFN hidden (per layer)
    vocab: int = 32000
    max_seq: int = 8192
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # gemma2-style alternating local/global attention. window applies to
    # every layer whose index % 2 == 0 when local_global=True.
    local_global: bool = False
    sliding_window: int = 4096
    final_softcap: Optional[float] = None   # gemma2: 30.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # minicpm-style mup-ish scaling knobs (1.0 = off)
    embed_scale: float = 1.0
    residual_scale: float = 1.0
    logit_scale: float = 1.0
    # zamba2: apply the shared attention block after every k-th mamba layer
    hybrid_attn_every: int = 6
    # whisper: encoder depth (decoder depth = n_layers)
    n_encoder_layers: int = 0
    frontend: Optional[str] = None    # None | 'vision_stub' | 'audio_stub'
    n_frontend_tokens: int = 256      # patch / frame count provided by stub
    # numerics & memory policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat_policy: str = "nothing"     # 'nothing'|'dots'|'none'
    # distribution policy
    shard_activations_model: bool = True   # 2D activation sharding (SP-like)
    loss_chunk: int = 0               # >0: chunked cross-entropy over seq
    # optimizer-state dtype policy ('float32'|'bfloat16'|'int8')
    opt_state_dtype: str = "float32"
    # --- §Perf hillclimb levers (EXPERIMENTS.md §Perf records each) -------
    # explicit expert-parallel shard_map MoE dispatch (vs GSPMD scatter)
    moe_shard_map: bool = True
    # head-aligned q/k/v sharding constraints (vs GSPMD head_dim splits)
    attn_head_constraints: bool = True
    # tensor parallelism at all (off => pure DP/FSDP; for tiny models the
    # model axis produces only overhead — whisper-base)
    tp_enabled: bool = True
    # residual-stream layout between blocks: 'seq' shards the SEQUENCE axis
    # over 'model' (Megatron-SP: norms local, bf16 AG/RS at block entry);
    # 'hidden' shards D over model (partial-sum all-reduces at dot grads)
    activation_layout: str = "hidden"

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up so embedding/logits shard evenly over the model
        axis (and MXU-align); true-vocab entries beyond ``vocab`` are never
        produced by the data pipeline and are masked from the loss."""
        return _round_up(self.vocab, 2048)


@dataclasses.dataclass(frozen=True)
class MetaTrainConfig:
    """Task-batched LITE meta-training knobs (repro.core.episodic_train).

    tasks_per_step: tasks whose gradients are averaged into ONE optimizer
      step (the batch-of-episodes axis; 1 reproduces paper Algorithm 1).
    dp_shards: data-parallel shards over the task axis within one host's
      ICI domain (shard_map 'data' axis); 1 = single-device vmap only.
    dcn_shards: outer host-level shards (the 'dcn' mesh axis of
      repro.launch.mesh.make_two_level_dp_mesh).  Each host differentiates
      its task slice and gradients reduce across hosts over DCN —
      'pmean' by default or error-feedback 'compressed' (grad_reduce).
    grad_reduce: cross-DCN gradient reduction mode: 'pmean' (exact) |
      'compressed' (int8 error-feedback compressed_psum from
      repro.optim.compress; residual carried in opt_state['ef']).
    accum_steps: sequential gradient-accumulation microbatches per
      optimizer step — each shard scans accum_steps chunks of its local
      tasks before the single cross-mesh reduction, so tasks_per_step can
      exceed per-host memory.
    Divisibility (tasks_per_step % (dp_shards * dcn_shards * accum_steps))
    and mode validity are checked HERE at construction time, not at trace
    time.
    lite_dtype: LiteSpec.compute_dtype for the no-grad complement pass
      (None = fp32; 'bfloat16' runs the dominant no-grad FLOPs in half
      precision with fp32 accumulation; gradients are unchanged).
    schedule: LR schedule name (None = constant ``lr``; 'cosine' | 'wsd',
      resolved by repro.optim.schedules.schedule_for with ``lr`` as peak
      over warmup_steps/total_steps).
    prefetch: background host->device batch lookahead depth for the train
      loop (0 = synchronous); donate: donate params/opt-state buffers to
      the jitted step so they update in place.
    kernel_backend: repro.kernels.dispatch backend for the episodic
      aggregation kernels (class segment sums, Simple CNAPs second
      moments, Mahalanobis head): 'ref' (default; fused jnp — the second
      moment is contracted without the per-example (B, F, F) outer
      tensor), 'pallas' (Pallas kernels; interpret off-TPU), 'auto'
      (pallas on TPU else ref), or 'naive' (the materializing legacy
      composite, bit-exact with the pre-dispatch code).  The episodic
      train-step adapter binds it at trace time.
    skip_nonfinite: arm the non-finite-update guard in the step — a
      NaN/inf gradient suppresses the optimizer update bit-exactly (a
      fused where-select; metrics['nonfinite'] reports it) instead of
      corrupting params; the fault-tolerant loop bounds how many
      consecutive skips count as divergence and rolls back.
    """

    tasks_per_step: int = 8
    dp_shards: int = 1
    dcn_shards: int = 1
    grad_reduce: str = "pmean"       # 'pmean' | 'compressed'
    accum_steps: int = 1
    lite_h: int = 8
    lite_chunk: Optional[int] = None
    lite_dtype: Optional[str] = None
    lr: float = 1e-3
    max_grad_norm: float = 10.0
    schedule: Optional[str] = None
    warmup_steps: int = 0
    total_steps: int = 0
    prefetch: int = 2
    donate: bool = True
    kernel_backend: str = "ref"
    skip_nonfinite: bool = True

    def __post_init__(self):
        # fail at CONFIG time, not at trace time deep inside shard_map
        if self.grad_reduce not in ("pmean", "compressed"):
            raise ValueError(
                f"grad_reduce={self.grad_reduce!r} (want 'pmean' or "
                f"'compressed')")
        if self.grad_reduce == "compressed" and self.dcn_shards < 2:
            raise ValueError(
                "grad_reduce='compressed' compresses CROSS-HOST traffic; "
                f"with dcn_shards={self.dcn_shards} there is none to "
                "compress and gradients would be quantized for a "
                "singleton reduction — set dcn_shards >= 2 (or keep "
                "grad_reduce='pmean')")
        for name in ("dp_shards", "dcn_shards", "accum_steps",
                     "tasks_per_step"):
            if getattr(self, name) < 1:
                raise ValueError(f"{name}={getattr(self, name)} must be >= 1")
        denom = self.dp_shards * self.dcn_shards * self.accum_steps
        if self.tasks_per_step % denom:
            raise ValueError(
                f"tasks_per_step={self.tasks_per_step} must be divisible by "
                f"dp_shards*dcn_shards*accum_steps = {self.dp_shards}*"
                f"{self.dcn_shards}*{self.accum_steps} = {denom} (every "
                f"shard scans accum_steps equal task chunks)")


# -- step shapes (assigned input-shape set for LM-family archs) -------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # 'train' | 'prefill' | 'decode'


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}
