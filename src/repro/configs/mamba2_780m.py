"""mamba2-780m — SSD state-space LM [arXiv:2405.21060].

48L d_model=1536 attn-free, ssm_state=128, vocab=50280.
d_inner = 2*1536 = 3072, head_dim 64 -> 48 SSD heads. Runs long_500k
(O(1) decode state).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="mamba2",
    n_layers=48,
    d_model=1536,
    d_ff=0,
    vocab=50280,
    max_seq=1 << 20,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk_size=256),
    tie_embeddings=True,
    loss_chunk=512,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="mamba2",
    n_layers=2, d_model=64, d_ff=0, vocab=256, max_seq=2048,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1,
                  chunk_size=32),
    tie_embeddings=True,
    remat_policy="none",
)
