"""phi-3-vision-4.2b — phi3-mini backbone + CLIP frontend stub
[hf:microsoft/Phi-3-vision-128k-instruct].

32L d_model=3072 32H (MHA) d_ff=8192 vocab=32064.  Vision frontend is a
STUB per the brief: ``input_specs`` provides precomputed patch embeddings
(B, 256, d_model) which the model prepends to the token embeddings.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="transformer",
    n_layers=32,
    d_model=3072,
    d_ff=8192,
    vocab=32064,
    max_seq=131072,
    attention=AttentionConfig(kind="gqa", n_heads=32, n_kv_heads=32,
                              head_dim=96, rope_theta=10000.0),
    frontend="vision_stub",
    n_frontend_tokens=256,
    loss_chunk=512,
)

SMOKE = ModelConfig(
    name="phi-3-vision-smoke",
    family="transformer",
    n_layers=2, d_model=64, d_ff=128, vocab=256, max_seq=512,
    attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=4, head_dim=16),
    frontend="vision_stub", n_frontend_tokens=8,
    remat_policy="none",
)
