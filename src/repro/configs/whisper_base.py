"""whisper-base — encoder-decoder audio backbone [arXiv:2212.04356].

6L (x2: encoder + decoder) d_model=512 8H (MHA) d_ff=2048 vocab=51865.
Audio (conv/mel) frontend is a STUB: input_specs provides precomputed
frame embeddings (B, S_enc, d_model).
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    n_encoder_layers=6,
    d_model=512,
    d_ff=2048,
    vocab=51865,
    max_seq=65536,
    attention=AttentionConfig(kind="gqa", n_heads=8, n_kv_heads=8,
                              head_dim=64, rope_theta=10000.0),
    frontend="audio_stub",
    n_frontend_tokens=1500,
    tie_embeddings=True,
    loss_chunk=512,
    # d_model=512 over a 16-way model axis is pure overhead — run DP/FSDP
    tp_enabled=False,
    shard_activations_model=False,
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="encdec",
    n_layers=2, n_encoder_layers=2, d_model=64, d_ff=128, vocab=256,
    max_seq=512,
    attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=4, head_dim=16),
    frontend="audio_stub", n_frontend_tokens=16,
    tie_embeddings=True,
    remat_policy="none",
)
