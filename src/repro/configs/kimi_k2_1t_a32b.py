"""kimi-k2-1t-a32b — Kimi K2 trillion-param MoE [arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840,
MoE 384 experts top-8 (+1 shared, per the K2 report).
Optimizer states int8-blockwise + bf16 params: at 1.03T params this is the
only Adam footprint (4 B/param) that approaches a 256-chip v5e pod;
EXPERIMENTS.md §Dry-run records the exact bytes and the 2-pod requirement.
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="transformer",
    n_layers=61,
    d_model=7168,
    d_ff=2048,                      # unused for MoE layers (kept for record)
    vocab=163840,
    max_seq=131072,
    attention=AttentionConfig(
        kind="gqa", n_heads=64, n_kv_heads=8, head_dim=128,
        rope_theta=50000.0),
    moe=MoEConfig(n_experts=384, top_k=8, n_shared=1, d_ff=2048,
                  capacity_factor=1.25),
    param_dtype="bfloat16",
    opt_state_dtype="int8",
    loss_chunk=512,
)

SMOKE = ModelConfig(
    name="kimi-k2-smoke",
    family="transformer",
    n_layers=2, d_model=64, d_ff=128, vocab=256, max_seq=512,
    attention=AttentionConfig(kind="gqa", n_heads=8, n_kv_heads=2, head_dim=16),
    moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, d_ff=64),
    remat_policy="none",
)
