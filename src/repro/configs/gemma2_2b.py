"""gemma2-2b — local+global alternating attention with logit softcaps
[arXiv:2408.00118].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
Even layers: sliding-window 4096; odd layers: global.  Attention softcap
50.0; final-logit softcap 30.0; tied embeddings scaled by sqrt(d_model).
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="transformer",
    n_layers=26,
    d_model=2304,
    d_ff=9216,
    vocab=256000,
    max_seq=131072,
    attention=AttentionConfig(kind="gqa", n_heads=8, n_kv_heads=4,
                              head_dim=256, attn_softcap=50.0,
                              rope_theta=10000.0),
    local_global=True,
    sliding_window=4096,
    final_softcap=30.0,
    tie_embeddings=True,
    embed_scale=2304.0 ** 0.5,
    loss_chunk=512,
)

SMOKE = ModelConfig(
    name="gemma2-smoke",
    family="transformer",
    n_layers=2, d_model=64, d_ff=128, vocab=256, max_seq=512,
    attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=2, head_dim=16,
                              attn_softcap=50.0),
    local_global=True, sliding_window=32, final_softcap=30.0,
    tie_embeddings=True, embed_scale=8.0,
    remat_policy="none",
)
