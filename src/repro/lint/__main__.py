"""CLI: ``python -m repro.lint`` / the ``repro-lint`` entry point.

The default run is the pure-AST scan (no jax import, sub-second).
``--contracts`` additionally runs the compiled-HLO contract cells; those
need a 4-device platform, so the CLI re-execs itself in a subprocess
with ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (guarded by
``REPRO_LINT_CONTRACTS_WORKER`` so the worker doesn't recurse).
"""
from __future__ import annotations

import argparse
import os
import pathlib
import subprocess
import sys
from typing import List

from repro.lint import engine, rules

_WORKER_ENV = "REPRO_LINT_CONTRACTS_WORKER"


def _list_rules() -> None:
    for r in rules.ALL_RULES:
        print(f"{r.name}")
        print(f"    invariant:  {r.invariant}")
        print(f"    recurrence: {r.recurrence}")


def _run_contracts(cells: List[str], as_json: bool) -> int:
    """Re-exec into a 4-device worker (or run directly if we are it)."""
    if os.environ.get(_WORKER_ENV) == "1":
        from repro.lint import contracts
        findings = contracts.run_cells(cells or None)
        return _emit(findings, as_json)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4").strip()
    env[_WORKER_ENV] = "1"
    env.setdefault("PYTHONPATH", str(engine.repo_root() / "src"))
    cmd = [sys.executable, "-m", "repro.lint", "--contracts", "--no-ast"]
    if as_json:
        cmd.append("--json")
    for c in cells:
        cmd += ["--cells", c]
    return subprocess.run(cmd, env=env).returncode


def _emit(findings, as_json: bool) -> int:
    if as_json:
        print(engine.findings_json(findings))
    else:
        for f in findings:
            print(f.format())
        if findings:
            print(f"{len(findings)} finding(s)", file=sys.stderr)
    return 1 if findings else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="repo-native static analysis: AST rules + compiled-HLO "
                    "contracts (see repro.lint.__doc__ for the catalog)")
    ap.add_argument("paths", nargs="*", type=pathlib.Path,
                    help="files/dirs to scan (default: src/ and tests/)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as JSON")
    ap.add_argument("--rules", action="append", default=[],
                    metavar="RULE", help="run only these rule ids")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--contracts", action="store_true",
                    help="also run the compiled-HLO contract cells "
                         "(spawns a 4-device worker)")
    ap.add_argument("--cells", action="append", default=[], metavar="CELL",
                    help="restrict --contracts to these cell names")
    ap.add_argument("--no-ast", action="store_true",
                    help="skip the AST scan (contracts only)")
    args = ap.parse_args(argv)

    if args.list_rules:
        _list_rules()
        return 0

    rc = 0
    if not args.no_ast:
        active = list(rules.ALL_RULES)
        if args.rules:
            unknown = set(args.rules) - set(rules.RULES_BY_NAME)
            if unknown:
                ap.error(f"unknown rule(s): {sorted(unknown)} — "
                         f"see --list-rules")
            active = [rules.RULES_BY_NAME[r] for r in args.rules]
        root = engine.repo_root()
        targets = args.paths or engine.default_targets(root)
        findings = engine.lint_paths(targets, root, active)
        rc = _emit(findings, args.as_json)

    if args.contracts:
        rc = max(rc, _run_contracts(args.cells, args.as_json))
    return rc


if __name__ == "__main__":
    sys.exit(main())
