"""Layer 2: structural contracts on compiled programs.

The AST rules catch source-level drift; these contracts catch the
failures that only exist after SPMD partitioning — a collective that
silently spans two replica groups, a layout whose wire bytes regressed
past what the checked-in benchmarks measured, a bucketed engine that
recompiles per request, a lite/chunked learner that materializes a
per-example outer-product tensor, an int8 serving path that keeps a
persistent fp32 copy of the frozen slice.  Each contract cell builds the
same miniature program an existing measured benchmark/test builds
(so the checked-in CSV numbers are directly comparable), compiles it for
real, and checks the post-SPMD HLO via :mod:`repro.roofline.hlo`.

Cells (4 emulated devices — the CLI re-execs with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``):

``replica_2x2``    weight-stationary predict on one replica group of
                   ``make_replica_mesh(2, 2)``: no collective group wider
                   than the replica's 2 devices, and per-step wire within
                   1.5x of ``benchmarks/results/serve_throughput.csv``'s
                   ``engine_replicas2_none`` row.
``int8_ws``        the int8 weight-stationary layout cell from
                   tests/test_quant_serving: ws wire strictly below the
                   training layout and within 1.5x of
                   ``serve_layouts.csv``'s measured row; the frozen slice
                   stays int8 — s8 entry parameters in the compiled
                   predict, int8 host tree, and measured frozen resident
                   bytes at least 3x below their fp32 equivalent.
``compile_flat``   a two-bucket ragged EpisodicServeEngine drained over
                   two request waves: ``adapt_compiles == len(buckets)``
                   and ``predict_compiles == 1`` — compile count must be
                   a function of the bucket plan, never the traffic.
``lite_outer``     simple_cnaps ``adapt_batch`` under a LiteSpec: no live
                   floating tensor shaped ``(..., F, F)`` with more than
                   tasks*way leading elements — the per-example
                   outer-product blowup LITE exists to avoid (the legit
                   per-class covariance is exactly ``(tasks, way, F, F)``).

The pure ``check_*`` helpers take data (HLO text / reports / stats), so
tests exercise pass AND fail paths without recompiling; the ``cell_*``
functions build the programs and need jax + 4 devices.
"""
from __future__ import annotations

import csv
import math
import pathlib
from typing import Dict, Iterable, List, Optional, Sequence

from repro.lint.engine import Finding, repo_root

#: budget slack over the checked-in measured numbers — generous enough to
#: absorb XLA version noise, tight enough that a layout regression (e.g.
#: weights gathered per step) blows straight through it
SLACK = 1.5

RESULTS = ("benchmarks", "results")


# ---------------------------------------------------------------- budgets

def _csv_rows(name: str) -> List[Dict[str, str]]:
    path = repo_root().joinpath(*RESULTS, name)
    with open(path, newline="") as f:
        return list(csv.DictReader(f))


def serve_layout_budgets(regime: str = "serve_small") -> Dict[str, float]:
    """layout -> measured wire_bytes from serve_layouts.csv."""
    return {r["layout"]: float(r["wire_bytes"])
            for r in _csv_rows("serve_layouts.csv") if r["regime"] == regime}


def replica_wire_budget(mode: str = "engine_replicas2_none") -> float:
    """One replica's measured per-step predict wire from
    serve_throughput.csv."""
    for r in _csv_rows("serve_throughput.csv"):
        if r["mode"] == mode:
            return float(r["wire_per_replica_bytes"])
    raise KeyError(f"no row {mode!r} in serve_throughput.csv")


# ---------------------------------------------------------- pure checks

def check_inter_group(per_kind: Dict[str, Dict[str, float]],
                      group_size: int) -> List[str]:
    """No collective may span more devices than one replica group: a
    wider group means the 'disjoint replicas' claim is structurally
    false in the compiled program."""
    out = []
    for kind, rec in per_kind.items():
        if rec.get("max_group", 1) > group_size:
            out.append(
                f"{kind} spans {int(rec['max_group'])} devices but the "
                f"replica group is {group_size} wide — an inter-group "
                f"collective breaks replica isolation (weights/state "
                f"would move across groups)")
    return out


def check_wire_budget(wire_bytes: float, budget: float,
                      label: str, slack: float = SLACK) -> List[str]:
    if wire_bytes > slack * budget:
        return [f"{label}: per-step wire {wire_bytes:.0f}B exceeds "
                f"{slack}x the checked-in budget {budget:.0f}B — the "
                f"layout regressed (re-measure and re-commit the CSV if "
                f"intentional)"]
    return []


def check_compile_flat(stats: Dict, n_buckets: int) -> List[str]:
    """Compile counters must track the bucket plan, not the traffic."""
    out = []
    if stats["adapt_compiles"] != n_buckets:
        out.append(
            f"adapt_compiles={stats['adapt_compiles']} after draining "
            f"{n_buckets} bucket(s) of ragged traffic — expected exactly "
            f"{n_buckets}: one compile per planned bucket, flat across "
            f"request waves")
    if stats["predict_compiles"] != 1:
        out.append(
            f"predict_compiles={stats['predict_compiles']} — the chunked "
            f"query dispatch must compile once (chunks are padded to one "
            f"shape; task state is bucket-independent)")
    return out


_FLOAT_DTYPES = ("f64", "f32", "bf16", "f16")


def find_outer_tensors(hlo_text: str, feature_dim: int,
                       max_leading: int) -> List[str]:
    """Live floating tensors shaped ``(..., F, F)`` with more than
    ``max_leading`` leading elements, in materializing (non-fusion)
    computations.  ``max_leading = tasks * way`` admits the legit
    per-class covariance and rejects any per-example expansion."""
    from repro.roofline import hlo as hlo_mod
    comps, calls, fusion_children, _, _, _ = hlo_mod._parse(hlo_text)
    out = []
    seen = set()
    for comp, instrs in comps.items():
        if comp in fusion_children:
            continue        # fusion internals never materialize
        for ins in instrs:
            for dtype, dims in ins.result_shapes:
                if dtype not in _FLOAT_DTYPES or not dims:
                    continue
                d = [int(x) for x in dims.split(",")]
                if len(d) < 3 or d[-1] != feature_dim or d[-2] != feature_dim:
                    continue
                lead = math.prod(d[:-2])
                if lead > max_leading:
                    key = (dtype, dims)
                    if key in seen:
                        continue
                    seen.add(key)
                    out.append(
                        f"live {dtype}[{dims}] ({lead} x {feature_dim}x"
                        f"{feature_dim} outer blocks; per-class budget is "
                        f"{max_leading}) — a per-example outer-product "
                        f"tensor escaped the LITE chunking")
    return out


def entry_param_dtypes(hlo_text: str) -> List[str]:
    """Dtypes of the entry computation's parameters (what is RESIDENT
    between steps, as opposed to fused temporaries)."""
    from repro.roofline import hlo as hlo_mod
    comps, calls, _, _, _, _ = hlo_mod._parse(hlo_text)
    called = set()
    for cs in calls.values():
        called |= cs
    dtypes = []
    for comp, instrs in comps.items():
        if comp in called:
            continue
        for ins in instrs:
            if ins.opcode == "parameter":
                dtypes.extend(d for d, _ in ins.result_shapes)
    return dtypes


def check_int8_residency(hlo_text: str, sw, bytes_report: Dict) -> List[str]:
    """The int8 frozen slice must be resident AS int8: s8 entry params in
    the compiled predict, int8 leaves in the host tree, and measured
    frozen bytes >= 3x below fp32 — together these rule out a persistent
    fp32 copy (eager dequantization outside the jitted step)."""
    import jax.numpy as jnp

    out = []
    if not sw.quant_paths:
        return ["serving weights carry no quantized paths — the int8 "
                "cell was built without quantize_frozen(mode='int8')"]
    if "s8" not in entry_param_dtypes(hlo_text):
        out.append(
            "no s8 parameter reaches the compiled predict's entry "
            "computation — the program consumes an already-dequantized "
            "(persistent fp32) copy of the frozen slice")
    from repro.serve.quant_params import is_quantized_leaf
    import jax
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            sw.tree, is_leaf=is_quantized_leaf)[0]:
        if is_quantized_leaf(leaf) and leaf["q"].dtype != jnp.int8:
            out.append(f"quantized leaf {path} stores q as "
                       f"{leaf['q'].dtype}, not int8")
            break
    froz, froz32 = (bytes_report["frozen_resident_bytes"],
                    bytes_report["frozen_fp32_bytes"])
    if froz * 3 > froz32:
        out.append(
            f"frozen slice resident bytes {froz} not >=3x below fp32 "
            f"equivalent {froz32} — an fp32 copy of the frozen slice is "
            f"persisting alongside the int8 one")
    return out


# ------------------------------------------------------------- the cells

def _require_devices(n: int = 4) -> None:
    import jax
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"contract cells need {n} devices "
            f"(run via `python -m repro.lint --contracts`, which re-execs "
            f"with XLA_FLAGS=--xla_force_host_platform_device_count={n}); "
            f"got {len(jax.devices())}")


def _compile_predict(learner, sw, states, query_x, mesh, layout: str):
    """Compile the engine's predict dispatch under a named serving layout
    — same construction as roofline.analysis.score_serving_layout, but
    returning the HLO text so several contracts share one compile."""
    import jax

    from repro.roofline.analysis import batch_shardings, serving_shardings
    from repro.serve.quant_params import dequantize_params

    def predict(w, st, qx):
        return learner.predict_batch(dequantize_params(w), st, qx)

    in_sh = (serving_shardings(sw, mesh, layout),
             batch_shardings(states, mesh, layout),
             batch_shardings(query_x, mesh, layout))
    compiled = jax.jit(predict, in_shardings=in_sh).lower(
        sw, states, query_x).compile()
    return compiled.as_text()


def cell_replica_2x2() -> List[str]:
    """One group of make_replica_mesh(2, 2): intra-group-only collectives
    + wire budget (mirrors benchmarks/serve_throughput.py's replica rows)."""
    _require_devices(4)
    import jax
    import jax.numpy as jnp

    from repro.core.episodic_train import task_key
    from repro.core.lite import LiteSpec
    from repro.core.meta_learners import MetaLearnerConfig, make_learner
    from repro.core.set_encoder import SetEncoderConfig
    from repro.data.episodic import (EpisodicImageConfig, collate_task_batch,
                                     plan_buckets, sample_image_task)
    from repro.launch.mesh import make_replica_mesh
    from repro.models.conv_backbone import (ConvBackboneConfig,
                                            make_conv_backbone)
    from repro.roofline.hlo import collectives_report
    from repro.serve.quant_params import dequantize_params, quantize_frozen

    way, shot, query, image = 5, 4, 4, 12
    learner = make_learner(
        MetaLearnerConfig(kind="protonets", way=way),
        make_conv_backbone(ConvBackboneConfig(widths=(8,), feature_dim=16)),
        SetEncoderConfig(kind="conv", conv_blocks=1, conv_width=8,
                         task_dim=16))
    params = learner.init(jax.random.key(0))
    lite = LiteSpec(exact=True, chunk_size=32)
    cfg = EpisodicImageConfig(way=way, shot=shot, query_per_class=query,
                              image_size=image)
    buckets = plan_buckets([way * shot], max_buckets=1)
    probe = [sample_image_task(jax.random.key(i), cfg) for i in range(2)]
    pbatch = collate_task_batch(probe, support_size=max(buckets),
                                query_size=probe[0].query_x.shape[0])
    pkeys = jax.vmap(lambda i: task_key(jax.random.key(0), i))(jnp.arange(2))

    meshes = make_replica_mesh(2, 2)
    sw = quantize_frozen(learner, params, "none")
    states = learner.adapt_batch(dequantize_params(sw), pbatch, pkeys, lite)
    text = _compile_predict(learner, sw, states, pbatch.query_x,
                            meshes[0], "weight_stationary")
    rep = collectives_report(text)
    msgs = check_inter_group(rep["per_kind"], group_size=2)
    msgs += check_wire_budget(rep["total_wire_bytes"], replica_wire_budget(),
                              "replica_2x2 weight_stationary predict")
    return msgs


def cell_int8_ws() -> List[str]:
    """The int8 weight-stationary layout cell (mirrors
    tests/test_quant_serving's measured setup): wire strictly below the
    training layout and within budget, frozen slice resident as int8."""
    _require_devices(4)
    import jax
    import jax.numpy as jnp

    from repro.core.episodic_train import task_key
    from repro.core.lite import LiteSpec
    from repro.core.meta_learners import MetaLearnerConfig, make_learner
    from repro.core.set_encoder import SetEncoderConfig
    from repro.data.episodic import (EpisodicImageConfig, collate_task_batch,
                                     sample_image_task)
    from repro.models.conv_backbone import (ConvBackboneConfig,
                                            make_conv_backbone)
    from repro.roofline.hlo import collectives_report
    from repro.serve.quant_params import (dequantize_params, param_bytes,
                                          quantize_frozen)

    learner = make_learner(
        MetaLearnerConfig(kind="protonets", way=3),
        make_conv_backbone(ConvBackboneConfig(widths=(16, 32),
                                              feature_dim=64)),
        SetEncoderConfig(kind="conv", conv_blocks=2, conv_width=16,
                        task_dim=32))
    params = learner.init(jax.random.key(0))
    sw = quantize_frozen(learner, params, "int8")
    mesh = jax.make_mesh((4,), ("serve",))
    tasks = [sample_image_task(
        jax.random.key(100 + i),
        EpisodicImageConfig(way=3, shot=5, query_per_class=4, image_size=8))
        for i in range(2)]
    batch = collate_task_batch(tasks, support_size=16, query_size=12)
    keys = jax.vmap(lambda i: task_key(jax.random.key(0), i))(jnp.arange(2))
    lite = LiteSpec(exact=True, chunk_size=8)
    states = learner.adapt_batch(dequantize_params(sw), batch, keys, lite)

    ws_text = _compile_predict(learner, sw, states, batch.query_x,
                               mesh, "weight_stationary")
    tr_text = _compile_predict(learner, sw, states, batch.query_x,
                               mesh, "training")
    ws = collectives_report(ws_text)["total_wire_bytes"]
    tr = collectives_report(tr_text)["total_wire_bytes"]

    budgets = serve_layout_budgets("serve_small")
    msgs = check_wire_budget(ws, budgets["weight_stationary"],
                             "int8_ws weight_stationary predict")
    if not ws < tr:
        msgs.append(
            f"weight_stationary wire {ws:.0f}B is not strictly below the "
            f"training layout's {tr:.0f}B at serving batch sizes — the "
            f"layout's reason to exist (ship activations, not gathered "
            f"weights) no longer holds")
    msgs += check_int8_residency(ws_text, sw, param_bytes(sw))
    return msgs


def cell_compile_flat() -> List[str]:
    """Two-bucket ragged engine, two waves of fresh uids: compile
    counters must equal (len(buckets), 1) and stay flat across waves."""
    import numpy as np
    import jax

    from repro.core.lite import LiteSpec
    from repro.core.meta_learners import MetaLearnerConfig, make_learner
    from repro.core.set_encoder import SetEncoderConfig
    from repro.data.episodic import (EpisodicImageConfig, plan_buckets,
                                     sample_image_task)
    from repro.models.conv_backbone import (ConvBackboneConfig,
                                            make_conv_backbone)
    from repro.serve.episodic import EpisodicRequest, EpisodicServeEngine

    way = 3
    learner = make_learner(
        MetaLearnerConfig(kind="protonets", way=way),
        make_conv_backbone(ConvBackboneConfig(widths=(8,), feature_dim=16)),
        SetEncoderConfig(kind="conv", conv_blocks=1, conv_width=8,
                         task_dim=16))
    params = learner.init(jax.random.key(0))
    shots = (2, 5)                               # ragged: supports 6 and 15
    buckets = plan_buckets([way * s for s in shots], max_buckets=2)
    engine = EpisodicServeEngine(
        learner, params, lite=LiteSpec(exact=True, chunk_size=8),
        n_slots=1, query_chunk=8, support_buckets=buckets,
        cache_capacity=16)

    uid = 0
    for _wave in range(2):
        for shot in shots:
            cfg = EpisodicImageConfig(way=way, shot=shot, query_per_class=4,
                                      image_size=8)
            t = sample_image_task(jax.random.key(uid), cfg)
            engine.submit(EpisodicRequest(
                uid=uid, support_x=np.asarray(t.support_x),
                support_y=np.asarray(t.support_y),
                query_x=np.asarray(t.query_x), way=way))
            uid += 1
        while engine.busy:
            engine.step()
    return check_compile_flat(engine.stats(), n_buckets=len(buckets))


def cell_lite_outer() -> List[str]:
    """simple_cnaps adapt under LITE: the compiled program may hold the
    per-class (tasks, way, F, F) covariance but nothing wider."""
    import jax
    import jax.numpy as jnp

    from repro.core.episodic_train import task_key
    from repro.core.lite import LiteSpec
    from repro.core.meta_learners import MetaLearnerConfig, make_learner
    from repro.core.set_encoder import SetEncoderConfig
    from repro.data.episodic import (EpisodicImageConfig, collate_task_batch,
                                     sample_image_task)
    from repro.models.conv_backbone import (ConvBackboneConfig,
                                            make_conv_backbone)

    way, tasks, feature_dim = 3, 2, 16
    learner = make_learner(
        MetaLearnerConfig(kind="simple_cnaps", way=way),
        make_conv_backbone(ConvBackboneConfig(widths=(8,),
                                              feature_dim=feature_dim)),
        SetEncoderConfig(kind="conv", conv_blocks=1, conv_width=8,
                         task_dim=16))
    params = learner.init(jax.random.key(0))
    ts = [sample_image_task(
        jax.random.key(10 + i),
        EpisodicImageConfig(way=way, shot=5, query_per_class=4, image_size=8))
        for i in range(tasks)]
    batch = collate_task_batch(ts, support_size=16, query_size=12)
    keys = jax.vmap(lambda i: task_key(jax.random.key(0), i))(
        jnp.arange(tasks))
    lite = LiteSpec(exact=True, chunk_size=8)

    text = jax.jit(
        lambda p, b, k: learner.adapt_batch(p, b, k, lite)).lower(
        params, batch, keys).compile().as_text()
    # budget: per-class blocks times 2 — XLA materializes the
    # lam-weighted covariance pair (class + task) as one stacked
    # (tasks, 2, way, F, F) tensor before the sum; any per-example
    # expansion is >= shot x wider and still lands over budget
    return find_outer_tensors(text, feature_dim, max_leading=2 * tasks * way)


CELLS = {
    "replica_2x2": cell_replica_2x2,
    "int8_ws": cell_int8_ws,
    "compile_flat": cell_compile_flat,
    "lite_outer": cell_lite_outer,
}

_CELL_RULES = {
    "replica_2x2": "contract-replica",
    "int8_ws": "contract-int8",
    "compile_flat": "contract-compile-flat",
    "lite_outer": "contract-lite-outer",
}


def run_cells(names: Optional[Sequence[str]] = None) -> List[Finding]:
    names = list(names) if names else list(CELLS)
    unknown = set(names) - set(CELLS)
    if unknown:
        raise KeyError(f"unknown contract cell(s) {sorted(unknown)}; "
                       f"known: {sorted(CELLS)}")
    findings: List[Finding] = []
    for name in names:
        for msg in CELLS[name]():
            findings.append(Finding(path=f"contracts/{name}", line=0,
                                    rule=_CELL_RULES[name], message=msg))
    return findings
