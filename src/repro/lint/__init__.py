"""repro.lint — the repo-native static-analysis pass.

Two layers, one CLI::

    PYTHONPATH=src python -m repro.lint                  # AST scan (src/ + tests/)
    PYTHONPATH=src python -m repro.lint --json           # machine-readable findings
    PYTHONPATH=src python -m repro.lint path/to/file.py  # scoped scan
    PYTHONPATH=src python -m repro.lint --contracts      # + compiled-HLO contracts
    repro-lint                                           # console entry point

Exit status is nonzero iff findings survive; each finding prints as
``path:line: rule-id: message``.

Layer 1 — the AST rule engine (:mod:`repro.lint.engine` +
:mod:`repro.lint.rules`).  Stdlib-``ast`` only, no jax import, scans the
repo in well under a second.  The rule catalog — every rule encodes an
invariant this codebase already broke once:

=====================  ==================================================
``jax-api-drift``      shard_map / pallas CompilerParams only via the
                       repo shims (``repro.sharding``,
                       ``repro.kernels.tpu_compat``) — upstream renames
                       land in one file, not every call site
``raw-cost-analysis``  ``compiled.cost_analysis()`` only through
                       ``repro.roofline.hlo.xla_cost_analysis`` — the
                       dict/list/None drift is normalized exactly once
``clock-discipline``   serve/train/faults/launch code takes an injectable
                       ``clock`` parameter; bare ``time.time()`` /
                       ``time.monotonic()`` / ``time.sleep()`` CALLS are
                       findings (referencing ``time.monotonic`` as a
                       default is the contract, not a violation)
``atomic-publish``     durable writes under serve/ and the checkpointer
                       go tmp-then-``os.replace``; in-place ``open('wb')``
                       / ``write_text`` on a non-tmp path is a finding
``fault-site-registry``  fault sites at ``fire()`` / ``FaultSpec`` /
                       ``FaultPlan.single``/``seeded`` call sites must be
                       the ``repro.faults.plan`` constants — raw string
                       literals drift from the validated registry
``seeded-rng``         only explicitly seeded ``np.random.default_rng``
                       Generators in library code; legacy global
                       ``np.random.*`` calls and unseeded
                       ``default_rng()`` are findings
``static-aux-hashable``  pytree aux_data in ``register_pytree_node``
                       flatteners must be hashable — list/dict/set
                       displays there break the jit trace cache
=====================  ==================================================

Suppression pragma — inline, audited, reason mandatory::

    do_thing()  # lint: allow(clock-discipline): launcher wall-clock path

A standalone pragma comment (optionally continued over a comment block)
covers the next code line.  ``allow(...)`` without a reason is itself a
finding (``lint-pragma``).

Layer 2 — the compiled-program contract checker
(:mod:`repro.lint.contracts`).  Structural contracts on the actual
post-SPMD HLO of the serving/training cells (reusing
``repro.roofline.hlo``): no inter-replica-group collectives, wire-byte
budgets pinned against the checked-in benchmark CSVs, compile-counter
flatness across warm bucketed steps, no live per-example ``(B*Q, F, F)``
outer-product tensor, and no persistent fp32 copy of the int8 frozen
slice.  Needs jax and a 4-device (emulated) platform, so the CLI re-execs
itself in a subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
— the plain AST scan never imports jax.
"""
from repro.lint.engine import (Finding, LintContext, Rule, default_targets,
                               findings_json, lint_file, lint_paths,
                               lint_source, repo_root)
from repro.lint.rules import ALL_RULES, RULES_BY_NAME

__all__ = [
    "Finding", "LintContext", "Rule", "ALL_RULES", "RULES_BY_NAME",
    "default_targets", "findings_json", "lint_file", "lint_paths",
    "lint_source", "repo_root",
]
