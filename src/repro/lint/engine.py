"""AST rule engine for the repo-native linter.

Deliberately dependency-free (stdlib ``ast`` only — importing this module
must never import jax): the tier-1 smoke invocation scans the whole repo
in well under a second.  The engine owns the mechanics — walking files,
parsing, pragma suppression, finding aggregation — while the rules
themselves (what is actually checked) live in :mod:`repro.lint.rules`.

Pragma contract (``# lint: allow(<rule>): <reason>``):

* a trailing pragma suppresses findings of ``<rule>`` on its own line;
* a standalone comment-line pragma also suppresses the line below it
  (so multi-clause statements can carry a pragma without exceeding the
  line length);
* the reason is MANDATORY — an allow without one is itself a finding
  (rule id ``lint-pragma``), because a suppression nobody can audit is
  how invariants rot.

Findings are ``path:line: rule-id: message`` (paths repo-relative), and
the CLI (``python -m repro.lint``) exits nonzero when any survive.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set

BAD_PRAGMA_RULE = "lint-pragma"

# trailing or standalone:  # lint: allow(rule-id): reason
_PRAGMA_RE = re.compile(r"#\s*lint:\s*allow\(([\w-]+)\)\s*(?::\s*(\S.*))?")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at a source location."""

    path: str           # repo-relative posix path
    line: int
    rule: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule}: {self.message}"

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


class LintContext:
    """Everything a rule sees for one file: the parsed tree, the raw
    source (for ``ast.get_source_segment``), and the repo-relative path
    (rules scope themselves on it)."""

    def __init__(self, rel: str, src: str, tree: ast.AST):
        self.rel = rel
        self.src = src
        self.tree = tree

    def segment(self, node: ast.AST) -> str:
        return ast.get_source_segment(self.src, node) or ""

    def finding(self, node_or_line, rule: str, message: str) -> Finding:
        line = (node_or_line if isinstance(node_or_line, int)
                else getattr(node_or_line, "lineno", 0))
        return Finding(path=self.rel, line=line, rule=rule, message=message)


class Rule:
    """One invariant.  Subclasses set ``name`` (the pragma / CLI id),
    ``invariant`` (what must hold) and ``recurrence`` (the bug class it
    prevents — both strings feed the ``--list-rules`` catalog), override
    ``applies(rel)`` to scope themselves, and implement ``check(ctx)``."""

    name: str = ""
    invariant: str = ""
    recurrence: str = ""

    def applies(self, rel: str) -> bool:
        return True

    def check(self, ctx: LintContext) -> Iterable[Finding]:  # pragma: no cover
        raise NotImplementedError


def _pragmas(src: str):
    """Maps line -> {rule, ...} of allows, plus bad-pragma findings-to-be
    as (line, message) pairs."""
    allowed: Dict[int, Set[str]] = {}
    bad: List[tuple] = []
    for i, line in enumerate(src.splitlines(), 1):
        m = _PRAGMA_RE.search(line)
        if not m:
            continue
        rule, reason = m.group(1), m.group(2)
        if not reason:
            bad.append((i, f"allow({rule}) pragma without a reason — "
                           f"write '# lint: allow({rule}): <why>' so the "
                           f"suppression can be audited"))
            continue
        allowed.setdefault(i, set()).add(rule)
        if line.lstrip().startswith("#"):
            # standalone comment: covers the line it annotates (below);
            # chains of comment lines extend coverage to the statement
            allowed.setdefault(i + 1, set()).add(rule)
    # extend standalone-comment coverage through comment blocks
    for i in sorted(allowed):
        j = i
        lines = src.splitlines()
        while j <= len(lines) and j - 1 < len(lines) and \
                lines[j - 1].lstrip().startswith("#"):
            allowed.setdefault(j + 1, set()).update(allowed[i])
            j += 1
    return allowed, bad


def lint_source(src: str, rel: str, rules: Sequence[Rule]) -> List[Finding]:
    """Lint one in-memory source blob as if it lived at repo-relative
    ``rel`` (rule scoping applies) — the fixture-test workhorse and the
    single code path ``lint_file`` wraps."""
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Finding(path=rel, line=e.lineno or 0, rule="syntax-error",
                        message=f"file does not parse: {e.msg}")]
    ctx = LintContext(rel, src, tree)
    allowed, bad = _pragmas(src)
    out: List[Finding] = [
        ctx.finding(line, BAD_PRAGMA_RULE, msg) for line, msg in bad]
    for rule in rules:
        if not rule.applies(rel):
            continue
        for f in rule.check(ctx):
            if f.rule in allowed.get(f.line, ()):
                continue
            out.append(f)
    return sorted(out)


def _rel_path(path: pathlib.Path, root: pathlib.Path) -> str:
    """Repo-relative path for rule scoping.  A path outside ``root``
    (fixture files in a tmpdir) is anchored at its last ``src``/``tests``
    component so the same scoping applies; failing that, its basename."""
    rp = path.resolve()
    try:
        return rp.relative_to(root.resolve()).as_posix()
    except ValueError:
        parts = rp.parts
        for i in range(len(parts) - 1, -1, -1):
            if parts[i] in ("src", "tests"):
                return "/".join(parts[i:])
        return rp.name


def lint_file(path: pathlib.Path, root: pathlib.Path,
              rules: Sequence[Rule]) -> List[Finding]:
    return lint_source(path.read_text(), _rel_path(path, root), rules)


def iter_python_files(targets: Sequence[pathlib.Path]):
    for t in targets:
        if t.is_file() and t.suffix == ".py":
            yield t
        elif t.is_dir():
            yield from sorted(p for p in t.rglob("*.py")
                              if "__pycache__" not in p.parts)


def lint_paths(targets: Sequence[pathlib.Path], root: pathlib.Path,
               rules: Sequence[Rule]) -> List[Finding]:
    out: List[Finding] = []
    for path in iter_python_files(targets):
        out.extend(lint_file(path, root, rules))
    return sorted(out)


def repo_root() -> pathlib.Path:
    """The repo checkout this installed/`PYTHONPATH`ed package came from
    (src/repro/lint/engine.py -> three parents up)."""
    return pathlib.Path(__file__).resolve().parents[3]


def default_targets(root: Optional[pathlib.Path] = None) -> List[pathlib.Path]:
    """The self-scan surface: library code and tests (ISSUE-10 scope)."""
    root = root or repo_root()
    return [p for p in (root / "src", root / "tests") if p.exists()]


def findings_json(findings: Sequence[Finding]) -> str:
    return json.dumps([f.to_json() for f in findings], indent=2)
