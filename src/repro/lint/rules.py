"""The repo-specific rule set.

Each rule encodes an invariant this codebase has already paid for once:
a drift shim that exists because an upstream rename broke the build, a
clock/RNG/publish discipline that exists because a test was flaky or a
crash left half-written state.  The linter's job is to make the third
occurrence impossible, not to restyle code — so every rule is scoped to
the layers where its invariant is load-bearing and stays silent
elsewhere.

Rules must not import jax (the AST scan runs in the tier-1 test suite
and must stay sub-second); the compiled-program contracts that do need
jax live in :mod:`repro.lint.contracts`.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from repro.lint.engine import Finding, LintContext, Rule


def _dotted(node: ast.AST) -> str:
    """'jax.experimental.shard_map' for nested Attribute/Name chains,
    '' for anything dynamic."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _under(rel: str, *prefixes: str) -> bool:
    return any(rel == p or rel.startswith(p.rstrip("/") + "/")
               for p in prefixes)


class JaxApiDriftRule(Rule):
    name = "jax-api-drift"
    invariant = ("shard_map and pallas CompilerParams are reached only "
                 "through the repo shims (repro.sharding / "
                 "repro.kernels.tpu_compat)")
    recurrence = ("jax moved shard_map out of jax.experimental and renamed "
                  "TPUCompilerParams; every direct call site broke at once "
                  "— the shims absorb the next rename in one place")

    _SHIMS = ("src/repro/sharding/__init__.py",
              "src/repro/kernels/tpu_compat.py")
    _PARAMS = {"CompilerParams", "TPUCompilerParams"}

    def applies(self, rel: str) -> bool:
        return rel not in self._SHIMS

    def check(self, ctx: LintContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Attribute):
                dotted = _dotted(node)
                if dotted in ("jax.shard_map", "jax.experimental.shard_map",
                              "jax.experimental.shard_map.shard_map"):
                    yield ctx.finding(
                        node, self.name,
                        f"direct {dotted} — import shard_map from "
                        f"repro.sharding (the drift shim) instead")
                elif node.attr in self._PARAMS and \
                        _dotted(node.value) != "tpu_compat":
                    yield ctx.finding(
                        node, self.name,
                        f"direct pallas {node.attr} — use "
                        f"repro.kernels.tpu_compat.CompilerParams, which "
                        f"tracks the pltpu rename")
            elif isinstance(node, ast.ImportFrom) and node.module:
                mod = node.module
                names = {a.name for a in node.names}
                if (mod == "jax" and "shard_map" in names) or \
                        mod.startswith("jax.experimental.shard_map") or \
                        (mod == "jax.experimental" and "shard_map" in names):
                    yield ctx.finding(
                        node, self.name,
                        f"importing shard_map from {mod} — import it from "
                        f"repro.sharding (the drift shim) instead")
                elif "pallas" in mod and (names & self._PARAMS):
                    yield ctx.finding(
                        node, self.name,
                        f"importing {sorted(names & self._PARAMS)[0]} from "
                        f"{mod} — use repro.kernels.tpu_compat instead")


class RawCostAnalysisRule(Rule):
    name = "raw-cost-analysis"
    invariant = ("compiled.cost_analysis() is only called through "
                 "roofline.hlo.xla_cost_analysis")
    recurrence = ("cost_analysis() has returned a dict, a 1-list of dicts, "
                  "and None across jax versions; each bare call site "
                  "re-grows its own half of the normalization")

    def applies(self, rel: str) -> bool:
        return rel != "src/repro/roofline/hlo.py"

    def check(self, ctx: LintContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "cost_analysis":
                yield ctx.finding(
                    node, self.name,
                    "bare compiled.cost_analysis() — call "
                    "repro.roofline.hlo.xla_cost_analysis(compiled), which "
                    "normalizes the dict/list/None drift once")


class ClockDisciplineRule(Rule):
    name = "clock-discipline"
    invariant = ("serve/train/faults/launch code reads time only through "
                 "an injectable clock parameter (default time.monotonic); "
                 "wall-clock CALLS are confined to defaults and shims")
    recurrence = ("inline time.time() made SLO accounting untestable and "
                  "non-monotonic under clock steps; PR6/PR7 moved every "
                  "component onto injected clocks — new code kept "
                  "reintroducing bare calls")

    _FNS = {"time", "monotonic", "sleep", "perf_counter"}

    def applies(self, rel: str) -> bool:
        return _under(rel, "src/repro/serve", "src/repro/train",
                      "src/repro/faults", "src/repro/launch")

    def check(self, ctx: LintContext) -> Iterable[Finding]:
        # names bound by `from time import sleep [as z]`
        local = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ImportFrom) and node.module == "time":
                for a in node.names:
                    if a.name in self._FNS:
                        local[a.asname or a.name] = a.name
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            hit = None
            if isinstance(fn, ast.Attribute) and fn.attr in self._FNS and \
                    _dotted(fn.value) == "time":
                hit = f"time.{fn.attr}"
            elif isinstance(fn, ast.Name) and fn.id in local:
                hit = f"time.{local[fn.id]}"
            if hit:
                yield ctx.finding(
                    node, self.name,
                    f"bare {hit}() call — take an injectable "
                    f"`clock: Callable[[], float] = time.monotonic` "
                    f"parameter (referencing time.monotonic as a default "
                    f"is fine; calling it inline is not) so tests can "
                    f"drive a FakeClock")


class AtomicPublishRule(Rule):
    name = "atomic-publish"
    invariant = ("durable state under serve/ and the checkpointer is "
                 "written to a tmp path and published with os.replace — "
                 "never written in place")
    recurrence = ("a crash between open('wb') and close left a torn "
                  "checkpoint/warm-tier entry that a restart then trusted; "
                  "the fault suite (ckpt.pre_*, warm.corrupt) exists "
                  "because of it")

    def applies(self, rel: str) -> bool:
        return _under(rel, "src/repro/serve") or \
            rel == "src/repro/train/checkpoint.py"

    def check(self, ctx: LintContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            if isinstance(fn, ast.Name) and fn.id == "open" and node.args:
                mode = node.args[1] if len(node.args) > 1 else None
                for kw in node.keywords:
                    if kw.arg == "mode":
                        mode = kw.value
                if not (isinstance(mode, ast.Constant) and
                        isinstance(mode.value, str)):
                    continue  # dynamic mode: out of static reach
                if not (set(mode.value) & set("wax")):
                    continue  # read/update modes don't create torn files
                path_src = ctx.segment(node.args[0])
                if "tmp" not in path_src.lower():
                    yield ctx.finding(
                        node, self.name,
                        f"open({path_src!r}, {mode.value!r}) writes a "
                        f"durable path in place — write to a tmp sibling "
                        f"and publish with os.replace")
            elif isinstance(fn, ast.Attribute) and \
                    fn.attr in ("write_text", "write_bytes"):
                path_src = ctx.segment(fn.value)
                if "tmp" not in path_src.lower():
                    yield ctx.finding(
                        node, self.name,
                        f"{path_src}.{fn.attr}(...) writes a durable path "
                        f"in place — write to a tmp sibling and publish "
                        f"with os.replace")


class FaultSiteRegistryRule(Rule):
    name = "fault-site-registry"
    invariant = ("every fault site named at an injection or plan call site "
                 "uses a constant from repro.faults.plan, and the registry "
                 "(FAULT_SITES) validates FaultSpec at construction")
    recurrence = ('a raw "warm.corrupt" literal at a fire() site silently '
                  "decoupled from the registry; a typo there makes an "
                  "injection point unreachable with no error anywhere")

    _SITE_CALLS = {"fire": 0, "_maybe_kill": 0, "single": 0}

    def applies(self, rel: str) -> bool:
        return rel != "src/repro/faults/plan.py"

    def _constant_for(self, value: str) -> str:
        try:
            from repro.faults import plan
            for name in dir(plan):
                if name.isupper() and getattr(plan, name, None) == value:
                    return f"repro.faults.plan.{name}"
        except Exception:
            pass
        return "a repro.faults.plan constant"

    def check(self, ctx: LintContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            site_arg = None
            if isinstance(fn, ast.Attribute):
                if fn.attr in ("fire", "_maybe_kill"):
                    site_arg = node.args[0] if node.args else None
                elif fn.attr == "single" and _dotted(fn.value) == "FaultPlan":
                    site_arg = node.args[0] if node.args else None
                elif fn.attr == "seeded" and _dotted(fn.value) == "FaultPlan":
                    site_arg = node.args[1] if len(node.args) > 1 else None
            elif isinstance(fn, ast.Name) and fn.id in ("FaultSpec",
                                                        "_maybe_kill"):
                site_arg = node.args[0] if node.args else None
            for kw in node.keywords:
                if kw.arg == "site" and site_arg is None and \
                        (isinstance(fn, ast.Name) and fn.id == "FaultSpec"
                         or isinstance(fn, ast.Attribute) and
                         fn.attr in ("fire", "single", "seeded")):
                    site_arg = kw.value
            if isinstance(site_arg, ast.Constant) and \
                    isinstance(site_arg.value, str):
                yield ctx.finding(
                    node, self.name,
                    f"raw fault-site literal {site_arg.value!r} — use "
                    f"{self._constant_for(site_arg.value)} so the site "
                    f"registry and the wired injection points cannot "
                    f"drift apart")


class SeededRngRule(Rule):
    name = "seeded-rng"
    invariant = ("library code draws randomness only from explicitly "
                 "seeded np.random.default_rng / jax.random.key streams")
    recurrence = ("legacy np.random.* globals made fault soaks and "
                  "episodic samplers irreproducible across processes — "
                  "the whole harness is built on bit-exact replay")

    _CONSTRUCTORS = {"default_rng", "Generator", "PCG64", "PCG64DXSM",
                     "Philox", "SFC64", "MT19937", "SeedSequence",
                     "BitGenerator"}

    def applies(self, rel: str) -> bool:
        return _under(rel, "src/repro")

    def check(self, ctx: LintContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call) and
                    isinstance(node.func, ast.Attribute)):
                continue
            fn = node.func
            if _dotted(fn.value) not in ("np.random", "numpy.random"):
                continue
            if fn.attr not in self._CONSTRUCTORS:
                yield ctx.finding(
                    node, self.name,
                    f"legacy global-state np.random.{fn.attr}(...) — "
                    f"thread an explicit np.random.default_rng(seed) "
                    f"Generator instead")
            elif fn.attr == "default_rng" and not node.args and \
                    not node.keywords:
                yield ctx.finding(
                    node, self.name,
                    "np.random.default_rng() with no seed is entropy-"
                    "seeded — pass an explicit seed so runs replay "
                    "bit-exactly")


class StaticAuxHashableRule(Rule):
    name = "static-aux-hashable"
    invariant = ("pytree aux_data (the static half of register_pytree_node "
                 "flatteners) is built from hashable literals — tuples, "
                 "strings, numbers — never list/dict/set displays")
    recurrence = ("an unhashable aux turns every jit trace into a cache "
                  "miss (or a TypeError under newer jax) the first time "
                  "the pytree crosses a jit boundary — found the hard way "
                  "with ServingWeights quant_paths")

    def applies(self, rel: str) -> bool:
        return _under(rel, "src/repro")

    _UNHASHABLE = (ast.List, ast.Dict, ast.Set,
                   ast.ListComp, ast.DictComp, ast.SetComp)

    def _aux_nodes(self, flatten: ast.AST, tree: ast.AST):
        """Yield the aux expression(s) of a flatten fn given as a lambda
        or a reference to a module-level def."""
        if isinstance(flatten, ast.Lambda):
            body = flatten.body
            if isinstance(body, ast.Tuple) and len(body.elts) == 2:
                yield body.elts[1]
        elif isinstance(flatten, ast.Name):
            for fd in ast.walk(tree):
                if isinstance(fd, ast.FunctionDef) and fd.name == flatten.id:
                    for ret in ast.walk(fd):
                        if isinstance(ret, ast.Return) and \
                                isinstance(ret.value, ast.Tuple) and \
                                len(ret.value.elts) == 2:
                            yield ret.value.elts[1]

    def check(self, ctx: LintContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            is_reg = (isinstance(fn, ast.Attribute) and
                      fn.attr == "register_pytree_node") or \
                     (isinstance(fn, ast.Name) and
                      fn.id == "register_pytree_node")
            if not is_reg or len(node.args) < 2:
                continue
            for aux in self._aux_nodes(node.args[1], ctx.tree):
                for sub in ast.walk(aux):
                    if isinstance(sub, self._UNHASHABLE):
                        kind = type(sub).__name__
                        yield ctx.finding(
                            sub, self.name,
                            f"unhashable {kind} in pytree aux_data — aux "
                            f"must hash (it keys the jit trace cache); "
                            f"use tuples/frozensets")
                        break


ALL_RULES = (
    JaxApiDriftRule(),
    RawCostAnalysisRule(),
    ClockDisciplineRule(),
    AtomicPublishRule(),
    FaultSiteRegistryRule(),
    SeededRngRule(),
    StaticAuxHashableRule(),
)

RULES_BY_NAME = {r.name: r for r in ALL_RULES}
