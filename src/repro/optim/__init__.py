"""Optimizers, LR schedules, gradient transforms (self-contained; no optax)."""
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedules import cosine_schedule, wsd_schedule, linear_warmup
from repro.optim.clip import clip_by_global_norm
