"""LR schedules: cosine (llama-style) and WSD (warmup-stable-decay — the
MiniCPM schedule the assigned minicpm-2b config carries)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup_steps: int, peak: float):
    return peak * jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))


def cosine_schedule(step, peak: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1):
    warm = linear_warmup(step, warmup_steps, peak)
    t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup_steps, warm, peak * cos)


def wsd_schedule(step, peak: float, warmup_steps: int, stable_steps: int,
                 decay_steps: int, final_frac: float = 0.01):
    """Warmup -> Stable (constant peak) -> Decay (exponential-ish linear)."""
    warm = linear_warmup(step, warmup_steps, peak)
    in_decay = step >= warmup_steps + stable_steps
    t = jnp.clip((step - warmup_steps - stable_steps) / max(decay_steps, 1), 0.0, 1.0)
    decay = peak * jnp.exp(jnp.log(final_frac) * t)
    return jnp.where(step < warmup_steps, warm,
                     jnp.where(in_decay, decay, peak))


def schedule_for(name, peak: float, warmup_steps: int, total_steps: int):
    """Resolve a schedule name into a ``step -> lr`` callable.

    ``None`` returns None (constant-lr contract); 'cosine' and 'wsd' use
    the launcher's standard shape derivation (wsd: 80% stable, 18% decay
    of ``total_steps``).  The step argument is the optimizer update count,
    so resuming from a checkpoint lands on the same lr."""
    import functools

    if name is None:
        return None
    if total_steps <= 0:
        raise ValueError(f"schedule={name!r} needs total_steps > 0")
    if name == "cosine":
        return functools.partial(cosine_schedule, peak=peak,
                                 warmup_steps=warmup_steps,
                                 total_steps=total_steps)
    if name == "wsd":
        return functools.partial(
            wsd_schedule, peak=peak, warmup_steps=warmup_steps,
            stable_steps=int(total_steps * 0.8),
            decay_steps=max(int(total_steps * 0.18), 1))
    raise ValueError(f"unknown schedule {name!r} (None|'cosine'|'wsd')")
