"""LR schedules: cosine (llama-style) and WSD (warmup-stable-decay — the
MiniCPM schedule the assigned minicpm-2b config carries)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup_steps: int, peak: float):
    return peak * jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))


def cosine_schedule(step, peak: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1):
    warm = linear_warmup(step, warmup_steps, peak)
    t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup_steps, warm, peak * cos)


def wsd_schedule(step, peak: float, warmup_steps: int, stable_steps: int,
                 decay_steps: int, final_frac: float = 0.01):
    """Warmup -> Stable (constant peak) -> Decay (exponential-ish linear)."""
    warm = linear_warmup(step, warmup_steps, peak)
    in_decay = step >= warmup_steps + stable_steps
    t = jnp.clip((step - warmup_steps - stable_steps) / max(decay_steps, 1), 0.0, 1.0)
    decay = peak * jnp.exp(jnp.log(final_frac) * t)
    return jnp.where(step < warmup_steps, warm,
                     jnp.where(in_decay, decay, peak))
