"""Error-feedback int8 gradient compression for the DP all-reduce
(beyond-paper distributed trick; 1-bit-Adam/EF-SGD family).

Under pure jit+GSPMD the all-reduce is implicit, so compression is
expressed as a gradient transform around the reduction point:

    q, new_err = compress(g + err)      # int8 blockwise + residual memory
    g_hat      = decompress(q)          # what the wire carries

On a real deployment the transform runs inside shard_map around
``jax.lax.psum(q, 'data')`` — ``compressed_psum`` below is that wrapper;
on the 1-device test mesh it degenerates to identity-psum, and its
numerics (error feedback keeps the long-run bias at zero) are covered by
tests/test_compress.py.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.optim.quant import dequantize, quantize

PyTree = Any


def ef_compress(grads: PyTree, err: PyTree) -> Tuple[PyTree, PyTree]:
    """Compress (grads + err) to int8 per-leaf; returns (g_hat, new_err).
    g_hat is what gets all-reduced; new_err = (g+err) - g_hat is carried
    to the next step (error feedback)."""

    def one(g, e):
        tot = g.astype(jnp.float32) + e
        q = quantize(tot)
        g_hat = dequantize(q, tot.shape[-1])
        return g_hat.astype(g.dtype), tot - g_hat

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def zeros_error(grads: PyTree) -> PyTree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(grads: PyTree, axis_name: str, err: PyTree
                    ) -> Tuple[PyTree, PyTree]:
    """shard_map body: quantize locally, psum the int8-decoded values,
    carry the quantization residual."""
    g_hat, new_err = ef_compress(grads, err)
    summed = jax.tree.map(lambda g: jax.lax.psum(g, axis_name), g_hat)
    return summed, new_err
