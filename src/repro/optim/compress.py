"""Error-feedback int8 gradient compression for the DP all-reduce
(beyond-paper distributed trick; 1-bit-Adam/EF-SGD family).

Compression is a gradient transform around the reduction point:

    q, new_err = compress(g + err)      # int8 blockwise + residual memory
    g_hat      = decompress(q)          # the value the reduction sums

``compressed_psum`` is the shard_map reduction: the WIRE carries the int8
payload plus the per-128-block f32 scales (an ``all_gather`` of
``{q, scale}`` over the axis — ~1.03 bytes/element vs 4 for an f32 psum,
verified by ``collectives_report`` in benchmarks/dp_scaling.py); each
shard decodes and sums locally, which equals the psum of the per-shard
decoded values.  Error feedback (the carried residual) keeps the long-run
quantization bias at zero; numerics are covered by tests/test_optim.py
and the convergence test in tests/test_multihost.py.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.optim.quant import dequantize, quantize

PyTree = Any


def ef_compress(grads: PyTree, err: PyTree) -> Tuple[PyTree, PyTree]:
    """Compress (grads + err) to int8 per-leaf; returns (g_hat, new_err).
    g_hat is what gets all-reduced; new_err = (g+err) - g_hat is carried
    to the next step (error feedback)."""

    def one(g, e):
        tot = g.astype(jnp.float32) + e
        q = quantize(tot)
        g_hat = dequantize(q, tot.shape[-1])
        return g_hat.astype(g.dtype), tot - g_hat

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def zeros_error(grads: PyTree) -> PyTree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(grads: PyTree, axis_name: str, err: PyTree
                    ) -> Tuple[PyTree, PyTree]:
    """shard_map body: quantize locally, move ONLY the int8 payload +
    block scales over ``axis_name``, decode + sum locally, carry the
    quantization residual.  Returns (sum of per-shard decoded values,
    new residual) — identical in value to psum-ing the decoded f32s, at
    ~1/4 the wire bytes."""

    def one(g, e):
        tot = g.astype(jnp.float32) + e
        q = quantize(tot)
        g_hat = dequantize(q, tot.shape[-1])
        gathered = dict(q=jax.lax.all_gather(q["q"], axis_name),
                        scale=jax.lax.all_gather(q["scale"], axis_name))
        summed = jnp.sum(dequantize(gathered, tot.shape[-1]), axis=0)
        return summed.astype(g.dtype), tot - g_hat

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))
