"""Blockwise int8 quantization for optimizer state (beyond-paper memory
trick: 8-bit Adam a la Dettmers et al., adapted to a pure-pytree JAX form).

A quantized tensor is stored as {q: int8 same-shape, scale: f32 with the
last dim reduced by BLOCK, n: original trailing dim}.  Quantize/dequantize
are cheap elementwise ops fused into the optimizer update by XLA; the HBM
win is 4x vs f32 state (the difference between a 1T-param model fitting 2
pods or 4).

``n`` rides in the dict so callers no longer carry the trailing dim out of
band (``dequantize(qs)`` just works); the positional ``dequantize(qs, n)``
path is kept for back-compat.  Because ``quantize`` slices ``q`` back to
the original trailing dim, ``q.shape[-1]`` always equals ``n`` — the
stored value is a plain python int, never a traced array, so it stays a
static slice bound under jit and hashes into AOT compile-cache keys
without adding a leaf (see ``_N_IS_STATIC`` note below).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

BLOCK = 128


def _pad_to_block(x: jnp.ndarray):
    n = x.shape[-1]
    pad = (-n) % BLOCK
    if pad:
        cfg = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
        x = jnp.pad(x, cfg)
    return x, n


# _N_IS_STATIC: ``n`` is stored as a plain python int.  Crossing a jit
# boundary (or a checkpoint save/load) turns it into a 0-d array, at
# which point it is no longer a usable slice bound — ``resolve_n`` then
# falls back to ``q.shape[-1]``, which by construction always equals the
# original trailing dim (quantize slices q back after padding).  The
# stored int is therefore a convenience that can never go stale.


def resolve_n(qs: Dict[str, jnp.ndarray], n=None) -> int:
    """Original trailing dim of a quantized dict: explicit arg beats the
    stored ``n``, which is trusted only while it is still a plain python
    int (see _N_IS_STATIC); otherwise ``q.shape[-1]`` — always correct."""
    if n is None:
        n = qs.get("n")
    if not (isinstance(n, int) and not isinstance(n, bool)):
        n = qs["q"].shape[-1]
    return int(n)


def quantize(x: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """x: float (..., N) -> {q int8 (..., N), scale f32 (..., ceil(N/B)),
    n: N}."""
    xp, n = _pad_to_block(x.astype(jnp.float32))
    blocks = xp.reshape(xp.shape[:-1] + (-1, BLOCK))
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[..., None]), -127, 127).astype(jnp.int8)
    q = q.reshape(xp.shape)[..., :n]
    return dict(q=q, scale=scale, n=n)


def dequantize(qs: Dict[str, jnp.ndarray], n: int = None) -> jnp.ndarray:
    q, scale = qs["q"], qs["scale"]
    n = resolve_n(qs, n)
    qp, _ = _pad_to_block(q.astype(jnp.float32))
    blocks = qp.reshape(qp.shape[:-1] + (-1, BLOCK))
    x = blocks * scale[..., None]
    return x.reshape(qp.shape)[..., :n]


def zeros_quantized(shape) -> Dict[str, jnp.ndarray]:
    n = shape[-1]
    nb = (n + BLOCK - 1) // BLOCK
    return dict(q=jnp.zeros(shape, jnp.int8),
                scale=jnp.full(shape[:-1] + (nb,), 1e-12, jnp.float32),
                n=n)


# -- log-domain variant for strictly-positive, high-dynamic-range state ------
# (Adam's second moment: linear absmax int8 crushes small v entries to 0,
# making 1/sqrt(v) explode; quantizing log(v) bounds the error
# MULTIPLICATIVELY — the Dettmers-style dynamic-quant insight, in a
# pytree-friendly form.)

_LOG_FLOOR = 1e-12


def quantize_log(x: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    return quantize(jnp.log(jnp.maximum(x, _LOG_FLOOR)))


def dequantize_log(qs: Dict[str, jnp.ndarray], n: int = None) -> jnp.ndarray:
    v = jnp.exp(dequantize(qs, n))
    return jnp.where(v <= _LOG_FLOOR * 1.5, 0.0, v)


def zeros_quantized_log(shape) -> Dict[str, jnp.ndarray]:
    return quantize_log(jnp.zeros(shape, jnp.float32))
