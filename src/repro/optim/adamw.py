"""AdamW with a configurable optimizer-state dtype policy.

state dtype:
  'float32'   classic
  'bfloat16'  half-size m/v (fine at LM batch sizes)
  'int8'      blockwise-quantized m/v (repro.optim.quant) — 4x HBM win,
              the policy the 1T-param config needs to fit a pod.

The update is a pure function (state, grads, lr) -> (state, params) so the
whole step jits/shards; state leaves mirror param sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.optim.quant import (dequantize, dequantize_log, quantize,
                               quantize_log, zeros_quantized,
                               zeros_quantized_log)

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str = "float32"     # 'float32' | 'bfloat16' | 'int8'


def _zeros_like_state(p: jnp.ndarray, cfg: AdamWConfig, log: bool):
    if cfg.state_dtype == "int8":
        return zeros_quantized_log(p.shape) if log else zeros_quantized(p.shape)
    return jnp.zeros(p.shape, jnp.dtype(cfg.state_dtype))


def _read_state(s, n: int, cfg: AdamWConfig, log: bool) -> jnp.ndarray:
    if cfg.state_dtype == "int8":
        return dequantize_log(s, n) if log else dequantize(s, n)
    return s.astype(jnp.float32)


def _write_state(x: jnp.ndarray, cfg: AdamWConfig, log: bool):
    if cfg.state_dtype == "int8":
        return quantize_log(x) if log else quantize(x)
    return x.astype(jnp.dtype(cfg.state_dtype))


def adamw_init(params: PyTree, cfg: AdamWConfig) -> Dict:
    # mu (signed, well-scaled) quantizes linearly; nu (positive, huge
    # dynamic range — 1/sqrt(nu) in the update!) quantizes in log domain.
    mu = jax.tree.map(lambda p: _zeros_like_state(p, cfg, log=False), params)
    nu = jax.tree.map(lambda p: _zeros_like_state(p, cfg, log=True), params)
    return dict(mu=mu, nu=nu, count=jnp.zeros((), jnp.int32))


def adamw_update(params: PyTree, grads: PyTree, state: Dict, lr: jnp.ndarray,
                 cfg: AdamWConfig) -> Tuple[PyTree, Dict]:
    count = state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    is_state_leaf = (lambda x: isinstance(x, dict) and {"q", "scale"} <= set(x)) \
        if cfg.state_dtype == "int8" else None

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m_f = cfg.b1 * _read_state(m, p.shape[-1], cfg, False) + (1 - cfg.b1) * g
        v_f = cfg.b2 * _read_state(v, p.shape[-1], cfg, True) + (1 - cfg.b2) * g * g
        mhat = m_f / c1
        vhat = v_f / c2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:     # decay matrices only (norms/bias exempt)
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, _write_state(m_f, cfg, False), _write_state(v_f, cfg, True)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.flatten(state["mu"], is_leaf=is_state_leaf)[0]
    flat_v = jax.tree.flatten(state["nu"], is_leaf=is_state_leaf)[0]
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, dict(mu=new_mu, nu=new_nu, count=count)
