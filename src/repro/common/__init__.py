"""Shared low-level utilities: pytree math, initializers, dtype policy."""
from repro.common.tree import (
    tree_add,
    tree_scale,
    tree_sub,
    tree_zeros_like,
    tree_dot,
    global_norm,
    tree_size,
    tree_cast,
    tree_stop_gradient,
)
from repro.common.init import (
    lecun_normal,
    normal_init,
    zeros_init,
    ones_init,
    truncated_normal_init,
)

__all__ = [
    "tree_add",
    "tree_scale",
    "tree_sub",
    "tree_zeros_like",
    "tree_dot",
    "global_norm",
    "tree_size",
    "tree_cast",
    "tree_stop_gradient",
    "lecun_normal",
    "normal_init",
    "zeros_init",
    "ones_init",
    "truncated_normal_init",
]
