"""Pytree arithmetic helpers (the subset of optax/flax utilities we need).

All functions are jit-safe and operate leaf-wise on arbitrary pytrees of
jnp arrays.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    """Leaf-wise a + b."""
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    """Leaf-wise a - b."""
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    """Leaf-wise s * a for scalar s."""
    return jax.tree.map(lambda x: s * x, a)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_dot(a, b):
    """Sum over all leaves of <a_leaf, b_leaf> (flattened inner product)."""
    leaves = jax.tree.leaves(jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b))
    return jnp.sum(jnp.stack([jnp.asarray(l, jnp.float32) for l in leaves]))


def global_norm(a) -> jnp.ndarray:
    """L2 norm over the concatenation of all leaves."""
    leaves = jax.tree.leaves(a)
    if not leaves:
        return jnp.zeros((), jnp.float32)
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    return jnp.sqrt(sq)


def tree_size(a) -> int:
    """Total number of scalar parameters in the tree (static)."""
    return sum(x.size for x in jax.tree.leaves(a))


def tree_cast(a, dtype):
    """Cast all floating leaves to `dtype`, leave integer leaves alone."""

    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree.map(_cast, a)


def tree_stop_gradient(a):
    return jax.tree.map(jax.lax.stop_gradient, a)
