"""Weight initializers (fan-in scaled, matching common LM/vision practice)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def lecun_normal(key, shape, dtype=jnp.float32, in_axis: int = -2):
    """LeCun normal: std = 1/sqrt(fan_in). Default fan-in axis is -2
    (i.e. weight laid out (in, out))."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    std = 1.0 / np.sqrt(fan_in)
    return std * jax.random.normal(key, shape, dtype)


def normal_init(key, shape, std=0.02, dtype=jnp.float32):
    return std * jax.random.normal(key, shape, dtype)


def truncated_normal_init(key, shape, std=0.02, dtype=jnp.float32):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def zeros_init(key, shape, dtype=jnp.float32):
    del key
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype=jnp.float32):
    del key
    return jnp.ones(shape, dtype)


def split_keys(key, n: int):
    """Split into n keys; convenience with unpacking."""
    return list(jax.random.split(key, n))
