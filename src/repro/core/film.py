"""FiLM (Feature-wise Linear Modulation, Perez et al. 2018) — the adaptation
mechanism CNAPs-family meta-learners use to condition a (frozen) backbone on
the task embedding (paper Fig. B.3/B.4).

A FiLM layer scales and shifts channels:  film(x) = x * (1 + gamma) + beta,
with gamma/beta produced per-task by a hyper-network from the set-encoder's
task embedding.  We parameterize the generator exactly as the paper's
Fig. B.4: a shared 2-layer MLP trunk per FiLM site.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp

from repro.common.init import lecun_normal, normal_init


def apply_film(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
               channel_axis: int = -1) -> jnp.ndarray:
    """x * (1 + gamma) + beta with gamma/beta broadcast over all axes except
    the channel axis. Identity at gamma=beta=0 (generator zero-init)."""
    shape = [1] * x.ndim
    shape[channel_axis] = x.shape[channel_axis]
    g = gamma.reshape(shape).astype(x.dtype)
    b = beta.reshape(shape).astype(x.dtype)
    return x * (1.0 + g) + b


def init_film_generator(key: jax.Array, task_dim: int, channel_sizes: Sequence[int],
                        hidden: int = 64, out_std: float = 0.01) -> Dict:
    """Per-site 2-layer MLP: z -> hidden -> (gamma_i, beta_i).

    Output layers are zero-initialized so an untrained generator leaves the
    backbone unmodulated (gamma=beta=0 -> identity), matching how the paper
    warm-starts from a frozen pre-trained feature extractor.
    """
    sites = []
    keys = jax.random.split(key, len(channel_sizes))
    for k, ch in zip(keys, channel_sizes):
        k1, k2 = jax.random.split(k)
        sites.append(
            dict(
                w1=lecun_normal(k1, (task_dim, hidden)),
                b1=jnp.zeros((hidden,)),
                # near-identity init: small random (NOT exactly zero —
                # a zero last layer would block all gradient flow into the
                # set encoder and make LITE-vs-exact comparisons vacuous)
                w_gamma=normal_init(k2, (hidden, ch), std=out_std),
                b_gamma=jnp.zeros((ch,)),
                w_beta=normal_init(jax.random.fold_in(k2, 1), (hidden, ch), std=out_std),
                b_beta=jnp.zeros((ch,)),
            )
        )
    return dict(sites=sites)


def generate_film_params(params: Dict, z: jnp.ndarray) -> List[Dict[str, jnp.ndarray]]:
    """Map a task embedding z[task_dim] to a list of {gamma, beta} per site."""
    out = []
    for site in params["sites"]:
        h = jax.nn.relu(z @ site["w1"] + site["b1"])
        out.append(
            dict(gamma=h @ site["w_gamma"] + site["b_gamma"],
                 beta=h @ site["w_beta"] + site["b_beta"])
        )
    return out


def null_film(channel_sizes: Sequence[int]) -> List[Dict[str, jnp.ndarray]]:
    """Identity modulation (used when running a backbone outside episodic
    mode, e.g. plain LM training / serving)."""
    return [dict(gamma=jnp.zeros((c,)), beta=jnp.zeros((c,))) for c in channel_sizes]
