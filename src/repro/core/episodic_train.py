"""Algorithm 1, complete: the per-task meta-training step with the
paper's QUERY-batch loop (lines 1-10) as a scan-accumulated gradient —
query microbatching bounds the query-side activation memory exactly as
the paper's for-loop does, while LITE (inside meta_loss) bounds the
support side.  One optimizer step per task (line 11); the N/H weighting
is already baked into the LITE combinator's backward.

    step = make_meta_train_step(learner, lite_spec, query_batch=8)
    params, opt_state, metrics = step(params, opt_state, task, key)

Beyond the paper: the TASK-BATCHED engine (``make_batched_meta_train_step``)
amortizes the per-step cost over many tasks — ``vmap`` over the task axis of
a :class:`repro.core.episodic.TaskBatch`, per-task PRNG keys (each task draws
its own H subset), task-mean gradients, ONE optimizer step — and optionally
shards the task axis across devices via ``shard_map`` (pure data parallelism:
params replicated, gradients ``pmean``-ed over the mesh axis).

    batch = collate_task_batch(tasks)            # repro.data.episodic
    step = make_batched_meta_train_step(learner, lite_spec)
    params, opt_state, metrics = step(params, opt_state, batch, key)
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.episodic import Task, TaskBatch, query_batches
from repro.core.lite import LiteSpec
from repro.core.meta_learners import MetaLearner
from repro.optim import AdamWConfig, adamw_update, clip_by_global_norm

PyTree = Any


def make_meta_train_step(learner: MetaLearner, lite: LiteSpec,
                         query_batch: int = 0,
                         adamw: AdamWConfig = AdamWConfig(weight_decay=0.0),
                         lr: float = 1e-3,
                         max_grad_norm: float = 10.0) -> Callable:
    """query_batch=0 -> single query pass; >0 -> Algorithm 1's M_b loop
    via lax.scan gradient accumulation (repro.core.episodic.query_batches
    pads the tail batch and weights it out, so any query count works)."""

    def loss_for(params, task: Task, key):
        return learner.meta_loss(params, task, key, lite)[0]

    def grads_single(params, task: Task, key):
        return jax.value_and_grad(loss_for)(params, task, key)

    def grads_microbatched(params, task: Task, key):
        # query_batches pads the tail batch and emits per-example weights
        # (folding in any collator query_mask), so M need not divide evenly
        qx, qy, qm = query_batches(task, query_batch)

        def body(acc, xs):
            qxb, qyb, qmb = xs
            sub = Task(support_x=task.support_x, support_y=task.support_y,
                       query_x=qxb, query_y=qyb, way=task.way,
                       support_mask=task.support_mask, query_mask=qmb)
            # same key => same H subset across query batches (Alg. 1
            # draws H once per task, line 4 outside the inner use)
            l, g = jax.value_and_grad(loss_for)(params, sub, key)
            # weight each microbatch by its REAL query count so padded
            # tails don't dilute the task loss (uniform 1/nb when unmasked)
            wb = jnp.sum(qmb)
            loss_acc, g_acc = acc
            return (loss_acc + l * wb,
                    jax.tree.map(lambda a, b: a + b * wb, g_acc, g)), None

        zero = (jnp.zeros(()), jax.tree.map(jnp.zeros_like, params))
        (loss_sum, grad_sum), _ = jax.lax.scan(body, zero, (qx, qy, qm))
        w_tot = jnp.maximum(jnp.sum(qm), 1.0)
        return loss_sum / w_tot, jax.tree.map(lambda a: a / w_tot, grad_sum)

    def step(params: PyTree, opt_state: Dict, task: Task, key
             ) -> Tuple[PyTree, Dict, Dict]:
        if query_batch > 0:
            loss, grads = grads_microbatched(params, task, key)
        else:
            loss, grads = grads_single(params, task, key)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        params, opt_state = adamw_update(params, grads, opt_state, lr, adamw)
        return params, opt_state, dict(loss=loss, grad_norm=gnorm)

    return step


# ---------------------------------------------------------------------------
# Task-batched engine: many tasks -> one optimizer step, optionally
# data-parallel over the task axis.
# ---------------------------------------------------------------------------


def task_key(key: jax.Array, task_index) -> jax.Array:
    """Per-task PRNG key convention: fold the global task index into the
    step key.  Shared by the batched engine, its sharded path, and the
    looped reference so all three draw identical H subsets for task i."""
    return jax.random.fold_in(key, task_index)


def make_batched_meta_grads(learner: MetaLearner, lite: LiteSpec) -> Callable:
    """(params, batch: TaskBatch, key) -> (loss, accuracy, grads).

    ``vmap``s ``learner.meta_loss`` over the task axis with per-task keys
    (``task_key(key, i)`` — each task draws an independent H subset) and
    returns task-MEAN loss/accuracy/gradients.  The gradient is taken of
    the task-mean loss directly (one shared-parameter backward, peak
    gradient memory O(P)) rather than stacking T per-task gradient pytrees
    and averaging them.  An optional ``ids`` argument overrides the global
    task indices, which the data-parallel path uses so shard-local slots
    keep their global key.
    """

    def grads_fn(params: PyTree, batch: TaskBatch, key,
                 ids: Optional[jnp.ndarray] = None):
        if ids is None:
            ids = jnp.arange(batch.num_tasks)

        def batch_loss(p):
            def one_task(sx, sy, sm, qx, qy, qm, i):
                task = Task(support_x=sx, support_y=sy, query_x=qx,
                            query_y=qy, way=batch.way, support_mask=sm,
                            query_mask=qm)
                loss, aux = learner.meta_loss(p, task, task_key(key, i), lite)
                return loss, aux["accuracy"]

            losses, accs = jax.vmap(one_task)(
                batch.support_x, batch.support_y, batch.support_mask,
                batch.query_x, batch.query_y, batch.query_mask, ids)
            return jnp.mean(losses), jnp.mean(accs)

        (loss, acc), grads = jax.value_and_grad(batch_loss, has_aux=True)(
            params)
        return loss, acc, grads

    return grads_fn


def _tree_all_finite(tree: PyTree) -> jnp.ndarray:
    """Scalar bool: every element of every leaf is finite.  One fused
    check inside the step's jit — the guard the fault-tolerant loop relies
    on to turn a NaN/inf gradient into a skipped step instead of silent
    parameter corruption."""
    ok = jnp.asarray(True)
    for leaf in jax.tree.leaves(tree):
        ok = jnp.logical_and(ok, jnp.all(jnp.isfinite(leaf)))
    return ok


def init_ef_state(params: PyTree, dcn_shards: int) -> PyTree:
    """Zero error-feedback residuals for ``grad_reduce='compressed'``: one
    fp32 residual copy per DCN shard (leading axis ``dcn_shards``, sharded
    ``P('dcn')`` across the outer mesh axis).  Lives in ``opt_state['ef']``
    so checkpoints carry it and restarts stay exact."""
    return jax.tree.map(
        lambda p: jnp.zeros((dcn_shards,) + p.shape, jnp.float32), params)


def _accumulated_grads(grads_fn: Callable, params: PyTree, batch: TaskBatch,
                       key, ids, accum: int):
    """Mean loss/accuracy/grads over ``batch``, computed as ``accum``
    sequential task chunks (lax.scan) so peak activation memory is that of
    T/accum tasks.  Per-task keys ride on the GLOBAL ids, so the result is
    chunking-invariant; ``accum=1`` calls ``grads_fn`` directly and is
    bit-identical to the unaccumulated step."""
    if accum <= 1:
        return grads_fn(params, batch, key, ids)
    t = batch.num_tasks
    chunks = jax.tree.map(
        lambda a: a.reshape((accum, t // accum) + a.shape[1:]), batch)
    ids_c = ids.reshape(accum, t // accum)

    def body(carry, xs):
        chunk, cid = xs
        l, a, g = grads_fn(params, chunk, key, cid)
        cl, ca, cg = carry
        return (cl + l, ca + a, jax.tree.map(jnp.add, cg, g)), None

    zero = (jnp.zeros(()), jnp.zeros(()),
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
    (loss, acc, grads), _ = jax.lax.scan(body, zero, (chunks, ids_c))
    scale = 1.0 / accum       # equal chunk sizes: mean of chunk-means
    return loss * scale, acc * scale, jax.tree.map(lambda g: g * scale, grads)


def make_batched_meta_train_step(learner: MetaLearner, lite: LiteSpec,
                                 adamw: AdamWConfig = AdamWConfig(weight_decay=0.0),
                                 lr: float = 1e-3,
                                 max_grad_norm: float = 10.0,
                                 schedule: Optional[Callable] = None,
                                 mesh=None, dp_axis: str = "data",
                                 dcn_axis: str = "dcn",
                                 grad_reduce: str = "pmean",
                                 accum_steps: int = 1,
                                 skip_nonfinite: bool = True) -> Callable:
    """Task-batched meta-training step: T tasks -> ONE AdamW step.

        step(params, opt_state, batch: TaskBatch, key)
            -> (params, opt_state, metrics)

    Without a mesh the whole batch is vmapped on the local device.  With
    ``mesh`` the task axis is sharded via ``shard_map``:

    * 1-D mesh (``dp_axis`` only, today's single-host path): params/opt
      state replicated, each shard differentiates its T/S tasks, gradients
      ``pmean`` across the axis, every shard applies the identical update —
      bit-comparable to the single-device batched step.
    * two-level mesh (``make_two_level_dp_mesh``: outer ``dcn_axis`` x
      inner ``dp_axis``): the task axis shards over BOTH axes
      (``P((dcn, data))``); gradients first ``pmean`` over the fast ICI
      ``data`` axis, then reduce across hosts over ``dcn`` — exactly
      (``grad_reduce='pmean'``) or int8 error-feedback compressed
      (``'compressed'``, ``repro.optim.compress.compressed_psum``; the
      per-host residual lives in ``opt_state['ef']``, see
      :func:`init_ef_state`).  At ``dcn`` size 1 the extra reduction is a
      singleton all-reduce, so results are bit-identical to the 1-D path.

    ``accum_steps > 1`` scans that many sequential task chunks per shard
    before the single cross-mesh reduction (gradient accumulation), so
    ``tasks_per_step`` can exceed per-host memory; collective count per
    optimizer step is unchanged.

    ``schedule`` (step -> lr, e.g. from ``repro.optim.schedules``)
    overrides the constant ``lr``; the step index is the optimizer-state
    update count, so schedules survive checkpoint resume for free.
    Metrics report the lr actually applied.

    ``skip_nonfinite`` (default on) arms the non-finite-update guard: if
    any gradient element is NaN/inf the optimizer update is suppressed by
    a ``where``-select — params and opt state (count included) come out
    BIT-IDENTICAL to the inputs — and ``metrics['nonfinite']`` is 1.0.
    The select keeps the step a single fixed computation graph (no
    recompile, donation-safe); the fault-tolerant loop turns runs of
    skipped steps into a divergence rollback.  On a two-level mesh the
    verdict is computed on the fp32 gradients BEFORE the (possibly int8
    compressed) DCN reduction and ``pmin``-reduced across hosts, so every
    shard takes the same branch and quantized NaN garbage can never pass
    the check; the compressed path's error-feedback residual is frozen on
    skipped steps by the same select.
    """
    grads_fn = make_batched_meta_grads(learner, lite)

    def apply_update(params, opt_state, loss, acc, grads, ok=None):
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr_t = lr if schedule is None else schedule(opt_state["count"])
        new_params, new_opt = adamw_update(params, grads, opt_state, lr_t,
                                           adamw)
        metrics = dict(loss=loss, accuracy=acc, grad_norm=gnorm,
                       lr=jnp.asarray(lr_t, jnp.float32))
        if ok is not None:
            pick = lambda n, o: jnp.where(ok, n, o)  # noqa: E731
            new_params = jax.tree.map(pick, new_params, params)
            new_opt = jax.tree.map(pick, new_opt, opt_state)
            metrics["nonfinite"] = (~ok).astype(jnp.float32)
        return new_params, new_opt, metrics

    if grad_reduce not in ("pmean", "compressed"):
        raise ValueError(f"grad_reduce={grad_reduce!r} (want 'pmean' or "
                         f"'compressed')")
    sizes = {} if mesh is None else dict(mesh.shape)
    if mesh is not None and dp_axis not in sizes:
        raise ValueError(f"mesh axes {tuple(sizes)} lack "
                         f"dp_axis={dp_axis!r}")
    dp = sizes.get(dp_axis, 1)
    two_level = dcn_axis in sizes
    dcn = sizes.get(dcn_axis, 1)
    if grad_reduce == "compressed" and not two_level:
        raise ValueError(
            "grad_reduce='compressed' compresses the cross-host DCN "
            "reduction: it needs a two-level mesh "
            "(repro.launch.mesh.make_two_level_dp_mesh) with a "
            f"{dcn_axis!r} axis")
    shards = dp * dcn
    compressed = grad_reduce == "compressed"

    if mesh is None:
        def step(params: PyTree, opt_state: Dict, batch: TaskBatch, key
                 ) -> Tuple[PyTree, Dict, Dict]:
            if batch.num_tasks % accum_steps:
                raise ValueError(f"tasks_per_step={batch.num_tasks} not "
                                 f"divisible by accum_steps={accum_steps}")
            ids = jnp.arange(batch.num_tasks)
            loss, acc, grads = _accumulated_grads(grads_fn, params, batch,
                                                  key, ids, accum_steps)
            ok = _tree_all_finite(grads) if skip_nonfinite else None
            return apply_update(params, opt_state, loss, acc, grads, ok)

        return step

    from repro.optim.compress import compressed_psum
    from repro.sharding import shard_map

    task_spec = P((dcn_axis, dp_axis)) if two_level else P(dp_axis)
    in_specs = [P(), P(), task_spec, P(), task_spec]
    out_specs = [P(), P(), P()]
    if compressed:
        in_specs.append(P(dcn_axis))       # opt_state['ef'], leading axis
        out_specs.append(P(dcn_axis))

    def sharded_body(params, opt_state, local_batch, key_data, local_ids,
                     *maybe_ef):
        key = jax.random.wrap_key_data(key_data)
        loss, acc, grads = _accumulated_grads(grads_fn, params, local_batch,
                                              key, local_ids, accum_steps)
        loss = jax.lax.pmean(loss, dp_axis)
        acc = jax.lax.pmean(acc, dp_axis)
        grads = jax.lax.pmean(grads, dp_axis)
        # finite verdict on the exact fp32 grads BEFORE any dcn compression
        # (int8-quantized NaN can decode to finite garbage); pmin over dcn
        # replicates the decision so every host skips or applies together.
        ok = _tree_all_finite(grads) if skip_nonfinite else None
        if two_level:
            loss = jax.lax.pmean(loss, dcn_axis)
            acc = jax.lax.pmean(acc, dcn_axis)
            if ok is not None:
                ok = jax.lax.pmin(ok.astype(jnp.int32), dcn_axis).astype(bool)
            if compressed:
                ef = jax.tree.map(lambda e: e[0], maybe_ef[0])
                summed, new_ef = compressed_psum(grads, dcn_axis, ef)
                grads = jax.tree.map(lambda g: g / dcn, summed)
                if ok is not None:
                    new_ef = jax.tree.map(
                        lambda n, o: jnp.where(ok, n, o), new_ef, ef)
                new_ef = jax.tree.map(lambda e: e[None], new_ef)
            else:
                grads = jax.lax.pmean(grads, dcn_axis)
        out = apply_update(params, opt_state, loss, acc, grads, ok)
        return out + ((new_ef,) if compressed else ())

    def step(params: PyTree, opt_state: Dict, batch: TaskBatch, key
             ) -> Tuple[PyTree, Dict, Dict]:
        t = batch.num_tasks
        if t % (shards * accum_steps):
            raise ValueError(
                f"tasks_per_step={t} not divisible by dp_shards*dcn_shards*"
                f"accum_steps = {dp}*{dcn}*{accum_steps}")
        ids = jnp.arange(t)
        # raw uint32 key data crosses the shard_map boundary (extended
        # key dtypes and partitioning don't mix on all jax versions)
        key_data = jax.random.key_data(key)
        sharded = functools.partial(
            shard_map, mesh=mesh, in_specs=tuple(in_specs),
            out_specs=tuple(out_specs), check_rep=False)(sharded_body)
        if compressed:
            if "ef" not in opt_state:
                raise ValueError("grad_reduce='compressed' needs "
                                 "opt_state['ef'] — initialize it with "
                                 "init_ef_state(params, dcn_shards)")
            opt_in = {k: v for k, v in opt_state.items() if k != "ef"}
            params, opt, metrics, ef = sharded(params, opt_in, batch,
                                               key_data, ids,
                                               opt_state["ef"])
            return params, dict(opt, ef=ef), metrics
        return sharded(params, opt_state, batch, key_data, ids)

    return step


def jit_task_step(step: Callable, donate: bool = True):
    """jit a ``(params, opt_state, batch, key)`` task step, donating the
    params and optimizer-state buffers (arguments 0 and 1) so AdamW
    updates in place instead of allocating fresh copies each step.  The
    caller must thread the returned state — the donated inputs are dead
    after the call (on backends implementing donation, reuse raises)."""
    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


def run_looped_baseline(learner: MetaLearner, lite: LiteSpec,
                        params: PyTree, opt_state: Dict, tasks, key,
                        adamw: AdamWConfig = AdamWConfig(weight_decay=0.0),
                        lr: float = 1e-3, max_grad_norm: float = 10.0,
                        donate: bool = False):
    """Paper Algorithm 1 verbatim: one optimizer step PER task, in a Python
    loop.  The throughput baseline ``benchmarks/task_throughput.py`` compares
    the batched engine against; uses the same per-task key convention.
    ``donate=True`` updates params/opt state in place — the caller's input
    buffers are consumed by the first step."""
    step = jit_task_step(make_meta_train_step(learner, lite, adamw=adamw,
                                              lr=lr,
                                              max_grad_norm=max_grad_norm),
                         donate=donate)
    metrics = None
    for i, task in enumerate(tasks):
        params, opt_state, metrics = step(params, opt_state, task,
                                          task_key(key, i))
    return params, opt_state, metrics
