"""Algorithm 1, complete: the per-task meta-training step with the
paper's QUERY-batch loop (lines 1-10) as a scan-accumulated gradient —
query microbatching bounds the query-side activation memory exactly as
the paper's for-loop does, while LITE (inside meta_loss) bounds the
support side.  One optimizer step per task (line 11); the N/H weighting
is already baked into the LITE combinator's backward.

    step = make_meta_train_step(learner, lite_spec, query_batch=8)
    params, opt_state, metrics = step(params, opt_state, task, key)
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.episodic import Task
from repro.core.lite import LiteSpec
from repro.core.meta_learners import MetaLearner
from repro.optim import AdamWConfig, adamw_update, clip_by_global_norm

PyTree = Any


def make_meta_train_step(learner: MetaLearner, lite: LiteSpec,
                         query_batch: int = 0,
                         adamw: AdamWConfig = AdamWConfig(weight_decay=0.0),
                         lr: float = 1e-3,
                         max_grad_norm: float = 10.0) -> Callable:
    """query_batch=0 -> single query pass; >0 -> Algorithm 1's M_b loop
    via lax.scan gradient accumulation (query count must divide evenly;
    the data pipeline pads — see repro.core.episodic.query_batches)."""

    def loss_for(params, task: Task, key):
        return learner.meta_loss(params, task, key, lite)[0]

    def grads_single(params, task: Task, key):
        return jax.value_and_grad(loss_for)(params, task, key)

    def grads_microbatched(params, task: Task, key):
        m = task.query_x.shape[0]
        nb = max(m // query_batch, 1)
        qx = task.query_x.reshape((nb, query_batch) + task.query_x.shape[1:])
        qy = task.query_y.reshape(nb, query_batch)

        def body(acc, xs):
            qxb, qyb = xs
            sub = Task(support_x=task.support_x, support_y=task.support_y,
                       query_x=qxb, query_y=qyb, way=task.way)
            # same key => same H subset across query batches (Alg. 1
            # draws H once per task, line 4 outside the inner use)
            l, g = jax.value_and_grad(loss_for)(params, sub, key)
            loss_acc, g_acc = acc
            return (loss_acc + l / nb,
                    jax.tree.map(lambda a, b: a + b / nb, g_acc, g)), None

        zero = (jnp.zeros(()), jax.tree.map(jnp.zeros_like, params))
        (loss, grads), _ = jax.lax.scan(body, zero, (qx, qy))
        return loss, grads

    def step(params: PyTree, opt_state: Dict, task: Task, key
             ) -> Tuple[PyTree, Dict, Dict]:
        if query_batch > 0:
            loss, grads = grads_microbatched(params, task, key)
        else:
            loss, grads = grads_single(params, task, key)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        params, opt_state = adamw_update(params, grads, opt_state, lr, adamw)
        return params, opt_state, dict(loss=loss, grad_norm=gnorm)

    return step
