"""Gradient-estimator diagnostics — the paper's §5.3 / Fig. 4 / Tables
D.7-D.8 harness, as a library (tests and benchmarks/fig4_rmse call in).

For a fixed task and fixed params:
  * exact gradient      g*  = d meta_loss / d params at LiteSpec(exact)
  * LITE gradient       g_h = estimator with |H|=h (paper Eq. 8)
  * subsampled gradient s_h = forward AND backward on h examples (Fig. 4's
    "small task" baseline)

Reported per h over n_draws fresh index draws:
  * bias MSE:   || mean_draws(g) - g* ||^2 / dim     (Table D.7 analogue)
  * RMSE:       mean_draws ||g - g*|| / sqrt(dim)    (Fig. 4 / Table D.8)
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.episodic import Task
from repro.core.lite import LiteSpec


def _flat(tree) -> jnp.ndarray:
    return jnp.concatenate([jnp.ravel(x) for x in jax.tree.leaves(tree)])


def gradient_experiment(meta_loss: Callable, params, task: Task,
                        h_values: Sequence[int], n_draws: int,
                        key: jax.Array, subsampled_estimator=None,
                        param_filter: Optional[Callable] = None) -> Dict:
    """meta_loss(params, task, key, lite_spec, estimator=None) -> (loss, aux).

    param_filter: optional tree -> subtree selector.  The paper's Fig. 4
    measures RMSE on the FIRST Conv2D of Simple CNAPs' set encoder (the
    site where LITE's exact-forward advantage is cleanest); pass e.g.
    ``lambda p: p["enc"]["blocks"][0]["w"]`` to reproduce that.

    Returns {"exact_norm": float, "lite": {h: {bias_mse, rmse}},
             "subsampled": {h: {...}} (if subsampled_estimator given)}.
    """
    if param_filter is None:
        param_filter = lambda t: t
    grad_fn = jax.jit(
        jax.grad(lambda p, k, spec_h, exact, sub: _loss_dispatch(
            meta_loss, p, task, k, spec_h, exact, sub)[0]),
        static_argnums=(2, 3, 4))   # h determines slice shapes -> static

    g_exact = param_filter(grad_fn(params, key, 0, True, False))
    g_exact_f = _flat(g_exact)
    dim = g_exact_f.shape[0]

    out = {"exact_norm": float(jnp.linalg.norm(g_exact_f)),
           "lite": {}, "subsampled": {}}
    modes = [("lite", False)]
    if subsampled_estimator is not None:
        modes.append(("subsampled", True))

    for mode, use_sub in modes:
        for h in h_values:
            draws = []
            k = key
            for _ in range(n_draws):
                k, sub = jax.random.split(k)
                g = param_filter(grad_fn(params, sub, h, False, use_sub))
                draws.append(np.asarray(_flat(g), np.float64))
            draws = np.stack(draws)
            exact = np.asarray(g_exact_f, np.float64)
            bias_mse = float(np.mean((draws.mean(0) - exact) ** 2))
            rmse = float(np.mean(np.sqrt(np.mean((draws - exact) ** 2, axis=1))))
            out[mode][h] = dict(bias_mse=bias_mse, rmse=rmse)
    return out


def _loss_dispatch(meta_loss, params, task, key, h, exact, use_subsampled):
    spec = LiteSpec(h=h, exact=exact)
    if use_subsampled:
        return meta_loss(params, task, key, spec, estimator="subsampled")
    return meta_loss(params, task, key, spec)
