"""The paper's contribution: the LITE estimator (repro.core.lite), the
meta-learner families it plugs into (repro.core.meta_learners), and the
estimator diagnostics reproducing the paper's §5.3 analysis."""
