"""Deep-set task encoder e_phi1 (paper Eq. 2).

Encodes a support set into a permutation-invariant task embedding by MEAN
pooling per-example encodings — the aggregation site LITE subsamples.

Three variants:
  * conv   — small conv net for image supports (paper's encoder).
  * mlp    — for pre-featurized supports (modality-stub embeddings).
  * tokens — bag-of-tokens: normalized token histogram -> MLP, for the
    episodic-LM integration (support examples are token sequences).

Both expose  init(key) -> params  and  encode(params, x) -> (B, task_dim)
per-example embeddings; pooling/LITE happens in the meta-learner.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.common.init import lecun_normal


@dataclasses.dataclass(frozen=True)
class SetEncoderConfig:
    kind: str = "conv"            # "conv" | "mlp" | "tokens"
    in_channels: int = 3          # conv: image channels; mlp: feature dim; tokens: vocab
    task_dim: int = 64            # embedding width
    conv_blocks: int = 4
    conv_width: int = 32
    mlp_hidden: int = 128


def init_set_encoder(key: jax.Array, cfg: SetEncoderConfig) -> Dict:
    if cfg.kind == "conv":
        params = dict(blocks=[])
        ch = cfg.in_channels
        keys = jax.random.split(key, cfg.conv_blocks + 1)
        for i in range(cfg.conv_blocks):
            params["blocks"].append(
                dict(w=lecun_normal(keys[i], (3, 3, ch, cfg.conv_width), in_axis=2),
                     b=jnp.zeros((cfg.conv_width,)))
            )
            ch = cfg.conv_width
        params["head"] = dict(w=lecun_normal(keys[-1], (ch, cfg.task_dim)),
                              b=jnp.zeros((cfg.task_dim,)))
        return params
    if cfg.kind in ("mlp", "tokens"):
        k1, k2 = jax.random.split(key)
        return dict(
            w1=lecun_normal(k1, (cfg.in_channels, cfg.mlp_hidden)),
            b1=jnp.zeros((cfg.mlp_hidden,)),
            w2=lecun_normal(k2, (cfg.mlp_hidden, cfg.task_dim)),
            b2=jnp.zeros((cfg.task_dim,)),
        )
    raise ValueError(f"unknown set encoder kind: {cfg.kind}")


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def encode_set(params: Dict, x: jnp.ndarray, cfg: SetEncoderConfig) -> jnp.ndarray:
    """Per-example encodings (B, task_dim). No pooling here — LITE pools."""
    if cfg.kind == "conv":
        h = x
        for blk in params["blocks"]:
            h = _conv(h, blk["w"], blk["b"])
            h = jax.nn.relu(h)
            h = jax.lax.reduce_window(
                h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        h = jnp.mean(h, axis=(1, 2))  # global average pool -> (B, ch)
        return h @ params["head"]["w"] + params["head"]["b"]
    if cfg.kind in ("mlp", "tokens"):
        if cfg.kind == "tokens":
            # (B, S) int ids -> normalized histogram over the vocab
            oh = jax.nn.one_hot(x, cfg.in_channels, dtype=jnp.float32)
            x = jnp.mean(oh, axis=1)
        h = jax.nn.relu(x @ params["w1"] + params["b1"])
        return h @ params["w2"] + params["b2"]
    raise ValueError(cfg.kind)
