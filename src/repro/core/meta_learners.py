"""Meta-learners + LITE: ProtoNets, CNAPs, Simple CNAPs, first-order MAML,
and the FineTuner transfer baseline (paper §3.1 / §5).

All learners are pure functions over explicit param pytrees:

    learner = make_learner(cfg, backbone)
    params  = learner.init(key)
    loss, metrics = learner.meta_loss(params, task, key, lite_spec)

Test-time adaptation speaks ONE uniform, mask-aware batched contract for
every learner kind (the episodic serving engine's API; repro.serve.episodic
dispatches thousands of personalization requests through it):

    states = learner.adapt_batch(params, task_batch, keys, lite)  # (T, ...)
    logits = learner.predict_batch(params, states, query_x)       # (T, M, way)

``adapt_batch`` vmaps over the padded task axis of a
:class:`repro.core.episodic.TaskBatch` with per-task PRNG keys and honors
the collator's support masks, so a padded batch adapts bit-exactly like
its member tasks; the returned *task-state batch* is the single-task state
pytree with a leading task axis (stack/index helpers live in
repro.core.episodic).  At serve time adaptation is forward-only, so the
aggregating learners run the LITE-chunked exact estimators
(repro.core.lite.serve_sum / serve_segment_sum): support activations stay
O(chunk) no matter how many images the support set holds, and
``LiteSpec.compute_dtype`` down-casts the chunk compute with fp32
accumulation.  Thin single-task wrappers remain for the training path:

    task_state = learner.adapt(params, support_x, support_y, key)
    logits     = learner.predict(params, task_state, query_x)

LITE enters at every support-set aggregation site (the paper's Eqs. 2-5):
the set-encoder pooling and the class-pooled feature statistics.  The
N/H backward rescale is baked into the straight-through combinator
(repro.core.lite), so the optimizer step needs no extra weighting —
mathematically identical to Algorithm 1's step(phi, N/H).

The class-statistics sites and the Simple CNAPs Mahalanobis head run
through the kernel dispatch layer (repro.kernels.dispatch; backend
naive | ref | pallas | auto selected per site at trace time): per-class
feature sums and raw second moments are kernel-fused, so the covariance
path never materializes the per-example (B, F, F) outer-product tensor
— in training (H pass), LITE-chunked serving, and the batched
adapt_batch path alike.  Only the paper's naive small-task baseline
(estimator="subsampled") keeps the literal outer-product composite: its
forward sees just the H subset, where naive is the point.

A key LITE-correctness subtlety: anything task-adapted that feeds the
support encoder (e.g. CNAPs' FiLM parameters) must be passed through the
combinator's *params* argument, not captured in a closure — otherwise the
no-grad complement pass would leak gradients through the closure and the
estimator would no longer match Eq. 8.  See ``_film_as_params`` below.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.init import lecun_normal
from repro.common.tree import tree_stop_gradient
from repro.core.episodic import Task, TaskBatch
from repro.core.film import generate_film_params, init_film_generator
from repro.core.lite import (LiteSpec, lite_class_stats, lite_segment_sum,
                             lite_sum, serve_segment_sum, serve_sum,
                             subsampled_task_sum)
from repro.kernels import dispatch
from repro.kernels.dispatch import mahalanobis_head
from repro.core.set_encoder import (SetEncoderConfig, encode_set,
                                    init_set_encoder)
from repro.models.backbone import BackboneDef

PyTree = Any


@dataclasses.dataclass(frozen=True)
class MetaLearnerConfig:
    kind: str = "protonets"      # protonets|cnaps|simple_cnaps|fomaml|finetuner
    way: int = 5
    task_dim: int = 64
    gen_hidden: int = 64
    head_hidden: int = 64
    # fomaml / finetuner
    inner_lr: float = 0.01
    inner_steps: int = 5
    freeze_backbone: bool = False      # CNAPs-family default True via make_learner
    # simple-cnaps covariance regularization epsilon
    cov_eps: float = 1.0
    film_init_std: float = 0.1


@dataclasses.dataclass(frozen=True)
class MetaLearner:
    cfg: MetaLearnerConfig
    backbone: BackboneDef
    init: Callable[[jax.Array], PyTree]
    meta_loss: Callable[..., Tuple[jnp.ndarray, Dict]]
    adapt: Callable[..., PyTree]
    predict: Callable[[PyTree, PyTree, jnp.ndarray], jnp.ndarray]
    # uniform batched serving contract (vmapped over the padded task axis;
    # see _batched_api): every learner kind serves through these two.
    adapt_batch: Callable[..., PyTree]
    predict_batch: Callable[[PyTree, PyTree, jnp.ndarray], jnp.ndarray]


def _batched_api(adapt_one: Callable, predict: Callable
                 ) -> Tuple[Callable, Callable, Callable]:
    """Build the uniform batched contract from a mask-aware single-task
    ``adapt_one(params, sx, sy, mask, key, lite) -> task_state``.

    Returns ``(adapt, adapt_batch, predict_batch)``:

    * ``adapt(params, sx, sy, key=None, lite=..., mask=None)`` — the thin
      single-task wrapper (training/eval path; old call sites unchanged).
    * ``adapt_batch(params, batch: TaskBatch, keys, lite=...)`` — vmaps
      adaptation over the padded task axis with per-task keys, honoring the
      collator's support masks.  Returns a *task-state batch*: the
      single-task state pytree with a leading task axis on every leaf
      (stack/index via repro.core.episodic.stack_task_states /
      index_task_state).
    * ``predict_batch(params, states, qx)`` — vmaps query scoring over
      (state, query) pairs; ``qx`` is (T, M, ...) padded queries, result is
      (T, M, way) logits.

    Both batched entry points are plain vmaps of the same single-task
    functions at identical padded shapes, which is what makes batched
    serving bit-exact vs the per-task path.
    """

    def adapt(params, sx, sy, key=None, lite: LiteSpec = LiteSpec(exact=True),
              mask=None):
        key = jax.random.key(0) if key is None else key
        return adapt_one(params, sx, sy, mask, key, lite)

    def adapt_batch(params, batch: TaskBatch, keys,
                    lite: LiteSpec = LiteSpec(exact=True)):
        def one(sx, sy, sm, k):
            return adapt_one(params, sx, sy, sm, k, lite)

        return jax.vmap(one)(batch.support_x, batch.support_y,
                             batch.support_mask, keys)

    def predict_batch(params, states, qx):
        return jax.vmap(lambda st, q: predict(params, st, q))(states, qx)

    return adapt, adapt_batch, predict_batch


def _xent(logits: jnp.ndarray, labels: jnp.ndarray,
          w: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Cross-entropy; with ``w`` (validity weights) a weighted mean over the
    real examples only, so collator padding never moves the loss."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    if w is None:
        return -jnp.mean(ll)
    w = w.astype(ll.dtype)
    return -jnp.sum(ll * w) / jnp.maximum(jnp.sum(w), 1.0)


def _accuracy(logits: jnp.ndarray, labels: jnp.ndarray,
              w: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    hit = (jnp.argmax(logits, -1) == labels).astype(jnp.float32)
    if w is None:
        return jnp.mean(hit)
    return jnp.sum(hit * w) / jnp.maximum(jnp.sum(w), 1.0)


# ===========================================================================
# ProtoNets (+ LITE): metric head, all backbone params learned
# ===========================================================================

def make_protonets(cfg: MetaLearnerConfig, bb: BackboneDef) -> MetaLearner:
    def init(key):
        return dict(bb=bb.init(key))

    def _prototypes(params, sx, sy, key, lite: LiteSpec,
                    estimator=lite_segment_sum, mask=None):
        def encode(p, x):
            return bb.features(p, x, None)
        sums, counts = estimator(encode, params["bb"], sx, sy, cfg.way, key,
                                 lite, mask=mask)
        return sums / jnp.maximum(counts, 1.0)[:, None]

    def _logits(params, protos, qx):
        qf = bb.features(params["bb"], qx, None).astype(jnp.float32)
        d2 = jnp.sum((qf[:, None, :] - protos[None, :, :]) ** 2, axis=-1)
        return -d2

    def meta_loss(params, task: Task, key, lite: LiteSpec, estimator=None):
        seg = _sub_seg if estimator == "subsampled" else lite_segment_sum
        protos = _prototypes(params, task.support_x, task.support_y, key,
                             lite, seg, mask=task.support_mask)
        logits = _logits(params, protos, task.query_x)
        loss = _xent(logits, task.query_y, task.query_mask)
        return loss, dict(
            accuracy=_accuracy(logits, task.query_y, task.query_mask))

    def adapt_one(params, sx, sy, mask, key, lite: LiteSpec):
        # forward-only serve estimator: exact prototypes, chunked, no grad
        return _prototypes(params, sx, sy, key, lite, serve_segment_sum,
                           mask=mask)

    def predict(params, task_state, qx):
        return _logits(params, task_state, qx)

    adapt, adapt_batch, predict_batch = _batched_api(adapt_one, predict)
    return MetaLearner(cfg, bb, init, meta_loss, adapt, predict,
                       adapt_batch, predict_batch)


# ===========================================================================
# CNAPs / Simple CNAPs (+ LITE): amortization; frozen backbone + FiLM
# ===========================================================================

def _film_as_params(bb: BackboneDef, bb_params, film):
    """Bundle (frozen backbone params, live FiLM tensors) into the pytree
    LITE treats as differentiable state, so the complement pass stops
    gradients through FiLM as required by Eq. 8."""
    return (tree_stop_gradient(bb_params), film)


def _make_cnaps_family(cfg: MetaLearnerConfig, bb: BackboneDef,
                       set_cfg: SetEncoderConfig, simple: bool) -> MetaLearner:
    fdim = bb.feature_dim

    def init(key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p = dict(
            bb=bb.init(k1),
            enc=init_set_encoder(k2, set_cfg),
            film_gen=init_film_generator(k3, set_cfg.task_dim,
                                         bb.film_sites, cfg.gen_hidden,
                                         out_std=cfg.film_init_std),
        )
        if not simple:   # CNAPs: classifier-weight generator MLP
            ka, kb = jax.random.split(k4)
            p["head_gen"] = dict(
                w1=lecun_normal(ka, (fdim, cfg.head_hidden)),
                b1=jnp.zeros((cfg.head_hidden,)),
                w2=lecun_normal(kb, (cfg.head_hidden, fdim + 1)),
                b2=jnp.zeros((fdim + 1,)),
            )
        return p

    def _task_embedding(params, sx, key, lite: LiteSpec, estimator=lite_sum,
                        mask=None):
        n = sx.shape[0] if mask is None else jnp.maximum(jnp.sum(mask), 1.0)

        def enc(p, x):
            return encode_set(p, x, set_cfg)

        z_sum = estimator(enc, params["enc"], sx, key, lite, mask=mask)
        return z_sum / n

    def _features(pf, x):
        # dtype-preserving: fp32 params give fp32 feats (as before); under
        # a LiteSpec.compute_dtype complement the bf16 feats stay bf16
        # (the memory win) — the estimator accumulates class stats in fp32.
        bbp, f = pf
        return bb.features(bbp, x, f)

    def _class_stats(params, film, sx, sy, key, lite: LiteSpec,
                     mode: str = "lite", mask=None):
        """Per-class feature sums (+ raw second moments for Simple CNAPs)
        through the kernel-dispatched fused estimators — the per-example
        (B, F, F) outer-product tensor is never materialized on the
        lite/serve paths (repro.core.lite.lite_class_stats).  The
        ``subsampled`` mode is the paper's naive small-task baseline: its
        forward sees only the H subset, so it keeps the literal
        outer-product composite (h is small by construction)."""
        pf = _film_as_params(bb, params["bb"], film)
        if mode == "subsampled":
            def encode(p, x):
                feat = _features(p, x)
                if simple:
                    outer = jnp.einsum("bi,bj->bij", feat, feat)
                    return dict(feat=feat, outer=outer)
                return dict(feat=feat)

            return _sub_seg(encode, pf, sx, sy, cfg.way, key, lite,
                            mask=mask)
        sum_fn = serve_sum if mode == "serve" else lite_sum
        return lite_class_stats(_features, pf, sx, sy, cfg.way, key, lite,
                                mask=mask, second_moment=simple,
                                sum_fn=sum_fn)

    def _configure(params, sx, sy, key, lite: LiteSpec,
                   sum_estimator=lite_sum, stats_mode="lite",
                   mask=None):
        """Support set -> task_state (film + head statistics)."""
        z = _task_embedding(params, sx, key, lite, sum_estimator, mask=mask)
        film = generate_film_params(params["film_gen"], z)
        sums, counts = _class_stats(params, film, sx, sy, key, lite,
                                    stats_mode, mask=mask)
        k_c = jnp.maximum(counts, 1.0)
        mu = sums["feat"] / k_c[:, None]                       # (C, F)
        state = dict(film=film, mu=mu)
        if simple:
            # Simple CNAPs Mahalanobis statistics (paper Eq. in §3.1):
            # Sigma_c = l_c * S_c + (1 - l_c) * S_task + eps*I, l_c = k/(k+1)
            ex2 = sums["outer"] / k_c[:, None, None]
            cov_c = ex2 - jnp.einsum("ci,cj->cij", mu, mu)
            n_tot = jnp.maximum(jnp.sum(counts), 1.0)
            mu_t = jnp.sum(sums["feat"], 0) / n_tot
            ex2_t = jnp.sum(sums["outer"], 0) / n_tot
            cov_t = ex2_t - jnp.outer(mu_t, mu_t)
            lam = (k_c / (k_c + 1.0))[:, None, None]
            sigma = lam * cov_c + (1.0 - lam) * cov_t[None]
            # scale-aware ridge: cov_eps plus a fraction of the mean
            # diagonal, so f32 cancellation in E[xx^T] - mu mu^T can never
            # push eigenvalues below the jitter (cholesky would NaN).
            diag_mean = jnp.mean(jax.vmap(jnp.diag)(sigma), axis=-1)
            eps = cfg.cov_eps + 1e-3 * jnp.maximum(diag_mean, 0.0)
            sigma = sigma + eps[:, None, None] * jnp.eye(fdim)[None]
            state["chol"] = jax.vmap(jnp.linalg.cholesky)(sigma)
        else:
            h = jax.nn.relu(mu @ params["head_gen"]["w1"] + params["head_gen"]["b1"])
            wb = h @ params["head_gen"]["w2"] + params["head_gen"]["b2"]
            state["w"] = wb[:, :fdim]                          # (C, F)
            state["b"] = wb[:, fdim]
        return state

    def _logits(params, state, qx):
        qf = bb.features(tree_stop_gradient(params["bb"]), qx,
                         state["film"]).astype(jnp.float32)
        if simple:
            # Mahalanobis head through kernel dispatch: ref = the
            # cho_solve composite (bit-exact), pallas = the VMEM quadratic
            # -form kernel on the explicit inverse (custom_vjp backward);
            # serve-adapted states carry the precomputed inverse so query
            # dispatches skip the per-call O(C F^3) solves
            return -mahalanobis_head(qf, state["mu"], state["chol"],
                                     sinv=state.get("sinv"))
        return qf @ state["w"].T + state["b"]

    def meta_loss(params, task: Task, key, lite: LiteSpec, estimator=None):
        sub = estimator == "subsampled"
        sum_est = _sub_sum if sub else lite_sum
        state = _configure(params, task.support_x, task.support_y, key, lite,
                           sum_est, "subsampled" if sub else "lite",
                           mask=task.support_mask)
        logits = _logits(params, state, task.query_x)
        loss = _xent(logits, task.query_y, task.query_mask)
        return loss, dict(
            accuracy=_accuracy(logits, task.query_y, task.query_mask))

    def adapt_one(params, sx, sy, mask, key, lite: LiteSpec):
        # forward-only serve estimators at both aggregation sites (set
        # encoder pooling + class statistics): exact, chunked, no grad
        state = _configure(params, sx, sy, key, lite,
                           sum_estimator=serve_sum,
                           stats_mode="serve", mask=mask)
        if simple and dispatch.resolve_backend() == "pallas":
            # pallas Mahalanobis head consumes the explicit inverse:
            # compute it ONCE at adaptation and carry it in the task
            # state, so every cached/repeated query dispatch skips the
            # O(C F^3) cho_solve solves (trace-time backend binding —
            # ref-backend states stay unchanged)
            state["sinv"] = dispatch.chol_inverse(state["chol"])
        return state

    def predict(params, task_state, qx):
        return _logits(params, task_state, qx)

    adapt, adapt_batch, predict_batch = _batched_api(adapt_one, predict)
    return MetaLearner(cfg, bb, init, meta_loss, adapt, predict,
                       adapt_batch, predict_batch)


# naive small-task estimators (paper's Fig-4 baseline) with matching signatures
def _sub_sum(encode_fn, params, xs, key, spec, mask=None):
    return subsampled_task_sum(encode_fn, params, xs, key, spec, mask=mask)


def _sub_seg(encode_fn, params, xs, ys, num_classes, key, spec, mask=None):
    """Naive small-task baseline with class-stratified subsampling (paper
    App. D.4 guarantees >=1 example/class so class statistics stay
    finite).  Forward AND backward see only the subset."""
    from repro.core.lite import sample_stratified_indices
    n = jax.tree.leaves(xs)[0].shape[0]
    h = spec.resolved_h(n)
    w = jnp.ones((n,), jnp.float32) if mask is None else mask
    n_real = n if mask is None else jnp.sum(mask)
    if spec.exact or h >= n:
        idx = jnp.arange(n)
        scale = 1.0
    else:
        idx = sample_stratified_indices(key, ys, num_classes, h, mask=mask)
        scale = n_real / jnp.minimum(float(h), jnp.maximum(n_real, 1.0))
    take = lambda a: jnp.take(a, idx, axis=0)
    xs_h = jax.tree.map(take, xs)
    onehot_h = jax.nn.one_hot(ys[idx], num_classes, dtype=jnp.float32) \
        * w[idx][:, None]
    enc = encode_fn(params, xs_h)
    sums = jax.tree.map(
        lambda e: scale * jnp.einsum("b...,bc->c...",
                                     e.astype(jnp.float32), onehot_h), enc)
    counts = jnp.sum(jax.nn.one_hot(ys, num_classes, dtype=jnp.float32)
                     * w[:, None], axis=0)
    return sums, counts


# ===========================================================================
# First-order MAML (paper baseline; batched, no LITE needed)
# ===========================================================================

def make_fomaml(cfg: MetaLearnerConfig, bb: BackboneDef) -> MetaLearner:
    fdim = bb.feature_dim

    def init(key):
        k1, k2 = jax.random.split(key)
        return dict(bb=bb.init(k1),
                    head=dict(w=lecun_normal(k2, (fdim, cfg.way)),
                              b=jnp.zeros((cfg.way,))))

    def _logits_p(p, x):
        f = bb.features(p["bb"], x, None).astype(jnp.float32)
        return f @ p["head"]["w"] + p["head"]["b"]

    def _inner_adapt(params, sx, sy, sw=None):
        def inner_loss(p):
            return _xent(_logits_p(p, sx), sy, sw)

        p = params
        for _ in range(cfg.inner_steps):
            g = jax.grad(inner_loss)(p)
            p = jax.tree.map(lambda a, b: a - cfg.inner_lr * b, p, g)
        return p

    def meta_loss(params, task: Task, key, lite: LiteSpec, estimator=None):
        del key, lite, estimator
        adapted = _inner_adapt(params, task.support_x, task.support_y,
                               task.support_mask)
        # first-order: treat the adapted point as a constant offset
        adapted = jax.tree.map(
            lambda a, b: a + jax.lax.stop_gradient(b - a), params, adapted)
        logits = _logits_p(adapted, task.query_x)
        loss = _xent(logits, task.query_y, task.query_mask)
        return loss, dict(
            accuracy=_accuracy(logits, task.query_y, task.query_mask))

    def adapt_one(params, sx, sy, mask, key, lite: LiteSpec):
        del key, lite  # inner SGD is deterministic; no aggregation sites
        return _inner_adapt(params, sx, sy, mask)

    def predict(params, task_state, qx):
        return _logits_p(task_state, qx)

    adapt, adapt_batch, predict_batch = _batched_api(adapt_one, predict)
    return MetaLearner(cfg, bb, init, meta_loss, adapt, predict,
                       adapt_batch, predict_batch)


# ===========================================================================
# FineTuner transfer baseline (frozen backbone, linear head, K steps)
# ===========================================================================

def make_finetuner(cfg: MetaLearnerConfig, bb: BackboneDef) -> MetaLearner:
    fdim = bb.feature_dim

    def init(key):
        return dict(bb=bb.init(key))

    def adapt_one(params, sx, sy, mask, key, lite: LiteSpec):
        del key, lite
        sw = mask
        feats = bb.features(tree_stop_gradient(params["bb"]), sx, None)
        feats = jax.lax.stop_gradient(feats).astype(jnp.float32)
        head = dict(w=jnp.zeros((fdim, cfg.way)), b=jnp.zeros((cfg.way,)))

        def loss(h):
            logits = feats @ h["w"] + h["b"]
            return _xent(logits, sy, sw)

        def body(h, _):
            g = jax.grad(loss)(h)
            return jax.tree.map(lambda a, b: a - cfg.inner_lr * b, h, g), None

        head, _ = jax.lax.scan(body, head, None, length=cfg.inner_steps)
        return head

    def predict(params, head, qx):
        qf = bb.features(params["bb"], qx, None).astype(jnp.float32)
        return qf @ head["w"] + head["b"]

    def meta_loss(params, task: Task, key, lite: LiteSpec, estimator=None):
        head = adapt_one(params, task.support_x, task.support_y,
                         task.support_mask, key, lite)
        logits = predict(params, head, task.query_x)
        return _xent(logits, task.query_y, task.query_mask), dict(
            accuracy=_accuracy(logits, task.query_y, task.query_mask))

    adapt, adapt_batch, predict_batch = _batched_api(adapt_one, predict)
    return MetaLearner(cfg, bb, init, meta_loss, adapt, predict,
                       adapt_batch, predict_batch)


# ===========================================================================
# factory
# ===========================================================================

def make_learner(cfg: MetaLearnerConfig, bb: BackboneDef,
                 set_cfg: Optional[SetEncoderConfig] = None) -> MetaLearner:
    if cfg.kind == "protonets":
        return make_protonets(cfg, bb)
    if cfg.kind in ("cnaps", "simple_cnaps"):
        if set_cfg is None:
            raise ValueError("CNAPs-family learners need a SetEncoderConfig")
        return _make_cnaps_family(cfg, bb, set_cfg, simple=cfg.kind == "simple_cnaps")
    if cfg.kind == "fomaml":
        return make_fomaml(cfg, bb)
    if cfg.kind == "finetuner":
        return make_finetuner(cfg, bb)
    raise ValueError(f"unknown meta-learner kind: {cfg.kind}")
