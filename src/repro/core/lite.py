"""LITE — Large Image and Task Episodic training (Bronskill et al., NeurIPS 2021).

The paper's contribution, as a composable JAX transform.

Core observation (paper Eq. 5–8): when the loss depends on the support set
only through a permutation-invariant *sum* of per-example encodings,

    L = L( e_phi(D_S) ),   e_phi(D_S) = sum_n e_phi(x_n, y_n),

the gradient decomposes over support examples and admits the unbiased
Monte-Carlo estimator

    dL/dphi  ≈  (N/H) * L'(e_phi(D_S)) * sum_{h in H} d e^(n_h) / dphi,

where the H indices are drawn uniformly from {1..N}.  Crucially the forward
value e_phi(D_S) is EXACT (all N examples contribute); only the backward pass
is subsampled.  Memory drops from O(N) stored activations to
O(|H| + chunk_size): the complement set is forwarded in no-grad chunks whose
activations XLA never materializes for backward.

JAX realization
---------------
PyTorch toggles ``torch.grad.enabled``; in JAX the same effect is a
straight-through combinator built from ``lax.stop_gradient``:

    combined = value_full_stopped + scale * (value_H - stop_grad(value_H))

whose forward value is the exact full-set sum and whose backward is
``scale * d(value_H)``.  The complement ("H-bar") forward runs under
``stop_gradient``-ed parameters inside ``lax.map`` so that peak live
activations are bounded by one chunk — this is what makes LITE a *memory*
optimization rather than a notational one.

All public entry points operate on arbitrary pytrees of encodings so they can
aggregate anything a meta-learner pools: deep-set embeddings, backbone
features, per-class segment sums, inner-loop gradients (MAML, Eq. 3).

Every estimator is generic over a *reduction*: the default collapses the
example axis by weight-and-sum (the historical composite, bit-for-bit),
while the class-statistics entry points (:func:`lite_segment_sum`,
:func:`lite_class_stats` and their serve twins) run their chunk bodies
through :mod:`repro.kernels.dispatch`, so per-class sums and Simple
CNAPs second moments are kernel-fused on the ``ref``/``pallas`` backends
— the per-example ``(B, F, F)`` outer-product tensor the covariance path
used to materialize is gone from the H pass, the no-grad complement
chunks, and the exact path alike.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.tree import tree_cast, tree_stop_gradient
from repro.kernels import dispatch

PyTree = Any
EncodeFn = Callable[[PyTree, PyTree], PyTree]  # (params, batched_inputs) -> per-example encodings


@dataclasses.dataclass(frozen=True)
class LiteSpec:
    """Static configuration for one LITE aggregation site.

    Attributes:
      h: number of support examples to back-propagate (|H| in the paper).
         ``h >= n`` disables subsampling (exact gradient).
      chunk_size: batch size for the no-grad complement forward. Bounds
         activation memory of the H-bar pass. ``None`` -> one chunk.
      exact: force exact gradients (baseline / eval mode).
      compute_dtype: optional dtype name (e.g. ``"bfloat16"``) for the
         no-grad COMPLEMENT forward only: frozen params and inputs are cast
         down, per-chunk encodings are summed with float32 accumulation.
         The differentiable H pass is untouched, so gradients are
         bit-identical to the full-precision estimator; only the exact
         forward value carries low-precision rounding.  At large N the
         complement dominates the FLOPs and the live chunk activations, so
         this is the fast/low-memory path.  Ignored in exact mode (there
         is no complement pass).
    """

    h: int = 8
    chunk_size: int | None = None
    exact: bool = False
    compute_dtype: str | None = None

    def resolved_h(self, n: int) -> int:
        return n if self.exact else min(self.h, n)


def sample_stratified_indices(key: jax.Array, ys: jnp.ndarray,
                              num_classes: int, h: int,
                              mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """h indices with >= 1 example per class when h >= num_classes (the
    guarantee the paper's sub-sampled-task baseline uses, App. D.4 — a
    class with zero samples would make the naive baseline's class
    statistics singular).

    Built on the same per-index scores as ``sample_h_indices``: each
    example's within-class rank comes from ordering the class by its
    (key, index)-only uniforms, so — like the LITE draw — a task padded to
    a larger bucket selects the identical subset (padded rows contribute
    zero to every class count and rank strictly last)."""
    n = ys.shape[0]
    u = _index_scores(key, n)
    order = jnp.argsort(u)
    onehot_sorted = jax.nn.one_hot(ys[order], num_classes, dtype=jnp.float32)
    if mask is not None:
        onehot_sorted = onehot_sorted * mask[order][:, None]
    # rank of each row within its class when the class is ordered by u
    rank_sorted = jnp.sum(jnp.cumsum(onehot_sorted, axis=0) * onehot_sorted,
                          axis=1) - 1.0
    scores = jnp.zeros((n,)).at[order].set(rank_sorted + 0.5 * u[order])
    if mask is not None:
        scores = scores + 2.0 * n * (1.0 - mask)
    return jnp.argsort(scores)[:h]


def _index_scores(key: jax.Array, n: int) -> jnp.ndarray:
    """Per-index uniform scores depending only on (key, index).

    Built from ``fold_in`` per index rather than one shaped draw so the
    score of index i is invariant to n — a task padded to a larger bucket
    scores its real examples identically and therefore draws the same H
    subset (the padding-invariance the task-batch collator relies on).
    """
    return jax.vmap(
        lambda i: jax.random.uniform(jax.random.fold_in(key, i)))(jnp.arange(n))


def sample_h_indices(key: jax.Array, n: int, h: int,
                     mask: jnp.ndarray | None = None
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sample H distinct indices uniformly (without replacement) and return
    (h_idx[h], comp_idx[n-h]).

    Sampling *without* replacement matches the paper's Algorithm 1 line 4 in
    the regime H <= N and keeps the estimator unbiased (each index has equal
    marginal inclusion probability H/N, and the N/H rescaling corrects it).
    Ranking per-index scores yields the uniform permutation; with ``mask``
    (1 real / 0 padding) padded slots rank strictly after every real slot,
    so H fills with real examples first and the draw matches the unpadded
    task's draw index-for-index.
    """
    scores = _index_scores(key, n)
    if mask is not None:
        scores = scores + 2.0 * (1.0 - mask)
    order = jnp.argsort(scores)
    return order[:h], order[h:]


def straight_through(full_value: PyTree, grad_value: PyTree, scale) -> PyTree:
    """forward = full_value ; backward = scale * d(grad_value).

    Leaf-wise:  stop(full) + scale * (grad - stop(grad)).
    """

    def _one(f, g):
        return jax.lax.stop_gradient(f) + scale * (g - jax.lax.stop_gradient(g))

    return jax.tree.map(_one, full_value, grad_value)


def _chunked_nograd_reduce(reduce_fn: Callable, frozen_params: PyTree,
                           xs: PyTree, w: jnp.ndarray,
                           chunk_size: int | None,
                           accum_dtype: jnp.dtype | None = None) -> PyTree:
    """Weighted reduction of per-example encodings over xs, computed under
    stop-gradient'ed parameters, in sequential chunks via ``lax.map`` (so
    only one chunk's activations are ever live).

    ``reduce_fn(params, (xs_chunk, w_chunk), accum_dtype)`` collapses one
    chunk's leading example axis (default: weight rows and sum — see
    :func:`_weighted_reduce`; the segment-statistics sites pass a
    :mod:`repro.kernels.dispatch` reduction instead, which is what keeps
    fused class stats chunk-bounded too).  The chunk-pad tail folds into
    ``w`` as zero weights — 0/1 weight algebra keeps that bit-exact with
    masking the encodings after the fact.  ``accum_dtype`` upcasts each
    chunk's reduction (and the cross-chunk sum) — the fp32 accumulator
    the mixed-precision complement pass relies on."""
    leaves = jax.tree.leaves(xs)
    n = leaves[0].shape[0]
    if n == 0:
        raise ValueError("empty complement — use exact mode instead")
    xs = tree_stop_gradient(xs)

    if chunk_size is None or chunk_size >= n:
        return reduce_fn(frozen_params, (xs, w), accum_dtype)

    # Pad to a multiple of chunk_size; the padded tail carries zero weight.
    num_chunks = -(-n // chunk_size)
    pad = num_chunks * chunk_size - n

    def _pad(a):
        cfg = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
        return jnp.pad(a, cfg)

    def _reshape(a):
        return a.reshape((num_chunks, chunk_size) + a.shape[1:])

    xs_c = jax.tree.map(lambda a: _reshape(_pad(a)), xs)
    w_c = _reshape(_pad(w))

    def _one_chunk(args):
        chunk, wc = args
        return reduce_fn(frozen_params, (chunk, wc), accum_dtype)

    partials = jax.lax.map(_one_chunk, (xs_c, w_c))
    return jax.tree.map(lambda p: jnp.sum(p, axis=0), partials)


def _masked_encode(encode_fn: EncodeFn) -> EncodeFn:
    """Wrap encode_fn to take (inputs, mask) and zero-weight masked rows."""

    def enc(params, xm):
        xs, m = xm
        e = encode_fn(params, xs)
        return jax.tree.map(
            lambda t: t * m.reshape((-1,) + (1,) * (t.ndim - 1)).astype(t.dtype),
            e)

    return enc


def _weighted_reduce(encode_fn: EncodeFn) -> Callable:
    """Default estimator reduction: encode per-example, zero-weight masked
    rows, sum the leading axis — the same composite the estimators always
    ran, bit-for-bit.  Signature: ``reduce(params, (xs, w), accum_dtype)``
    with ``w`` the (N,) 0/1 validity weights."""
    enc_w = _masked_encode(encode_fn)

    def reduce_fn(params, xm, accum_dtype=None):
        enc = enc_w(params, xm)
        return jax.tree.map(
            lambda e: jnp.sum(e, axis=0, dtype=accum_dtype), enc)

    return reduce_fn


def _ones_mask_like(xs: PyTree) -> jnp.ndarray:
    """All-real validity mask for an unmasked input set.  ``mask=None`` and
    an explicit ones mask are the SAME estimator bit-for-bit (weighting by
    1.0 is exact and padded slots simply don't exist), which is what lets
    ``lite_sum``/``subsampled_task_sum`` share one body."""
    return jnp.ones((jax.tree.leaves(xs)[0].shape[0],), jnp.float32)


def _masked_scale(mask: jnp.ndarray, h: int) -> jnp.ndarray:
    """N/H rescale over REAL examples only: when fewer than H real examples
    exist every real example lands in H and the gradient is exact
    (scale 1)."""
    n_real = jnp.sum(mask)
    return n_real / jnp.minimum(float(h), jnp.maximum(n_real, 1.0))


def lite_sum(encode_fn: EncodeFn, params: PyTree, xs: PyTree, key: jax.Array,
             spec: LiteSpec, mask: jnp.ndarray | None = None,
             reduce_fn: Callable | None = None) -> PyTree:
    """LITE estimator of ``sum_n encode_fn(params, x_n)`` (paper Eq. 8).

    Forward value: exact sum over all N examples.
    Backward: (N/H) * d/dparams [ sum over the H sampled examples ].

    Args:
      encode_fn: maps (params, batched inputs) -> per-example encodings
        (any pytree whose leaves have a leading example axis).  May be
        ``None`` when ``reduce_fn`` is given.
      params: differentiable parameters.
      xs: pytree of support inputs, leading axis N on every leaf.
      key: PRNG key for the H subset draw.
      spec: LiteSpec.  With ``spec.compute_dtype`` the complement forward
        runs under down-cast frozen params/inputs with fp32 accumulation —
        gradients are untouched (they flow only through the H pass).
      mask: optional (N,) validity weights (1 real / 0 collator padding).
        Padded rows contribute nothing to forward or backward; the N/H
        rescale uses the REAL count, so a padded task batch reproduces the
        unpadded task's estimator exactly.  ``None`` is exactly equivalent
        to an all-ones mask.
      reduce_fn: optional fused reduction replacing the default
        encode-weight-sum composite; ``reduce_fn(params, (xs_rows,
        w_rows), accum_dtype)`` must collapse the leading example axis of
        a row subset.  This is the hook the class-statistics sites use to
        run their chunk bodies through :mod:`repro.kernels.dispatch`
        (H pass, complement chunks, and exact path all go through it, so
        the estimator algebra is unchanged).

    Returns:
      Pytree of summed encodings (leading axis reduced).
    """
    n = jax.tree.leaves(xs)[0].shape[0]
    h = spec.resolved_h(n)
    if mask is None:
        mask = _ones_mask_like(xs)
    if reduce_fn is None:
        reduce_fn = _weighted_reduce(encode_fn)
    if spec.exact or h >= n:
        return reduce_fn(params, (xs, mask), None)

    h_idx, comp_idx = sample_h_indices(key, n, h, mask)
    take = lambda a, i: jnp.take(a, i, axis=0)
    xs_h = jax.tree.map(partial(take, i=h_idx), xs)
    xs_c = jax.tree.map(partial(take, i=comp_idx), xs)
    w_c = mask[comp_idx]

    # Differentiable pass over H (single batch — |H| is small by
    # construction).
    sum_h = reduce_fn(params, (xs_h, mask[h_idx]), None)

    # No-grad pass over the complement, chunked; optionally in low
    # precision (the dominant FLOPs at large N) with fp32 accumulation.
    frozen = tree_stop_gradient(params)
    accum = None
    if spec.compute_dtype is not None:
        cd = jnp.dtype(spec.compute_dtype)
        frozen = tree_cast(frozen, cd)
        xs_c = tree_cast(xs_c, cd)
        accum = jnp.float32
    sum_c = _chunked_nograd_reduce(reduce_fn, frozen, xs_c, w_c,
                                   spec.chunk_size, accum_dtype=accum)

    full = jax.tree.map(lambda a, b: jax.lax.stop_gradient(a + b.astype(a.dtype)),
                        sum_h, sum_c)
    return straight_through(full, sum_h, _masked_scale(mask, h))


def serve_sum(encode_fn: EncodeFn, params: PyTree, xs: PyTree, key: jax.Array,
              spec: LiteSpec, mask: jnp.ndarray | None = None,
              reduce_fn: Callable | None = None) -> PyTree:
    """Serve-time twin of :func:`lite_sum`: the EXACT masked sum, computed
    the way LITE computes its complement — forward-only under
    ``stop_gradient``, in ``spec.chunk_size``-bounded chunks, optionally in
    low precision (``spec.compute_dtype``) with fp32 accumulation.

    Adaptation at serve time is a pure forward pass ("just a few
    optimization steps or a single forward pass" per new task — there is no
    meta-gradient to estimate), so the H-subset machinery is unnecessary:
    what LITE contributes at serve is the *memory* discipline of its
    complement pass, which lets a 1000-image support set adapt under the
    same O(chunk) activation bound as training.  ``key`` and
    ``spec.h``/``spec.exact`` are accepted (signature-compatible with
    ``lite_sum`` so learners thread it through the same estimator sites)
    but ignored.

    With ``chunk_size=None`` the value is bit-identical to exact
    ``lite_sum`` (same masked encode, same single ``jnp.sum``); chunking
    only reassociates the cross-chunk accumulation.  ``reduce_fn`` is the
    same fused-reduction hook as :func:`lite_sum`'s.
    """
    del key  # nothing is subsampled
    if mask is None:
        mask = _ones_mask_like(xs)
    if reduce_fn is None:
        reduce_fn = _weighted_reduce(encode_fn)
    frozen = tree_stop_gradient(params)
    xs = tree_stop_gradient(xs)
    accum = None
    if spec.compute_dtype is not None:
        cd = jnp.dtype(spec.compute_dtype)
        frozen = tree_cast(frozen, cd)
        xs = tree_cast(xs, cd)
        accum = jnp.float32
    return _chunked_nograd_reduce(reduce_fn, frozen, xs, mask,
                                  spec.chunk_size, accum_dtype=accum)


def _masked_onehot(ys: jnp.ndarray, num_classes: int,
                   mask: jnp.ndarray | None) -> jnp.ndarray:
    onehot_all = jax.nn.one_hot(ys, num_classes, dtype=jnp.float32)  # (N, C)
    if mask is not None:
        # padded labels are -1 (already a zero one-hot row); the explicit
        # product keeps counts exact even if a collator pads with 0..way-1
        onehot_all = onehot_all * mask[:, None]
    return onehot_all


def lite_segment_sum(encode_fn: EncodeFn, params: PyTree, xs: PyTree,
                     ys: jnp.ndarray, num_classes: int, key: jax.Array,
                     spec: LiteSpec, mask: jnp.ndarray | None = None,
                     sum_fn: Callable | None = None,
                     backend: str | None = None
                     ) -> Tuple[PyTree, jnp.ndarray]:
    """LITE estimator of per-class sums  S_c = sum_n 1(y_n = c) e(x_n).

    Needed by metric heads (ProtoNets prototypes, Simple CNAPs class
    means/covariances) and CNAPs' class-pooled classifier generator.  A single
    global N/H rescale keeps every class-sum unbiased because the H draw is
    uniform over ALL support indices:  E[sum_{h} 1(y=c) de] = (H/N) * S'_c.

    The chunk bodies (H pass, no-grad complement chunks, exact path) run
    through :func:`repro.kernels.dispatch.segment_sum` — ``backend``
    selects the implementation (None = the ambient dispatch default; the
    ``ref``/``naive`` backends reproduce the pre-dispatch expand+reduce
    composite bit-for-bit, ``pallas`` runs the one-hot MXU matmul kernel
    under a ``custom_vjp``).

    ``sum_fn`` swaps the underlying set-sum estimator (default
    :func:`lite_sum`); :func:`serve_segment_sum` passes :func:`serve_sum`
    for the forward-only serve path.

    Returns (class_sums pytree with leading axis C, counts[C] float32).
    Counts are exact (labels are not subsampled).
    """
    onehot_all = _masked_onehot(ys, num_classes, mask)
    counts = jnp.sum(onehot_all, axis=0)  # exact

    def seg_reduce(p, xm, accum_dtype=None):
        (inputs, onehot), w = xm
        # the 0/1 row weights (validity + chunk-pad tail) fold into the
        # one-hot — exact in ANY float dtype, so a low-precision
        # complement pass stays low-precision (fp32 class sums come from
        # the accum_dtype accumulation)
        oh = onehot * w.astype(onehot.dtype)[:, None]
        enc = encode_fn(p, inputs)  # leaves (B, ...)
        return jax.tree.map(
            lambda e: dispatch.segment_sum(e, oh, accum_dtype=accum_dtype,
                                           backend=backend), enc)

    sums = (sum_fn or lite_sum)(None, params, (xs, onehot_all), key,
                                spec, mask=mask, reduce_fn=seg_reduce)
    return sums, counts


def serve_segment_sum(encode_fn: EncodeFn, params: PyTree, xs: PyTree,
                      ys: jnp.ndarray, num_classes: int, key: jax.Array,
                      spec: LiteSpec, mask: jnp.ndarray | None = None,
                      backend: str | None = None
                      ) -> Tuple[PyTree, jnp.ndarray]:
    """Serve-time twin of :func:`lite_segment_sum`: exact per-class sums via
    :func:`serve_sum` — forward-only, chunked, optional low-precision
    compute with fp32 accumulation.  See ``serve_sum`` for the contract."""
    return lite_segment_sum(encode_fn, params, xs, ys, num_classes, key,
                            spec, mask=mask, sum_fn=serve_sum,
                            backend=backend)


def lite_class_stats(features_fn: Callable, params: PyTree, xs: PyTree,
                     ys: jnp.ndarray, num_classes: int, key: jax.Array,
                     spec: LiteSpec, mask: jnp.ndarray | None = None,
                     second_moment: bool = False,
                     sum_fn: Callable | None = None,
                     backend: str | None = None
                     ) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray]:
    """Fused per-class feature statistics under the LITE estimator.

    ``features_fn(params, inputs) -> (B, F)`` is a single feature matrix
    (NOT a pytree).  Returns ``(stats, counts)`` with ``stats["feat"]``
    the per-class feature sums (C, F) and — when ``second_moment`` —
    ``stats["outer"]`` the per-class raw second moments
    ``sum_n 1(y_n = c) f_n f_n^T`` (C, F, F).

    The point of this entry over :func:`lite_segment_sum` with an
    outer-product encode: the chunk bodies go through
    :func:`repro.kernels.dispatch.class_second_moment`, so on the ``ref``
    and ``pallas`` backends the per-example ``(B, F, F)`` outer tensor is
    NEVER materialized — not in the H pass, not in the no-grad complement
    chunks, not on the exact path.  Live bytes per chunk drop from
    O(chunk * F^2 * way) to O(chunk * F * way + F^2 * way).  (The
    ``naive`` backend keeps the materializing composite as the bit-exact
    legacy oracle; fused contractions reassociate the example-axis sum,
    so their fp32 bits differ from naive at the last ulp.)

    Same estimator algebra as every LITE site: exact forward, H-subset
    backward with the global N/H rescale, mask/padded-lane invariance,
    ``spec.compute_dtype`` complement with fp32 accumulation.
    """
    onehot_all = _masked_onehot(ys, num_classes, mask)
    counts = jnp.sum(onehot_all, axis=0)  # exact

    def stats_reduce(p, xm, accum_dtype=None):
        (inputs, onehot), w = xm
        oh = onehot * w.astype(onehot.dtype)[:, None]
        feat = features_fn(p, inputs)                       # (B, F)
        out = dict(feat=dispatch.segment_sum(feat, oh,
                                             accum_dtype=accum_dtype,
                                             backend=backend))
        if second_moment:
            out["outer"] = dispatch.class_second_moment(
                feat, oh, accum_dtype=accum_dtype, backend=backend)
        return out

    stats = (sum_fn or lite_sum)(None, params, (xs, onehot_all), key,
                                 spec, mask=mask, reduce_fn=stats_reduce)
    return stats, counts


def lite_value_and_grad(loss_fn: Callable, argnums: int = 0):
    """Convenience: ``jax.value_and_grad`` for losses already built on
    ``lite_sum``/``lite_segment_sum`` sites.  Exists so call sites read as a
    single named concept; the estimator itself lives in the combinators."""
    return jax.value_and_grad(loss_fn, argnums=argnums)


# ---------------------------------------------------------------------------
# Naive baseline the paper compares against (Fig. 4 / Table D.8): training on
# a sub-sampled *small task* — forward AND backward see only H examples.
# ---------------------------------------------------------------------------


def subsampled_task_sum(encode_fn: EncodeFn, params: PyTree, xs: PyTree,
                        key: jax.Array, spec: LiteSpec,
                        mask: jnp.ndarray | None = None) -> PyTree:
    """Forward and backward both restricted to the H subset, rescaled by N/H
    so the *expected forward value* matches the full sum.  Unbiased in value
    but — unlike LITE — the downstream L'(e) factor is evaluated at a noisy
    encoding, which is what inflates its gradient RMSE (paper Fig. 4)."""
    n = jax.tree.leaves(xs)[0].shape[0]
    h = spec.resolved_h(n)
    if mask is None:
        mask = _ones_mask_like(xs)
    enc_w = _masked_encode(encode_fn)
    if spec.exact or h >= n:
        enc = enc_w(params, (xs, mask))
        return jax.tree.map(lambda e: jnp.sum(e, axis=0), enc)
    h_idx, _ = sample_h_indices(key, n, h, mask)
    enc = enc_w(params, (jax.tree.map(lambda a: jnp.take(a, h_idx, axis=0), xs),
                         mask[h_idx]))
    scale = _masked_scale(mask, h)
    return jax.tree.map(lambda e: scale * jnp.sum(e, axis=0), enc)
