"""Episodic task abstractions (paper §2).

A task τ is a support set D_S = {(x_n, y_n)}_{n=1..N} and a query set
D_Q = {(x*_m, y*_m)}_{m=1..M} drawn over the same classes.  Labels are
task-local (0..way-1).  Tasks are plain pytrees so they can be sharded,
scanned over, and fed to jit'd steps directly.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Task:
    """One episodic task. Leaves:

      support_x: (N, ...) inputs (images, token sequences, embeddings)
      support_y: (N,) int32 task-local labels in [0, way)
      query_x:   (M, ...) inputs
      query_y:   (M,) int32 task-local labels
      way:       static number of classes (data field would break pytree
                 flattening under vmap; kept as metadata)
    """

    support_x: jnp.ndarray
    support_y: jnp.ndarray
    query_x: jnp.ndarray
    query_y: jnp.ndarray
    way: int = dataclasses.field(metadata=dict(static=True), default=5)

    @property
    def n_support(self) -> int:
        return self.support_x.shape[0]

    @property
    def n_query(self) -> int:
        return self.query_x.shape[0]


def validate_task(task: Task) -> None:
    """Host-side invariant checks (used by tests and the data pipeline)."""
    assert task.support_x.shape[0] == task.support_y.shape[0], "support len mismatch"
    assert task.query_x.shape[0] == task.query_y.shape[0], "query len mismatch"


def query_batches(task: Task, batch_size: int):
    """Split the query set into ceil(M / batch_size) padded batches plus a
    per-example weight mask (Algorithm 1's outer loop).  Returns
    (query_x[B, Mb, ...], query_y[B, Mb], weight[B, Mb])."""
    m = task.query_x.shape[0]
    b = -(-m // batch_size)
    pad = b * batch_size - m

    def _pad(a):
        cfg = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
        return jnp.pad(a, cfg)

    qx = _pad(task.query_x).reshape((b, batch_size) + task.query_x.shape[1:])
    qy = _pad(task.query_y).reshape(b, batch_size)
    w = (jnp.arange(b * batch_size) < m).astype(jnp.float32).reshape(b, batch_size)
    return qx, qy, w
