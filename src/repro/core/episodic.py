"""Episodic task abstractions (paper §2).

A task τ is a support set D_S = {(x_n, y_n)}_{n=1..N} and a query set
D_Q = {(x*_m, y*_m)}_{m=1..M} drawn over the same classes.  Labels are
task-local (0..way-1).  Tasks are plain pytrees so they can be sharded,
scanned over, and fed to jit'd steps directly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Task:
    """One episodic task. Leaves:

      support_x: (N, ...) inputs (images, token sequences, embeddings)
      support_y: (N,) int32 task-local labels in [0, way)
      query_x:   (M, ...) inputs
      query_y:   (M,) int32 task-local labels
      way:       static number of classes (data field would break pytree
                 flattening under vmap; kept as metadata)
      support_mask / query_mask: optional (N,)/(M,) float32 validity masks
                 (1 = real example, 0 = collator padding).  ``None`` means
                 "all real"; learners and the LITE estimators treat masked
                 examples as absent, so a padded task computes the same
                 loss/gradients as its unpadded original.  Padded support
                 labels are -1 (one-hot maps them to the zero row).
    """

    support_x: jnp.ndarray
    support_y: jnp.ndarray
    query_x: jnp.ndarray
    query_y: jnp.ndarray
    way: int = dataclasses.field(metadata=dict(static=True), default=5)
    support_mask: Optional[jnp.ndarray] = None
    query_mask: Optional[jnp.ndarray] = None

    @property
    def n_support(self) -> int:
        return self.support_x.shape[0]

    @property
    def n_query(self) -> int:
        return self.query_x.shape[0]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TaskBatch:
    """T tasks padded to one bucket shape and stacked on a leading task axis.

    The batch is what the task-batched training engine consumes: every leaf
    has static shape so ``vmap``/``shard_map`` over axis 0 sees one SPMD
    program regardless of the original (ragged) task sizes.  Leaves:

      support_x: (T, N, ...)   support_y: (T, N)   support_mask: (T, N)
      query_x:   (T, M, ...)   query_y:   (T, M)   query_mask:   (T, M)

    Masks are float32 validity weights (1 real / 0 padding); padded support
    labels are -1 so one-hot aggregation drops them.  ``way`` is static and
    shared by all tasks in the batch (the collator enforces this).
    """

    support_x: jnp.ndarray
    support_y: jnp.ndarray
    query_x: jnp.ndarray
    query_y: jnp.ndarray
    support_mask: jnp.ndarray
    query_mask: jnp.ndarray
    way: int = dataclasses.field(metadata=dict(static=True), default=5)

    @property
    def num_tasks(self) -> int:
        return self.support_x.shape[0]

    def task(self, i: int) -> Task:
        """Host-side view of one member task (padding kept, masks attached)."""
        return Task(support_x=self.support_x[i], support_y=self.support_y[i],
                    query_x=self.query_x[i], query_y=self.query_y[i],
                    way=self.way, support_mask=self.support_mask[i],
                    query_mask=self.query_mask[i])


def validate_task(task: Task) -> None:
    """Host-side invariant checks (used by tests and the data pipeline)."""
    assert task.support_x.shape[0] == task.support_y.shape[0], "support len mismatch"
    assert task.query_x.shape[0] == task.query_y.shape[0], "query len mismatch"


def validate_task_batch(batch: TaskBatch) -> None:
    t = batch.support_x.shape[0]
    for leaf in (batch.support_y, batch.support_mask, batch.query_x,
                 batch.query_y, batch.query_mask):
        assert leaf.shape[0] == t, "task-axis length mismatch"
    assert batch.support_mask.shape == batch.support_y.shape
    assert batch.query_mask.shape == batch.query_y.shape


def stack_task_states(states) -> PyTree:
    """Stack single-task adapted states into a *task-state batch* — the
    pytree ``learner.predict_batch`` consumes: every leaf gains a leading
    task axis.  The inverse of :func:`index_task_state`.  All states must
    share treedef and leaf shapes (same learner kind, way, and pad
    buckets) — exactly what the serving engine's slot discipline
    guarantees."""
    return jax.tree.map(lambda *ls: jnp.stack(ls), *states)


def index_task_state(states: PyTree, i: int) -> PyTree:
    """One member state of a task-state batch (leading-axis index ``i``) —
    what the serving engine's LRU cache stores per task uid."""
    return jax.tree.map(lambda a: a[i], states)


def query_batches(task: Task, batch_size: int):
    """Split the query set into ceil(M / batch_size) padded batches plus a
    per-example weight mask (Algorithm 1's outer loop).  Returns
    (query_x[B, Mb, ...], query_y[B, Mb], weight[B, Mb]).  An existing
    ``task.query_mask`` (collator padding) folds into the weights."""
    m = task.query_x.shape[0]
    b = -(-m // batch_size)
    pad = b * batch_size - m

    def _pad(a):
        cfg = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
        return jnp.pad(a, cfg)

    qx = _pad(task.query_x).reshape((b, batch_size) + task.query_x.shape[1:])
    qy = _pad(task.query_y).reshape(b, batch_size)
    w = (jnp.arange(b * batch_size) < m).astype(jnp.float32).reshape(b, batch_size)
    if task.query_mask is not None:
        w = w * _pad(task.query_mask).reshape(b, batch_size)
    return qx, qy, w
