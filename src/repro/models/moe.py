"""Mixture-of-Experts FFN with sort-based token dispatch.

TPU-native dispatch (no per-token one-hot over all experts, which would be
O(T*E) memory): token→expert assignments are sorted by expert id, packed
into an (E, C, D) capacity buffer via scatter, run through a single batched
expert matmul (the MXU-friendly grouped GEMM), and combined back with the
router weights.  Capacity C = ceil(T * top_k / E * capacity_factor); slots
past capacity are dropped (GShard semantics) — the drop fraction is tiny at
cf >= 1.25 and exactly zero in the balanced limit.

Under SPMD the expert axis shards over 'model' (EP) and the token axis over
'data'; XLA inserts the dispatch all-to-all at the scatter/gather
boundaries.  All ops here are differentiable (gathers/scatter-adds), so the
same code path serves train and inference.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.init import lecun_normal
from repro.configs.base import MoEConfig

Params = Dict


def init_moe(key: jax.Array, d_model: int, cfg: MoEConfig) -> Params:
    ks = jax.random.split(key, 5)
    e, f = cfg.n_experts, cfg.d_ff
    p = dict(
        router=lecun_normal(ks[0], (d_model, e)),
        w_gate=lecun_normal(ks[1], (e, d_model, f)),
        w_up=lecun_normal(ks[2], (e, d_model, f)),
        w_down=lecun_normal(ks[3], (e, f, d_model)),
    )
    if cfg.n_shared > 0:
        sf = cfg.n_shared * f
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = dict(
            w_gate=lecun_normal(k1, (d_model, sf)),
            w_up=lecun_normal(k2, (d_model, sf)),
            w_down=lecun_normal(k3, (sf, d_model)),
        )
    return p


def router_probs(p: Params, x: jnp.ndarray, cfg: MoEConfig
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (T, D) -> (weights (T,k), expert_ids (T,k), probs (T,E))."""
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    if cfg.router_softcap is not None:
        logits = cfg.router_softcap * jnp.tanh(logits / cfg.router_softcap)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalize
    return top_p, top_i, probs


def load_balance_loss(probs: jnp.ndarray, expert_ids: jnp.ndarray,
                      n_experts: int) -> jnp.ndarray:
    """Switch-style auxiliary loss: E * sum_e f_e * P_e."""
    t = probs.shape[0]
    f = jnp.zeros((n_experts,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0 / (t * expert_ids.shape[-1]))
    pbar = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(f * pbar)


def capacity(t: int, cfg: MoEConfig) -> int:
    c = int(t * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return max(8, ((c + 7) // 8) * 8)   # align slots


def moe_ffn(p: Params, x: jnp.ndarray, cfg: MoEConfig,
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (T, D) flattened tokens -> (y (T, D), aux_loss scalar)."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(t, cfg)

    weights, expert_ids, probs = router_probs(p, x, cfg)        # (T,k)
    aux = load_balance_loss(probs, expert_ids, e)

    flat_e = expert_ids.reshape(-1)                              # (T*k,)
    flat_w = weights.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)                      # token of slot

    order = jnp.argsort(flat_e)                                  # stable sort
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    w_sorted = flat_w[order]

    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts                         # segment starts
    pos_in_e = jnp.arange(t * k) - starts[e_sorted]              # rank in expert
    keep = pos_in_e < c                                          # capacity drop
    slot = e_sorted * c + jnp.minimum(pos_in_e, c - 1)           # (T*k,)

    # pack tokens into the (E*C, D) dispatch buffer
    buf = jnp.zeros((e * c, d), x.dtype)
    contrib = jnp.where(keep[:, None], x[tok_sorted], 0).astype(x.dtype)
    buf = buf.at[slot].add(contrib, mode="drop")
    buf = buf.reshape(e, c, d)

    # grouped expert FFN — one batched matmul per projection (MXU path)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype)))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
    out = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"].astype(x.dtype))
    out = out.reshape(e * c, d)

    # combine back to token order with router weights
    gathered = out[slot] * (w_sorted * keep).astype(x.dtype)[:, None]
    y = jnp.zeros((t, d), x.dtype).at[tok_sorted].add(gathered)

    if cfg.n_shared > 0:
        s = p["shared"]
        sg = jax.nn.silu(x @ s["w_gate"].astype(x.dtype))
        su = x @ s["w_up"].astype(x.dtype)
        y = y + (sg * su) @ s["w_down"].astype(x.dtype)
    return y, aux


# ===========================================================================
# Expert-parallel shard_map path (§Perf hillclimb: the GSPMD-partitioned
# scatter dispatch above degenerates to replicate+all-reduce of the FULL
# (T*k, D) contribution tensor — 241 GB/layer at kimi scale.  This variant
# pins the communication pattern explicitly:
#   * tokens stay on their (pod, data) shard;
#   * routing is computed redundantly on each model shard (cheap);
#   * each model shard gathers ONLY its own E/16 experts' tokens locally,
#     runs the grouped GEMMs, scatter-adds its partial outputs;
#   * one all-gather (model) of activations in + one reduce-scatter out.
# Wire/layer: 2 x T_loc x D instead of ~3 x T x k x D x f32.
#
# The boundary spec must MATCH the residual-stream layout, or GSPMD
# reshards the full activation at every layer entry/exit (measured: f32
# (B, S, D) all-gathers dominating the deepseek prefill cell, WORSE than
# the GSPMD-scatter baseline).  Two layouts:
#   * 'hidden' (default residual_spec): tokens over (pod,)data, D over
#     model -> xl is (T_loc, D/m); the body all-gathers the HIDDEN axis
#     and psum_scatters it back.
#   * 'seq': the flattened token axis nests over ((pod,)data, model), D
#     replicated -> the body all-gathers the TOKEN axis back to the data
#     shard and psum_scatters tokens out.
# Both move 2 x T_loc x D per layer inside the body and ZERO bytes at the
# boundary.
# ===========================================================================


def _moe_local_body(cfg: MoEConfig, n_model: int, data_axes=("data",),
                    gather_axis: int = 0):
    def body(xl, router, wg, wu, wd):
        """Per-shard code. xl: (T_loc, D/m) ['hidden': gather_axis=1] or
        (T_loc/m, D) ['seq': gather_axis=0] — gathered to (T_loc, D).
        wg/wu/wd: this shard's (E_loc, ...) expert slice."""
        xf = jax.lax.all_gather(xl, "model", axis=gather_axis, tiled=True)
        t_loc, d = xf.shape
        e, k = cfg.n_experts, cfg.top_k
        e_loc = e // n_model
        c = capacity(t_loc, cfg)

        logits = xf.astype(jnp.float32) @ router.astype(jnp.float32)
        if cfg.router_softcap is not None:
            logits = cfg.router_softcap * jnp.tanh(logits / cfg.router_softcap)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
        aux = load_balance_loss(probs, top_i, e)

        flat_e = top_i.reshape(-1)
        flat_w = top_p.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(t_loc), k)
        order = jnp.argsort(flat_e)
        e_sorted = flat_e[order]
        tok_sorted = flat_tok[order]
        w_sorted = flat_w[order]
        counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
        starts = jnp.cumsum(counts) - counts

        # my experts: [m0, m0 + e_loc)
        m_idx = jax.lax.axis_index("model")
        m0 = m_idx * e_loc
        my_counts = jax.lax.dynamic_slice(counts, (m0,), (e_loc,))
        my_starts = jax.lax.dynamic_slice(starts, (m0,), (e_loc,))
        slot_pos = jnp.arange(c)[None, :]                       # (1, C)
        src = my_starts[:, None] + slot_pos                     # (E_loc, C)
        valid = slot_pos < my_counts[:, None]
        src = jnp.clip(src, 0, t_loc * k - 1)
        my_tok = tok_sorted[src]                                # (E_loc, C)
        my_w = jnp.where(valid, w_sorted[src], 0.0)

        buf = jnp.where(valid[..., None], xf[my_tok], 0).astype(xl.dtype)
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg.astype(xl.dtype)))
        u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(xl.dtype))
        out = jnp.einsum("ecf,efd->ecd", g * u, wd.astype(xl.dtype))
        out = out * my_w[..., None].astype(xl.dtype)

        y = jnp.zeros((t_loc, d), xl.dtype)
        y = y.at[my_tok.reshape(-1)].add(out.reshape(-1, d), mode="drop")
        y = jax.lax.psum_scatter(y, "model", scatter_dimension=gather_axis,
                                 tiled=True)
        for ax in data_axes:          # incl. 'pod' on multi-pod meshes
            aux = jax.lax.pmean(aux, ax)
        aux = jax.lax.pmean(aux, "model")
        return y, aux

    return body


def moe_ffn_sharded(p: Params, x: jnp.ndarray, cfg: MoEConfig, mesh,
                    layout: str = "hidden"
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE over an explicit mesh (tokens: (pod,)data;
    experts: model).  ``layout`` names the residual-stream layout the
    boundary specs must match ('hidden' | 'seq', see block comment above).
    Falls back to moe_ffn when the shapes don't divide.  x: (T, D) global."""
    from jax.sharding import PartitionSpec as P

    from repro.sharding import shard_map
    sizes = dict(mesh.shape)
    n_model = sizes.get("model", 1)
    n_data = sizes.get("data", 1) * sizes.get("pod", 1)
    t, d = x.shape
    hidden = layout == "hidden"
    divides = (t % n_data == 0 and d % n_model == 0) if hidden \
        else t % (n_data * n_model) == 0
    if n_model <= 1 or cfg.n_experts % n_model or not divides:
        return moe_ffn(p, x, cfg)

    data_axes = ("pod", "data") if "pod" in sizes else ("data",)
    if hidden:
        x_spec = P(data_axes, "model")
    else:
        x_spec = P(data_axes + ("model",), None)
    body = _moe_local_body(cfg, n_model, data_axes,
                           gather_axis=1 if hidden else 0)

    def wrapped(xl, router, wg, wu, wd):
        return body(xl, router, wg, wu, wd)

    y, aux = shard_map(
        wrapped, mesh=mesh,
        in_specs=(x_spec, P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(x_spec, P()),
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if cfg.n_shared > 0:
        s = p["shared"]
        sg = jax.nn.silu(x @ s["w_gate"].astype(x.dtype))
        su = x @ s["w_up"].astype(x.dtype)
        y = y + (sg * su) @ s["w_down"].astype(x.dtype)
    return y, aux
