"""Whisper-style encoder-decoder transformer backbone.

Per the assignment brief, the conv/audio frontend is a STUB: the encoder
consumes precomputed frame embeddings (B, S_enc, d_model) provided by
``input_specs`` / the data pipeline.  Encoder: bidirectional self-attention;
decoder: causal self-attention + cross-attention to the encoder output.

Deviation from the original (recorded in DESIGN.md): rotary positions
instead of learned/sinusoidal tables, so sequence length is unconstrained
for the assigned 32k decode shapes.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.init import lecun_normal
from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.transformer import logits_head, _xent, _remat
from repro.sharding.ctx import constrain, residual_spec, P

Params = Dict


def init_cross_attn(key: jax.Array, cfg: ModelConfig) -> Params:
    a = cfg.attention
    d, h, dh = cfg.d_model, a.n_heads, a.head_dim
    ks = jax.random.split(key, 4)
    return dict(
        wq=lecun_normal(ks[0], (d, h * dh)),
        wk=lecun_normal(ks[1], (d, h * dh)),
        wv=lecun_normal(ks[2], (d, h * dh)),
        wo=lecun_normal(ks[3], (h * dh, d)),
    )


def init_enc_block(key: jax.Array, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return dict(
        attn_norm=jnp.zeros((cfg.d_model,)),
        ffn_norm=jnp.zeros((cfg.d_model,)),
        attn=L.init_gqa(k1, cfg),
        ffn=L.init_mlp(k2, cfg.d_model, cfg.d_ff),
    )


def init_dec_block(key: jax.Array, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return dict(
        attn_norm=jnp.zeros((cfg.d_model,)),
        cross_norm=jnp.zeros((cfg.d_model,)),
        ffn_norm=jnp.zeros((cfg.d_model,)),
        attn=L.init_gqa(k1, cfg),
        cross=init_cross_attn(k2, cfg),
        ffn=L.init_mlp(k3, cfg.d_model, cfg.d_ff),
    )


def init_whisper(key: jax.Array, cfg: ModelConfig) -> Params:
    k_embed, k_enc, k_dec = jax.random.split(key, 3)
    enc_keys = jax.random.split(k_enc, cfg.n_encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    return dict(
        embed=L.init_embed(k_embed, cfg.vocab_padded, cfg.d_model),
        encoder=jax.vmap(lambda k: init_enc_block(k, cfg))(enc_keys),
        decoder=jax.vmap(lambda k: init_dec_block(k, cfg))(dec_keys),
        enc_norm=jnp.zeros((cfg.d_model,)),
        final_norm=jnp.zeros((cfg.d_model,)),
    )


def encode(params: Params, frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """frames: (B, S_enc, D) stub embeddings -> encoder states."""
    a = cfg.attention
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    x = constrain(x, P("data", None, None))

    def body(lp, x):
        h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        x = x + L.gqa_attention_bidir(lp["attn"], h, a)
        h = L.rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
        x = x + L.mlp(lp["ffn"], h)
        return constrain(x, residual_spec(cfg))

    body = _remat(body, cfg)

    def step(x, lp):
        return body(lp, x), None

    x, _ = jax.lax.scan(step, x, params["encoder"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def cross_attention(cp: Params, x: jnp.ndarray, enc: jnp.ndarray,
                    cfg: ModelConfig) -> jnp.ndarray:
    a = cfg.attention
    b, s, _ = x.shape
    se = enc.shape[1]
    q = (x @ cp["wq"].astype(x.dtype)).reshape(b, s, a.n_heads, a.head_dim)
    k = (enc @ cp["wk"].astype(x.dtype)).reshape(b, se, a.n_heads, a.head_dim)
    v = (enc @ cp["wv"].astype(x.dtype)).reshape(b, se, a.n_heads, a.head_dim)
    o = L.attention_scores(q, k, v, causal=False)
    return o.reshape(b, s, -1) @ cp["wo"].astype(x.dtype)


def cross_attention_cached(cp: Params, x: jnp.ndarray, k: jnp.ndarray,
                           v: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    a = cfg.attention
    b, s, _ = x.shape
    q = (x @ cp["wq"].astype(x.dtype)).reshape(b, s, a.n_heads, a.head_dim)
    o = L.attention_scores(q, k, v, causal=False)
    return o.reshape(b, s, -1) @ cp["wo"].astype(x.dtype)


def decode_trunk(params: Params, x: jnp.ndarray, enc: jnp.ndarray,
                 cfg: ModelConfig) -> jnp.ndarray:
    a = cfg.attention

    def body(lp, x):
        h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        x = x + L.gqa_attention(lp["attn"], h, a)
        h = L.rms_norm(x, lp["cross_norm"], cfg.norm_eps)
        x = x + cross_attention(lp["cross"], h, enc, cfg)
        h = L.rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
        x = x + L.mlp(lp["ffn"], h)
        return constrain(x, residual_spec(cfg))

    body = _remat(body, cfg)

    def step(x, lp):
        return body(lp, x), None

    x, _ = jax.lax.scan(step, x, params["decoder"])
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


def loss(params: Params, batch: Dict, cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    """batch: frames (B, S_enc, D) float, tokens (B, S) int32."""
    tokens = batch["tokens"]
    enc = encode(params, batch["frontend_embeds"], cfg)
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.compute_dtype))
    x = constrain(x, P("data", None, None))
    h = decode_trunk(params, x, enc, cfg)
    labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
    mask = jnp.pad(jnp.ones_like(tokens[:, 1:], jnp.float32), ((0, 0), (0, 1)))
    nll = _xent(params, h, labels, mask, cfg)
    return nll, dict(nll=nll, aux=jnp.zeros((), jnp.float32))


# --------------------------------------------------------------------------
# inference: decoder self-attn KV cache + precomputed cross K/V
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int) -> Dict:
    a = cfg.attention
    dt = jnp.dtype(cfg.compute_dtype)
    lb = (cfg.n_layers, batch_size)
    se = cfg.n_frontend_tokens
    return dict(
        k=jnp.zeros(lb + (max_seq, a.n_kv_heads, a.head_dim), dt),
        v=jnp.zeros(lb + (max_seq, a.n_kv_heads, a.head_dim), dt),
        cross_k=jnp.zeros(lb + (se, a.n_heads, a.head_dim), dt),
        cross_v=jnp.zeros(lb + (se, a.n_heads, a.head_dim), dt),
        len=jnp.zeros((), jnp.int32),
    )


def prefill(params: Params, batch: Dict, cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    a = cfg.attention
    tokens = batch["tokens"]
    enc = encode(params, batch["frontend_embeds"], cfg)
    b, s = tokens.shape
    se = enc.shape[1]
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.compute_dtype))
    positions = jnp.arange(s)

    def step(x, lp):
        h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        q, k, v = L.gqa_project_qkv(lp["attn"], h, a, positions)
        o = L.attention_scores(q, k, v, causal=True)
        x = x + o.reshape(b, s, -1) @ lp["attn"]["wo"].astype(h.dtype)
        h = L.rms_norm(x, lp["cross_norm"], cfg.norm_eps)
        ck = (enc @ lp["cross"]["wk"].astype(h.dtype)).reshape(b, se, a.n_heads, a.head_dim)
        cv = (enc @ lp["cross"]["wv"].astype(h.dtype)).reshape(b, se, a.n_heads, a.head_dim)
        x = x + cross_attention_cached(lp["cross"], h, ck, cv, cfg)
        h = L.rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
        x = x + L.mlp(lp["ffn"], h)
        return x, (k, v, ck, cv)

    x, (ks, vs, cks, cvs) = jax.lax.scan(step, x, params["decoder"])
    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_head(params, h[:, -1:, :], cfg)[:, 0, :]
    return logits, dict(k=ks, v=vs, cross_k=cks, cross_v=cvs,
                        len=jnp.asarray(s, jnp.int32))


def decode_step(params: Params, cache: Dict, tokens: jnp.ndarray,
                cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    a = cfg.attention
    b = tokens.shape[0]
    pos = cache["len"]
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.compute_dtype))

    def step(x, xs):
        lp, k_c, v_c, ck, cv = xs
        h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        q, k, v = L.gqa_project_qkv(lp["attn"], h, a, jnp.full((b, 1), pos, jnp.int32))
        k_c = jax.lax.dynamic_update_slice(k_c, k, (0, pos, 0, 0))
        v_c = jax.lax.dynamic_update_slice(v_c, v, (0, pos, 0, 0))
        o = L.attention_scores(q, k_c, v_c, causal=False,
                               q_positions=jnp.full((1,), pos, jnp.int32),
                               k_positions=jnp.arange(k_c.shape[1]),
                               k_len=pos + 1)
        x = x + o.reshape(b, 1, -1) @ lp["attn"]["wo"].astype(h.dtype)
        h = L.rms_norm(x, lp["cross_norm"], cfg.norm_eps)
        x = x + cross_attention_cached(lp["cross"], h, ck, cv, cfg)
        h = L.rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
        x = x + L.mlp(lp["ffn"], h)
        return x, (k_c, v_c)

    x, (ks, vs) = jax.lax.scan(
        step, x, (params["decoder"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_head(params, h, cfg)[:, 0, :]
    return logits, dict(k=ks, v=vs, cross_k=cache["cross_k"],
                        cross_v=cache["cross_v"], len=pos + 1)
