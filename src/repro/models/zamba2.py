"""Zamba2-style hybrid: a Mamba-2 trunk with one SHARED attention+MLP block
applied at a fixed cadence (every ``hybrid_attn_every``-th depth position).

Depth layout for n_layers=81, every=6:
  13 groups x (5 mamba layers + shared-attn application) + 3 tail mamba
The shared block's weights appear ONCE in the param tree (the Zamba trick —
transformer capacity at ~1/13th the parameter cost); its activations differ
per application site, so decode keeps a KV cache per SITE, not per layer
(13 caches, not 81 — this is what keeps long_500k decode feasible).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as MB
from repro.models.transformer import logits_head, _xent
from repro.sharding.ctx import constrain, residual_spec, P

Params = Dict


def layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_groups, mamba_per_group, n_tail_mamba)."""
    every = cfg.hybrid_attn_every
    n_groups = cfg.n_layers // every
    per_group = every - 1
    tail = cfg.n_layers - n_groups * every
    return n_groups, per_group, tail


def n_mamba_layers(cfg: ModelConfig) -> int:
    g, pg, tail = layout(cfg)
    return g * pg + tail


def init_shared_block(key: jax.Array, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return dict(
        attn_norm=jnp.zeros((cfg.d_model,)),
        ffn_norm=jnp.zeros((cfg.d_model,)),
        attn=L.init_gqa(k1, cfg),
        ffn=L.init_mlp(k2, cfg.d_model, cfg.d_ff),
    )


def init_zamba2(key: jax.Array, cfg: ModelConfig) -> Params:
    k_embed, k_m, k_s = jax.random.split(key, 3)
    nm = n_mamba_layers(cfg)
    keys = jax.random.split(k_m, nm)
    return dict(
        embed=L.init_embed(k_embed, cfg.vocab_padded, cfg.d_model),
        mamba=jax.vmap(lambda k: MB.init_mamba_block(k, cfg))(keys),
        shared=init_shared_block(k_s, cfg),
        final_norm=jnp.zeros((cfg.d_model,)),
    )


def _split_mamba(params: Params, cfg: ModelConfig):
    """Stacked mamba params -> (grouped (G, PG, ...), tail (T, ...))."""
    g, pg, tail = layout(cfg)
    grouped = jax.tree.map(lambda a: a[: g * pg].reshape((g, pg) + a.shape[1:]),
                           params["mamba"])
    tail_p = jax.tree.map(lambda a: a[g * pg:], params["mamba"])
    return grouped, tail_p


def shared_attn_apply(sp: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    a = cfg.attention
    h = L.rms_norm(x, sp["attn_norm"], cfg.norm_eps)
    x = x + L.gqa_attention(sp["attn"], h, a,
                            head_constraints=cfg.attn_head_constraints)
    h = L.rms_norm(x, sp["ffn_norm"], cfg.norm_eps)
    x = x + L.mlp(sp["ffn"], h)
    return constrain(x, residual_spec(cfg))


def trunk(params: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    grouped, tail_p = _split_mamba(params, cfg)
    g, pg, tail = layout(cfg)
    body = MB._remat(lambda lp, h: MB.mamba_block(lp, h, cfg), cfg)

    def inner(h, lp):
        return body(lp, h), None

    def group_step(h, glp):
        h, _ = jax.lax.scan(inner, h, glp)
        h = shared_attn_apply(params["shared"], h, cfg)
        return h, None

    x, _ = jax.lax.scan(group_step, x, grouped)
    if tail:
        x, _ = jax.lax.scan(inner, x, tail_p)
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


def loss(params: Params, batch: Dict, cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.compute_dtype))
    x = constrain(x, P("data", None, None))
    h = trunk(params, x, cfg)
    labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
    mask = jnp.pad(jnp.ones_like(tokens[:, 1:], jnp.float32), ((0, 0), (0, 1)))
    nll = _xent(params, h, labels, mask, cfg)
    return nll, dict(nll=nll, aux=jnp.zeros((), jnp.float32))


# --------------------------------------------------------------------------
# inference: mamba states per mamba layer + KV cache per shared-attn SITE
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int) -> Dict:
    a = cfg.attention
    s = cfg.ssm
    dt = jnp.dtype(cfg.compute_dtype)
    g, _, _ = layout(cfg)
    nm = n_mamba_layers(cfg)
    nh, hp = s.n_heads(cfg.d_model), s.head_dim
    return dict(
        conv=jnp.zeros((nm, batch_size, MB.conv_dim(cfg), s.d_conv - 1), dt),
        ssm=jnp.zeros((nm, batch_size, nh, hp, s.d_state), jnp.float32),
        k=jnp.zeros((g, batch_size, max_seq, a.n_kv_heads, a.head_dim), dt),
        v=jnp.zeros((g, batch_size, max_seq, a.n_kv_heads, a.head_dim), dt),
        len=jnp.zeros((), jnp.int32),
    )


def prefill(params: Params, batch: Dict, cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    tokens = batch["tokens"]
    a = cfg.attention
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.compute_dtype))
    x = constrain(x, P("data", None, None))
    grouped, tail_p = _split_mamba(params, cfg)
    g, pg, tail = layout(cfg)
    s_len = tokens.shape[1]
    positions = jnp.arange(s_len)

    def inner(h, lp):
        hn = L.rms_norm(h, lp["norm"], cfg.norm_eps)
        out, (conv_s, ssm_s) = MB.mamba_mixer(lp, hn, cfg, want_state=True)
        return h + out, (conv_s, ssm_s)

    def group_step(h, glp):
        h, states = jax.lax.scan(inner, h, glp)
        sp = params["shared"]
        hn = L.rms_norm(h, sp["attn_norm"], cfg.norm_eps)
        q, k, v = L.gqa_project_qkv(sp["attn"], hn, a, positions,
                                    head_constraints=cfg.attn_head_constraints)
        o = L.attention_scores(q, k, v, causal=True, cap=a.attn_softcap)
        h = h + o.reshape(h.shape[0], s_len, -1) @ sp["attn"]["wo"].astype(h.dtype)
        hn = L.rms_norm(h, sp["ffn_norm"], cfg.norm_eps)
        h = h + L.mlp(sp["ffn"], hn)
        h = constrain(h, residual_spec(cfg))
        return h, (states, k, v)

    x, (g_states, ks, vs) = jax.lax.scan(group_step, x, grouped)
    conv_g, ssm_g = g_states          # (G, PG, ...)
    if tail:
        x, (conv_t, ssm_t) = jax.lax.scan(inner, x, tail_p)
    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_head(params, h[:, -1:, :], cfg)[:, 0, :]

    def flat(gp, tp=None):
        gp = gp.reshape((-1,) + gp.shape[2:])
        return jnp.concatenate([gp, tp], axis=0) if tp is not None else gp

    cache = dict(
        conv=flat(conv_g, conv_t if tail else None),
        ssm=flat(ssm_g, ssm_t if tail else None),
        k=ks, v=vs,
        len=jnp.asarray(s_len, jnp.int32),
    )
    return logits, cache


def decode_step(params: Params, cache: Dict, tokens: jnp.ndarray,
                cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    a = cfg.attention
    g, pg, tail = layout(cfg)
    b = tokens.shape[0]
    pos = cache["len"]
    x = L.embed(params["embed"], tokens[:, 0], jnp.dtype(cfg.compute_dtype))
    grouped, tail_p = _split_mamba(params, cfg)
    conv_g = cache["conv"][: g * pg].reshape((g, pg) + cache["conv"].shape[1:])
    ssm_g = cache["ssm"][: g * pg].reshape((g, pg) + cache["ssm"].shape[1:])
    conv_t = cache["conv"][g * pg:]
    ssm_t = cache["ssm"][g * pg:]

    def inner(h, xs):
        lp, conv_s, ssm_s = xs
        hn = L.rms_norm(h, lp["norm"], cfg.norm_eps)
        out, nc, ns = MB.mamba_decode_mixer(lp, hn, cfg, conv_s, ssm_s)
        return h + out, (nc, ns)

    def group_step(h, xs):
        glp, conv_s, ssm_s, k_c, v_c = xs
        h, (nc, ns) = jax.lax.scan(inner, h, (glp, conv_s, ssm_s))
        sp = params["shared"]
        hn = L.rms_norm(h[:, None, :], sp["attn_norm"], cfg.norm_eps)
        q, k, v = L.gqa_project_qkv(sp["attn"], hn, a, jnp.full((b, 1), pos, jnp.int32))
        k_c = jax.lax.dynamic_update_slice(k_c, k, (0, pos, 0, 0))
        v_c = jax.lax.dynamic_update_slice(v_c, v, (0, pos, 0, 0))
        o = L.attention_scores(q, k_c, v_c, causal=False, cap=a.attn_softcap,
                               q_positions=jnp.full((1,), pos, jnp.int32),
                               k_positions=jnp.arange(k_c.shape[1]),
                               k_len=pos + 1)
        h2 = h[:, None, :] + o.reshape(b, 1, -1) @ sp["attn"]["wo"].astype(h.dtype)
        hn = L.rms_norm(h2, sp["ffn_norm"], cfg.norm_eps)
        h2 = h2 + L.mlp(sp["ffn"], hn)
        return h2[:, 0, :], (nc, ns, k_c, v_c)

    x, (conv_gn, ssm_gn, ks, vs) = jax.lax.scan(
        group_step, x, (grouped, conv_g, ssm_g, cache["k"], cache["v"]))
    if tail:
        x, (conv_tn, ssm_tn) = jax.lax.scan(inner, x, (tail_p, conv_t, ssm_t))

    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_head(params, h[:, None, :], cfg)[:, 0, :]

    def flat(gp, tp=None):
        gp = gp.reshape((-1,) + gp.shape[2:])
        return jnp.concatenate([gp, tp], axis=0) if tp is not None else gp

    new_cache = dict(
        conv=flat(conv_gn, conv_tn if tail else None),
        ssm=flat(ssm_gn, ssm_tn if tail else None),
        k=ks, v=vs, len=pos + 1,
    )
    return logits, new_cache
