"""Mamba-2 (SSD — state-space duality) language model.

Training/prefill use the chunked SSD algorithm: within-chunk terms are
dense matmuls (MXU work), across-chunk terms a short ``lax.scan`` over the
per-head (P, N) states.  Decode is the O(1)-state recurrence.  The
intra-chunk contraction is also provided as a Pallas kernel
(``repro.kernels.ssd_scan``); this module is the jnp/XLA path that the
SPMD dry-run lowers.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.init import lecun_normal
from repro.configs.base import ModelConfig, SSMConfig
from repro.models import layers as L
from repro.sharding.ctx import constrain, residual_spec, P

Params = Dict


def conv_dim(cfg: ModelConfig) -> int:
    s = cfg.ssm
    return s.d_inner(cfg.d_model) + 2 * s.n_groups * s.d_state


def in_proj_dim(cfg: ModelConfig) -> int:
    s = cfg.ssm
    return 2 * s.d_inner(cfg.d_model) + 2 * s.n_groups * s.d_state + s.n_heads(cfg.d_model)


def init_mamba_block(key: jax.Array, cfg: ModelConfig) -> Params:
    s = cfg.ssm
    d_inner = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    ks = jax.random.split(key, 4)
    dt = jnp.exp(jax.random.uniform(ks[2], (nh,)) *
                 (jnp.log(s.dt_max) - jnp.log(s.dt_min)) + jnp.log(s.dt_min))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))   # inverse softplus
    return dict(
        norm=jnp.zeros((cfg.d_model,)),
        in_proj=lecun_normal(ks[0], (cfg.d_model, in_proj_dim(cfg))),
        conv_w=0.1 * jax.random.normal(ks[1], (conv_dim(cfg), s.d_conv)),
        conv_b=jnp.zeros((conv_dim(cfg),)),
        A_log=jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        D=jnp.ones((nh,)),
        dt_bias=dt_bias,
        gate_norm=jnp.zeros((d_inner,)),
        out_proj=lecun_normal(ks[3], (d_inner, cfg.d_model)),
    )


def init_mamba2(key: jax.Array, cfg: ModelConfig) -> Params:
    k_embed, k_layers = jax.random.split(key)
    keys = jax.random.split(k_layers, cfg.n_layers)
    return dict(
        embed=L.init_embed(k_embed, cfg.vocab_padded, cfg.d_model),
        layers=jax.vmap(lambda k: init_mamba_block(k, cfg))(keys),
        final_norm=jnp.zeros((cfg.d_model,)),
    )


# --------------------------------------------------------------------------
# SSD core
# --------------------------------------------------------------------------

def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """x: (..., cs) -> (..., cs, cs) where out[i, j] = sum_{j < t <= i} x[t],
    -inf above the diagonal (the 1-semiseparable mask of SSD)."""
    cs = x.shape[-1]
    cum = jnp.cumsum(x, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    i = jnp.arange(cs)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                B: jnp.ndarray, C: jnp.ndarray, chunk: int,
                init_state: jnp.ndarray | None = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD scan (Mamba-2 §6 listing, jnp).

    x: (b, s, h, p); dt: (b, s, h) post-softplus; A: (h,) negative;
    B, C: (b, s, h, n) (groups already broadcast to heads).
    Returns (y (b, s, h, p), final_state (b, h, p, n)). f32 math inside.
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        # zero-padded tail: dt=0 -> exp(0)=1 decay, zero input — an
        # identity extension of the recurrence (y tail sliced off below)
        padseq = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        x, dt, B, C = padseq(x), padseq(dt), padseq(B), padseq(C)
        s = s + pad
    nc = s // chunk
    f32 = jnp.float32
    xc = x.reshape(b, nc, chunk, h, p).astype(f32)
    dtc = dt.reshape(b, nc, chunk, h).astype(f32)
    Bc = B.reshape(b, nc, chunk, h, n).astype(f32)
    Cc = C.reshape(b, nc, chunk, h, n).astype(f32)
    dA = dtc * A.astype(f32)                                  # (b,nc,cs,h)
    dA_cum = jnp.cumsum(dA, axis=2)

    # 1. intra-chunk (the dense MXU part)
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))          # (b,nc,h,cs,cs)
    CB = jnp.einsum("bclhn,bcshn->bchls", Cc, Bc)
    Y_diag = jnp.einsum("bchls,bcsh,bcshp->bclhp", CB * Lmat, dtc, xc)

    # 2. per-chunk input states
    decay_states = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)      # (b,nc,cs,h)
    states = jnp.einsum("bcshn,bcsh,bcshp->bchpn", Bc, decay_states * dtc, xc)

    # 3. inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])                 # (b,nc,h)
    s0 = (jnp.zeros((b, h, p, n), f32) if init_state is None
          else init_state.astype(f32))

    def scan_fn(carry, xs):
        st, dec = xs                                           # (b,h,p,n),(b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                                      # emit PREV state

    final, prev_states = jax.lax.scan(
        scan_fn, s0, (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)         # (b,nc,h,p,n)

    # 4. inter-chunk outputs
    state_decay = jnp.exp(dA_cum)                              # (b,nc,cs,h)
    Y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", Cc, prev_states, state_decay)
    y = (Y_diag + Y_off).reshape(b, s, h, p)
    if pad:
        y = y[:, : s - pad]
    return y.astype(x.dtype), final


def _causal_conv(xBC: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray
                 ) -> jnp.ndarray:
    """Depthwise causal conv1d. xBC: (b, s, c); w: (c, k)."""
    k = w.shape[-1]
    pad = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[:, i] for i in range(k))
    return out + bias


def mamba_mixer(lp: Params, x: jnp.ndarray, cfg: ModelConfig,
                want_state: bool = False):
    """x: (b, s, d_model) -> y (b, s, d_model) [, (conv_state, ssm_state)]."""
    s_cfg = cfg.ssm
    b, s, _ = x.shape
    d_inner = s_cfg.d_inner(cfg.d_model)
    nh, hp, gn = s_cfg.n_heads(cfg.d_model), s_cfg.head_dim, s_cfg.n_groups
    n = s_cfg.d_state

    zxbcdt = x @ lp["in_proj"].astype(x.dtype)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:d_inner + conv_dim(cfg)]
    dt = zxbcdt[..., d_inner + conv_dim(cfg):]
    conv_in = xBC
    xBC = jax.nn.silu(_causal_conv(xBC, lp["conv_w"].astype(x.dtype),
                                   lp["conv_b"].astype(x.dtype)))
    xs = xBC[..., :d_inner].reshape(b, s, nh, hp)
    Bmat = xBC[..., d_inner:d_inner + gn * n].reshape(b, s, gn, n)
    Cmat = xBC[..., d_inner + gn * n:].reshape(b, s, gn, n)
    rep = nh // gn
    Bmat = jnp.repeat(Bmat, rep, axis=2)
    Cmat = jnp.repeat(Cmat, rep, axis=2)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
    A = -jnp.exp(lp["A_log"])

    y, final_state = ssd_chunked(xs, dt, A, Bmat, Cmat, s_cfg.chunk_size)
    y = y + xs * lp["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, s, d_inner)
    y = L.rms_norm(y * jax.nn.silu(z), lp["gate_norm"], cfg.norm_eps)
    out = y @ lp["out_proj"].astype(x.dtype)
    if want_state:
        conv_state = conv_in[:, -(s_cfg.d_conv - 1):, :].swapaxes(1, 2)  # (b,c,k-1)
        return out, (conv_state, final_state)
    return out


def mamba_block(lp: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    h = L.rms_norm(x, lp["norm"], cfg.norm_eps)
    x = x + mamba_mixer(lp, h, cfg)
    return constrain(x, residual_spec(cfg))


# --------------------------------------------------------------------------
# model-level entry points
# --------------------------------------------------------------------------

def _remat(fn, cfg):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def trunk(params: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    body = _remat(functools.partial(
        lambda lp, h: mamba_block(lp, h, cfg)), cfg)

    def step(h, lp):
        return body(lp, h), None

    x, _ = jax.lax.scan(step, x, params["layers"])
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


def loss(params: Params, batch: Dict, cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    from repro.models.transformer import _xent  # shared chunked CE
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.compute_dtype))
    x = constrain(x, P("data", None, None))
    h = trunk(params, x, cfg)
    labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
    mask = jnp.pad(jnp.ones_like(tokens[:, 1:], jnp.float32), ((0, 0), (0, 1)))
    nll = _xent(params, h, labels, mask, cfg)
    return nll, dict(nll=nll, aux=jnp.zeros((), jnp.float32))


def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int) -> Dict:
    """SSM decode state is O(1) in sequence length (the long_500k win)."""
    del max_seq
    s = cfg.ssm
    dt = jnp.dtype(cfg.compute_dtype)
    nh, hp = s.n_heads(cfg.d_model), s.head_dim
    return dict(
        conv=jnp.zeros((cfg.n_layers, batch_size, conv_dim(cfg), s.d_conv - 1), dt),
        ssm=jnp.zeros((cfg.n_layers, batch_size, nh, hp, s.d_state), jnp.float32),
        len=jnp.zeros((), jnp.int32),
    )


def prefill(params: Params, batch: Dict, cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.compute_dtype))
    x = constrain(x, P("data", None, None))

    def step(h, lp):
        hn = L.rms_norm(h, lp["norm"], cfg.norm_eps)
        out, (conv_state, ssm_state) = mamba_mixer(lp, hn, cfg, want_state=True)
        h = h + out
        return h, (conv_state, ssm_state)

    x, (conv_s, ssm_s) = jax.lax.scan(step, x, params["layers"])
    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    from repro.models.transformer import logits_head
    logits = logits_head(params, h[:, -1:, :], cfg)[:, 0, :]
    cache = dict(conv=conv_s, ssm=ssm_s, len=jnp.asarray(tokens.shape[1], jnp.int32))
    return logits, cache


def mamba_decode_mixer(lp: Params, x: jnp.ndarray, cfg: ModelConfig,
                       conv_state: jnp.ndarray, ssm_state: jnp.ndarray):
    """Single-token recurrence. x: (b, d_model); conv_state: (b, c, k-1);
    ssm_state: (b, h, p, n) f32."""
    s_cfg = cfg.ssm
    b = x.shape[0]
    d_inner = s_cfg.d_inner(cfg.d_model)
    nh, hp, gn, n = (s_cfg.n_heads(cfg.d_model), s_cfg.head_dim,
                     s_cfg.n_groups, s_cfg.d_state)
    zxbcdt = x @ lp["in_proj"].astype(x.dtype)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:d_inner + conv_dim(cfg)]
    dt = zxbcdt[..., d_inner + conv_dim(cfg):]

    window = jnp.concatenate([conv_state, xBC[:, :, None]], axis=-1)  # (b,c,k)
    new_conv_state = window[..., 1:]
    conv_out = jnp.sum(window * lp["conv_w"].astype(x.dtype), axis=-1) + lp["conv_b"].astype(x.dtype)
    xBC = jax.nn.silu(conv_out)

    xs = xBC[..., :d_inner].reshape(b, nh, hp)
    Bv = jnp.repeat(xBC[..., d_inner:d_inner + gn * n].reshape(b, gn, n), nh // gn, axis=1)
    Cv = jnp.repeat(xBC[..., d_inner + gn * n:].reshape(b, gn, n), nh // gn, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])     # (b, h)
    A = -jnp.exp(lp["A_log"])
    dA = jnp.exp(dt * A)                                             # (b, h)
    upd = jnp.einsum("bh,bhn,bhp->bhpn", dt, Bv.astype(jnp.float32),
                     xs.astype(jnp.float32))
    new_state = ssm_state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Cv.astype(jnp.float32)).astype(x.dtype)
    y = y + xs * lp["D"].astype(x.dtype)[None, :, None]
    y = y.reshape(b, d_inner)
    y = L.rms_norm(y * jax.nn.silu(z), lp["gate_norm"], cfg.norm_eps)
    return y @ lp["out_proj"].astype(x.dtype), new_conv_state, new_state


def decode_step(params: Params, cache: Dict, tokens: jnp.ndarray,
                cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    x = L.embed(params["embed"], tokens[:, 0], jnp.dtype(cfg.compute_dtype))

    def step(h, xs):
        lp, conv_s, ssm_s = xs
        hn = L.rms_norm(h, lp["norm"], cfg.norm_eps)
        out, new_conv, new_ssm = mamba_decode_mixer(lp, hn, cfg, conv_s, ssm_s)
        return h + out, (new_conv, new_ssm)

    x, (conv_s, ssm_s) = jax.lax.scan(step, x, (params["layers"], cache["conv"], cache["ssm"]))
    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    from repro.models.transformer import logits_head
    logits = logits_head(params, h[:, None, :], cfg)[:, 0, :]
    return logits, dict(conv=conv_s, ssm=ssm_s, len=cache["len"] + 1)
