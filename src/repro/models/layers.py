"""Transformer building blocks shared by every LM-family architecture.

Pure-jnp implementations: this is the path the SPMD dry-run lowers (so the
roofline reads real HLO FLOPs).  The Pallas kernels in ``repro.kernels``
are drop-in TPU hot-spot replacements validated against these in tests.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.common.init import lecun_normal
from repro.configs.base import AttentionConfig, ModelConfig

Params = Dict


def _mixed_dot_ok() -> bool:
    """bf16 x bf16 -> f32 dots: native on TPU/GPU MXUs (and in AOT
    lowering), but the CPU *runtime* thunk rejects them.  The dry-run sets
    REPRO_MIXED_DOT=1 (it only compiles); CPU test execution falls back to
    materialized f32 casts."""
    import os
    if os.environ.get("REPRO_MIXED_DOT"):
        return True
    return jax.default_backend() in ("tpu", "gpu")


def dot_f32(subscripts: str, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """einsum with f32 accumulation that avoids materializing f32 copies of
    big bf16 operands wherever the backend allows (see _mixed_dot_ok)."""
    if a.dtype == jnp.float32 and b.dtype == jnp.float32:
        return jnp.einsum(subscripts, a, b)
    if _mixed_dot_ok():
        return jnp.einsum(subscripts, a, b,
                          preferred_element_type=jnp.float32)
    return jnp.einsum(subscripts, a.astype(jnp.float32),
                      b.astype(jnp.float32))


# --------------------------------------------------------------------------
# norms / rotary / misc
# --------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, n_heads, head_dim); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, d/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, d/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# --------------------------------------------------------------------------
# attention core (shared by GQA and MLA after head projection)
# --------------------------------------------------------------------------

def attention_scores(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     *, causal: bool, window: Optional[jnp.ndarray] = None,
                     cap: Optional[float] = None,
                     q_positions: Optional[jnp.ndarray] = None,
                     k_positions: Optional[jnp.ndarray] = None,
                     k_len: Optional[jnp.ndarray] = None,
                     scale: Optional[float] = None) -> jnp.ndarray:
    """Grouped scaled-dot-product attention.

    q: (B, Sq, Hq, Dh);  k/v: (B, Sk, Hkv, Dh) with Hq % Hkv == 0.
    window: optional traced scalar — sliding-window width (tokens attend to
      keys with q_pos - k_pos < window). Enables gemma2's per-layer
      local/global alternation inside one scanned block.
    k_len: optional traced scalar — number of valid cache entries (decode).
    Returns (B, Sq, Hq, Dh) in q.dtype; softmax in f32.
    """
    b, sq, hq, dh = q.shape
    _, sk, hkv, _ = k.shape
    group = hq // hkv
    qg = q.reshape(b, sq, hkv, group, dh)
    if scale is None:
        scale = dh ** -0.5
    # bf16 operands, f32 accumulation — an explicit .astype(f32) here would
    # MATERIALIZE an f32 copy of the whole K cache every decode step
    # (measured: the dominant decode-memory term)
    logits = dot_f32("bqkgd,bskd->bkgqs", qg, k) * scale
    logits = softcap(logits, cap)
    qpos = jnp.arange(sq) if q_positions is None else q_positions
    kpos = jnp.arange(sk) if k_positions is None else k_positions
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    if k_len is not None:
        mask &= kpos[None, :] < k_len
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = dot_f32("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, hq, v.shape[-1]).astype(q.dtype)


# --------------------------------------------------------------------------
# GQA attention layer
# --------------------------------------------------------------------------

def init_gqa(key: jax.Array, cfg: ModelConfig) -> Params:
    a = cfg.attention
    d, hq, hkv, dh = cfg.d_model, a.n_heads, a.n_kv_heads, a.head_dim
    ks = jax.random.split(key, 4)
    p = dict(
        wq=lecun_normal(ks[0], (d, hq * dh)),
        wk=lecun_normal(ks[1], (d, hkv * dh)),
        wv=lecun_normal(ks[2], (d, hkv * dh)),
        wo=lecun_normal(ks[3], (hq * dh, d)),
    )
    if a.qkv_bias:
        p.update(bq=jnp.zeros((hq * dh,)), bk=jnp.zeros((hkv * dh,)),
                 bv=jnp.zeros((hkv * dh,)))
    return p


def gqa_project_qkv(p: Params, x: jnp.ndarray, a: AttentionConfig,
                    positions: jnp.ndarray, head_constraints: bool = False):
    b, s, _ = x.shape
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if a.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, s, a.n_heads, a.head_dim)
    k = k.reshape(b, s, a.n_kv_heads, a.head_dim)
    v = v.reshape(b, s, a.n_kv_heads, a.head_dim)
    if head_constraints:
        # §Perf: pin sharding to the HEAD axis.  Without this GSPMD splits
        # head_dim across 'model' and pays a partial-sum all-reduce of the
        # full (B, H, S, S) logits tensor per layer.  When heads do NOT
        # divide the axis: replicating is cheap ONLY for true-GQA small
        # k/v (kv_width << d_model); for MHA-wide k/v (minicpm: 36x64)
        # a replicate pin costs a full k/v all-gather — skip instead
        # (measured 32x prefill-collective regression).
        from repro.sharding.ctx import constrain, P
        q = constrain(q, P("data", None, "model", None), require_full=True)
        kv_small = a.n_kv_heads * a.head_dim * 4 <= a.n_heads * a.head_dim
        k = constrain(k, P("data", None, "model", None),
                      require_full=not kv_small)
        v = constrain(v, P("data", None, "model", None),
                      require_full=not kv_small)
    q = apply_rope(q, positions, a.rope_theta)
    k = apply_rope(k, positions, a.rope_theta)
    return q, k, v


def gqa_attention(p: Params, x: jnp.ndarray, a: AttentionConfig, *,
                  window: Optional[jnp.ndarray] = None,
                  head_constraints: bool = False) -> jnp.ndarray:
    """Full-sequence (train / prefill) GQA self-attention."""
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q, k, v = gqa_project_qkv(p, x, a, positions,
                              head_constraints=head_constraints)
    o = attention_scores(q, k, v, causal=True, window=window, cap=a.attn_softcap)
    return o.reshape(b, s, -1) @ p["wo"].astype(x.dtype)


def gqa_attention_bidir(p: Params, x: jnp.ndarray, a: AttentionConfig) -> jnp.ndarray:
    """Bidirectional self-attention (whisper encoder)."""
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q, k, v = gqa_project_qkv(p, x, a, positions)
    o = attention_scores(q, k, v, causal=False, cap=a.attn_softcap)
    return o.reshape(b, s, -1) @ p["wo"].astype(x.dtype)


# --------------------------------------------------------------------------
# MLA attention layer (DeepSeek-V2): low-rank latent KV cache
# --------------------------------------------------------------------------

def init_mla(key: jax.Array, cfg: ModelConfig) -> Params:
    a = cfg.attention
    d, h = cfg.d_model, a.n_heads
    qk = a.qk_nope_dim + a.qk_rope_dim
    ks = jax.random.split(key, 8)
    p: Params = dict(
        # query path (optionally low-rank)
        wkv_a=lecun_normal(ks[1], (d, a.kv_lora_rank + a.qk_rope_dim)),
        kv_norm=jnp.zeros((a.kv_lora_rank,)),
        wk_b=lecun_normal(ks[2], (a.kv_lora_rank, h * a.qk_nope_dim)),
        wv_b=lecun_normal(ks[3], (a.kv_lora_rank, h * a.v_head_dim)),
        wo=lecun_normal(ks[4], (h * a.v_head_dim, d)),
    )
    if a.q_lora_rank > 0:
        p["wq_a"] = lecun_normal(ks[5], (d, a.q_lora_rank))
        p["q_norm"] = jnp.zeros((a.q_lora_rank,))
        p["wq_b"] = lecun_normal(ks[6], (a.q_lora_rank, h * qk))
    else:
        p["wq"] = lecun_normal(ks[0], (d, h * qk))
    return p


def mla_queries(p: Params, x: jnp.ndarray, a: AttentionConfig, eps: float,
                positions: jnp.ndarray):
    """Returns (q_nope (B,S,H,nope), q_rope (B,S,H,rope))."""
    b, s, _ = x.shape
    if a.q_lora_rank > 0:
        ql = rms_norm(x @ p["wq_a"].astype(x.dtype), p["q_norm"], eps)
        q = ql @ p["wq_b"].astype(x.dtype)
    else:
        q = x @ p["wq"].astype(x.dtype)
    q = q.reshape(b, s, a.n_heads, a.qk_nope_dim + a.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [a.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, a.rope_theta)
    return q_nope, q_rope


def mla_latent(p: Params, x: jnp.ndarray, a: AttentionConfig, eps: float,
               positions: jnp.ndarray):
    """Compress x -> (c_kv (B,S,R) normalized latent, k_rope (B,S,1,rope)).
    This pair IS the decode-time KV cache (paper: latent cache)."""
    b, s, _ = x.shape
    kv = x @ p["wkv_a"].astype(x.dtype)
    c_kv, k_rope = jnp.split(kv, [a.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, p["kv_norm"], eps)
    k_rope = apply_rope(k_rope.reshape(b, s, 1, a.qk_rope_dim), positions,
                        a.rope_theta)
    return c_kv, k_rope


def mla_attention(p: Params, x: jnp.ndarray, a: AttentionConfig,
                  eps: float) -> jnp.ndarray:
    """Full-sequence MLA (train / prefill): expand latent to per-head K/V."""
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q_nope, q_rope = mla_queries(p, x, a, eps, positions)
    c_kv, k_rope = mla_latent(p, x, a, eps, positions)
    k_nope = (c_kv @ p["wk_b"].astype(x.dtype)).reshape(b, s, a.n_heads, a.qk_nope_dim)
    v = (c_kv @ p["wv_b"].astype(x.dtype)).reshape(b, s, a.n_heads, a.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, a.n_heads, a.qk_rope_dim))], axis=-1)
    scale = (a.qk_nope_dim + a.qk_rope_dim) ** -0.5
    o = attention_scores(q, k, v, causal=True, cap=a.attn_softcap, scale=scale)
    return o.reshape(b, s, -1) @ p["wo"].astype(x.dtype)


def mla_decode_attention(p: Params, x: jnp.ndarray, a: AttentionConfig, eps: float,
                         cache_ckv: jnp.ndarray, cache_krope: jnp.ndarray,
                         cache_len: jnp.ndarray) -> jnp.ndarray:
    """Absorbed-matmul MLA decode: queries are mapped into the latent space
    (q_nope @ wk_b per head) so attention runs directly against the R-dim
    latent cache — the MLA memory/bandwidth win. x: (B, 1, D).
    cache_ckv: (B, Smax, R); cache_krope: (B, Smax, rope)."""
    b, s, _ = x.shape
    h, rope, nope, dv = a.n_heads, a.qk_rope_dim, a.qk_nope_dim, a.v_head_dim
    r = a.kv_lora_rank
    positions = cache_len[None] + jnp.arange(s)[None, :] * jnp.ones((b, 1), jnp.int32)
    q_nope, q_rope = mla_queries(p, x, a, eps, positions)
    wk_b = p["wk_b"].astype(x.dtype).reshape(r, h, nope)
    # absorb: q_lat[b,s,h,r] = q_nope . wk_b^T
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, wk_b)
    kpos = jnp.arange(cache_ckv.shape[1])
    # bf16 latent cache with f32 accumulation — never materialize an f32
    # copy of the (B, Smax, R) cache (see attention_scores note)
    logits = (dot_f32("bshr,bkr->bhsk", q_lat, cache_ckv) +
              dot_f32("bshn,bkn->bhsk", q_rope, cache_krope))
    logits = logits * ((nope + rope) ** -0.5)
    logits = softcap(logits, a.attn_softcap)
    mask = kpos[None, None, None, :] < (cache_len + 1)
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o_lat = dot_f32("bhsk,bkr->bshr", probs.astype(cache_ckv.dtype),
                    cache_ckv)
    wv_b = p["wv_b"].astype(x.dtype).reshape(r, h, dv)
    o = jnp.einsum("bshr,rhd->bshd", o_lat.astype(x.dtype), wv_b)
    return o.reshape(b, s, -1) @ p["wo"].astype(x.dtype)


# --------------------------------------------------------------------------
# dense gated-MLP
# --------------------------------------------------------------------------

def init_mlp(key: jax.Array, d_model: int, d_ff: int) -> Params:
    ks = jax.random.split(key, 3)
    return dict(
        w_gate=lecun_normal(ks[0], (d_model, d_ff)),
        w_up=lecun_normal(ks[1], (d_model, d_ff)),
        w_down=lecun_normal(ks[2], (d_ff, d_model)),
    )


def mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    g = jax.nn.silu(x @ p["w_gate"].astype(x.dtype))
    u = x @ p["w_up"].astype(x.dtype)
    return (g * u) @ p["w_down"].astype(x.dtype)


# --------------------------------------------------------------------------
# embedding / unembedding
# --------------------------------------------------------------------------

def init_embed(key: jax.Array, vocab_padded: int, d_model: int) -> jnp.ndarray:
    return 0.02 * jax.random.normal(key, (vocab_padded, d_model), jnp.float32)


def embed(table: jnp.ndarray, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0).astype(dtype)


def unembed(table: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """x: (..., D) -> logits (..., Vp) in f32."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      table.astype(jnp.float32))
