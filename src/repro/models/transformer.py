"""Unified decoder-only transformer covering the dense / MoE / MLA /
local-global / softcap / QKV-bias variants in the assigned pool.

Layer stack is a ``lax.scan`` over stacked per-layer parameters (compile
time and HLO size are O(1) in depth).  Per-layer heterogeneity that the
pool needs (gemma2's local/global alternation) rides through the scan as a
per-layer window array, so one block body serves all layers.

Three entry points per model:
  loss(params, batch)          training objective (chunked cross-entropy)
  prefill(params, batch)       full-sequence forward -> (last logits, cache)
  decode_step(params, cache, tokens)  one-token KV-cache decode
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.init import lecun_normal
from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.sharding.ctx import constrain, residual_spec, P

Params = Dict
AUX_COEF = 0.01
GLOBAL_WINDOW = 1 << 30


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def moe_dispatch(lp: Params, h2d: jnp.ndarray, cfg: ModelConfig):
    """Route to the explicit expert-parallel shard_map dispatch when a
    mesh is active and divisibility allows (§Perf), else the jnp path."""
    if cfg.moe_shard_map:
        from repro.sharding.ctx import _active_mesh
        mesh = _active_mesh()
        if mesh is not None and hasattr(mesh, "devices"):
            # boundary specs must match residual_spec's layout (else GSPMD
            # reshards the full activation at every layer — see moe.py)
            layout = (getattr(cfg, "activation_layout", "hidden")
                      if cfg.shard_activations_model else "seq")
            return M.moe_ffn_sharded(lp, h2d, cfg.moe, mesh, layout=layout)
    return M.moe_ffn(lp, h2d, cfg.moe)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_block(key: jax.Array, cfg: ModelConfig) -> Params:
    k_attn, k_ffn = jax.random.split(key)
    a = cfg.attention
    p: Params = dict(
        attn_norm=jnp.zeros((cfg.d_model,)),
        ffn_norm=jnp.zeros((cfg.d_model,)),
    )
    p["attn"] = L.init_mla(k_attn, cfg) if a.kind == "mla" else L.init_gqa(k_attn, cfg)
    if cfg.moe is not None:
        p["ffn"] = M.init_moe(k_ffn, cfg.d_model, cfg.moe)
    else:
        p["ffn"] = L.init_mlp(k_ffn, cfg.d_model, cfg.d_ff)
    return p


def init_transformer(key: jax.Array, cfg: ModelConfig) -> Params:
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: init_block(k, cfg))(layer_keys)
    p = dict(
        embed=L.init_embed(k_embed, cfg.vocab_padded, cfg.d_model),
        layers=layers,
        final_norm=jnp.zeros((cfg.d_model,)),
    )
    if not cfg.tie_embeddings:
        p["lm_head"] = lecun_normal(k_head, (cfg.vocab_padded, cfg.d_model))
    return p


def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer attention window (gemma2: even layers local)."""
    if not cfg.local_global:
        return jnp.full((cfg.n_layers,), GLOBAL_WINDOW, jnp.int32)
    idx = jnp.arange(cfg.n_layers)
    return jnp.where(idx % 2 == 0, cfg.sliding_window, GLOBAL_WINDOW).astype(jnp.int32)


# --------------------------------------------------------------------------
# block body (shared by train / prefill; decode has its own)
# --------------------------------------------------------------------------

def block(cfg: ModelConfig, lp: Params, x: jnp.ndarray, window: jnp.ndarray,
          film: Optional[Dict] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (x', aux_loss).  ``film`` (episodic adaptation):
    per-layer {gamma, beta} of width d_model applied to the residual
    stream after the block — the LM-family FiLM site (DESIGN.md §3)."""
    a = cfg.attention
    h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    if a.kind == "mla":
        attn_out = L.mla_attention(lp["attn"], h, a, cfg.norm_eps)
    else:
        attn_out = L.gqa_attention(lp["attn"], h, a, window=window,
                                   head_constraints=cfg.attn_head_constraints)
    x = x + cfg.residual_scale * attn_out
    x = constrain(x, residual_spec(cfg))

    h = L.rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        b, s, d = h.shape
        y, aux = moe_dispatch(lp["ffn"], h.reshape(b * s, d), cfg)
        y = y.reshape(b, s, d)
    else:
        y = L.mlp(lp["ffn"], h)
    x = x + cfg.residual_scale * y
    if film is not None:
        from repro.core.film import apply_film
        x = apply_film(x, film["gamma"], film["beta"])
    x = constrain(x, residual_spec(cfg))
    return x, aux


def _remat(fn, cfg: ModelConfig):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)       # 'nothing' saveable


def trunk(params: Params, x: jnp.ndarray, cfg: ModelConfig,
          film: Optional[Dict] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Embedded inputs -> final hidden states. x: (B, S, D).
    film: optional {gamma (L, D), beta (L, D)} stacked per-layer FiLM."""
    windows = layer_windows(cfg)
    body = _remat(functools.partial(block, cfg), cfg)

    def step(carry, xs):
        if film is not None:
            lp, w, f = xs
        else:
            lp, w = xs
            f = None
        x, aux = carry
        x, a = body(lp, x, w, f)
        return (x, aux + a), None

    xs = (params["layers"], windows)
    if film is not None:
        xs = xs + (film,)
    (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)), xs)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def logits_head(params: Params, h: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    table = params["embed"] if cfg.tie_embeddings else params.get("lm_head", params["embed"])
    logits = L.unembed(table, h) * cfg.logit_scale
    logits = L.softcap(logits, cfg.final_softcap)
    if cfg.vocab_padded != cfg.vocab:
        pad_mask = jnp.arange(cfg.vocab_padded) < cfg.vocab
        logits = jnp.where(pad_mask, logits, -1e30)
    return logits


# --------------------------------------------------------------------------
# training loss (chunked cross-entropy over the sequence axis)
# --------------------------------------------------------------------------

def _xent(params: Params, h: jnp.ndarray, labels: jnp.ndarray,
          mask: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """h: (B, S, D); labels/mask: (B, S). Mean NLL over mask."""
    b, s, d = h.shape
    chunk = cfg.loss_chunk if cfg.loss_chunk > 0 else s
    n = s // chunk if s % chunk == 0 else 0
    if n <= 1:
        logits = logits_head(params, h, cfg)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    hc = h.reshape(b, n, chunk, d).swapaxes(0, 1)          # (n, B, chunk, D)
    lc = labels.reshape(b, n, chunk).swapaxes(0, 1)
    mc = mask.reshape(b, n, chunk).swapaxes(0, 1)

    def body(acc, xs):
        hi, li, mi = xs
        logits = logits_head(params, hi, cfg)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        return acc + jnp.sum((lse - ll) * mi), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc, mc))
    return total / jnp.maximum(jnp.sum(mask), 1.0)


def embed_inputs(params: Params, batch: Dict, cfg: ModelConfig) -> jnp.ndarray:
    """Token embedding (+ optional modality-stub embeddings prepended)."""
    x = L.embed(params["embed"], batch["tokens"], _dtype(cfg)) * cfg.embed_scale
    if cfg.frontend is not None and "frontend_embeds" in batch:
        fe = batch["frontend_embeds"].astype(_dtype(cfg))
        x = jnp.concatenate([fe, x], axis=1)
    return x


def loss(params: Params, batch: Dict, cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    """Next-token loss. batch: tokens (B, S) int32 [+ frontend_embeds]."""
    tokens = batch["tokens"]
    x = embed_inputs(params, batch, cfg)
    x = constrain(x, P("data", None, None))
    h, aux = trunk(params, x, cfg)
    n_front = x.shape[1] - tokens.shape[1]
    h = h[:, n_front:, :]
    labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
    mask = jnp.pad(jnp.ones_like(tokens[:, 1:], jnp.float32), ((0, 0), (0, 1)))
    nll = _xent(params, h, labels, mask, cfg)
    total = nll + AUX_COEF * aux
    return total, dict(nll=nll, aux=aux)


# --------------------------------------------------------------------------
# KV-cache inference
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int) -> Dict:
    a = cfg.attention
    dt = _dtype(cfg)
    lshape = (cfg.n_layers, batch_size, max_seq)
    if a.kind == "mla":
        cache = dict(
            ckv=jnp.zeros(lshape + (a.kv_lora_rank,), dt),
            krope=jnp.zeros(lshape + (a.qk_rope_dim,), dt),
        )
    else:
        cache = dict(
            k=jnp.zeros(lshape + (a.n_kv_heads, a.head_dim), dt),
            v=jnp.zeros(lshape + (a.n_kv_heads, a.head_dim), dt),
        )
    cache["len"] = jnp.zeros((), jnp.int32)
    return cache


def prefill(params: Params, batch: Dict, cfg: ModelConfig
            ) -> Tuple[jnp.ndarray, Dict]:
    """Full forward over the prompt; returns (last-token logits (B, Vp),
    populated cache).  The cache is collected as scan ys so only one
    layer's K/V is live during the sweep."""
    a = cfg.attention
    tokens = batch["tokens"]
    x = embed_inputs(params, batch, cfg)
    x = constrain(x, P("data", None, None))
    s = x.shape[1]
    positions = jnp.arange(s)
    windows = layer_windows(cfg)

    def step(carry, xs):
        lp, w = xs
        x, aux = carry
        h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        if a.kind == "mla":
            ckv, krope = L.mla_latent(lp["attn"], h, a, cfg.norm_eps, positions)
            attn_out = L.mla_attention(lp["attn"], h, a, cfg.norm_eps)
            kv = dict(ckv=ckv, krope=krope.reshape(krope.shape[0], s, -1))
        else:
            q, k, v = L.gqa_project_qkv(lp["attn"], h, a, positions,
                                        head_constraints=cfg.attn_head_constraints)
            o = L.attention_scores(q, k, v, causal=True, window=w, cap=a.attn_softcap)
            attn_out = o.reshape(h.shape[0], s, -1) @ lp["attn"]["wo"].astype(h.dtype)
            kv = dict(k=k, v=v)
        x = x + cfg.residual_scale * attn_out
        h = L.rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
        if cfg.moe is not None:
            b_, s_, d_ = h.shape
            y, aux2 = moe_dispatch(lp["ffn"], h.reshape(b_ * s_, d_), cfg)
            y = y.reshape(b_, s_, d_)
            aux = aux + aux2
        else:
            y = L.mlp(lp["ffn"], h)
        x = x + cfg.residual_scale * y
        x = constrain(x, residual_spec(cfg))
        return (x, aux), kv

    (x, _), kvs = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)),
                               (params["layers"], windows))
    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_head(params, h[:, -1:, :], cfg)[:, 0, :]
    cache = dict(kvs)
    cache["len"] = jnp.asarray(s, jnp.int32)
    return logits, cache


def decode_step(params: Params, cache: Dict, tokens: jnp.ndarray,
                cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    """One decode step. tokens: (B, 1) int32; cache from init_cache/prefill.
    Returns (logits (B, Vp), updated cache)."""
    a = cfg.attention
    dt = _dtype(cfg)
    x = L.embed(params["embed"], tokens, dt) * cfg.embed_scale
    pos = cache["len"]
    windows = layer_windows(cfg)
    b = tokens.shape[0]

    def step(x, xs):
        if a.kind == "mla":
            lp, w, ckv_c, krope_c = xs
        else:
            lp, w, k_c, v_c = xs
        h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        if a.kind == "mla":
            positions = jnp.full((b, 1), pos, jnp.int32)
            ckv_new, krope_new = L.mla_latent(lp["attn"], h, a, cfg.norm_eps, positions)
            ckv_c = jax.lax.dynamic_update_slice(ckv_c, ckv_new, (0, pos, 0))
            krope_c = jax.lax.dynamic_update_slice(
                krope_c, krope_new.reshape(b, 1, -1), (0, pos, 0))
            attn_out = L.mla_decode_attention(lp["attn"], h, a, cfg.norm_eps,
                                              ckv_c, krope_c, pos)
            new_kv = (ckv_c, krope_c)
        else:
            positions = jnp.full((b, 1), pos, jnp.int32)
            q, k, v = L.gqa_project_qkv(lp["attn"], h, a, positions,
                                        head_constraints=cfg.attn_head_constraints)
            k_c = jax.lax.dynamic_update_slice(k_c, k, (0, pos, 0, 0))
            v_c = jax.lax.dynamic_update_slice(v_c, v, (0, pos, 0, 0))
            o = L.attention_scores(
                q, k_c, v_c, causal=False, window=w, cap=a.attn_softcap,
                q_positions=jnp.full((1,), pos, jnp.int32),
                k_positions=jnp.arange(k_c.shape[1]),
                k_len=pos + 1)
            attn_out = o.reshape(b, 1, -1) @ lp["attn"]["wo"].astype(h.dtype)
            new_kv = (k_c, v_c)
        x = x + cfg.residual_scale * attn_out
        h = L.rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
        if cfg.moe is not None:
            y, _ = moe_dispatch(lp["ffn"], h.reshape(b, -1), cfg)
            y = y.reshape(b, 1, -1)
        else:
            y = L.mlp(lp["ffn"], h)
        x = x + cfg.residual_scale * y
        return x, new_kv

    if a.kind == "mla":
        xs = (params["layers"], windows, cache["ckv"], cache["krope"])
    else:
        xs = (params["layers"], windows, cache["k"], cache["v"])
    x, new_kvs = jax.lax.scan(step, x, xs)
    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_head(params, h, cfg)[:, 0, :]
    new_cache = dict(len=cache["len"] + 1)
    if a.kind == "mla":
        new_cache["ckv"], new_cache["krope"] = new_kvs
    else:
        new_cache["k"], new_cache["v"] = new_kvs
    return logits, new_cache
