"""The paper-faithful vision backbone: a conv feature extractor with a FiLM
site after every block (paper Fig. B.3 places FiLM after each conv /
depthwise-separable conv in EfficientNet-B0; we reproduce the structure at
configurable width/depth so the SAME code runs the paper's 224x224 regime on
TPU and an 84x84 / reduced regime on CPU tests).

Blocks: conv3x3 -> FiLM -> relu -> maxpool2 (the classic few-shot "Conv-N"
family, which the paper's small-image baselines use), plus an optional
channel-expanding stem matching EfficientNet-ish widths.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.common.init import lecun_normal
from repro.core.film import apply_film
from repro.kernels import dispatch
from repro.models.backbone import BackboneDef


@dataclasses.dataclass(frozen=True)
class ConvBackboneConfig:
    in_channels: int = 3
    widths: Sequence[int] = (32, 64, 128, 256)
    feature_dim: int = 256
    name: str = "convnet"


def init_conv_backbone(key: jax.Array, cfg: ConvBackboneConfig) -> Dict:
    params: Dict[str, Any] = dict(blocks=[])
    ch = cfg.in_channels
    keys = jax.random.split(key, len(cfg.widths) + 1)
    for i, w in enumerate(cfg.widths):
        params["blocks"].append(
            dict(w=lecun_normal(keys[i], (3, 3, ch, w), in_axis=2),
                 b=jnp.zeros((w,)))
        )
        ch = w
    params["head"] = dict(w=lecun_normal(keys[-1], (ch, cfg.feature_dim)),
                          b=jnp.zeros((cfg.feature_dim,)))
    return params


def conv_features(params: Dict, x: jnp.ndarray, film: Optional[List[Dict]],
                  cfg: ConvBackboneConfig) -> jnp.ndarray:
    """x: (B, H, W, C) -> (B, feature_dim). One FiLM site per block."""
    h = x
    for i, blk in enumerate(params["blocks"]):
        h = jax.lax.conv_general_dilated(
            h, blk["w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + blk["b"]
        if film is not None:
            h = apply_film(h, film[i]["gamma"], film[i]["beta"], channel_axis=-1)
        h = jax.nn.relu(h)
        if h.shape[1] >= 2 and h.shape[2] >= 2:
            h = jax.lax.reduce_window(
                h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    h = jnp.mean(h, axis=(1, 2))
    w = params["head"]["w"]
    if isinstance(w, dict):
        # serving-time quantized head (ServingWeights leaves "head/w" in
        # the blockwise int8 form): the int8 tiles feed the MXU directly
        return dispatch.int8_matmul(h, w) + params["head"]["b"]
    return h @ w + params["head"]["b"]


def make_conv_backbone(cfg: ConvBackboneConfig) -> BackboneDef:
    return BackboneDef(
        init=lambda key: init_conv_backbone(key, cfg),
        features=lambda p, x, film: conv_features(p, x, film, cfg),
        feature_dim=cfg.feature_dim,
        film_sites=tuple(cfg.widths),
        name=cfg.name,
        quant_native_paths=("head/w",),
    )
