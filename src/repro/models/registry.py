"""Model API dispatch: one uniform interface over all families.

    api = get_api(cfg)
    params = api.init(key, cfg)
    loss, metrics = api.loss(params, batch, cfg)
    logits, cache = api.prefill(params, batch, cfg)
    logits, cache = api.decode_step(params, cache, tokens, cfg)
    cache = api.init_cache(cfg, batch_size, max_seq)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

from repro.configs.base import ModelConfig
from repro.models import mamba2, transformer, whisper, zamba2


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    init: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable


_APIS: Dict[str, ModelAPI] = {
    "transformer": ModelAPI(
        init=transformer.init_transformer,
        loss=transformer.loss,
        prefill=transformer.prefill,
        decode_step=transformer.decode_step,
        init_cache=transformer.init_cache,
    ),
    "mamba2": ModelAPI(
        init=mamba2.init_mamba2,
        loss=mamba2.loss,
        prefill=mamba2.prefill,
        decode_step=mamba2.decode_step,
        init_cache=mamba2.init_cache,
    ),
    "hybrid": ModelAPI(
        init=zamba2.init_zamba2,
        loss=zamba2.loss,
        prefill=zamba2.prefill,
        decode_step=zamba2.decode_step,
        init_cache=zamba2.init_cache,
    ),
    "encdec": ModelAPI(
        init=whisper.init_whisper,
        loss=whisper.loss,
        prefill=whisper.prefill,
        decode_step=whisper.decode_step,
        init_cache=whisper.init_cache,
    ),
}


def get_api(cfg: ModelConfig) -> ModelAPI:
    return _APIS[cfg.family]
