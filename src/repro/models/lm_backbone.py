"""Expose LM-family architectures as episodic BackboneDefs (DESIGN.md §3):
the paper's scheme wraps ANY feature extractor — here the support/query
"examples" are token sequences and features are mean-pooled final hidden
states, with per-layer FiLM on the residual stream as the adaptation site.

Works for family='transformer' and 'mamba2' (the families with a plain
scanned trunk); the conv vision backbone remains the paper-faithful path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2, transformer
from repro.models.backbone import BackboneDef


def _film_stack(film_list, n_layers: int, d_model: int) -> Optional[dict]:
    """List of per-site {gamma, beta} (len = n_layers) -> stacked arrays."""
    if film_list is None:
        return None
    gamma = jnp.stack([f["gamma"] for f in film_list])
    beta = jnp.stack([f["beta"] for f in film_list])
    return dict(gamma=gamma, beta=beta)


def make_lm_backbone(cfg: ModelConfig) -> BackboneDef:
    if cfg.family == "transformer":
        init_fn, trunk_fn = transformer.init_transformer, transformer.trunk
    elif cfg.family == "mamba2":
        init_fn = mamba2.init_mamba2

        def trunk_fn(params, x, cfg, film=None):
            # mamba trunk has no film plumbed through scan; apply the
            # stacked film to the final states as the (documented) site.
            h = mamba2.trunk(params, x, cfg)
            return h, jnp.zeros((), jnp.float32)
    else:
        raise ValueError(f"episodic LM backbone unsupported for {cfg.family}")

    def init(key):
        return init_fn(key, cfg)

    def features(params, tokens, film):
        x = L.embed(params["embed"], tokens, jnp.dtype(cfg.compute_dtype))
        x = x * cfg.embed_scale
        fs = _film_stack(film, cfg.n_layers, cfg.d_model)
        if cfg.family == "transformer":
            h, _ = trunk_fn(params, x, cfg, fs)
        else:
            h, _ = trunk_fn(params, x, cfg)
            if film is not None:
                # final-state site for SSM (mean of per-layer film)
                from repro.core.film import apply_film
                h = apply_film(h, fs["gamma"].mean(0), fs["beta"].mean(0))
        return jnp.mean(h.astype(jnp.float32), axis=1)   # (B, d_model)

    return BackboneDef(
        init=init,
        features=features,
        feature_dim=cfg.d_model,
        film_sites=tuple([cfg.d_model] * cfg.n_layers),
        name=f"lm:{cfg.name}",
    )
