"""Backbone protocol: anything that maps raw inputs to a feature vector and
exposes FiLM modulation sites can serve as a meta-learner's feature extractor
(the paper uses ResNet-18 / EfficientNet-B0; here it is also how the assigned
LM architectures plug into the episodic layer).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class BackboneDef:
    """A feature extractor usable by the episodic meta-learning layer.

    Attributes:
      init: key -> params pytree.
      features: (params, x, film) -> (B, feature_dim) per-example features.
        `film` is a list of {gamma, beta} dicts, one per modulation site
        (len == len(film_sites)); pass ``None`` for identity modulation.
      feature_dim: output feature width.
      film_sites: channel count at each FiLM site (drives the generator).
      name: for logging / benchmark tables.
    """

    init: Callable[[Any], PyTree]
    features: Callable[[PyTree, jnp.ndarray, Any], jnp.ndarray]
    feature_dim: int
    film_sites: Sequence[int]
    name: str = "backbone"
