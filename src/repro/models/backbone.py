"""Backbone protocol: anything that maps raw inputs to a feature vector and
exposes FiLM modulation sites can serve as a meta-learner's feature extractor
(the paper uses ResNet-18 / EfficientNet-B0; here it is also how the assigned
LM architectures plug into the episodic layer).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class BackboneDef:
    """A feature extractor usable by the episodic meta-learning layer.

    Attributes:
      init: key -> params pytree.
      features: (params, x, film) -> (B, feature_dim) per-example features.
        `film` is a list of {gamma, beta} dicts, one per modulation site
        (len == len(film_sites)); pass ``None`` for identity modulation.
      feature_dim: output feature width.
      film_sites: channel count at each FiLM site (drives the generator).
      name: for logging / benchmark tables.
      quant_native_paths: '/'-joined param paths (e.g. "head/w") whose
        weight the ``features`` fn can consume DIRECTLY in the blockwise
        int8 ``{q, scale, n}`` form of ``repro.optim.quant`` — i.e. the
        matmul sites routed through ``repro.kernels.dispatch.int8_matmul``.
        The serving-time ``ServingWeights`` leaves these leaves quantized
        end-to-end (no dequantize even inside the jitted step); everything
        else it dequantizes lazily in-jit.  Empty = fp32-only backbone.
    """

    init: Callable[[Any], PyTree]
    features: Callable[[PyTree, jnp.ndarray, Any], jnp.ndarray]
    feature_dim: int
    film_sites: Sequence[int]
    name: str = "backbone"
    quant_native_paths: Sequence[str] = ()
