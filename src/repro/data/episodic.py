"""Synthetic episodic task generators (ORBIT / VTAB+MD stand-ins — the
real datasets are unavailable offline; DESIGN.md §8 records this).

Image tasks: each class is a Gaussian blob in pixel space with a class-
specific low-frequency pattern — linearly separable enough that accuracy
trends (flat-in-|H|, LITE > subsampled-task) are measurable in minutes on
CPU, yet non-trivial for a conv net from scratch.

Token tasks: each class is a distinct unigram distribution over the vocab;
sequences sample iid from it.  Used by the episodic-LM integration.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.episodic import Task


@dataclasses.dataclass(frozen=True)
class EpisodicImageConfig:
    way: int = 5
    shot: int = 10                   # support examples per class
    query_per_class: int = 10
    image_size: int = 32
    channels: int = 3
    class_sep: float = 0.5           # distance between class means
    noise: float = 1.5


def sample_image_task(key: jax.Array, cfg: EpisodicImageConfig) -> Task:
    km, ks, kq, kp = jax.random.split(key, 4)
    h = w = cfg.image_size
    # class prototype pattern: low-freq random image per class
    base = jax.random.normal(kp, (cfg.way, h // 4, w // 4, cfg.channels))
    base = jax.image.resize(base, (cfg.way, h, w, cfg.channels), "linear")
    base = cfg.class_sep * base / jnp.sqrt(jnp.mean(base ** 2) + 1e-8)

    def draw(k, per_class):
        noise = cfg.noise * jax.random.normal(
            k, (cfg.way, per_class, h, w, cfg.channels))
        x = base[:, None] + noise
        y = jnp.repeat(jnp.arange(cfg.way), per_class)
        return x.reshape(-1, h, w, cfg.channels), y

    sx, sy = draw(ks, cfg.shot)
    qx, qy = draw(kq, cfg.query_per_class)
    perm = jax.random.permutation(km, sx.shape[0])
    return Task(support_x=sx[perm], support_y=sy[perm],
                query_x=qx, query_y=qy, way=cfg.way)


def image_task_stream(key: jax.Array, cfg: EpisodicImageConfig) -> Iterator[Task]:
    while True:
        key, sub = jax.random.split(key)
        yield sample_image_task(sub, cfg)


@dataclasses.dataclass(frozen=True)
class EpisodicTokenConfig:
    way: int = 5
    shot: int = 8
    query_per_class: int = 8
    seq_len: int = 64
    vocab: int = 256
    concentration: float = 0.3       # lower = more distinct class unigrams


def sample_token_task(key: jax.Array, cfg: EpisodicTokenConfig) -> Task:
    kd, ks, kq, km = jax.random.split(key, 4)
    logits = jax.random.normal(kd, (cfg.way, cfg.vocab)) / cfg.concentration

    def draw(k, per_class):
        keys = jax.random.split(k, cfg.way)
        xs = jnp.stack([
            jax.random.categorical(kk, logits[c], shape=(per_class, cfg.seq_len))
            for c, kk in enumerate(keys)])
        y = jnp.repeat(jnp.arange(cfg.way), per_class)
        return xs.reshape(-1, cfg.seq_len).astype(jnp.int32), y

    sx, sy = draw(ks, cfg.shot)
    qx, qy = draw(kq, cfg.query_per_class)
    perm = jax.random.permutation(km, sx.shape[0])
    return Task(support_x=sx[perm], support_y=sy[perm],
                query_x=qx, query_y=qy, way=cfg.way)
