"""Synthetic episodic task generators (ORBIT / VTAB+MD stand-ins — the
real datasets are unavailable offline; DESIGN.md §8 records this).

Image tasks: each class is a Gaussian blob in pixel space with a class-
specific low-frequency pattern — linearly separable enough that accuracy
trends (flat-in-|H|, LITE > subsampled-task) are measurable in minutes on
CPU, yet non-trivial for a conv net from scratch.  Two sources: the jitted
on-device sampler (``task_batch_at``) and a host-side numpy twin
(``host_task_batch_at``) whose collation/augmentation a
``repro.train.pipeline.Prefetcher`` can overlap with device compute.

Shape bucketing: ``plan_buckets`` turns a stream histogram of task sizes
into <= ``max_buckets`` pad targets and ``collate_with_buckets`` collates
against them, so ragged streams hit a small closed set of compiled shapes
(paired with ``repro.train.pipeline.BucketedStepCache``).

Token tasks: each class is a distinct unigram distribution over the vocab;
sequences sample iid from it.  Used by the episodic-LM integration.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterator, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.episodic import Task, TaskBatch


@dataclasses.dataclass(frozen=True)
class EpisodicImageConfig:
    way: int = 5
    shot: int = 10                   # support examples per class
    query_per_class: int = 10
    image_size: int = 32
    channels: int = 3
    class_sep: float = 0.5           # distance between class means
    noise: float = 1.5


def sample_image_task(key: jax.Array, cfg: EpisodicImageConfig) -> Task:
    km, ks, kq, kp = jax.random.split(key, 4)
    h = w = cfg.image_size
    # class prototype pattern: low-freq random image per class
    base = jax.random.normal(kp, (cfg.way, h // 4, w // 4, cfg.channels))
    base = jax.image.resize(base, (cfg.way, h, w, cfg.channels), "linear")
    base = cfg.class_sep * base / jnp.sqrt(jnp.mean(base ** 2) + 1e-8)

    def draw(k, per_class):
        noise = cfg.noise * jax.random.normal(
            k, (cfg.way, per_class, h, w, cfg.channels))
        x = base[:, None] + noise
        y = jnp.repeat(jnp.arange(cfg.way), per_class)
        return x.reshape(-1, h, w, cfg.channels), y

    sx, sy = draw(ks, cfg.shot)
    qx, qy = draw(kq, cfg.query_per_class)
    perm = jax.random.permutation(km, sx.shape[0])
    return Task(support_x=sx[perm], support_y=sy[perm],
                query_x=qx, query_y=qy, way=cfg.way)


def image_task_stream(key: jax.Array, cfg: EpisodicImageConfig) -> Iterator[Task]:
    while True:
        key, sub = jax.random.split(key)
        yield sample_image_task(sub, cfg)


# ---------------------------------------------------------------------------
# Task-batch collation (the task-batched engine's input side)
# ---------------------------------------------------------------------------


def bucket_size(n: int, multiple: int = 8) -> int:
    """Round n up to the next bucket boundary.  Bucketing the pad targets
    keeps the number of distinct compiled shapes small when task sizes vary
    stream-to-stream (each (support, query) bucket pair is one XLA program)."""
    return max(((n + multiple - 1) // multiple) * multiple, multiple)


def plan_buckets(sizes: Sequence[int], max_buckets: int = 4,
                 multiple: int = 8) -> Tuple[int, ...]:
    """Choose at most ``max_buckets`` pad targets from a stream histogram.

    Every observed size rounds up (``bucket_size``) into a candidate cap;
    candidates are then greedily merged — always absorbing the cap whose
    removal adds the least total padding, weighted by how many stream
    elements land in it — until at most ``max_buckets`` remain.  The
    returned caps are ascending, cover ``max(sizes)``, and each is a
    multiple of ``multiple``, so a ragged stream collated against them
    produces a bounded set of compiled shapes with near-minimal padding
    waste for the observed distribution.
    """
    if not sizes:
        raise ValueError("plan_buckets needs a non-empty size histogram")
    if max_buckets < 1:
        raise ValueError(f"max_buckets={max_buckets} must be >= 1")
    hist: dict = {}
    for s in sizes:
        cap = bucket_size(s, multiple)
        hist[cap] = hist.get(cap, 0) + 1
    caps = sorted(hist)
    counts = [hist[c] for c in caps]
    while len(caps) > max_buckets:
        # merging cap i into cap i+1 pads each of its count_i elements by
        # at most (caps[i+1] - caps[i]) extra rows
        costs = [(caps[i + 1] - caps[i]) * counts[i]
                 for i in range(len(caps) - 1)]
        i = costs.index(min(costs))
        counts[i + 1] += counts[i]
        del caps[i], counts[i]
    return tuple(caps)


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """Smallest planned bucket that fits ``n``.  Overflow raises (same
    explicit-contract behavior as ``collate_task_batch`` with a fixed
    size): a stream element larger than every planned cap means the
    histogram the plan was built from is stale — recompute the plan rather
    than silently minting a new compiled shape."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"size {n} exceeds every planned bucket {tuple(buckets)}; "
                     f"re-plan buckets from a fresh stream histogram")


def collate_with_buckets(tasks: Sequence[Task],
                         support_buckets: Sequence[int],
                         query_buckets: Sequence[int]) -> TaskBatch:
    """Collate against planned buckets: pad targets are the smallest
    support/query caps covering the batch maxima, so every batch from the
    stream lands on one of ``len(support_buckets) * len(query_buckets)``
    compiled shapes."""
    return collate_task_batch(
        tasks,
        support_size=bucket_for(max(t.n_support for t in tasks),
                                support_buckets),
        query_size=bucket_for(max(t.n_query for t in tasks), query_buckets))


def collate_task_batch(tasks: Sequence[Task],
                       support_size: Optional[int] = None,
                       query_size: Optional[int] = None,
                       bucket_multiple: int = 0) -> TaskBatch:
    """Stack ragged tasks into one static-shape :class:`TaskBatch`.

    Support/query sets are right-padded to a common length (the batch max,
    an explicit ``support_size``/``query_size``, or the batch max rounded to
    ``bucket_multiple``) and validity masks record which rows are real.
    Padded support labels are -1 — the zero row of ``one_hot`` — so class
    sums/counts never see them; padded query labels are 0 and only the mask
    keeps them out of the loss.  All tasks must share ``way``.
    """
    if not tasks:
        raise ValueError("collate_task_batch needs at least one task")
    way = tasks[0].way
    if any(t.way != way for t in tasks):
        raise ValueError("all tasks in a batch must share `way`")

    # An explicit support_size/query_size is a fixed-compiled-shape
    # contract: it is used EXACTLY, and tasks that overflow it raise rather
    # than silently emitting a new shape.  Without one, the pad target is
    # the batch max, optionally rounded up to bucket_multiple.
    def target(actual: int, explicit: Optional[int], kind: str) -> int:
        if explicit is not None:
            if actual > explicit:
                raise ValueError(f"task {kind} size {actual} exceeds bucket "
                                 f"{kind}_size={explicit}")
            return explicit
        return bucket_size(actual, bucket_multiple) if bucket_multiple else actual

    n = target(max(t.n_support for t in tasks), support_size, "support")
    m = target(max(t.n_query for t in tasks), query_size, "query")

    def pad_rows(a: np.ndarray, rows: int, fill) -> np.ndarray:
        a = np.asarray(a)
        cfg = [(0, rows - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
        return np.pad(a, cfg, constant_values=fill)

    def mask_rows(real: int, rows: int) -> np.ndarray:
        return (np.arange(rows) < real).astype(np.float32)

    return TaskBatch(
        support_x=jnp.asarray(np.stack(
            [pad_rows(t.support_x, n, 0) for t in tasks])),
        support_y=jnp.asarray(np.stack(
            [pad_rows(t.support_y, n, -1) for t in tasks])),
        support_mask=jnp.asarray(np.stack(
            [mask_rows(t.n_support, n) for t in tasks])),
        query_x=jnp.asarray(np.stack(
            [pad_rows(t.query_x, m, 0) for t in tasks])),
        query_y=jnp.asarray(np.stack(
            [pad_rows(t.query_y, m, 0) for t in tasks])),
        query_mask=jnp.asarray(np.stack(
            [mask_rows(t.n_query, m) for t in tasks])),
        way=way,
    )


def iter_query_chunks(query_x: np.ndarray, chunk: int
                      ) -> Iterator[Tuple[np.ndarray, np.ndarray, int]]:
    """Split a request's query stream into fixed-shape ``(chunk, ...)``
    pieces: yields ``(padded_chunk, mask, n_real)`` per piece, tail
    zero-padded with a float32 validity mask.  The serve-side twin of
    :func:`repro.core.episodic.query_batches` (which is device-side for
    Algorithm 1's training loop): host numpy, streamed lazily, so the
    episodic serving engine's micro-batcher pulls one fixed-shape piece per
    live task per step and every ``predict_batch`` dispatch lands on one
    compiled shape."""
    if chunk < 1:
        raise ValueError(f"query chunk must be >= 1, got {chunk}")
    q = np.asarray(query_x)
    for s in range(0, q.shape[0], chunk):
        piece = q[s:s + chunk]
        n = piece.shape[0]
        if n < chunk:
            piece = np.pad(piece,
                           [(0, chunk - n)] + [(0, 0)] * (piece.ndim - 1))
        yield piece, (np.arange(chunk) < n).astype(np.float32), n


@functools.partial(jax.jit, static_argnums=(1, 2))
def sample_image_task_batch(key: jax.Array, cfg: EpisodicImageConfig,
                            num_tasks: int) -> TaskBatch:
    """vmapped synthetic sampler: num_tasks equally-shaped tasks as one
    TaskBatch (all-ones masks — no padding needed on the synthetic stream).
    Jitted (cfg/num_tasks static), so per-step data generation compiles
    once instead of re-tracing op-by-op in the training loop."""
    tasks = jax.vmap(lambda k: sample_image_task(k, cfg))(
        jax.random.split(key, num_tasks))
    ones = lambda a: jnp.ones(a.shape[:2], jnp.float32)
    return TaskBatch(support_x=tasks.support_x, support_y=tasks.support_y,
                     query_x=tasks.query_x, query_y=tasks.query_y,
                     support_mask=ones(tasks.support_y),
                     query_mask=ones(tasks.query_y), way=cfg.way)


def task_batch_at(key: jax.Array, cfg: EpisodicImageConfig,
                  tasks_per_step: int, step: int) -> TaskBatch:
    """Deterministic batch-for-step: a pure function of (key, cfg, step) —
    the contract repro.train.loop relies on for checkpoint-exact restarts."""
    return sample_image_task_batch(jax.random.fold_in(key, step), cfg,
                                   tasks_per_step)


# ---------------------------------------------------------------------------
# Host-side task source (the production-loader stand-in)
# ---------------------------------------------------------------------------
#
# Real episodic datasets (ORBIT video frames, VTAB images) are decoded,
# augmented, and collated on the HOST.  ``host_task_batch_at`` is the numpy
# twin of the device-side synthetic sampler: same class-blob task family,
# but all work runs in plain numpy (large GIL-releasing ops) so a
# :class:`repro.train.pipeline.Prefetcher` can overlap it with device
# compute — the device-side ``task_batch_at`` serializes with the train
# step on the accelerator queue and has nothing to overlap.


@dataclasses.dataclass(frozen=True)
class HostEpisodicConfig:
    """Host (numpy) episodic image stream.  ``augment`` adds the standard
    loader work — random crop (from ``image_size + crop_pad``), horizontal
    flip, per-image standardization — all vectorized over the batch."""

    way: int = 5
    shot: int = 10
    query_per_class: int = 10
    image_size: int = 32
    channels: int = 3
    class_sep: float = 0.5
    noise: float = 1.5
    augment: bool = True
    crop_pad: int = 4


def host_task_batch_at(seed: int, cfg: HostEpisodicConfig,
                       tasks_per_step: int, step: int) -> TaskBatch:
    """Deterministic host-side batch-for-step: a pure function of
    (seed, cfg, step) — the same restart-exactness contract as
    ``task_batch_at``, built on a counter-based PRNG
    (``np.random.SeedSequence([seed, step])``) so any step is
    reconstructible in isolation."""
    rng = np.random.Generator(np.random.PCG64(
        np.random.SeedSequence([seed, step])))
    t, way, c = tasks_per_step, cfg.way, cfg.channels
    per = cfg.shot + cfg.query_per_class
    big = cfg.image_size + (cfg.crop_pad if cfg.augment else 0)
    # class prototype: low-freq pattern upsampled 2x (numpy nearest);
    # built at ceil(big/2) and cropped so odd sizes work too
    base = rng.standard_normal(
        (t, way, (big + 1) // 2, (big + 1) // 2, c)).astype(np.float32)
    base = base.repeat(2, axis=2).repeat(2, axis=3)[:, :, :big, :big]
    base *= cfg.class_sep / np.sqrt((base ** 2).mean() + 1e-8)
    noise = cfg.noise * rng.standard_normal(
        (t, way, per, big, big, c)).astype(np.float32)
    x = (base[:, :, None] + noise).reshape(t * way * per, big, big, c)
    if cfg.augment:
        m, img = x.shape[0], cfg.image_size
        oy = rng.integers(0, cfg.crop_pad + 1, m)
        ox = rng.integers(0, cfg.crop_pad + 1, m)
        iy = oy[:, None] + np.arange(img)
        ix = ox[:, None] + np.arange(img)
        x = x[np.arange(m)[:, None, None], iy[:, :, None], ix[:, None, :]]
        flip = rng.integers(0, 2, m).astype(bool)
        x[flip] = x[flip, :, ::-1]
        mu = x.mean(axis=(1, 2), keepdims=True)
        sd = x.std(axis=(1, 2), keepdims=True) + 1e-6
        x = (x - mu) / sd
    img = cfg.image_size
    x = x.reshape(t, way, per, img, img, c)
    sx = np.ascontiguousarray(
        x[:, :, :cfg.shot].reshape(t, way * cfg.shot, img, img, c))
    qx = np.ascontiguousarray(
        x[:, :, cfg.shot:].reshape(t, way * cfg.query_per_class, img, img, c))
    sy = np.tile(np.repeat(np.arange(way), cfg.shot), (t, 1)).astype(np.int32)
    qy = np.tile(np.repeat(np.arange(way), cfg.query_per_class),
                 (t, 1)).astype(np.int32)
    ones = lambda y: np.ones(y.shape, np.float32)
    return TaskBatch(support_x=sx, support_y=sy, query_x=qx, query_y=qy,
                     support_mask=ones(sy), query_mask=ones(qy), way=way)


@dataclasses.dataclass(frozen=True)
class EpisodicTokenConfig:
    way: int = 5
    shot: int = 8
    query_per_class: int = 8
    seq_len: int = 64
    vocab: int = 256
    concentration: float = 0.3       # lower = more distinct class unigrams


def sample_token_task(key: jax.Array, cfg: EpisodicTokenConfig) -> Task:
    kd, ks, kq, km = jax.random.split(key, 4)
    logits = jax.random.normal(kd, (cfg.way, cfg.vocab)) / cfg.concentration

    def draw(k, per_class):
        keys = jax.random.split(k, cfg.way)
        xs = jnp.stack([
            jax.random.categorical(kk, logits[c], shape=(per_class, cfg.seq_len))
            for c, kk in enumerate(keys)])
        y = jnp.repeat(jnp.arange(cfg.way), per_class)
        return xs.reshape(-1, cfg.seq_len).astype(jnp.int32), y

    sx, sy = draw(ks, cfg.shot)
    qx, qy = draw(kq, cfg.query_per_class)
    perm = jax.random.permutation(km, sx.shape[0])
    return Task(support_x=sx[perm], support_y=sy[perm],
                query_x=qx, query_y=qy, way=cfg.way)
