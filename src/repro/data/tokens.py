"""Synthetic LM token pipeline: sharded host->device feed with prefetch.

Produces next-token-predictable streams (orderful Markov chains) so losses
fall during smoke training runs, plus a deterministic per-step PRNG layout
so restarts reproduce the exact byte stream (checkpoint-exactness tests
rely on this).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int = 256
    seq_len: int = 128
    global_batch: int = 8
    branching: int = 4               # Markov out-degree (predictability)
    seed: int = 0


class TokenPipeline:
    """Deterministic function of (config, step) — restart-exact."""

    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self._next = rng.integers(
            0, cfg.vocab, size=(cfg.vocab, cfg.branching)).astype(np.int32)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        tok = np.empty((cfg.global_batch, cfg.seq_len), np.int32)
        tok[:, 0] = rng.integers(0, cfg.vocab, cfg.global_batch)
        for t in range(1, cfg.seq_len):
            branch = rng.integers(0, cfg.branching, cfg.global_batch)
            tok[:, t] = self._next[tok[:, t - 1], branch]
        return dict(tokens=tok)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (host->device overlap on real hardware)."""

    def __init__(self, it: Iterator, depth: int = 2, sharding=None):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._sharding = sharding
        self._it = it
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._stop = False
        self._thread.start()

    def _run(self):
        for item in self._it:
            if self._stop:
                return
            if self._sharding is not None:
                item = jax.tree.map(
                    lambda a: jax.device_put(a, self._sharding), item)
            self._q.put(item)

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop = True
