"""Episodic serving engine: production-shaped adapt-many-tasks serving.

The LM engine (repro.serve.engine) serves token decode; this engine serves
the paper's test-time workload — ORBIT-style per-user personalization at
traffic scale.  A request is one episode: a support set to adapt on and a
query stream to answer.  The paper's headline tradeoff is that
meta-learners are cheap at test time ("just a few optimization steps or a
single forward pass" per new task); at "millions of users" the scarce
resource is therefore millions of *adapted task states*, not params, and
re-adaptation is the expensive tail (fomaml re-adapt is ~66x a query
chunk per table1_adaptation_cost.csv).  The engine is built around that:

* **Continuous batching with admission control** — ``submit`` enqueues;
  each ``step`` admits FIFO from the queue into up to ``n_slots`` live
  task lanes (head-of-line: a request whose uid is already live defers so
  one uid is never adapted twice concurrently), batch-adapts newly
  admitted tasks, and micro-batches the next query chunk of every live
  task, all through a per-shape AOT compile cache
  (:class:`repro.train.pipeline.BucketedStepCache`) padded to fixed
  shapes so compile counters stay flat and co-scheduling is bit-exact.
* **Per-request latency accounting from an injectable clock** — requests
  carry enqueue/admit/adapt/first-logit/done timestamps stamped from the
  engine ``clock`` (default ``time.monotonic``; tests inject a manually
  advanced ``FakeClock``), and ``stats()`` reports exact nearest-rank
  p50/p99 adapt latency (enqueue → state ready) and query latency
  (enqueue → first logit) plus the current queue depth.
* **SLO-aware dispatch scheduling** — adaptation is the expensive tail,
  query chunks are cheap.  With ``query_slo_us`` set, a step whose
  pending adapt wave would push a live lane's first-unserved-query past
  its deadline (estimated from an EWMA of measured adapt-dispatch cost,
  seedable via ``adapt_cost_hint_us``) *defers the adapt wave* and spends
  the dispatch on queries instead; a deadline that is already missed no
  longer preempts (the SLO is blown either way), so adapt waves cannot
  starve.  ``stats()['slo_preemptions']`` counts the decisions.
* **Two-tier task-state store** — adapted states live in an L1 LRU
  (:class:`TaskStateCache`) keyed by task uid; with ``warm_dir`` set, L1
  eviction *spills* the state to a disk warm tier
  (:class:`WarmTaskStore`) through the checkpoint serialization
  (``repro.train.checkpoint.save_array_tree``), and a repeat uid that
  misses L1 *rehydrates* from the warm tier instead of re-adapting —
  bit-exact to the originally adapted state, with unchanged avals so the
  compiled predict dispatch is reused (counters flat).  Without
  ``warm_dir`` eviction discards, as before.
* **LITE-chunked forward-only adaptation** — the aggregating learners run
  the serve estimators (repro.core.lite.serve_sum / serve_segment_sum):
  exact values, no-grad chunks, so a 1000-image support set adapts under
  an O(chunk_size) activation bound.

    engine = EpisodicServeEngine(learner, params, n_slots=4,
                                 support_buckets=(64,), query_chunk=8,
                                 warm_dir="/tmp/warm", query_slo_us=5e4)
    engine.run_to_completion([EpisodicRequest(uid=0, support_x=sx,
                                              support_y=sy, query_x=qx)])
"""
from __future__ import annotations

import collections
import dataclasses
import math
import os
import pathlib
import pickle
import shutil
import time
import zlib
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.episodic import (Task, index_task_state, stack_task_states)
from repro.core.episodic_train import task_key
from repro.kernels import dispatch
from repro.core.lite import LiteSpec
from repro.core.meta_learners import MetaLearner
from repro.data.episodic import (bucket_for, collate_task_batch,
                                 iter_query_chunks)
from repro.faults.plan import WARM_CORRUPT, WARM_VANISH
from repro.serve.quant_params import (dequantize_params, param_bytes,
                                      place_serving_weights, quantize_frozen)
from repro.train.checkpoint import load_array_tree, save_array_tree
from repro.train.pipeline import BucketedStepCache

PyTree = Any


def stable_uid_hash(uid: int) -> int:
    """Process-stable hash of a task uid (crc32 of its 8-byte encoding).

    Python's builtin ``hash`` is salted per process; routing and warm-dir
    sharding both need a uid -> integer map that agrees across engine
    restarts and across replica processes, so repeat visitors always land
    on the replica (and warm subdir) holding their state."""
    return zlib.crc32(int(uid).to_bytes(8, "little", signed=True))


def _pctl(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (classic definition): exact and assertable
    against a scripted arrival stream — no interpolation."""
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[max(0, math.ceil(q / 100.0 * len(s)) - 1)]


@dataclasses.dataclass
class EpisodicRequest:
    """One personalization episode.

    ``uid`` is the task identity (the state-store key): two requests with
    the same uid are the same task, and the second may omit its support
    set entirely if the first's state is still in either store tier.
    ``query_x`` is the query stream — served in engine-sized chunks,
    logits accumulated in arrival order.

    Degradation outcomes (each also a ``stats()`` counter): ``rejected``
    — bounded-queue backpressure refused the submit (``retry_after_us``
    estimates when to re-offer; the request was never admitted and no
    admitted request is ever dropped for it); ``abandoned`` — the
    per-request deadline passed before the first logit (queued or still
    awaiting adaptation) and the engine freed the lane; ``failed`` — a
    support-less request whose only stored state turned out corrupt
    (quarantined): nothing can ever produce its logits.

    The ``t_*`` timestamps are stamped by the engine from its injectable
    clock (seconds, monotonic): ``t_enqueue`` at submit, ``t_admit`` when
    a slot is taken, ``t_adapt`` when the adapted state lands (absent on
    a state-store hit), ``t_first_logit`` when the first query chunk
    returns, ``t_done`` at retirement."""

    uid: int
    query_x: np.ndarray                          # (M, ...) query stream
    support_x: Optional[np.ndarray] = None       # (N, ...); None ok on a
    support_y: Optional[np.ndarray] = None       # (N,)     expected cache hit
    way: int = 5
    logits: List[np.ndarray] = dataclasses.field(default_factory=list)
    served: int = 0
    cache_hit: Optional[bool] = None             # set at admission
    done: bool = False
    rejected: bool = False                       # backpressure refusal
    retry_after_us: Optional[float] = None       # stamped on rejection
    abandoned: bool = False                      # deadline passed pre-logit
    failed: bool = False                         # unrecoverable (see above)
    t_enqueue: Optional[float] = None
    t_admit: Optional[float] = None
    t_adapt: Optional[float] = None
    t_first_logit: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def n_queries(self) -> int:
        return int(np.asarray(self.query_x).shape[0])

    def all_logits(self) -> np.ndarray:
        """(M, way) logits in query order (complete once ``done``)."""
        if not self.logits:
            return np.zeros((0, self.way), np.float32)
        return np.concatenate(self.logits, axis=0)

    def predictions(self) -> np.ndarray:
        return np.argmax(self.all_logits(), axis=-1)


class TaskStateCache:
    """LRU cache of adapted task states keyed by task uid — the L1 of the
    two-tier store.

    Stats contract (well-defined, tested): ``hits``/``misses`` count
    ``get`` lookups ONLY.  ``put`` on an existing uid is an *overwrite* —
    it refreshes recency and bumps ``overwrites``, never hits/misses.
    ``evictions`` counts capacity evictions (never overwrites); each
    evicted ``(uid, state)`` is handed to ``on_evict`` — the two-tier
    store's spill path — before being dropped from L1."""

    def __init__(self, capacity: int = 64,
                 on_evict: Optional[Callable[[int, PyTree], None]] = None):
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.hits = 0
        self.misses = 0
        self.overwrites = 0
        self.evictions = 0
        self._on_evict = on_evict
        self._d: "collections.OrderedDict[int, PyTree]" = \
            collections.OrderedDict()

    def get(self, uid: int) -> Optional[PyTree]:
        if uid in self._d:
            self._d.move_to_end(uid)
            self.hits += 1
            return self._d[uid]
        self.misses += 1
        return None

    def put(self, uid: int, state: PyTree) -> None:
        if uid in self._d:
            self.overwrites += 1
        self._d[uid] = state
        self._d.move_to_end(uid)
        while len(self._d) > self.capacity:
            old_uid, old_state = self._d.popitem(last=False)
            self.evictions += 1
            if self._on_evict is not None:
                self._on_evict(old_uid, old_state)

    def __contains__(self, uid: int) -> bool:
        return uid in self._d

    def __len__(self) -> int:
        return len(self._d)


class WarmTaskStore:
    """Disk warm tier for spilled task states: one self-describing npz
    per uid (atomic tmp + ``os.replace``), written/read through the
    checkpoint serialization (``save_array_tree``/``load_array_tree``) so
    a rehydrated state is bit-exact to the spilled one.  The abstract
    template per uid (shapes/dtypes/treedef — tiny) is held host-side AND
    persisted beside the npz as a pickle sidecar (``uid_N.tmpl.pkl``,
    atomic tmp + replace), so spilled states survive an engine restart: a
    fresh store over the same directory rescans the sidecars and serves
    every surviving uid bit-exactly (``template_restores`` counts them).
    A sidecar that fails to load is dropped (its uid just re-adapts); a
    quarantined npz drops its sidecar too, so restart can never resurrect
    an entry that was ruled corrupt.

    **Sharded layout + cross-process safety** (the multi-replica serving
    contract): with ``shards > 1`` each uid's files live in the uid-hash
    subdir ``shard_{stable_uid_hash(uid) % shards}`` — a pure function of
    the uid, so every store over the same directory (one per serving
    replica) agrees on where a uid lives without coordination, and
    replicas whose routed uid sets map to disjoint shards never contend
    on a subdir.  The template index is no longer frozen at construction:
    a ``get``/``in`` miss *rescans* the uid's canonical sidecar path (and,
    defensively, every shard subdir) before giving up, so a uid spilled by
    replica A AFTER replica B's startup scan is still found by B — the
    post-failover rehydration path (``rescan_hits`` counts these late
    finds).  Entries written under a different shard count remain
    loadable: the rescan walks all subdirs, and a later ``put`` migrates
    the files to the canonical shard.

    Every read verifies the whole-content CRC32 the writer embedded
    (``load_array_tree(verify=True)``); a zero-byte/truncated file fails
    earlier inside ``np.load``.  ANY read failure — bad zip, checksum
    mismatch, missing leaf — *quarantines* the entry: the file is renamed
    aside (``quarantine_uid_*.npz``, kept for forensics), the template is
    dropped, ``quarantined`` is bumped, and ``get`` returns None so the
    caller falls back to re-adaptation.  A file that vanished outright
    (template present, path gone) counts as quarantined too.  ``fault_plan``
    (:class:`repro.faults.FaultPlan`) drives site ``warm.corrupt``:
    fired at a uid's ``put``, the just-published npz is truncated to
    ``payload`` bytes — crash-mid-write residue, deterministically."""

    def __init__(self, directory: str | pathlib.Path, fault_plan=None,
                 shards: int = 1):
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.shards = int(shards)
        self._templates: Dict[int, PyTree] = {}
        # where each known uid's files actually live (its subdir) — equals
        # the canonical shard dir except for entries written under a
        # different shard count and not yet migrated by a fresh put
        self._homes: Dict[int, pathlib.Path] = {}
        self._fault_plan = fault_plan
        self.quarantined = 0
        self.template_restores = 0
        self.rescan_hits = 0
        # durable warm tier: rescan template sidecars left by a previous
        # store over this directory (engine restart) — an unreadable
        # sidecar is dropped, its uid simply re-adapts
        for side in sorted(self.dir.glob("uid_*.tmpl.pkl")) + \
                sorted(self.dir.glob("shard_*/uid_*.tmpl.pkl")):
            if self._load_sidecar(side):
                self.template_restores += 1

    def _load_sidecar(self, side: pathlib.Path) -> bool:
        try:
            uid = int(side.name.split(".")[0].split("_", 1)[1])
            with open(side, "rb") as f:
                self._templates[uid] = pickle.load(f)
            self._homes[uid] = side.parent
            return True
        except Exception as e:  # noqa: BLE001 — any unreadable sidecar
            print(f"warm tier: dropping unreadable template sidecar "
                  f"{side.name} ({type(e).__name__}: {e})", flush=True)
            side.unlink(missing_ok=True)
            return False

    def _shard_dir(self, uid: int) -> pathlib.Path:
        """Canonical subdir for ``uid`` — a pure function of (uid, shards),
        so independent stores over the same directory agree on it."""
        if self.shards == 1:
            return self.dir
        return self.dir / f"shard_{stable_uid_hash(uid) % self.shards}"

    def _home(self, uid: int) -> pathlib.Path:
        return self._homes.get(uid, self._shard_dir(uid))

    def _path(self, uid: int) -> pathlib.Path:
        return self._home(uid) / f"uid_{uid}.npz"

    def _tmpl_path(self, uid: int) -> pathlib.Path:
        return self._home(uid) / f"uid_{uid}.tmpl.pkl"

    def _rescan(self, uid: int) -> bool:
        """Rescan-on-miss: look for ``uid``'s sidecar written AFTER this
        store's startup scan (another replica's spill — the post-failover
        rehydration path).  Canonical shard path first, then every shard
        subdir and the root (entries from a different shard count)."""
        candidates = [self._shard_dir(uid) / f"uid_{uid}.tmpl.pkl",
                      self.dir / f"uid_{uid}.tmpl.pkl"]
        candidates += sorted(self.dir.glob(f"shard_*/uid_{uid}.tmpl.pkl"))
        for side in candidates:
            if side.exists() and self._load_sidecar(side):
                self.rescan_hits += 1
                return True
        return False

    def put(self, uid: int, state: PyTree) -> None:
        home = self._shard_dir(uid)
        if home != self.dir:
            # parents=False: a vanished warm ROOT must stay an OSError for
            # the caller (warm.vanish degrades to L1-only), never be
            # silently recreated here
            home.mkdir(exist_ok=True)
        old_home = self._homes.get(uid)
        tmp = home / f".tmp_uid_{uid}.npz"
        save_array_tree(tmp, state)
        os.replace(tmp, home / f"uid_{uid}.npz")
        tmpl = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(jnp.shape(a), jnp.result_type(a)),
            state)
        self._templates[uid] = tmpl
        self._homes[uid] = home
        # template sidecar AFTER the npz: a crash between the two leaves
        # an orphan npz that a restarted store simply never lists (safe),
        # never a template pointing at a half-written payload
        side_tmp = home / f".tmp_uid_{uid}.tmpl.pkl"
        with open(side_tmp, "wb") as f:
            pickle.dump(tmpl, f)
        os.replace(side_tmp, home / f"uid_{uid}.tmpl.pkl")
        if old_home is not None and old_home != home:
            # migrated from a stale shard layout: drop the old files so a
            # directory-walking rescan can never resurrect the stale copy
            (old_home / f"uid_{uid}.npz").unlink(missing_ok=True)
            (old_home / f"uid_{uid}.tmpl.pkl").unlink(missing_ok=True)
        if self._fault_plan is not None:
            spec = self._fault_plan.fire(WARM_CORRUPT, uid)
            if spec is not None:
                keep = int(spec.payload) if spec.payload is not None else 16
                with open(self._path(uid), "r+b") as f:
                    f.truncate(keep)

    def _quarantine(self, uid: int, err: Exception) -> None:
        path = self._path(uid)
        self.quarantined += 1
        self._tmpl_path(uid).unlink(missing_ok=True)
        self._templates.pop(uid, None)
        if path.exists():
            aside = path.parent / \
                f"quarantine_uid_{uid}_{self.quarantined}.npz"
            os.replace(path, aside)
            where = f"moved aside to {aside.name}"
        else:
            where = "file already gone"
        self._homes.pop(uid, None)
        print(f"warm tier: quarantined uid={uid} ({type(err).__name__}: "
              f"{err}; {where})", flush=True)

    def get(self, uid: int) -> Optional[PyTree]:
        if uid not in self._templates and not self._rescan(uid):
            return None
        if not self._path(uid).exists():
            self._quarantine(uid, FileNotFoundError(str(self._path(uid))))
            return None
        try:
            return load_array_tree(self._path(uid), self._templates[uid],
                                   verify=True)
        except Exception as e:  # noqa: BLE001 — any unreadable entry
            self._quarantine(uid, e)
            return None

    def __contains__(self, uid: int) -> bool:
        if uid not in self._templates and not self._rescan(uid):
            return False
        return self._path(uid).exists()

    def __len__(self) -> int:
        return sum(1 for uid in self._templates if self._path(uid).exists())


class TwoTierTaskStore:
    """L1 LRU of resident task states over an optional disk warm tier.

    ``get`` promotes a warm-tier hit back into L1 (which may spill
    another state — states cascade, none is silently lost while the warm
    tier holds it).  ``hits``/``misses`` are the L1's; ``spills`` counts
    evictions that landed in the warm tier, ``rehydrates`` counts
    warm-tier loads.  With ``warm_dir=None`` eviction discards (the PR3
    behavior) and ``rehydrates`` stays 0.

    A spill whose write fails (warm directory removed out from under the
    engine — tmpfs cleanup, the ``warm.vanish`` fault site) does NOT take
    the engine down: the error is logged once, ``spill_errors`` is
    bumped, and the store degrades to L1-only for the rest of its life
    (evictions discard, warm lookups stop) — correctness is untouched
    because a discarded state just re-adapts on the next request."""

    def __init__(self, capacity: int = 64,
                 warm_dir: Optional[str | pathlib.Path] = None,
                 fault_plan=None, warm_shards: int = 1):
        self.warm = (WarmTaskStore(warm_dir, fault_plan=fault_plan,
                                   shards=warm_shards)
                     if warm_dir is not None else None)
        self.l1 = TaskStateCache(capacity, on_evict=self._spill)
        self._fault_plan = fault_plan
        self.spills = 0
        self.rehydrates = 0
        self.spill_errors = 0
        self.warm_disabled = False

    @property
    def quarantined(self) -> int:
        return self.warm.quarantined if self.warm is not None else 0

    @property
    def rescan_hits(self) -> int:
        return self.warm.rescan_hits if self.warm is not None else 0

    def _warm_live(self) -> bool:
        return self.warm is not None and not self.warm_disabled

    def _spill(self, uid: int, state: PyTree) -> None:
        if not self._warm_live():
            return
        if self._fault_plan is not None and \
                self._fault_plan.fire(WARM_VANISH, uid) is not None:
            shutil.rmtree(self.warm.dir, ignore_errors=True)
        try:
            self.warm.put(uid, state)
        except OSError as e:
            self.spill_errors += 1
            self.warm_disabled = True
            print(f"warm tier: spill of uid={uid} failed "
                  f"({type(e).__name__}: {e}) — degrading to L1-only, "
                  f"evicted states will re-adapt", flush=True)
            return
        self.spills += 1

    def get(self, uid: int) -> Optional[PyTree]:
        state = self.l1.get(uid)
        if state is not None:
            return state
        if self._warm_live():
            state = self.warm.get(uid)
            if state is not None:
                self.rehydrates += 1
                self.l1.put(uid, state)      # promote (may spill another)
                return state
        return None

    def put(self, uid: int, state: PyTree) -> None:
        self.l1.put(uid, state)

    def __contains__(self, uid: int) -> bool:
        return uid in self.l1 or (self._warm_live() and uid in self.warm)

    def __len__(self) -> int:
        return len(self.l1)


@dataclasses.dataclass
class _Slot:
    req: EpisodicRequest
    state: Optional[PyTree]                      # None => awaiting adaptation
    stream: Iterator


class EpisodicServeEngine:
    """Single-host adapt-many-tasks engine over the batched TaskState
    contract (``learner.adapt_batch`` / ``learner.predict_batch``) with
    continuous batching, SLO accounting, and a two-tier state store (see
    module docstring for the full contract).

    ``support_buckets`` are the planned support pad caps
    (:func:`repro.data.episodic.plan_buckets` builds them from a stream
    histogram); a support set larger than every cap is rejected at
    admission with an actionable error, same stale-histogram contract as
    training-side collation.  All requests must share the learner's
    ``way`` and one query trailing shape — one engine per model input
    spec, as with the LM engine.
    """

    def __init__(self, learner: MetaLearner, params: PyTree, *,
                 lite: Optional[LiteSpec] = None, n_slots: int = 4,
                 query_chunk: int = 8,
                 support_buckets: Sequence[int] = (64,),
                 cache_capacity: int = 64, seed: int = 0,
                 kernel_backend: Optional[str] = None,
                 clock: Optional[Callable[[], float]] = None,
                 warm_dir: Optional[str | pathlib.Path] = None,
                 query_slo_us: Optional[float] = None,
                 adapt_cost_hint_us: Optional[float] = None,
                 fault_plan=None,
                 max_queue: Optional[int] = None,
                 deadline_us: Optional[float] = None,
                 serve_quant: str = "none",
                 serve_layout: Optional[str] = None,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 warm_shards: int = 1):
        """Fault-tolerance knobs: ``fault_plan`` threads to the store tiers
        (sites ``warm.corrupt`` / ``warm.vanish``); ``max_queue`` bounds
        the admission queue — a submit over the bound is REJECTED with a
        ``retry_after_us`` estimate from the adapt-cost EWMA instead of
        growing the queue without bound (admitted requests are never
        dropped); ``deadline_us`` abandons a request whose deadline
        (from ``t_enqueue``) passes before its first logit, freeing the
        lane/queue slot.  All three default off — behavior unchanged.

        Weight-stationary quantized serving: ``serve_quant='int8'``
        quantizes the learner kind's FROZEN param slice into the blockwise
        int8 form (``repro.serve.quant_params.quantize_frozen``) —
        dequantized lazily inside both jitted dispatches, never resident
        in f32 — and ``stats()`` reports the measured resident parameter
        bytes.  ``serve_layout`` + ``mesh`` place the serving weights in a
        named layout from ``repro.roofline.analysis.SERVING_LAYOUTS``
        (e.g. ``weight_stationary``: contracting dims sharded so the
        per-step wire carries small activations instead of gathered
        weights); resolve ``'auto'`` to a concrete name with
        ``choose_serving_layout`` BEFORE construction (the launcher and
        benchmarks do) — the engine applies a layout, it does not score
        one.  In a multi-replica deployment ``mesh`` is the replica's OWN
        disjoint device group (``make_replica_mesh``): weights are
        stationary within the group and no predict-step collective ever
        crosses it.

        ``warm_shards`` partitions the warm directory into uid-hash
        subdirs (see :class:`WarmTaskStore`) — replicas sharing one warm
        root spill/rehydrate without contending on a subdir."""
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.learner = learner
        self.params = params
        # serving weights: the frozen slice quantized (or wrapped as-is
        # for mode 'none' — the dispatch path is identical either way, so
        # flipping --serve-quant can never change compile counters)
        self.serve_quant = serve_quant
        self._weights = quantize_frozen(learner, params, serve_quant)
        self._param_bytes = param_bytes(self._weights)
        self.serve_layout = serve_layout
        self.mesh = mesh
        self._weights = place_serving_weights(self._weights, mesh,
                                              serve_layout)
        # serve-time default: exact forward values, chunk-bounded memory
        self.lite = lite if lite is not None else LiteSpec(exact=True,
                                                           chunk_size=32)
        self.n_slots = n_slots
        self.query_chunk = query_chunk
        self.support_buckets = tuple(sorted(support_buckets))
        self.store = TwoTierTaskStore(cache_capacity, warm_dir,
                                      fault_plan=fault_plan,
                                      warm_shards=warm_shards)
        self.clock = clock if clock is not None else time.monotonic
        self.query_slo_us = query_slo_us
        self.max_queue = max_queue
        self.deadline_us = deadline_us
        # EWMA of measured adapt-dispatch wall time; zero-duration
        # observations (a FakeClock that wasn't advanced) are ignored so
        # scripted tests keep a stable, assertable estimate
        self._adapt_cost_est_us: Optional[float] = adapt_cost_hint_us
        self._queue: "collections.deque[EpisodicRequest]" = \
            collections.deque()
        self._slots: List[Optional[_Slot]] = [None] * n_slots
        self._base_key = jax.random.key(seed)
        # The aggregation-kernel backend (repro.kernels.dispatch) is an
        # ENGINE property, resolved once at construction (None = the
        # ambient dispatch default) and bound at trace time inside both
        # dispatches.  The per-shape compile cache keys on shapes alone,
        # so flipping the ambient default on a warm engine never
        # recompiles or changes results — a different backend is a
        # different engine.
        self.kernel_backend = dispatch.resolve_backend(kernel_backend)

        def _adapt_fn(sw, batch, keys):
            with dispatch.use_backend(self.kernel_backend):
                # lazy in-jit dequantize: XLA fuses the int8->f32 expansion
                # into the step; the f32 weights never persist between steps
                return learner.adapt_batch(dequantize_params(sw), batch,
                                           keys, self.lite)

        def _predict_fn(sw, states, qx):
            with dispatch.use_backend(self.kernel_backend):
                return learner.predict_batch(dequantize_params(sw), states,
                                             qx)

        self._adapt = BucketedStepCache(_adapt_fn)
        self._predict = BucketedStepCache(_predict_fn)
        # resident stacked states for an unchanged live cohort — slot
        # states are immutable after adaptation, so the (n_slots, ...)
        # predict-side stack is rebuilt only when a slot joins or retires
        self._stacked_states: Optional[tuple] = None
        self._adapt_lat_us: List[float] = []
        self._query_lat_us: List[float] = []
        self.tasks_adapted = 0
        self.queries_served = 0
        self.slo_preemptions = 0
        self.steps = 0
        self.rejections = 0
        self.deadline_abandoned = 0
        self.failed_requests = 0

    # -- scheduling ----------------------------------------------------------

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self._slots):
            if s is None:
                return i
        return None

    def submit(self, req: EpisodicRequest) -> bool:
        """Enqueue ``req`` (stamps ``t_enqueue``); admission happens FIFO
        inside ``step`` as slots free up — the continuous-batching entry
        point.  With ``max_queue`` set, a submit that would overflow the
        bound is REJECTED (returns False; ``req.rejected`` set): the
        request is not enqueued, nothing already admitted/queued is
        displaced, and ``req.retry_after_us`` carries a re-offer estimate
        — queue-depth-ahead / n_slots adapt waves at the EWMA-estimated
        adapt cost (0 when no estimate exists yet)."""
        if req.t_enqueue is None:
            req.t_enqueue = self.clock()
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            req.rejected = True
            est = self._adapt_cost_est_us or 0.0
            req.retry_after_us = math.ceil(
                (len(self._queue) + 1) / self.n_slots) * est
            self.rejections += 1
            return False
        self._queue.append(req)
        return True

    def add_request(self, req: EpisodicRequest) -> bool:
        """Immediate-admission compatibility path: try to place ``req`` in
        a free slot right now; False when all slots are live or the uid is
        already live (re-offer after a step).  ``submit`` + ``step`` is
        the production path."""
        if req.t_enqueue is None:
            req.t_enqueue = self.clock()
        return self._try_admit(req)

    def _try_admit(self, req: EpisodicRequest) -> bool:
        """Admit ``req`` into a free slot.  False defers (no free slot, or
        its uid is already live — the state in flight will be shared, one
        uid is never adapted twice concurrently).  A support-less request
        whose uid is in neither store tier is an error — nothing will
        ever produce its state; a support set exceeding every planned
        bucket is an error at admission, not at dispatch."""
        if self._free_slot() is None:
            return False
        if req.way != self.learner.cfg.way:
            raise ValueError(f"request way={req.way} != learner way="
                             f"{self.learner.cfg.way}")
        if any(s is not None and s.req.uid == req.uid for s in self._slots):
            return False
        if req.support_x is not None:
            n = int(np.asarray(req.support_x).shape[0])
            if n > self.support_buckets[-1]:
                raise ValueError(
                    f"request uid={req.uid}: support size {n} exceeds every "
                    f"planned bucket {self.support_buckets}; re-plan buckets "
                    f"from a fresh stream histogram")
        elif req.uid not in self.store:
            raise ValueError(f"request uid={req.uid}: no cached task state "
                             f"and no support set to adapt on")
        state = self.store.get(req.uid)
        if state is None and req.support_x is None:
            # membership said the state existed, but the read quarantined
            # it (corrupt warm entry discovered at load).  With no support
            # set nothing can ever produce this task's state — terminal
            # failure, not a crash, and the slot stays free.  A request
            # WITH support just falls through to re-adaptation.
            req.failed = True
            req.done = True
            req.t_done = self.clock()
            self.failed_requests += 1
            return True                          # consumed; no slot taken
        req.cache_hit = state is not None
        req.t_admit = self.clock()
        self._slots[self._free_slot()] = _Slot(
            req=req, state=state,
            stream=iter_query_chunks(req.query_x, self.query_chunk))
        return True

    def _admit_from_queue(self) -> None:
        """FIFO admission with head-of-line order (matching the PR3
        run_to_completion loop): the queue head is admitted or everyone
        waits — deterministic, no reordering."""
        while self._queue and self._try_admit(self._queue[0]):
            self._queue.popleft()

    def _earliest_query_deadline_us(self) -> Optional[float]:
        """Earliest SLO deadline over live ADAPTED lanes with queries
        still to serve — the lanes a deferred adapt wave would actually
        help.  Lanes awaiting adaptation are excluded: adaptation is
        their prerequisite, deferring it only hurts them."""
        if self.query_slo_us is None:
            return None
        deadlines = [s.req.t_enqueue * 1e6 + self.query_slo_us
                     for s in self._slots
                     if s is not None and s.state is not None]
        return min(deadlines) if deadlines else None

    def _adapt_wave_preempted(self, now: float) -> bool:
        """The SLO decision: defer the pending adapt wave iff some live
        query lane's deadline is still AHEAD but would be missed by
        waiting out the (estimated) adapt dispatch.  An already-missed
        deadline never preempts — the SLO is blown either way — so adapt
        waves cannot be starved by a permanently-late stream."""
        if self._adapt_cost_est_us is None:
            return False
        dmin = self._earliest_query_deadline_us()
        if dmin is None:
            return False
        now_us = now * 1e6
        return now_us < dmin <= now_us + self._adapt_cost_est_us

    # -- the two batched dispatches ------------------------------------------

    def _adapt_pending(self) -> None:
        """One adapt_batch dispatch per support-bucket group of slots
        awaiting adaptation, each padded to n_slots task lanes.  A task's
        pad cap is chosen by its OWN support size — never by its
        co-tenants' — so the adapted (and stored) state is a pure function
        of (params, support, uid) and co-scheduling stays bit-exact even
        with several planned buckets."""
        need = [i for i, s in enumerate(self._slots)
                if s is not None and s.state is None]
        if not need:
            return
        groups: Dict[int, List[int]] = {}
        for i in need:
            n = int(np.asarray(self._slots[i].req.support_x).shape[0])
            groups.setdefault(bucket_for(n, self.support_buckets),
                              []).append(i)
        for cap, idxs in sorted(groups.items()):
            tasks, uids = [], []
            for i in idxs:
                r = self._slots[i].req
                sx = np.asarray(r.support_x, np.float32)
                # queries ride their own micro-batched dispatch; the
                # collated task carries a 1-row dummy so the adapt shape
                # key is fixed
                tasks.append(Task(
                    support_x=sx,
                    support_y=np.asarray(r.support_y, np.int32),
                    query_x=np.zeros((1,) + sx.shape[1:], np.float32),
                    query_y=np.zeros((1,), np.int32), way=r.way))
                uids.append(r.uid)
            while len(tasks) < self.n_slots:   # fixed task-lane count
                tasks.append(tasks[0])
                uids.append(uids[0])
            batch = collate_task_batch(tasks, support_size=cap, query_size=1)
            keys = jax.vmap(lambda u: task_key(self._base_key, u))(
                jnp.asarray(uids))
            t0 = self.clock()
            states = jax.block_until_ready(
                self._adapt(self._weights, batch, keys))
            t1 = self.clock()
            dt_us = (t1 - t0) * 1e6
            if dt_us > 0:                      # fake clocks may not advance
                self._adapt_cost_est_us = (
                    dt_us if self._adapt_cost_est_us is None
                    else 0.7 * self._adapt_cost_est_us + 0.3 * dt_us)
            for lane, i in enumerate(idxs):
                st = index_task_state(states, lane)
                slot = self._slots[i]
                slot.state = st
                slot.req.t_adapt = t1
                self._adapt_lat_us.append((t1 - slot.req.t_enqueue) * 1e6)
                self.store.put(slot.req.uid, st)
            self.tasks_adapted += len(idxs)

    def _retire(self, i: int) -> None:
        r = self._slots[i].req
        r.done = True
        r.t_done = self.clock()
        self._slots[i] = None

    def _serve_queries(self) -> int:
        """ONE predict_batch dispatch serving the next query chunk of every
        live task; empty lanes carry a filler state and zero queries."""
        lanes = []                               # (slot_idx, chunk, n_real)
        for i, s in enumerate(self._slots):
            if s is None or s.state is None:     # awaiting (deferred) adapt
                continue
            item = next(s.stream, None)
            if item is None:                     # stream exhausted (M == 0)
                self._retire(i)
                continue
            chunk, _, n_real = item
            lanes.append((i, chunk, n_real))
        if not lanes:
            return 0
        chunk_shape = lanes[0][1].shape
        if any(l[1].shape != chunk_shape for l in lanes):
            raise ValueError("live tasks disagree on query trailing shape; "
                             "one engine serves one model input spec")
        qx = np.zeros((self.n_slots,) + chunk_shape, np.float32)
        for lane, (i, chunk, _) in enumerate(lanes):
            qx[lane] = chunk
        cohort = tuple((i, self._slots[i].req.uid) for i, _, _ in lanes)
        if (self._stacked_states is not None
                and self._stacked_states[0] == cohort):
            stacked = self._stacked_states[1]
        else:
            states = [self._slots[i].state for i, _, _ in lanes]
            filler = states[0]                   # well-conditioned pad state
            states.extend([filler] * (self.n_slots - len(lanes)))
            stacked = stack_task_states(states)
            self._stacked_states = (cohort, stacked)
        logits = np.asarray(
            self._predict(self._weights, stacked, jnp.asarray(qx)))
        t_out = self.clock()
        served = 0
        for lane, (i, _, n_real) in enumerate(lanes):
            r = self._slots[i].req
            r.logits.append(logits[lane, :n_real])
            r.served += n_real
            served += n_real
            if r.t_first_logit is None:
                r.t_first_logit = t_out
                self._query_lat_us.append((t_out - r.t_enqueue) * 1e6)
            if r.served >= r.n_queries:
                self._retire(i)
        return served

    def _abandon_hopeless(self) -> None:
        """Deadline abandonment (``deadline_us``): a request whose
        deadline (``t_enqueue + deadline_us``) has passed before its FIRST
        logit is never going to meet it — drop it from the queue, or
        retire its lane if it was admitted but still awaiting (possibly
        SLO-deferred) adaptation, so the capacity goes to requests that
        can still be served in time.  A request already streaming logits
        is past the latency-critical point and runs to completion —
        abandonment never discards produced output."""
        if self.deadline_us is None:
            return
        now_us = self.clock() * 1e6

        def hopeless(r: EpisodicRequest) -> bool:
            return (r.t_first_logit is None
                    and now_us > r.t_enqueue * 1e6 + self.deadline_us)

        kept = collections.deque()
        for r in self._queue:
            if hopeless(r):
                r.abandoned = True
                r.done = True
                r.t_done = now_us / 1e6
                self.deadline_abandoned += 1
            else:
                kept.append(r)
        self._queue = kept
        for i, s in enumerate(self._slots):
            if s is not None and hopeless(s.req):
                s.req.abandoned = True
                self.deadline_abandoned += 1
                self._retire(i)

    def step(self) -> int:
        """One engine step: deadline abandonment first (frees lanes/queue
        slots), then FIFO admission from the queue, then spend the
        step's dispatches — the pending adapt wave first UNLESS the SLO
        scheduler preempts it (a live lane's query deadline would be
        missed waiting out the adapt dispatch), then one micro-batched
        query dispatch.  Returns #queries served."""
        self._abandon_hopeless()
        self._admit_from_queue()
        pending_adapt = any(s is not None and s.state is None
                            for s in self._slots)
        if pending_adapt:
            if self._adapt_wave_preempted(self.clock()):
                self.slo_preemptions += 1
            else:
                self._adapt_pending()
        served = self._serve_queries()
        self.queries_served += served
        self.steps += 1
        return served

    def run_to_completion(self, requests: List[EpisodicRequest],
                          max_steps: int = 100000) -> List[EpisodicRequest]:
        for r in requests:
            self.submit(r)
        steps = 0
        while (self._queue or any(s is not None for s in self._slots)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return requests

    def drain_unfinished(self) -> List[EpisodicRequest]:
        """Remove and return every request this engine still owes logits —
        live lanes first (slot order == admission order), then the queue
        FIFO — leaving the engine empty.  The replica-failover hook: when
        a replica group dies, the router drains the dead engine and
        re-routes its unfinished requests to the survivors (the warm tier
        makes spilled state rehydratable there; the rest re-adapts)."""
        out: List[EpisodicRequest] = []
        for i, s in enumerate(self._slots):
            if s is not None:
                out.append(s.req)
                self._slots[i] = None
        out.extend(self._queue)
        self._queue.clear()
        self._stacked_states = None
        return out

    @property
    def busy(self) -> bool:
        """True while any request is queued or live in a slot."""
        return bool(self._queue) or any(s is not None for s in self._slots)

    # -- observability -------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        """Counters plus exact nearest-rank latency percentiles (µs).
        ``adapt_p*_us`` is enqueue → adapted state ready (cold requests
        only); ``query_p*_us`` is enqueue → first logit; both computed
        from the injected clock.  ``cache_*``/``hit_rate`` are the L1's;
        ``spills``/``rehydrates`` count warm-tier traffic.

        Degradation counters: ``quarantined`` (corrupt/vanished warm
        entries moved aside), ``spill_errors`` (warm writes that failed —
        >0 means the store degraded to L1-only), ``rejections``
        (bounded-queue backpressure refusals), ``deadline_abandoned``
        (requests dropped past their deadline pre-first-logit),
        ``failed_requests`` (support-less requests whose only stored
        state was quarantined)."""
        l1 = self.store.l1
        lookups = l1.hits + l1.misses
        return dict(
            tasks_adapted=self.tasks_adapted,
            queries_served=self.queries_served,
            steps=self.steps,
            queue_depth=len(self._queue),
            cache_hits=l1.hits,
            cache_misses=l1.misses,
            hit_rate=l1.hits / lookups if lookups else 0.0,
            evictions=l1.evictions,
            overwrites=l1.overwrites,
            spills=self.store.spills,
            rehydrates=self.store.rehydrates,
            rescan_hits=self.store.rescan_hits,
            quarantined=self.store.quarantined,
            spill_errors=self.store.spill_errors,
            rejections=self.rejections,
            deadline_abandoned=self.deadline_abandoned,
            failed_requests=self.failed_requests,
            slo_preemptions=self.slo_preemptions,
            adapt_cost_est_us=(self._adapt_cost_est_us
                               if self._adapt_cost_est_us is not None
                               else 0.0),
            adapt_p50_us=_pctl(self._adapt_lat_us, 50),
            adapt_p99_us=_pctl(self._adapt_lat_us, 99),
            query_p50_us=_pctl(self._query_lat_us, 50),
            query_p99_us=_pctl(self._query_lat_us, 99),
            adapt_compiles=self._adapt.compile_count,
            predict_compiles=self._predict.compile_count,
            # measured resident parameter bytes (host accounting over the
            # stored arrays; int8 engines count q+scale, not f32)
            param_bytes_resident=self._param_bytes["resident_bytes"],
            param_bytes_fp32=self._param_bytes["fp32_bytes"],
            frozen_param_bytes_resident=(
                self._param_bytes["frozen_resident_bytes"]),
            frozen_param_bytes_fp32=self._param_bytes["frozen_fp32_bytes"],
        )
