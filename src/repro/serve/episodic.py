"""Episodic serving engine: adapt-many-tasks personalization serving.

The LM engine (repro.serve.engine) serves token decode; this engine serves
the paper's test-time workload — ORBIT-style per-user personalization at
traffic scale.  A request is one episode: a support set to adapt on and a
query stream to answer.  The paper's headline tradeoff is that
meta-learners are cheap here ("just a few optimization steps or a single
forward pass" per new task); this engine turns that per-task cheapness
into throughput:

* **Slotted scheduler** — up to ``n_slots`` live tasks, continuous
  admission (requests join as slots free), in the spirit of
  :class:`repro.serve.engine.ServeEngine`.
* **Batched adaptation** — slots awaiting adaptation are collated into
  padded :class:`repro.core.episodic.TaskBatch` es and adapted in one
  ``learner.adapt_batch`` dispatch per planned support bucket: the
  uniform, mask-aware batched contract all four learner kinds share.  A
  task's pad cap comes from its OWN support size and its PRNG key is
  ``task_key(base, uid)``, so a task's state is a pure function of
  (params, support, uid) — recomputing equals the cache, regardless of
  co-tenants.
* **LITE-chunked forward-only adaptation** — the aggregating learners run
  the serve estimators (repro.core.lite.serve_sum / serve_segment_sum):
  exact values, no-grad chunks, so a 1000-image support set adapts under
  an O(chunk_size) activation bound, optionally in
  ``LiteSpec.compute_dtype`` with fp32 accumulation.
* **LRU task-state cache** — adapted states keyed by task uid; a repeat
  request (same user, new queries) skips adaptation entirely and may even
  omit its support set.
* **Query micro-batching** — each step serves the next fixed-size query
  chunk of EVERY live task in ONE ``predict_batch`` dispatch.
* **Compile discipline** — both dispatches go through a per-shape AOT
  cache (:class:`repro.train.pipeline.BucketedStepCache`), and every
  dispatch is padded to the full ``n_slots`` task lanes + a planned
  support bucket + the fixed query chunk, so a ragged request stream hits
  a closed set of compiled shapes (``stats()`` exposes the counters) AND
  results are bit-exact regardless of how requests are co-scheduled (the
  program never changes shape, only lane occupancy).

    engine = EpisodicServeEngine(learner, params, n_slots=4,
                                 support_buckets=(64,), query_chunk=8)
    engine.run_to_completion([EpisodicRequest(uid=0, support_x=sx,
                                              support_y=sy, query_x=qx)])
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict, Iterator, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.episodic import (Task, index_task_state, stack_task_states)
from repro.core.episodic_train import task_key
from repro.kernels import dispatch
from repro.core.lite import LiteSpec
from repro.core.meta_learners import MetaLearner
from repro.data.episodic import (bucket_for, collate_task_batch,
                                 iter_query_chunks)
from repro.train.pipeline import BucketedStepCache

PyTree = Any


@dataclasses.dataclass
class EpisodicRequest:
    """One personalization episode.

    ``uid`` is the task identity (the state-cache key): two requests with
    the same uid are the same task, and the second may omit its support
    set entirely if the first's state is still cached.  ``query_x`` is the
    query stream — served in engine-sized chunks, logits accumulated in
    arrival order."""

    uid: int
    query_x: np.ndarray                          # (M, ...) query stream
    support_x: Optional[np.ndarray] = None       # (N, ...); None ok on a
    support_y: Optional[np.ndarray] = None       # (N,)     expected cache hit
    way: int = 5
    logits: List[np.ndarray] = dataclasses.field(default_factory=list)
    served: int = 0
    cache_hit: Optional[bool] = None             # set at admission
    done: bool = False

    @property
    def n_queries(self) -> int:
        return int(np.asarray(self.query_x).shape[0])

    def all_logits(self) -> np.ndarray:
        """(M, way) logits in query order (complete once ``done``)."""
        if not self.logits:
            return np.zeros((0, self.way), np.float32)
        return np.concatenate(self.logits, axis=0)

    def predictions(self) -> np.ndarray:
        return np.argmax(self.all_logits(), axis=-1)


class TaskStateCache:
    """LRU cache of adapted task states keyed by task uid."""

    def __init__(self, capacity: int = 64):
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.hits = 0
        self.misses = 0
        self._d: "collections.OrderedDict[int, PyTree]" = \
            collections.OrderedDict()

    def get(self, uid: int) -> Optional[PyTree]:
        if uid in self._d:
            self._d.move_to_end(uid)
            self.hits += 1
            return self._d[uid]
        self.misses += 1
        return None

    def put(self, uid: int, state: PyTree) -> None:
        self._d[uid] = state
        self._d.move_to_end(uid)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)

    def __contains__(self, uid: int) -> bool:
        return uid in self._d

    def __len__(self) -> int:
        return len(self._d)


@dataclasses.dataclass
class _Slot:
    req: EpisodicRequest
    state: Optional[PyTree]                      # None => awaiting adaptation
    stream: Iterator


class EpisodicServeEngine:
    """Single-host adapt-many-tasks engine over the batched TaskState
    contract (``learner.adapt_batch`` / ``learner.predict_batch``).

    ``support_buckets`` are the planned support pad caps
    (:func:`repro.data.episodic.plan_buckets` builds them from a stream
    histogram); a support set larger than every cap raises, same
    stale-histogram contract as training-side collation.  All requests
    must share the learner's ``way`` and one query trailing shape — one
    engine per model input spec, as with the LM engine.
    """

    def __init__(self, learner: MetaLearner, params: PyTree, *,
                 lite: Optional[LiteSpec] = None, n_slots: int = 4,
                 query_chunk: int = 8,
                 support_buckets: Sequence[int] = (64,),
                 cache_capacity: int = 64, seed: int = 0,
                 kernel_backend: Optional[str] = None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.learner = learner
        self.params = params
        # serve-time default: exact forward values, chunk-bounded memory
        self.lite = lite if lite is not None else LiteSpec(exact=True,
                                                           chunk_size=32)
        self.n_slots = n_slots
        self.query_chunk = query_chunk
        self.support_buckets = tuple(sorted(support_buckets))
        self.cache = TaskStateCache(cache_capacity)
        self._slots: List[Optional[_Slot]] = [None] * n_slots
        self._base_key = jax.random.key(seed)
        # The aggregation-kernel backend (repro.kernels.dispatch) is an
        # ENGINE property, resolved once at construction (None = the
        # ambient dispatch default) and bound at trace time inside both
        # dispatches.  The per-shape compile cache keys on shapes alone,
        # so flipping the ambient default on a warm engine never
        # recompiles or changes results — a different backend is a
        # different engine.
        self.kernel_backend = dispatch.resolve_backend(kernel_backend)

        def _adapt_fn(p, batch, keys):
            with dispatch.use_backend(self.kernel_backend):
                return learner.adapt_batch(p, batch, keys, self.lite)

        def _predict_fn(p, states, qx):
            with dispatch.use_backend(self.kernel_backend):
                return learner.predict_batch(p, states, qx)

        self._adapt = BucketedStepCache(_adapt_fn)
        self._predict = BucketedStepCache(_predict_fn)
        # resident stacked states for an unchanged live cohort — slot
        # states are immutable after adaptation, so the (n_slots, ...)
        # predict-side stack is rebuilt only when a slot joins or retires
        self._stacked_states: Optional[tuple] = None
        self.tasks_adapted = 0
        self.queries_served = 0
        self.steps = 0

    # -- scheduling ----------------------------------------------------------

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self._slots):
            if s is None:
                return i
        return None

    def add_request(self, req: EpisodicRequest) -> bool:
        """Admit ``req`` into a free slot; False when all slots are live.
        A cached state (same uid served before) is attached immediately —
        the request never enters the adaptation batch.

        A support-less request whose uid is not cached YET but is live in
        another slot (its first visit is still in flight) is deferred
        (False — re-offer after a step lands the state); the same request
        with no in-flight producer either is an error, since nothing will
        ever cache its state."""
        slot = self._free_slot()
        if slot is None:
            return False
        if req.way != self.learner.cfg.way:
            raise ValueError(f"request way={req.way} != learner way="
                             f"{self.learner.cfg.way}")
        if req.support_x is None and req.uid not in self.cache:
            if any(s is not None and s.req.uid == req.uid
                   for s in self._slots):
                return False
            raise ValueError(f"request uid={req.uid}: no cached task state "
                             f"and no support set to adapt on")
        state = self.cache.get(req.uid)
        req.cache_hit = state is not None
        self._slots[slot] = _Slot(
            req=req, state=state,
            stream=iter_query_chunks(req.query_x, self.query_chunk))
        return True

    # -- the two batched dispatches ------------------------------------------

    def _adapt_pending(self) -> None:
        """One adapt_batch dispatch per support-bucket group of slots
        awaiting adaptation, each padded to n_slots task lanes.  A task's
        pad cap is chosen by its OWN support size — never by its
        co-tenants' — so the adapted (and cached) state is a pure function
        of (params, support, uid) and co-scheduling stays bit-exact even
        with several planned buckets."""
        need = [i for i, s in enumerate(self._slots)
                if s is not None and s.state is None]
        if not need:
            return
        groups: Dict[int, List[int]] = {}
        for i in need:
            n = int(np.asarray(self._slots[i].req.support_x).shape[0])
            groups.setdefault(bucket_for(n, self.support_buckets),
                              []).append(i)
        for cap, idxs in sorted(groups.items()):
            tasks, uids = [], []
            for i in idxs:
                r = self._slots[i].req
                sx = np.asarray(r.support_x, np.float32)
                # queries ride their own micro-batched dispatch; the
                # collated task carries a 1-row dummy so the adapt shape
                # key is fixed
                tasks.append(Task(
                    support_x=sx,
                    support_y=np.asarray(r.support_y, np.int32),
                    query_x=np.zeros((1,) + sx.shape[1:], np.float32),
                    query_y=np.zeros((1,), np.int32), way=r.way))
                uids.append(r.uid)
            while len(tasks) < self.n_slots:   # fixed task-lane count
                tasks.append(tasks[0])
                uids.append(uids[0])
            batch = collate_task_batch(tasks, support_size=cap, query_size=1)
            keys = jax.vmap(lambda u: task_key(self._base_key, u))(
                jnp.asarray(uids))
            states = self._adapt(self.params, batch, keys)
            for lane, i in enumerate(idxs):
                st = index_task_state(states, lane)
                self._slots[i].state = st
                self.cache.put(self._slots[i].req.uid, st)
            self.tasks_adapted += len(idxs)

    def _serve_queries(self) -> int:
        """ONE predict_batch dispatch serving the next query chunk of every
        live task; empty lanes carry a filler state and zero queries."""
        lanes = []                               # (slot_idx, chunk, n_real)
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            item = next(s.stream, None)
            if item is None:                     # stream exhausted (M == 0)
                s.req.done = True
                self._slots[i] = None
                continue
            chunk, _, n_real = item
            lanes.append((i, chunk, n_real))
        if not lanes:
            return 0
        chunk_shape = lanes[0][1].shape
        if any(l[1].shape != chunk_shape for l in lanes):
            raise ValueError("live tasks disagree on query trailing shape; "
                             "one engine serves one model input spec")
        qx = np.zeros((self.n_slots,) + chunk_shape, np.float32)
        for lane, (i, chunk, _) in enumerate(lanes):
            qx[lane] = chunk
        cohort = tuple((i, self._slots[i].req.uid) for i, _, _ in lanes)
        if (self._stacked_states is not None
                and self._stacked_states[0] == cohort):
            stacked = self._stacked_states[1]
        else:
            states = [self._slots[i].state for i, _, _ in lanes]
            filler = states[0]                   # well-conditioned pad state
            states.extend([filler] * (self.n_slots - len(lanes)))
            stacked = stack_task_states(states)
            self._stacked_states = (cohort, stacked)
        logits = np.asarray(
            self._predict(self.params, stacked, jnp.asarray(qx)))
        served = 0
        for lane, (i, _, n_real) in enumerate(lanes):
            r = self._slots[i].req
            r.logits.append(logits[lane, :n_real])
            r.served += n_real
            served += n_real
            if r.served >= r.n_queries:
                r.done = True
                self._slots[i] = None
        return served

    def step(self) -> int:
        """One engine step: batched adaptation of newly admitted tasks,
        then one micro-batched query dispatch.  Returns #queries served."""
        self._adapt_pending()
        served = self._serve_queries()
        self.queries_served += served
        self.steps += 1
        return served

    def run_to_completion(self, requests: List[EpisodicRequest],
                          max_steps: int = 100000) -> List[EpisodicRequest]:
        pending = list(requests)
        steps = 0
        while (pending or any(s is not None for s in self._slots)) \
                and steps < max_steps:
            while pending and self.add_request(pending[0]):
                pending.pop(0)
            self.step()
            steps += 1
        return requests

    # -- observability -------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        lookups = self.cache.hits + self.cache.misses
        return dict(
            tasks_adapted=self.tasks_adapted,
            queries_served=self.queries_served,
            steps=self.steps,
            cache_hits=self.cache.hits,
            cache_misses=self.cache.misses,
            hit_rate=self.cache.hits / lookups if lookups else 0.0,
            adapt_compiles=self._adapt.compile_count,
            predict_compiles=self._predict.compile_count,
        )
