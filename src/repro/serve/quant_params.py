"""Serving-time weight quantization: the frozen slice of a learner's
params in blockwise int8, dequantized lazily inside the jitted step.

The paper's serving regime adapts a task head / FiLM layers around a
FROZEN backbone — at serve time the backbone leaves are pure read-only
traffic, so they ride in the int8 ``{q, scale, n}`` form of
``repro.optim.quant`` (~4x fewer resident HBM bytes) while everything
adaptation actually writes (FiLM generators, set encoder, heads, fomaml's
fully-adapted params) stays fp32.

Which leaves freeze is a property of the learner *kind*, not a heuristic:

  protonets / cnaps / simple_cnaps / finetuner   params["bb"] — the
      backbone is stop_gradient'd (cnaps family, finetuner) or simply
      never written by adaptation (protonets); quantizing it perturbs
      support and query features THROUGH THE SAME WEIGHTS, so class
      statistics and query scores move together (argmax agreement stays
      high; see tests/test_quant_serving.py).
  fomaml   nothing — inner SGD adapts every leaf, so the frozen slice is
      empty and int8 serving is a principled no-op (bit-identical).

:class:`ServingWeights` is a registered pytree: the (mixed fp32 +
quantized-dict) param tree is the child, and the quantized/native path
sets plus the mode ride as static aux data — so it flows through jit and
the shape-bucketed AOT compile cache (``BucketedStepCache``) like any
params tree, while int8-vs-none engines can never collide on a cache
entry.  ``dequantize_params`` runs INSIDE the jitted step: XLA fuses the
int8->f32 expansion into the consumers and the f32 copy lives only for
the step (never materialized persistently); leaves on the backbone's
``quant_native_paths`` skip even that and feed
``repro.kernels.dispatch.int8_matmul`` as raw int8 tiles.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.optim.quant import BLOCK, dequantize, quantize
from repro.train.checkpoint import _path_str

PyTree = Any

SERVE_QUANT_MODES = ("none", "int8")

# learner kind -> top-level param keys that adaptation never writes
FROZEN_SLICES: Dict[str, Tuple[str, ...]] = {
    "protonets": ("bb",),
    "cnaps": ("bb",),
    "simple_cnaps": ("bb",),
    "finetuner": ("bb",),
    "fomaml": (),
}


def is_quantized_leaf(x) -> bool:
    """A blockwise-int8 quantized dict (``repro.optim.quant`` form)."""
    return isinstance(x, dict) and {"q", "scale"} <= set(x)


@dataclasses.dataclass(frozen=True)
class ServingWeights:
    """Params pytree with the frozen slice quantized (or not: mode='none').

    tree: the param tree; quantized leaves are ``{q, scale, n}`` dicts.
    quant_paths: '/'-joined paths of the quantized leaves (static aux).
    native_paths: subset consumed as int8 by the backbone's matmul sites
        (``BackboneDef.quant_native_paths``) — never dequantized at all.
    frozen_roots: the kind's frozen top-level keys (recorded even for
        mode='none' so byte accounting can name the frozen slice).
    mode: 'none' | 'int8'.
    """

    tree: PyTree
    quant_paths: Tuple[str, ...] = ()
    native_paths: Tuple[str, ...] = ()
    frozen_roots: Tuple[str, ...] = ()
    mode: str = "none"


jax.tree_util.register_pytree_node(
    ServingWeights,
    lambda sw: ((sw.tree,), (sw.quant_paths, sw.native_paths,
                             sw.frozen_roots, sw.mode)),
    lambda aux, ch: ServingWeights(ch[0], *aux),
)


def _quantizable(leaf) -> bool:
    return (hasattr(leaf, "dtype") and
            jnp.issubdtype(leaf.dtype, jnp.floating) and leaf.ndim >= 1)


def quantize_frozen(learner, params: PyTree, mode: str = "int8"
                    ) -> ServingWeights:
    """Quantize the frozen slice of ``params`` for serving.

    learner: a :class:`repro.core.meta_learners.MetaLearner` (its
    ``cfg.kind`` names the frozen slice, its ``backbone`` names the
    native int8 matmul sites).  mode='none' wraps params untouched, so
    the engine's dispatch path is identical either way.
    """
    if mode not in SERVE_QUANT_MODES:
        raise ValueError(f"unknown serve_quant mode {mode!r}; "
                         f"choose from {SERVE_QUANT_MODES}")
    kind = learner.cfg.kind
    roots = FROZEN_SLICES.get(kind, ())
    if mode == "none" or not roots:
        return ServingWeights(tree=params, frozen_roots=roots, mode="none")
    native_rel = set(getattr(learner.backbone, "quant_native_paths", ()))
    quant_paths, native_paths = [], []

    def visit(path, leaf):
        p = _path_str(path)
        root, _, rel = p.partition("/")
        if root not in roots or not _quantizable(leaf):
            return leaf
        quant_paths.append(p)
        if rel in native_rel and leaf.ndim == 2:
            native_paths.append(p)
        return quantize(leaf)

    tree = jax.tree_util.tree_map_with_path(visit, params)
    return ServingWeights(tree=tree, quant_paths=tuple(quant_paths),
                          native_paths=tuple(native_paths),
                          frozen_roots=roots, mode="int8")


def dequantize_params(sw: ServingWeights) -> PyTree:
    """Rebuild a params tree the learner can consume — called INSIDE the
    jitted adapt/predict step, so the f32 expansion is fused into the
    step and never persists.  Native-path leaves stay quantized dicts;
    the backbone's matmul site consumes them via ``dispatch.int8_matmul``.
    """
    if sw.mode == "none":
        return sw.tree
    native = set(sw.native_paths)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        sw.tree, is_leaf=is_quantized_leaf)
    out = []
    for path, leaf in flat:
        if is_quantized_leaf(leaf) and _path_str(path) not in native:
            leaf = dequantize(leaf)
        out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def place_serving_weights(sw: ServingWeights, mesh, layout) -> ServingWeights:
    """Place serving weights in a named layout on a (sub)mesh.

    The weights become stationary on ``mesh``'s device group: in a
    multi-replica deployment each replica calls this with its OWN disjoint
    group mesh (``repro.launch.mesh.make_replica_mesh``), so weights never
    move across replica groups — the per-step wire is whatever the layout
    pays WITHIN the group (zero for ``replicated``, partial-sum
    activations for ``weight_stationary``).  ``mesh=None`` or
    ``layout in (None, 'none')`` is the identity (single-device
    placement); ``'auto'`` must be resolved to a concrete name by
    ``repro.roofline.analysis.choose_serving_layout`` before this point —
    placement applies a layout, it does not score one."""
    if mesh is None or layout in (None, "none"):
        return sw
    if layout == "auto":
        raise ValueError(
            "resolve serve_layout='auto' with "
            "repro.roofline.analysis.choose_serving_layout before placing "
            "the serving weights")
    from repro.roofline.analysis import serving_shardings
    return jax.device_put(sw, serving_shardings(sw, mesh, layout))


def param_bytes(sw: ServingWeights) -> Dict[str, int]:
    """Measured resident parameter bytes (host-side accounting over the
    ACTUAL stored arrays — not a model).  Returns totals plus the frozen
    slice alone (the ≥3x reduction guard in tests/benchmarks), and the
    fp32-equivalent bytes the same leaves would occupy unquantized."""
    tot = tot_fp32 = froz = froz_fp32 = 0
    flat, _ = jax.tree_util.tree_flatten_with_path(
        sw.tree, is_leaf=is_quantized_leaf)
    for path, leaf in flat:
        p = _path_str(path)
        in_frozen = p.split("/", 1)[0] in sw.frozen_roots
        if is_quantized_leaf(leaf):
            nbytes = leaf["q"].size * leaf["q"].dtype.itemsize \
                + leaf["scale"].size * leaf["scale"].dtype.itemsize
            fp32 = 4 * leaf["q"].size
        elif hasattr(leaf, "size"):
            nbytes = leaf.size * leaf.dtype.itemsize
            fp32 = 4 * leaf.size if jnp.issubdtype(
                leaf.dtype, jnp.floating) else nbytes
        else:                                   # python scalar (e.g. 'n')
            nbytes = fp32 = 0
        tot += nbytes
        tot_fp32 += fp32
        if in_frozen:
            froz += nbytes
            froz_fp32 += fp32
    return dict(resident_bytes=tot, fp32_bytes=tot_fp32,
                frozen_resident_bytes=froz, frozen_fp32_bytes=froz_fp32)
