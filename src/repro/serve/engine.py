"""Batched serving engine: continuous-batching KV-cache decode over the
uniform model API (GQA / MLA-latent / SSM-state / hybrid caches all ride
the same ``init_cache/prefill/decode_step`` contract).

The engine keeps one padded decode batch live; requests join by having
their prompt prefilled into a slot's cache region and leave on EOS/max
tokens.  Active slots whose caches agree on decode position are stacked
into ONE batched decode dispatch per step (the per-slot path remains as
the fallback for ragged joins).  On TPU the decode step is the
latency-bound program the roofline decode cells measure; here it runs the
same code on CPU at smoke scale.

This engine speaks LM token decode only; the episodic adapt-many-tasks
workload (support set in, query logits out) is served by its sibling
:class:`repro.serve.episodic.EpisodicServeEngine`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.registry import get_api


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                   # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0             # 0 => greedy
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Single-host reference engine (batch = n_slots, one sequence each)."""

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 4,
                 max_seq: int = 256, eos_id: Optional[int] = None,
                 seed: int = 0, batched_decode: bool = True):
        self.cfg = cfg
        self.params = params
        self.api = get_api(cfg)
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.batched_decode = batched_decode
        self._key = jax.random.key(seed)

        # per-slot independent caches (batch axis = 1) so prefill results
        # can be spliced in/out without touching other slots.
        self._caches = [self.api.init_cache(cfg, 1, max_seq)
                        for _ in range(n_slots)]
        self._reqs: List[Optional[Request]] = [None] * n_slots
        # resident stacked cache for an unchanged decoding cohort:
        # (active slot list, stacked cache).  Re-stacking / un-stacking
        # copies every slot's full max_seq cache region, so it happens only
        # when the cohort changes, not per token.
        self._stacked: Optional[tuple] = None

        self._prefill = jax.jit(
            lambda p, b: self.api.prefill(p, b, cfg))
        self._decode = jax.jit(
            lambda p, c, t: self.api.decode_step(p, c, t, cfg))

    # -- scheduling ----------------------------------------------------------

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self._reqs):
            if r is None:
                return i
        return None

    def add_request(self, req: Request) -> bool:
        slot = self._free_slot()
        if slot is None:
            return False
        self._flush_stacked()          # a splice changes the cohort
        batch = dict(tokens=jnp.asarray(req.prompt, jnp.int32)[None, :])
        if self.cfg.frontend is not None:
            batch["frontend_embeds"] = jnp.zeros(
                (1, self.cfg.n_frontend_tokens, self.cfg.d_model),
                jnp.dtype(self.cfg.compute_dtype))
        logits, cache = self._prefill(self.params, batch)
        # splice the prefilled cache into a max_seq-capacity cache
        full = self.api.init_cache(self.cfg, 1, self.max_seq)
        plen = int(req.prompt.shape[0])
        full = _splice_cache(full, cache, plen, self.cfg)
        self._caches[slot] = full
        self._reqs[slot] = req
        # the prefill-sampled token counts against the budget and may be
        # EOS — _commit retires the request (and frees the slot) if so
        self._commit(slot, logits)
        return True

    def _sample(self, logits: jnp.ndarray, req: Request) -> List[int]:
        if req.temperature <= 0.0:
            return [int(t) for t in np.asarray(jnp.argmax(logits, -1)).ravel()]
        self._key, sub = jax.random.split(self._key)
        draw = jax.random.categorical(sub, logits / req.temperature, axis=-1)
        return [int(t) for t in np.asarray(draw).ravel()]

    # -- decode --------------------------------------------------------------

    def _stack_caches(self, caches: List[Dict]) -> Optional[Dict]:
        """Concatenate per-slot (batch=1) caches along the batch axis into
        one decode batch.  Stacking requires every slot to agree on the
        scalar decode position ``len`` (positions/attention spans are
        shared across the batch) and on leaf shapes; a ragged mix — e.g.
        a freshly spliced prompt joining mid-cohort — returns None and the
        caller decodes per slot."""
        first = caches[0]
        try:
            if any(sorted(c.keys()) != sorted(first.keys()) for c in caches):
                return None
            if any(int(c["len"]) != int(first["len"]) for c in caches[1:]):
                return None
            out = {}
            for k in first:
                if k == "len":
                    out[k] = first[k]
                    continue
                leaves = [c[k] for c in caches]
                if any(l.ndim < 2 or l.shape != leaves[0].shape
                       for l in leaves):
                    return None
                out[k] = jnp.concatenate(leaves, axis=1)
            return out
        except (TypeError, AttributeError):
            return None

    @staticmethod
    def _unstack_cache(cache: Dict, n: int) -> List[Dict]:
        return [{k: (v if k == "len" else v[:, j:j + 1])
                 for k, v in cache.items()} for j in range(n)]

    def _flush_stacked(self) -> None:
        """Write the resident stacked cache back into the per-slot caches
        (called whenever the decoding cohort is about to change)."""
        if self._stacked is None:
            return
        cohort, cache = self._stacked
        self._stacked = None
        slot_caches = self._unstack_cache(cache, len(cohort))
        for j, i in enumerate(cohort):
            self._caches[i] = slot_caches[j]

    def _commit(self, i: int, logits: jnp.ndarray) -> None:
        """Sample + append the next token for slot ``i``; retire on EOS or
        length budget."""
        req = self._reqs[i]
        nxt = self._sample(logits, req)[0]
        req.out_tokens.append(nxt)
        if (len(req.out_tokens) >= req.max_new_tokens or
                (self.eos_id is not None and nxt == self.eos_id)):
            req.done = True
            self._reqs[i] = None

    def step(self) -> int:
        """One decode step over all active slots — a single stacked decode
        dispatch when the slot caches stack (finished slots are already
        masked out of the active set), the per-slot loop otherwise.  The
        stacked cache stays resident while the cohort is unchanged, so
        steady-state decode does no per-token stack/unstack copies.
        Returns #active."""
        active = [i for i, r in enumerate(self._reqs) if r is not None]
        if not active:
            return 0
        stacked = None
        if self.batched_decode and len(active) > 1:
            if self._stacked is not None and self._stacked[0] == active:
                stacked = self._stacked[1]         # unchanged cohort
            else:
                self._flush_stacked()
                stacked = self._stack_caches([self._caches[i]
                                              for i in active])
        else:
            self._flush_stacked()
        if stacked is not None:
            toks = jnp.asarray([[self._reqs[i].out_tokens[-1]]
                                for i in active], jnp.int32)
            logits, new_cache = self._decode(self.params, stacked, toks)
            self._stacked = (list(active), new_cache)
            # sample in slot order (same key-consumption order as the
            # per-slot fallback, so seeded runs are path-independent)
            for j, i in enumerate(active):
                self._commit(i, logits[j:j + 1])
        else:
            for i in active:
                req = self._reqs[i]
                tok = jnp.asarray([[req.out_tokens[-1]]], jnp.int32)
                logits, self._caches[i] = self._decode(self.params,
                                                       self._caches[i], tok)
                self._commit(i, logits)
        return len(active)

    def run_to_completion(self, requests: List[Request],
                          max_steps: int = 10000) -> List[Request]:
        pending = list(requests)
        steps = 0
        while (pending or any(r is not None for r in self._reqs)) \
                and steps < max_steps:
            while pending and self.add_request(pending[0]):
                pending.pop(0)
            self.step()
            steps += 1
        return requests


def _splice_cache(full: Dict, pre: Dict, plen: int, cfg: ModelConfig) -> Dict:
    """Copy a prefill cache (seq capacity = prompt len) into the head of a
    long-capacity cache.  SSM states are O(1) and copy wholesale."""
    out = dict(full)
    for k in full:
        if k == "len":
            out[k] = pre["len"]
        elif k in ("ssm", "conv"):
            out[k] = pre[k]
        elif k in ("cross_k", "cross_v"):
            out[k] = pre[k]
        elif k in ("k", "v"):           # (L, B, S, H, D)
            out[k] = jax.lax.dynamic_update_slice(
                full[k], pre[k], (0, 0, 0, 0, 0))
        elif k in ("ckv", "krope"):     # (L, B, S, R)
            out[k] = jax.lax.dynamic_update_slice(
                full[k], pre[k], (0, 0, 0, 0))
        else:
            out[k] = pre[k]
    return out
