"""Multi-replica episodic serving: replicated backbones, a uid-sharded
task population, and a replica-aware router.

The paper's test-time story is that meta-learners amortize adaptation into
a cheap forward pass — so at "millions of users" the scaling axis is the
*task population*, not the model.  One :class:`EpisodicServeEngine` is
bounded by its slot count and its device group; this module scales past it
by replication, porting the serving-group discipline of
``scaling_transformer_inference_efficiency`` (Pope et al. — the
latency-oriented 2D-partitioning repo in ``/root/related``) to episodic
serving:

* **Weights are stationary within a replica group, never moved across
  groups.**  Each replica owns a full copy of the serving weights
  (``ServingWeights``: the frozen slice optionally blockwise-int8, the
  ``serve_layout`` placement applied PER GROUP on the replica's own
  disjoint mesh from :func:`repro.launch.mesh.make_replica_mesh`).  Any
  collective the predict step emits is intra-group by construction — the
  compiled program only knows the group's devices — so per-step wire
  bytes scale with the replica's device count, not the full mesh's.
* **The task population is partitioned by uid hash.**
  ``stable_uid_hash(uid) % replicas`` (process-stable crc32, never
  Python's salted ``hash``) routes every request; repeat visitors land on
  the replica already holding their adapted state (L1 or warm tier), so
  replication multiplies the servable working set instead of diluting the
  caches.  Each replica keeps its OWN L1 ``TaskStateCache``; the warm
  tier is one shared directory partitioned into uid-hash subdirs
  (``WarmTaskStore(shards=...)``) — replicas spill and rehydrate without
  contending, and because the subdir is a pure function of the uid (and a
  FIXED shard count, independent of the replica count), any replica can
  find any uid's spilled state: the failover and resize paths.
* **Per-step round-robin dispatch.**  ``step()`` steps every live replica
  once, rotating which goes first, so one replica's slow adapt wave never
  systematically delays the others' admission — the single-process stand-
  in for replicas stepping concurrently on their own hosts.
* **Admission rebalances only at the queue.**  ``submit`` delegates to
  the routed replica's bounded queue: an overload rejection carries a
  ``retry_after_us`` computed from THAT replica's adapt-cost EWMA (a hot
  replica quotes honest, longer retry hints than an idle one), never a
  global average.
* **Replica failover** (fault site ``replica.dead``): a replica group
  injected (or detected) dead is quarantined — the router drains its
  unfinished requests and re-routes them to the surviving replicas by
  deterministic linear probing of the same hash, so post-failover routing
  is as stable as the original.  A re-routed uid whose state had spilled
  rehydrates BIT-exactly on the survivor (shared warm root + rescan-on-
  miss); one whose state lived only in the dead replica's L1 re-adapts
  cold if its support set rode along, else fails terminally (counted,
  never a crash).  ``stats()['replica_failovers']`` counts quarantine
  events.

``stats()`` aggregates the per-replica counters and merges the RAW
latency observations before taking percentiles — exact nearest-rank
p50/p99 over the whole deployment, not an average of per-replica
percentiles — with the full per-replica breakdown under ``per_replica``.

    meshes = make_replica_mesh(replicas=2, devices_per_replica=2)
    router = ReplicatedServeEngine(learner, params, replicas=2,
                                   meshes=meshes, warm_dir="/tmp/warm",
                                   serve_quant="int8",
                                   serve_layout="weight_stationary",
                                   n_slots=4, support_buckets=(64,))
    router.run_to_completion(requests)
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.faults.plan import REPLICA_DEAD
from repro.serve.episodic import (EpisodicRequest, EpisodicServeEngine,
                                  _pctl, stable_uid_hash)

# Fixed default warm-shard count: a pure function of the uid partitions
# the directory, so it must NOT follow the replica count — resizing the
# deployment re-routes uids but every spilled npz stays exactly where any
# replica's store will look for it.  8 is divisible by the 1/2/4-replica
# configurations this container can emulate; when the replica count
# divides it, each replica touches a disjoint set of subdirs.
DEFAULT_WARM_SHARDS = 8


def uid_replica(uid: int, replicas: int) -> int:
    """The uid's home replica: ``stable_uid_hash(uid) % replicas``.  Pure
    and process-stable — the routing contract repeat visitors rely on."""
    return stable_uid_hash(uid) % replicas


def _reset_for_reroute(req: EpisodicRequest) -> None:
    """Scrub a request drained from a dead replica back to submittable
    state.  Produced logits died with the replica (host-side partials are
    discarded rather than risking a seam); ``t_enqueue`` is KEPT so the
    merged latency percentiles honestly include the failover detour."""
    req.logits = []
    req.served = 0
    req.cache_hit = None
    req.done = False
    req.t_admit = None
    req.t_adapt = None
    req.t_first_logit = None
    req.t_done = None


class ReplicatedServeEngine:
    """Replica-aware router over N :class:`EpisodicServeEngine` replicas.

    Construction kwargs split three ways: ``replicas``/``meshes``/
    ``warm_dir``/``warm_shards``/``fault_plan``/``clock`` are router-
    level; everything else (``n_slots``, ``support_buckets``,
    ``serve_quant``, ``serve_layout``, ``cache_capacity``, ...) is passed
    to every replica engine verbatim, so the int8 x layout composition of
    the single-engine path applies per replica unchanged.  ``meshes``
    (from :func:`repro.launch.mesh.make_replica_mesh`) pins replica r's
    weights to its own disjoint device group; ``meshes=None`` runs all
    replicas on default placement (the single-device test/demo mode —
    routing, failover, and store semantics are identical).

    All replicas share ``seed`` (default 0, via engine kwargs): an adapted
    state is a pure function of (params, support, uid, seed), so which
    replica adapts a task can never change its logits — the bit-exactness
    contract the acceptance tests pin down.
    """

    def __init__(self, learner, params, *, replicas: int = 2,
                 meshes: Optional[Sequence] = None,
                 warm_dir=None, warm_shards: Optional[int] = None,
                 fault_plan=None, clock=None, **engine_kw):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        if meshes is not None and len(meshes) != replicas:
            raise ValueError(f"got {len(meshes)} meshes for {replicas} "
                             f"replicas; build them with "
                             f"make_replica_mesh(replicas, "
                             f"devices_per_replica)")
        if warm_shards is None:
            warm_shards = DEFAULT_WARM_SHARDS
        self.n_replicas = replicas
        self.fault_plan = fault_plan
        self.replicas: List[EpisodicServeEngine] = [
            EpisodicServeEngine(
                learner, params,
                mesh=meshes[r] if meshes is not None else None,
                warm_dir=warm_dir, warm_shards=warm_shards,
                fault_plan=fault_plan, clock=clock, **engine_kw)
            for r in range(replicas)]
        self._dead: set[int] = set()
        self._rr = 0                      # round-robin rotation offset
        self.steps = 0
        self.replica_failovers = 0
        self.rerouted_requests = 0
        self.failover_failed = 0

    # -- routing -------------------------------------------------------------

    def route(self, uid: int) -> int:
        """The live replica serving ``uid``: its hash home, or — when that
        group is quarantined — the first live replica by deterministic
        linear probing from it.  Stable across router restarts (pure
        hash) and across the failover (same probe order for everyone)."""
        for k in range(self.n_replicas):
            cand = (uid_replica(uid, self.n_replicas) + k) % self.n_replicas
            if cand not in self._dead:
                return cand
        raise RuntimeError("all replica groups are dead")

    @property
    def live_replicas(self) -> List[int]:
        return [r for r in range(self.n_replicas) if r not in self._dead]

    def submit(self, req: EpisodicRequest) -> bool:
        """Route ``req`` by uid and enqueue it on its replica.  Overload
        rejection (``max_queue``) happens at the ROUTED replica's queue
        with that replica's own adapt-cost EWMA pricing the
        ``retry_after_us`` — admission rebalances only at the queue."""
        return self.replicas[self.route(req.uid)].submit(req)

    # -- failover ------------------------------------------------------------

    def _check_faults(self) -> None:
        if self.fault_plan is None:
            return
        for r in list(self.live_replicas):
            if self.fault_plan.fire(REPLICA_DEAD, r) is not None:
                self.quarantine_replica(r)

    def quarantine_replica(self, r: int) -> None:
        """Take replica ``r`` out of rotation and re-route its unfinished
        requests to the survivors.  Spilled state rehydrates on the new
        replica (shared warm root, rescan-on-miss); L1-only state is lost
        with the replica — a drained request with support re-adapts cold,
        a support-less one whose uid the survivor cannot find anywhere
        fails terminally (``failover_failed``; the request is marked, the
        router keeps serving)."""
        if r in self._dead:
            return
        if len(self.live_replicas) == 1:
            raise RuntimeError(
                f"cannot quarantine replica {r}: it is the last live "
                f"replica group")
        self._dead.add(r)
        self.replica_failovers += 1
        orphans = self.replicas[r].drain_unfinished()
        for req in orphans:
            _reset_for_reroute(req)
            target = self.replicas[self.route(req.uid)]
            if req.support_x is None and req.uid not in target.store:
                # nothing anywhere can rebuild this task's state: its L1
                # copy died with the replica and it never spilled
                req.failed = True
                req.done = True
                req.t_done = target.clock()
                self.failover_failed += 1
                continue
            self.rerouted_requests += 1
            target.submit(req)
        print(f"replica router: quarantined replica {r}, re-routed "
              f"{self.rerouted_requests} request(s) to survivors "
              f"{self.live_replicas}", flush=True)

    # -- stepping ------------------------------------------------------------

    def step(self) -> int:
        """One router step: fire any pending ``replica.dead`` faults, then
        step every live replica once in round-robin rotated order (the
        replica that went first last step goes last this step).  Returns
        total queries served across replicas."""
        self._check_faults()
        live = self.live_replicas
        if not live:
            raise RuntimeError("all replica groups are dead")
        k = self._rr % len(live)
        self._rr += 1
        served = 0
        for r in live[k:] + live[:k]:
            served += self.replicas[r].step()
        self.steps += 1
        return served

    @property
    def busy(self) -> bool:
        return any(self.replicas[r].busy for r in self.live_replicas)

    def run_to_completion(self, requests: List[EpisodicRequest],
                          max_steps: int = 100000) -> List[EpisodicRequest]:
        for req in requests:
            self.submit(req)
        steps = 0
        while self.busy and steps < max_steps:
            self.step()
            steps += 1
        return requests

    # -- observability -------------------------------------------------------

    def stats(self) -> Dict[str, object]:
        """Aggregated counters + EXACT merged latency percentiles.

        Counters (``tasks_adapted``, ``queries_served``, cache/store/
        degradation counters, compile counters, resident param bytes) are
        summed across replicas — ``param_bytes_resident`` therefore counts
        the replication cost honestly (R full copies).  ``adapt/query
        p50/p99`` are nearest-rank percentiles over the POOLED raw
        observations of every replica (merging percentiles would be
        wrong).  Router-level: ``replica_failovers`` (quarantine events),
        ``rerouted_requests``, ``failover_failed``, ``live_replicas``,
        ``steps`` (router steps; each steps every live replica once).
        ``per_replica`` carries each replica's full ``stats()`` dict."""
        per = [eng.stats() for eng in self.replicas]
        summed = (
            "tasks_adapted", "queries_served", "queue_depth", "cache_hits",
            "cache_misses", "evictions", "overwrites", "spills",
            "rehydrates", "rescan_hits", "quarantined", "spill_errors",
            "rejections", "deadline_abandoned", "failed_requests",
            "slo_preemptions", "adapt_compiles", "predict_compiles",
            "param_bytes_resident", "param_bytes_fp32",
            "frozen_param_bytes_resident", "frozen_param_bytes_fp32")
        out: Dict[str, object] = {k: sum(p[k] for p in per) for k in summed}
        lookups = out["cache_hits"] + out["cache_misses"]
        out["hit_rate"] = out["cache_hits"] / lookups if lookups else 0.0
        adapt_lat = [x for eng in self.replicas for x in eng._adapt_lat_us]
        query_lat = [x for eng in self.replicas for x in eng._query_lat_us]
        out["adapt_p50_us"] = _pctl(adapt_lat, 50)
        out["adapt_p99_us"] = _pctl(adapt_lat, 99)
        out["query_p50_us"] = _pctl(query_lat, 50)
        out["query_p99_us"] = _pctl(query_lat, 99)
        out["failed_requests"] += self.failover_failed
        out["steps"] = self.steps
        out["n_replicas"] = self.n_replicas
        out["live_replicas"] = len(self.live_replicas)
        out["replica_failovers"] = self.replica_failovers
        out["rerouted_requests"] = self.rerouted_requests
        out["failover_failed"] = self.failover_failed
        out["per_replica"] = per
        return out
