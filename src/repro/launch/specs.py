"""ShapeDtypeStruct stand-ins for every model input — the dry-run's
zero-allocation batch/state builders (and the shape contract the data
pipeline and serving engine follow)."""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec, SHAPES_BY_NAME
from repro.models.registry import get_api

SDS = jax.ShapeDtypeStruct


def batch_specs_for(cfg: ModelConfig, shape: ShapeSpec) -> Dict:
    """Model-input ShapeDtypeStructs for one (arch x shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    cdt = jnp.dtype(cfg.compute_dtype)
    if shape.kind == "train":
        batch = dict(tokens=SDS((b, s), jnp.int32))
        if cfg.frontend == "vision_stub":
            batch["frontend_embeds"] = SDS((b, cfg.n_frontend_tokens, cfg.d_model), cdt)
        if cfg.family == "encdec":
            batch["frontend_embeds"] = SDS((b, cfg.n_frontend_tokens, cfg.d_model), cdt)
        return batch
    if shape.kind == "prefill":
        batch = dict(tokens=SDS((b, s), jnp.int32))
        if cfg.frontend == "vision_stub":
            batch["frontend_embeds"] = SDS((b, cfg.n_frontend_tokens, cfg.d_model), cdt)
        if cfg.family == "encdec":
            batch["frontend_embeds"] = SDS((b, cfg.n_frontend_tokens, cfg.d_model), cdt)
        return batch
    # decode: one new token against a seq_len-deep cache
    return dict(tokens=SDS((b, 1), jnp.int32))


def abstract_cache_for(cfg: ModelConfig, shape: ShapeSpec):
    api = get_api(cfg)
    return jax.eval_shape(
        functools.partial(api.init_cache, cfg, shape.global_batch, shape.seq_len))


def abstract_params_for(cfg: ModelConfig):
    api = get_api(cfg)
    return jax.eval_shape(functools.partial(api.init, cfg=cfg),
                          jax.random.key(0))


def shape_by_name(name: str) -> ShapeSpec:
    return SHAPES_BY_NAME[name]
