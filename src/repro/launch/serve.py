"""Production serving launcher: continuous-batching decode over the
uniform cache API, and episodic adapt-many-tasks personalization serving.

LM token decode (default):

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-7b \
        --requests 8 --slots 4 --max-new 16

Episodic personalization (``--episodic``): each request is a support set
to adapt on + a query stream to answer; all four learner kinds serve
through the same batched ``adapt_batch``/``predict_batch`` contract, with
LITE-chunked forward-only adaptation, a TWO-TIER task-state store keyed
by task uid (an L1 LRU of ``--cache-capacity`` resident states over an
optional ``--warm-dir`` disk tier: evicted states spill through the
checkpoint serialization and repeat visitors rehydrate bit-exactly
instead of re-adapting; ``--repeat-frac`` controls how much of the
traffic is repeat users), continuous batching with per-request latency
accounting (p50/p99 adapt and query latency in the summary), SLO-aware
scheduling (``--query-slo-us`` lets near-deadline query chunks preempt
an adapt wave, cost-estimated from ``--adapt-cost-hint-us`` until
measured), micro-batched query dispatch, and the aggregation kernels
(class statistics, Mahalanobis head) routed through
``repro.kernels.dispatch`` (``--kernel-backend``):

    PYTHONPATH=src python -m repro.launch.serve --episodic \
        --learner protonets --requests 16 --slots 4 --shot 10 \
        --repeat-frac 0.5 --lite-chunk 32 --cache-capacity 4 \
        --warm-dir /tmp/warm_states --query-slo-us 50000

``--replicas R`` scales the same engine horizontally (R engines on
disjoint device groups, uid-hash routing, shared sharded warm tier —
``repro.serve.replica``); with ``--serve-layout auto`` the roofline
chooser scores ONE replica group and the winner applies to all:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.serve --episodic \
        --replicas 2 --serve-layout auto --serve-quant int8 --requests 16

Runs the smoke config on this container; on a TPU slice the same engines
serve the full config (params sharded by repro.sharding.rules — see
EXPERIMENTS.md §Perf cell 2 for the topology guidance: size the slice so
weights are resident, don't decode one stream set on a full pod).
"""
from __future__ import annotations

import argparse
import time
from typing import Callable

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.models.registry import get_api
from repro.serve.engine import Request, ServeEngine


def run_episodic(args, clock: Callable[[], float] = time.monotonic) -> None:
    """``clock`` is injectable (the PR6/PR7 clock-discipline contract):
    the launcher's wall-clock default is the reference monotonic clock;
    tests can pass a FakeClock and the printed throughput numbers become
    deterministic.  The engine itself receives its own injectable clock
    via ``EpisodicServeEngine(clock=...)``."""
    from repro.core.lite import LiteSpec
    from repro.core.meta_learners import MetaLearnerConfig, make_learner
    from repro.core.set_encoder import SetEncoderConfig
    from repro.data.episodic import (EpisodicImageConfig, plan_buckets,
                                     sample_image_task)
    from repro.models.conv_backbone import (ConvBackboneConfig,
                                            make_conv_backbone)
    from repro.serve.episodic import EpisodicRequest, EpisodicServeEngine

    backbone = make_conv_backbone(ConvBackboneConfig(widths=(16, 32),
                                                     feature_dim=64))
    learner = make_learner(
        MetaLearnerConfig(kind=args.learner, way=5), backbone,
        SetEncoderConfig(kind="conv", conv_blocks=2, conv_width=16,
                         task_dim=32))
    params = learner.init(jax.random.key(0))
    lite = LiteSpec(exact=True, chunk_size=args.lite_chunk,
                    compute_dtype=args.lite_dtype)

    # synthetic personalization traffic: exactly n_users distinct users
    # visit first (cold), then the remaining repeat_frac of requests
    # revisit them (warm; supports still attached, as real clients send —
    # the engine skips adaptation on the cache hit)
    rng = np.random.default_rng(0)
    n_users = max(1, round(args.requests * (1.0 - args.repeat_frac)))
    n_users = min(n_users, args.requests)
    cfg = EpisodicImageConfig(way=5, shot=args.shot, query_per_class=4,
                              image_size=args.image_size)
    def request_for(uid):
        t = sample_image_task(jax.random.key(uid), cfg)
        return EpisodicRequest(uid=uid, support_x=np.asarray(t.support_x),
                               support_y=np.asarray(t.support_y),
                               query_x=np.asarray(t.query_x))

    cold = [request_for(uid) for uid in range(n_users)]
    warm = [request_for(int(rng.integers(0, n_users)))
            for _ in range(args.requests - n_users)]
    reqs = cold + warm
    buckets = plan_buckets([r.support_x.shape[0] for r in reqs],
                           max_buckets=2)

    # weight-stationary serving layout: build a 1-D mesh (with --replicas
    # R > 1: R disjoint group meshes, each over len(devices)//R devices)
    # and either honor an explicit layout name or let the roofline chooser
    # score every candidate on the compiled predict step (one replica
    # group prices them all — the groups are congruent)
    replicas = args.replicas
    serve_layout, meshes, layout_rows = args.serve_layout, None, None
    multi_dev = len(jax.devices()) >= max(2, replicas)
    if serve_layout != "none" and multi_dev:
        if replicas > 1:
            from repro.launch.mesh import make_replica_mesh
            meshes = make_replica_mesh(replicas,
                                       len(jax.devices()) // replicas)
        else:
            meshes = [jax.make_mesh((len(jax.devices()),), ("serve",))]
        if serve_layout == "auto":
            import jax.numpy as jnp
            from repro.core.episodic_train import task_key
            from repro.data.episodic import collate_task_batch
            from repro.roofline.analysis import choose_replica_serving_layout
            from repro.serve.quant_params import (dequantize_params,
                                                  quantize_frozen)
            sw = quantize_frozen(learner, params, args.serve_quant)
            probe = [sample_image_task(jax.random.key(i), cfg)
                     for i in range(2)]
            batch = collate_task_batch(
                probe, support_size=max(buckets),
                query_size=probe[0].query_x.shape[0])
            keys = jax.vmap(lambda i: task_key(jax.random.key(0), i))(
                jnp.arange(2))
            states = learner.adapt_batch(dequantize_params(sw), batch,
                                         keys, lite)
            pick = choose_replica_serving_layout(
                lambda w, st, qx: learner.predict_batch(
                    dequantize_params(w), st, qx),
                sw, (states, batch.query_x), meshes)
            serve_layout, layout_rows = pick["choice"], pick["rows"]
    elif serve_layout == "auto":
        serve_layout = "none"               # single device: nothing to place

    engine_kw = dict(lite=lite, n_slots=args.slots,
                     query_chunk=args.query_chunk,
                     support_buckets=buckets,
                     kernel_backend=args.kernel_backend,
                     cache_capacity=args.cache_capacity,
                     warm_dir=args.warm_dir,
                     query_slo_us=args.query_slo_us,
                     adapt_cost_hint_us=args.adapt_cost_hint_us,
                     max_queue=args.max_queue,
                     deadline_us=args.deadline_us,
                     serve_quant=args.serve_quant,
                     serve_layout=(None if serve_layout == "none"
                                   else serve_layout))
    if replicas > 1:
        from repro.serve.replica import ReplicatedServeEngine
        engine = ReplicatedServeEngine(learner, params, replicas=replicas,
                                       meshes=meshes,
                                       warm_shards=args.warm_shards,
                                       **engine_kw)
    else:
        engine = EpisodicServeEngine(
            learner, params, mesh=meshes[0] if meshes else None, **engine_kw)
    # cold wave first so every warm request finds its user's state cached
    # regardless of slot count — warm traffic measures the cache, not
    # admission-wave luck
    t0 = clock()
    engine.run_to_completion(cold)
    engine.run_to_completion(warm)
    dt = clock() - t0
    s = engine.stats()
    # every request reaches a terminal outcome: served, or a counted
    # degradation (backpressure rejection / deadline abandonment / failed)
    assert all(r.done or r.rejected for r in reqs)
    print(f"episodic serve: learner={args.learner} {len(reqs)} requests "
          f"({n_users} distinct users) in {dt:.2f}s on {args.slots} slots")
    print(f"  tasks adapted {s['tasks_adapted']} "
          f"({s['tasks_adapted']/dt:.1f}/s), "
          f"queries {s['queries_served']} ({s['queries_served']/dt:.1f}/s), "
          f"cache hit-rate {s['hit_rate']:.2f}, "
          f"compiles adapt={s['adapt_compiles']} "
          f"predict={s['predict_compiles']}")
    print(f"  latency: adapt p50/p99 {s['adapt_p50_us']:.0f}/"
          f"{s['adapt_p99_us']:.0f} us, query (first logit) p50/p99 "
          f"{s['query_p50_us']:.0f}/{s['query_p99_us']:.0f} us; "
          f"store: evictions={s['evictions']} spills={s['spills']} "
          f"rehydrates={s['rehydrates']}, "
          f"slo_preemptions={s['slo_preemptions']}")
    print(f"  degradation: quarantined={s['quarantined']:.0f} "
          f"spill_errors={s['spill_errors']:.0f} "
          f"rejections={s['rejections']:.0f} "
          f"deadline_abandoned={s['deadline_abandoned']:.0f} "
          f"failed_requests={s['failed_requests']:.0f}")
    print(f"  weights: quant={args.serve_quant} layout={serve_layout} "
          f"resident {s['param_bytes_resident']} B "
          f"(fp32 {s['param_bytes_fp32']} B; frozen slice "
          f"{s['frozen_param_bytes_resident']} / "
          f"{s['frozen_param_bytes_fp32']} B)")
    if layout_rows is not None:
        for lo, r in layout_rows.items():
            print(f"    layout {lo:18s} wire={r['wire_bytes']:12.0f} B "
                  f"bottleneck={r['bottleneck']}")
    if replicas > 1:
        print(f"  replicas: {s['live_replicas']}/{s['n_replicas']} live, "
              f"failovers={s['replica_failovers']} "
              f"rerouted={s['rerouted_requests']}")
        for i, p in enumerate(s["per_replica"]):
            print(f"    replica {i}: adapted={p['tasks_adapted']:.0f} "
                  f"queries={p['queries_served']:.0f} "
                  f"hit_rate={p['hit_rate']:.2f} "
                  f"compiles adapt={p['adapt_compiles']:.0f} "
                  f"predict={p['predict_compiles']:.0f}")
    for r in reqs[:4]:
        print(f"  req uid={r.uid}: cache_hit={r.cache_hit} "
              f"preds={r.predictions()[:8].tolist()}")


def main(clock: Callable[[], float] = time.monotonic) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="minitron-4b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--episodic", action="store_true",
                    help="adapt-many-tasks personalization serving")
    ap.add_argument("--learner", default="protonets",
                    choices=["protonets", "cnaps", "simple_cnaps", "fomaml",
                             "finetuner"])
    ap.add_argument("--shot", type=int, default=10)
    ap.add_argument("--image-size", type=int, default=24)
    ap.add_argument("--query-chunk", type=int, default=8)
    ap.add_argument("--repeat-frac", type=float, default=0.5,
                    help="fraction of requests from repeat users "
                         "(task-state cache hits)")
    ap.add_argument("--lite-chunk", type=int, default=32,
                    help="LITE serve-time adaptation chunk size")
    ap.add_argument("--cache-capacity", type=int, default=64,
                    help="L1 task-state LRU capacity (resident adapted "
                         "states); evictions spill to --warm-dir when set")
    ap.add_argument("--warm-dir", default=None,
                    help="disk warm tier for evicted task states: spilled "
                         "via the checkpoint serialization, rehydrated "
                         "bit-exactly on a repeat uid instead of "
                         "re-adapting (default: off, evictions discard)")
    ap.add_argument("--query-slo-us", type=float, default=None,
                    help="per-request first-logit SLO in microseconds: a "
                         "pending adapt wave is deferred when it would "
                         "push a live lane's queries past this deadline")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound the admission queue: a submit over the "
                         "bound is rejected with a retry-after estimate "
                         "(EWMA adapt cost) instead of queueing unbounded "
                         "(default: unbounded)")
    ap.add_argument("--deadline-us", type=float, default=None,
                    help="per-request deadline from enqueue: a request "
                         "still logit-less past it is abandoned and its "
                         "lane/queue slot freed (default: off)")
    ap.add_argument("--adapt-cost-hint-us", type=float, default=None,
                    help="seed for the EWMA adapt-dispatch cost estimate "
                         "the SLO scheduler plans with (measured "
                         "thereafter)")
    ap.add_argument("--lite-dtype", choices=["bfloat16", "float16"],
                    default=None,
                    help="serve-time adaptation compute dtype")
    ap.add_argument("--serve-quant", choices=["none", "int8"],
                    default="none",
                    help="quantize the learner kind's FROZEN param slice "
                         "(the backbone for the CNAPs family / finetuner; "
                         "nothing for fomaml) into blockwise int8 for "
                         "serving — dequantized lazily inside the jitted "
                         "step, ~3-4x fewer resident weight bytes, logits "
                         "within quantization tolerance (fomaml "
                         "bit-identical)")
    ap.add_argument("--serve-layout",
                    choices=["auto", "none", "training",
                             "weight_stationary", "replicated"],
                    default="none",
                    help="serving weight placement on the local device "
                         "mesh: auto = compile every candidate and pick "
                         "by the three-term roofline over the actual HLO "
                         "(repro.roofline.analysis.choose_serving_layout), "
                         "weight_stationary = shard matmul weights on the "
                         "contracting dim (small-batch serving moves "
                         "activations, not gathered weights), training = "
                         "the ZeRO-ish weight-gathered train placement, "
                         "replicated = every chip holds all weights "
                         "(default: none — single-device placement)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serving replicas (repro.serve.replica): each "
                         "replica owns a full copy of the serving weights "
                         "pinned to its own disjoint device group "
                         "(len(devices)//replicas devices each, "
                         "make_replica_mesh) and its own L1 state cache; "
                         "requests route by stable uid hash, the shared "
                         "--warm-dir is partitioned into uid-hash shard "
                         "subdirs, and no predict-step collective ever "
                         "crosses a group (default: 1 — single engine)")
    ap.add_argument("--warm-shards", type=int, default=None,
                    help="uid-hash shard subdirs under --warm-dir for the "
                         "replicated path (default: 8; keep it FIXED "
                         "across deployments of the same warm root — "
                         "resizing --replicas re-routes uids but never "
                         "moves their warm files)")
    ap.add_argument("--kernel-backend",
                    choices=["ref", "pallas", "auto", "naive"],
                    default="ref",
                    help="episodic aggregation-kernel backend "
                         "(repro.kernels.dispatch), bound per engine at "
                         "construction: ref = fused jnp, pallas = Pallas "
                         "kernels (interpret off-TPU), auto = pallas on "
                         "TPU else ref, naive = materializing legacy "
                         "composite")
    args = ap.parse_args()

    if args.episodic:
        run_episodic(args, clock=clock)
        return

    cfg = get_smoke_config(args.arch)
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)
    engine = ServeEngine(cfg, params, n_slots=args.slots,
                         max_seq=args.prompt_len + args.max_new + 8)

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature)
            for i in range(args.requests)]
    t0 = clock()
    engine.run_to_completion(reqs)
    dt = clock() - t0
    n_tok = sum(len(r.out_tokens) for r in reqs)
    print(f"{cfg.name} ({cfg.family} cache): {len(reqs)} requests, "
          f"{n_tok} tokens in {dt:.2f}s ({n_tok/dt:.1f} tok/s on "
          f"{len(jax.devices())} {jax.devices()[0].platform} device(s))")
    for r in reqs[:4]:
        print(f"  req {r.uid}: {r.prompt.tolist()} -> {r.out_tokens}")
    assert all(r.done for r in reqs)


if __name__ == "__main__":
    main()
