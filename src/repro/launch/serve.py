"""Production serving launcher: continuous-batching decode over the
uniform cache API.

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-7b \
        --requests 8 --slots 4 --max-new 16

Runs the smoke config on this container; on a TPU slice the same engine
serves the full config (params sharded by repro.sharding.rules — see
EXPERIMENTS.md §Perf cell 2 for the topology guidance: size the slice so
weights are resident, don't decode one stream set on a full pod).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.models.registry import get_api
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="minitron-4b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--prompt-len", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)
    engine = ServeEngine(cfg, params, n_slots=args.slots,
                         max_seq=args.prompt_len + args.max_new + 8)

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature)
            for i in range(args.requests)]
    t0 = time.time()
    engine.run_to_completion(reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.out_tokens) for r in reqs)
    print(f"{cfg.name} ({cfg.family} cache): {len(reqs)} requests, "
          f"{n_tok} tokens in {dt:.2f}s ({n_tok/dt:.1f} tok/s on "
          f"{len(jax.devices())} {jax.devices()[0].platform} device(s))")
    for r in reqs[:4]:
        print(f"  req {r.uid}: {r.prompt.tolist()} -> {r.out_tokens}")
    assert all(r.done for r in reqs)


if __name__ == "__main__":
    main()
