"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-72b \
        --steps 100000 --ckpt-dir gs://.../qwen2  [--pods 2]

On a real TPU deployment each host runs this same script (jax.distributed
initializes from the TPU environment); on this container it runs the
reduced smoke config on the local device so the full control path —
sharded state init, fault-tolerant loop, checkpoint/auto-resume,
straggler monitoring — is exercised end to end.
"""
from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import SHAPES_BY_NAME
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.optim.schedules import cosine_schedule, wsd_schedule
from repro.sharding import rules
from repro.sharding.ctx import P
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import train
from repro.train.step import adamw_for, make_init_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="minitron-4b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--schedule", choices=["cosine", "wsd"], default="cosine")
    ap.add_argument("--peak-lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--full", action="store_true",
                    help="full assigned config (pod-scale deployment)")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    if args.full and n_dev >= 256:
        mesh = make_production_mesh(multi_pod=args.pods > 1, pods=args.pods)
        cfg = get_config(args.arch)
    else:
        mesh = make_test_mesh()
        cfg = get_smoke_config(args.arch)
        if args.full:
            print(f"[warn] --full needs >=256 devices (have {n_dev}); "
                  f"running the smoke config on the test mesh")
    print(f"arch={cfg.name} mesh={dict(mesh.shape)} devices={n_dev}")

    init = make_init_state(cfg, adamw_for(cfg))
    if args.schedule == "wsd":
        sched = functools.partial(wsd_schedule, peak=args.peak_lr,
                                  warmup_steps=max(args.steps // 50, 1),
                                  stable_steps=int(args.steps * 0.8),
                                  decay_steps=max(int(args.steps * 0.18), 1))
    else:
        sched = functools.partial(cosine_schedule, peak=args.peak_lr,
                                  warmup_steps=max(args.steps // 50, 1),
                                  total_steps=args.steps)
    step = make_train_step(cfg, adamw_for(cfg), schedule=sched)

    # sharded state init
    state_abs = jax.eval_shape(init, jax.random.key(0))
    sspecs = rules.sanitize(
        dict(params=rules.param_specs(state_abs["params"]),
             opt=rules.opt_state_specs(state_abs["opt"])),
        state_abs, mesh)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs,
                             is_leaf=lambda x: isinstance(x, P))
    with mesh:
        state = jax.jit(init, out_shardings=shardings)(jax.random.key(0))

        pipe = TokenPipeline(TokenPipelineConfig(
            vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))

        def batch_at(s):
            return {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}

        ckpt = CheckpointManager(args.ckpt_dir, keep=3)
        result = train(state, step, batch_at, args.steps,
                       ckpt=ckpt, ckpt_every=args.ckpt_every,
                       state_template=state_abs, log_every=25)
    print(f"done at step {result.step}; "
          f"loss {result.metrics_history[0]['loss']:.4f} -> "
          f"{result.metrics_history[-1]['loss']:.4f}; "
          f"stragglers={result.straggler_steps}; "
          f"resumed_from={result.resumed_from}")


if __name__ == "__main__":
    main()
