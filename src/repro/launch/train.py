"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-72b \
        --steps 100000 --ckpt-dir gs://.../qwen2  [--pods 2]

On a real TPU deployment each host runs this same script (jax.distributed
initializes from the TPU environment); on this container it runs the
reduced smoke config on the local device so the full control path —
sharded state init, fault-tolerant loop, checkpoint/auto-resume,
straggler monitoring — is exercised end to end.

``--episodic`` switches to the paper's workload: task-batched LITE
meta-training (repro.core.episodic_train) on the synthetic episodic image
stream, with ``--tasks-per-step`` tasks per optimizer step and the task
axis optionally sharded over ``--dp-shards`` devices within a host — and,
beyond one host, over a two-level (dcn, data) mesh with ``--dcn-shards``
outer host-level shards, ``--grad-reduce pmean|compressed`` cross-host
gradient reduction (compressed = int8 error feedback, residual
checkpointed in the optimizer state), and ``--accum-steps`` sequential
gradient-accumulation chunks so tasks_per_step can exceed per-host
memory:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
    PYTHONPATH=src python -m repro.launch.train --episodic --steps 20 \\
        --tasks-per-step 8 --dp-shards 2 --dcn-shards 2 \\
        --grad-reduce compressed --accum-steps 2

The throughput engine knobs: ``--prefetch N`` (background batch lookahead; default 2),
``--no-donate`` (disable in-place params/opt-state updates),
``--data-source host`` (host-side numpy collation the prefetcher can
overlap with device compute), ``--schedule cosine|wsd`` (per-step lr),
``--lite-dtype bfloat16`` (mixed-precision no-grad complement), and
``--kernel-backend ref|pallas|auto|naive`` (the
repro.kernels.dispatch backend for the fused class-statistics /
Mahalanobis aggregation kernels):

    PYTHONPATH=src python -m repro.launch.train --episodic \
        --steps 100 --tasks-per-step 8 --dp-shards 1 \
        --data-source host --prefetch 4 --schedule cosine
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import SHAPES_BY_NAME, MetaTrainConfig
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.launch.mesh import (make_dp_mesh, make_production_mesh,
                               make_test_mesh, make_two_level_dp_mesh)
from repro.optim.schedules import schedule_for
from repro.sharding import rules
from repro.sharding.ctx import P
from repro.faults import PreemptionSignal
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import PreemptedError, train
from repro.train.step import (adamw_for, make_episodic_init_state,
                              make_episodic_train_step, make_init_state,
                              make_train_step)

# EX_TEMPFAIL: the canonical "retry me" exit — a preempted run flushed a
# checkpoint and rerunning the same command resumes bit-exactly.
EXIT_PREEMPTED = 75


def _finish_preempted(e: PreemptedError) -> None:
    print(f"preempted: {e} — rerun to resume", flush=True)
    sys.exit(EXIT_PREEMPTED)


def _fault_summary(result) -> str:
    return (f"nonfinite_skips={len(result.nonfinite_steps)} "
            f"rollbacks={result.rollbacks} "
            f"data_retries={result.data_retries} "
            f"stragglers={result.straggler_steps}")


def run_episodic(args) -> None:
    from repro.core.lite import LiteSpec
    from repro.core.meta_learners import MetaLearnerConfig, make_learner
    from repro.core.set_encoder import SetEncoderConfig
    from repro.data.episodic import (EpisodicImageConfig, HostEpisodicConfig,
                                     host_task_batch_at, task_batch_at)
    from repro.models.conv_backbone import (ConvBackboneConfig,
                                            make_conv_backbone)
    from repro.optim import AdamWConfig

    meta = MetaTrainConfig(tasks_per_step=args.tasks_per_step,
                           dp_shards=args.dp_shards,
                           dcn_shards=args.dcn_shards,
                           grad_reduce=args.grad_reduce,
                           accum_steps=args.accum_steps, lr=args.peak_lr,
                           schedule=args.schedule,
                           warmup_steps=max(args.steps // 50, 1),
                           total_steps=args.steps,
                           lite_dtype=args.lite_dtype,
                           prefetch=args.prefetch,
                           donate=not args.no_donate,
                           kernel_backend=args.kernel_backend)
    if meta.dcn_shards > 1 or meta.grad_reduce == "compressed":
        mesh = make_two_level_dp_mesh(meta.dcn_shards, meta.dp_shards)
    elif meta.dp_shards > 1:
        mesh = make_dp_mesh(meta.dp_shards)
    else:
        mesh = None
    print(f"episodic meta-training: learner={args.learner} "
          f"tasks_per_step={meta.tasks_per_step} dp_shards={meta.dp_shards} "
          f"dcn_shards={meta.dcn_shards} grad_reduce={meta.grad_reduce} "
          f"accum_steps={meta.accum_steps} "
          f"schedule={meta.schedule or 'constant'} "
          f"prefetch={meta.prefetch} donate={meta.donate} "
          f"lite_dtype={meta.lite_dtype or 'float32'} "
          f"kernel_backend={meta.kernel_backend} "
          f"devices={len(jax.devices())}")

    backbone = make_conv_backbone(ConvBackboneConfig(widths=(16, 32),
                                                     feature_dim=64))
    learner = make_learner(
        MetaLearnerConfig(kind=args.learner, way=5),
        backbone,
        SetEncoderConfig(kind="conv", conv_blocks=2, conv_width=16,
                         task_dim=32))
    lite = LiteSpec(h=meta.lite_h, chunk_size=meta.lite_chunk,
                    compute_dtype=meta.lite_dtype)
    adamw = AdamWConfig(weight_decay=0.0)

    init = make_episodic_init_state(learner, adamw, meta_cfg=meta)
    step = make_episodic_train_step(learner, lite, meta, adamw, mesh=mesh)
    state = init(jax.random.key(0))
    state_abs = jax.eval_shape(init, jax.random.key(0))

    # land prefetched batches directly in the mesh layout the sharded
    # step consumes (task axis over (dcn, data)); key stays replicated
    batch_put = None
    if mesh is not None:
        task_sharding = NamedSharding(mesh, P(tuple(mesh.axis_names)))

        def batch_put(b):
            # the PRNG key stays host-side (extended key dtypes and
            # explicit shardings don't mix on all jax versions)
            return dict(
                tasks=jax.tree.map(
                    lambda a: jax.device_put(a, task_sharding), b["tasks"]),
                key=b["key"])

    step_key = jax.random.key(23)
    if args.data_source == "host":
        # host-side collation+augmentation — the path the prefetcher can
        # genuinely overlap with device compute
        hcfg = HostEpisodicConfig(way=5, shot=10, query_per_class=6,
                                  image_size=args.image_size)

        def batch_at(s):
            return dict(tasks=host_task_batch_at(17, hcfg,
                                                 meta.tasks_per_step, s),
                        key=jax.random.fold_in(step_key, s))
    else:
        tcfg = EpisodicImageConfig(way=5, shot=10, query_per_class=6,
                                   image_size=args.image_size)
        data_key = jax.random.key(17)

        def batch_at(s):
            return dict(tasks=task_batch_at(data_key, tcfg,
                                            meta.tasks_per_step, s),
                        key=jax.random.fold_in(step_key, s))

    # distinct default dir per workload AND per state template: learner,
    # plus grad_reduce/dcn_shards when compressed (opt['ef'] adds a
    # (dcn_shards, ...) leaf) — restoring a checkpoint into a different
    # template is a shape mismatch / missing-leaf KeyError
    suffix = (f"_ef{meta.dcn_shards}"
              if meta.grad_reduce == "compressed" else "")
    ckpt_dir = args.ckpt_dir or \
        f"/tmp/repro_train_ckpt_episodic_{args.learner}{suffix}"
    ckpt = CheckpointManager(ckpt_dir, keep=3)
    preempt = PreemptionSignal().install()
    try:
        result = train(state, step, batch_at, args.steps, ckpt=ckpt,
                       ckpt_every=args.ckpt_every, state_template=state_abs,
                       log_every=max(args.steps // 10, 1),
                       prefetch=meta.prefetch, donate=meta.donate,
                       batch_put=batch_put, preempt=preempt,
                       max_nonfinite=args.max_nonfinite_skips,
                       data_retries=args.data_retries)
    except PreemptedError as e:
        _finish_preempted(e)
    if not result.metrics_history:
        print(f"nothing to do: checkpoint already at step {result.step} "
              f"(resumed_from={result.resumed_from})")
        return
    print(f"done at step {result.step}; resumed_from={result.resumed_from}; "
          f"loss {result.metrics_history[0]['loss']:.4f} -> "
          f"{result.metrics_history[-1]['loss']:.4f}; "
          f"accuracy {result.metrics_history[-1]['accuracy']:.3f}; "
          f"throughput {result.throughput(meta.tasks_per_step):.1f} tasks/s; "
          f"{_fault_summary(result)}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="minitron-4b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--schedule", choices=["cosine", "wsd"], default=None,
                    help="LR schedule (LM default cosine; --episodic "
                         "default constant --peak-lr)")
    ap.add_argument("--peak-lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None,
                    help="defaults to /tmp/repro_train_ckpt (LM) or "
                         "/tmp/repro_train_ckpt_episodic (--episodic)")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--full", action="store_true",
                    help="full assigned config (pod-scale deployment)")
    ap.add_argument("--episodic", action="store_true",
                    help="task-batched LITE meta-training workload")
    ap.add_argument("--learner", default="protonets",
                    choices=["protonets", "cnaps", "simple_cnaps"])
    ap.add_argument("--tasks-per-step", type=int, default=8)
    ap.add_argument("--dp-shards", type=int, default=1,
                    help="inner ICI data-parallel shards over the task "
                         "axis (shard_map 'data' axis)")
    ap.add_argument("--dcn-shards", type=int, default=1,
                    help="outer host-level DCN shards: tasks split across "
                         "hosts on a two-level (dcn, data) mesh and "
                         "gradients reduce across hosts per --grad-reduce "
                         "(emulate hosts on CPU with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count)")
    ap.add_argument("--grad-reduce", choices=["pmean", "compressed"],
                    default="pmean",
                    help="cross-DCN gradient reduction: exact pmean, or "
                         "int8 error-feedback compression "
                         "(repro.optim.compress; residual checkpointed in "
                         "opt_state['ef'])")
    ap.add_argument("--accum-steps", type=int, default=1,
                    help="sequential gradient-accumulation chunks per "
                         "optimizer step, so --tasks-per-step can exceed "
                         "per-host memory")
    ap.add_argument("--image-size", type=int, default=24)
    ap.add_argument("--prefetch", type=int, default=2,
                    help="background batch lookahead depth (0 = sync loop)")
    ap.add_argument("--data-source", choices=["device", "host"],
                    default="device",
                    help="episodic task stream: jitted on-device sampler, "
                         "or host-side numpy collation+augmentation (the "
                         "loader-realistic path prefetch can overlap)")
    ap.add_argument("--no-donate", action="store_true",
                    help="disable params/opt-state buffer donation")
    ap.add_argument("--lite-dtype", choices=["bfloat16", "float16"],
                    default=None,
                    help="LITE no-grad complement compute dtype "
                         "(default fp32)")
    ap.add_argument("--max-nonfinite-skips", type=int, default=8,
                    help="consecutive NaN/inf-skipped steps tolerated "
                         "before divergence rollback to the last "
                         "checkpoint (then DivergenceError)")
    ap.add_argument("--data-retries", type=int, default=2,
                    help="bounded exponential-backoff retries for a "
                         "failing batch source before the error "
                         "propagates")
    ap.add_argument("--kernel-backend",
                    choices=["ref", "pallas", "auto", "naive"],
                    default="ref",
                    help="episodic aggregation-kernel backend "
                         "(repro.kernels.dispatch): ref = fused jnp "
                         "(no (B,F,F) outer intermediate), pallas = "
                         "Pallas kernels (interpret off-TPU), auto = "
                         "pallas on TPU else ref, naive = materializing "
                         "legacy composite (bit-exact pre-dispatch "
                         "oracle)")
    args = ap.parse_args()

    if args.episodic:
        run_episodic(args)
        return

    n_dev = len(jax.devices())
    if args.full and n_dev >= 256:
        mesh = make_production_mesh(multi_pod=args.pods > 1, pods=args.pods)
        cfg = get_config(args.arch)
    else:
        mesh = make_test_mesh()
        cfg = get_smoke_config(args.arch)
        if args.full:
            print(f"[warn] --full needs >=256 devices (have {n_dev}); "
                  f"running the smoke config on the test mesh")
    print(f"arch={cfg.name} mesh={dict(mesh.shape)} devices={n_dev}")

    init = make_init_state(cfg, adamw_for(cfg))
    sched = schedule_for(args.schedule or "cosine", args.peak_lr,
                         max(args.steps // 50, 1), args.steps)
    step = make_train_step(cfg, adamw_for(cfg), schedule=sched)

    # sharded state init
    state_abs = jax.eval_shape(init, jax.random.key(0))
    sspecs = rules.sanitize(
        dict(params=rules.param_specs(state_abs["params"]),
             opt=rules.opt_state_specs(state_abs["opt"])),
        state_abs, mesh)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs,
                             is_leaf=lambda x: isinstance(x, P))
    with mesh:
        state = jax.jit(init, out_shardings=shardings)(jax.random.key(0))

        pipe = TokenPipeline(TokenPipelineConfig(
            vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))

        def batch_at(s):
            return {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}

        ckpt = CheckpointManager(args.ckpt_dir or "/tmp/repro_train_ckpt",
                                 keep=3)
        preempt = PreemptionSignal().install()
        try:
            result = train(state, step, batch_at, args.steps,
                           ckpt=ckpt, ckpt_every=args.ckpt_every,
                           state_template=state_abs, log_every=25,
                           preempt=preempt,
                           max_nonfinite=args.max_nonfinite_skips,
                           data_retries=args.data_retries)
        except PreemptedError as e:
            _finish_preempted(e)
    if not result.metrics_history:
        print(f"nothing to do: checkpoint already at step {result.step} "
              f"(resumed_from={result.resumed_from})")
        return
    print(f"done at step {result.step}; "
          f"loss {result.metrics_history[0]['loss']:.4f} -> "
          f"{result.metrics_history[-1]['loss']:.4f}; "
          f"resumed_from={result.resumed_from}; "
          f"{_fault_summary(result)}")


if __name__ == "__main__":
    main()
