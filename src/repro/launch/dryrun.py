"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell
with ShapeDtypeStruct inputs (zero allocation) on 512 placeholder devices,
and record memory / FLOPs / collective traffic for the roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k

Output: one JSON record per cell appended to --out (default
benchmarks/results/dryrun.json), keyed "arch/shape/mesh", so interrupted
sweeps resume where they stopped.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
os.environ["REPRO_MIXED_DOT"] = "1"   # AOT-only: bf16 dots w/ f32 accum

import argparse
import json
import pathlib
import time
import traceback
from typing import Callable, Dict

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.configs.base import SHAPES, SHAPES_BY_NAME
from repro.configs.registry import ARCH_IDS, cell_supported, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (abstract_cache_for, abstract_params_for,
                                batch_specs_for)
from repro.models.registry import get_api
from repro.roofline import hlo as hlo_parse
from repro.sharding import rules
from repro.sharding.ctx import P
from repro.train.step import adamw_for, make_init_state, make_train_step

DEFAULT_OUT = pathlib.Path("benchmarks/results/dryrun.json")


def _named(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def _mem_analysis(compiled) -> Dict:
    try:
        ma = compiled.memory_analysis()
        keys = ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes")
        return {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}
    except Exception as e:  # CPU backend may not implement it
        return {"error": str(e)}


def _analytic_state_bytes(abstract_state, specs, mesh) -> int:
    """Per-device parameter+optimizer bytes implied by the shardings —
    byte-exact fallback/cross-check for memory_analysis."""
    sizes = dict(mesh.shape)
    total = 0

    def shard_elems(shape, spec):
        n = int(np.prod(shape)) if shape else 1
        denom = 1
        for entry in spec:
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            for nm in names:
                denom *= sizes[nm]
        return n // max(denom, 1)

    flat_s, _ = jax.tree.flatten(abstract_state)
    flat_p = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for a, sp in zip(flat_s, flat_p):
        total += shard_elems(a.shape, sp) * a.dtype.itemsize
    return total


VARIANTS = {
    # paper-faithful GSPMD-only lowering — the §Perf baseline
    "baseline": dict(moe_shard_map=False, attn_head_constraints=False,
                     tp_enabled=True),
    # production defaults (all §Perf levers on)
    "optimized": dict(),
}


def apply_variant(cfg, variant: str):
    import dataclasses
    over = dict(VARIANTS[variant])
    if variant == "baseline":
        # baseline keeps per-arch tp choice out of the picture too
        over["tp_enabled"] = True
        over["shard_activations_model"] = True
    return dataclasses.replace(cfg, **over) if over else cfg


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             variant: str = "optimized",
             clock: Callable[[], float] = time.monotonic) -> Dict:
    """``clock`` is injectable (PR6/PR7 clock discipline): the default is
    a monotonic wall clock for the launcher path; tests may pass a
    FakeClock so the recorded lower/compile timings are deterministic."""
    cfg = apply_variant(get_config(arch), variant)
    shape = SHAPES_BY_NAME[shape_name]
    api = get_api(cfg)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))

    batch_abs = batch_specs_for(cfg, shape)
    # tp_enabled=False is only a win when the batch can cover the WHOLE
    # mesh as pure DP (model axis folded into the batch); otherwise chips
    # would idle/replicate — fall back to TP for that shape.
    sizes = dict(mesh.shape)
    axes, prod = [], 1
    for ax in ("pod", "data", "model"):
        if ax in sizes and shape.global_batch % (prod * sizes[ax]) == 0:
            axes.append(ax)
            prod *= sizes[ax]
    full_dp = prod == n_chips
    tp_off = (not cfg.tp_enabled) and full_dp
    if tp_off:
        dp = tuple(axes)
        braw = jax.tree.map(
            lambda a: P(*([dp] + [None] * (len(a.shape) - 1))) if a.shape else P(),
            batch_abs)
    else:
        braw = rules.batch_specs(batch_abs)
    bspecs = rules.sanitize(braw, batch_abs, mesh)

    def tp_strip(specs):
        return rules.strip_axes(specs) if tp_off else specs

    t0 = clock()
    if shape.kind == "train":
        init = make_init_state(cfg, adamw_for(cfg))
        state_abs = jax.eval_shape(init, jax.random.key(0))
        sspecs = dict(
            params=rules.param_specs(state_abs["params"]),
            opt=rules.opt_state_specs(state_abs["opt"]),
        )
        sspecs = rules.sanitize(tp_strip(sspecs), state_abs, mesh)
        step = make_train_step(cfg, adamw_for(cfg))
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(_named(sspecs, mesh), _named(bspecs, mesh)),
                out_shardings=(_named(sspecs, mesh), None),
            ).lower(state_abs, batch_abs)
        state_bytes = _analytic_state_bytes(state_abs, sspecs, mesh)
    elif shape.kind == "prefill":
        params_abs = abstract_params_for(cfg)
        pspecs = rules.sanitize(tp_strip(rules.param_specs(params_abs)),
                                params_abs, mesh)
        cache_abs = abstract_cache_for(cfg, shape)
        cspecs = rules.sanitize(
            tp_strip(rules.cache_specs(cache_abs, shape.global_batch,
                                       mesh.shape["data"])),
            cache_abs, mesh)

        def prefill_fn(params, batch):
            return api.prefill(params, batch, cfg)

        with mesh:
            lowered = jax.jit(
                prefill_fn,
                in_shardings=(_named(pspecs, mesh), _named(bspecs, mesh)),
                out_shardings=(None, _named(cspecs, mesh)),
            ).lower(params_abs, batch_abs)
        state_bytes = _analytic_state_bytes(params_abs, pspecs, mesh)
    else:  # decode
        params_abs = abstract_params_for(cfg)
        pspecs = rules.sanitize(tp_strip(rules.param_specs(params_abs)),
                                params_abs, mesh)
        cache_abs = abstract_cache_for(cfg, shape)
        cspecs = rules.sanitize(
            tp_strip(rules.cache_specs(cache_abs, shape.global_batch,
                                       mesh.shape["data"])),
            cache_abs, mesh)

        def decode_fn(params, cache, tokens):
            return api.decode_step(params, cache, tokens, cfg)

        with mesh:
            lowered = jax.jit(
                decode_fn,
                in_shardings=(_named(pspecs, mesh), _named(cspecs, mesh),
                              _named(bspecs, mesh)["tokens"]),
                out_shardings=(None, _named(cspecs, mesh)),
            ).lower(params_abs, cache_abs, batch_abs["tokens"])
        state_bytes = (_analytic_state_bytes(params_abs, pspecs, mesh) +
                       _analytic_state_bytes(cache_abs, cspecs, mesh))
    t_lower = clock() - t0

    t0 = clock()
    compiled = lowered.compile()
    t_compile = clock() - t0

    cost = hlo_parse.xla_cost_analysis(compiled)
    analysis = hlo_parse.analyze(compiled.as_text())
    mem = _mem_analysis(compiled)

    return dict(
        arch=arch, shape=shape_name, mesh=mesh_kind, chips=n_chips,
        variant=variant, status="ok",
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        # loop-aware (repro.roofline.hlo) — the roofline inputs
        flops_per_device=analysis["dot_flops"],
        bytes_per_device=analysis["bytes_accessed"],
        collectives=analysis["collectives"],
        # XLA's loop-naive numbers, for reference / cross-check
        xla_flops_per_device=float(cost.get("flops", 0.0)),
        xla_bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        memory_analysis=mem,
        state_bytes_per_device=int(state_bytes),
    )


def load_results(path: pathlib.Path) -> Dict:
    if path.exists():
        return json.loads(path.read_text())
    return {}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS) + ["all"], default="all")
    ap.add_argument("--shape", choices=[s.name for s in SHAPES] + ["all"],
                    default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    ap.add_argument("--variant", choices=list(VARIANTS), default="optimized")
    ap.add_argument("--force", action="store_true", help="recompute existing cells")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    args.out.parent.mkdir(parents=True, exist_ok=True)
    results = load_results(args.out)
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            ok, reason = cell_supported(arch, shape)
            for mesh_kind in meshes:
                key = f"{arch}/{shape}/{mesh_kind}"
                if key in results and results[key].get("status") in ("ok", "skipped") \
                        and not args.force:
                    continue
                if not ok:
                    results[key] = dict(arch=arch, shape=shape, mesh=mesh_kind,
                                        status="skipped", reason=reason)
                    args.out.write_text(json.dumps(results, indent=1))
                    print(f"[skip] {key}: {reason}")
                    continue
                print(f"[run ] {key} ({args.variant}) ...", flush=True)
                try:
                    rec = run_cell(arch, shape, mesh_kind, args.variant)
                    print(f"[ ok ] {key}: compile={rec['compile_s']}s "
                          f"flops/dev={rec['flops_per_device']:.3e} "
                          f"state_bytes/dev={rec['state_bytes_per_device']:.3e}",
                          flush=True)
                except Exception as e:
                    n_fail += 1
                    rec = dict(arch=arch, shape=shape, mesh=mesh_kind,
                               status="fail", error=str(e)[-2000:],
                               tb=traceback.format_exc()[-4000:])
                    print(f"[FAIL] {key}: {e}", flush=True)
                results[key] = rec
                args.out.write_text(json.dumps(results, indent=1))
    print(f"done: {len(results)} cells, {n_fail} failures this run")


if __name__ == "__main__":
    main()
