"""Mesh builders.  Functions, not module constants — importing this module
never touches jax device state.

Production topology (TPU v5e): 256 chips/pod as a 16x16 (data, model) ICI
mesh; multi-pod adds a leading 'pod' DCN axis.  ``pods`` generalizes to any
pod count (the 1000+-node deployment is `pods=N` with the same rules; the
dry-run exercises N=2).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, pods: int = 2):
    shape = (pods, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(devices_shape, axes):
    """Elastic helper: build a mesh for an arbitrary live-device topology
    (used by the elastic re-mesh path and tests)."""
    return jax.make_mesh(tuple(devices_shape), tuple(axes))


def make_test_mesh():
    """Whatever devices exist (usually 1 CPU) as a (data, model)=(n, 1) mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_dp_mesh(shards: int, axis: str = "data"):
    """1-D data-parallel mesh over the first ``shards`` devices (the
    task-batched meta-training engine shards the task axis over it)."""
    import numpy as np
    from jax.sharding import Mesh

    devices = jax.devices()
    if shards > len(devices):
        raise ValueError(f"dp_shards={shards} but only {len(devices)} devices")
    return Mesh(np.asarray(devices[:shards]), (axis,))
