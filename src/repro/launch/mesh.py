"""Mesh builders.  Functions, not module constants — importing this module
never touches jax device state.

Production topology (TPU v5e): 256 chips/pod as a 16x16 (data, model) ICI
mesh; multi-pod adds a leading 'pod' DCN axis.  ``pods`` generalizes to any
pod count (the 1000+-node deployment is `pods=N` with the same rules; the
dry-run exercises N=2).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, pods: int = 2):
    shape = (pods, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(devices_shape, axes):
    """Elastic helper: build a mesh for an arbitrary live-device topology
    (used by the elastic re-mesh path and tests)."""
    return jax.make_mesh(tuple(devices_shape), tuple(axes))


def make_test_mesh():
    """Whatever devices exist (usually 1 CPU) as a (data, model)=(n, 1) mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


_EMULATE_HINT = (
    "set XLA_FLAGS=--xla_force_host_platform_device_count=N before importing "
    "jax (or run in a fresh subprocess with that env var) to emulate N "
    "devices on CPU — the pattern tests/test_multihost.py uses")


def make_dp_mesh(shards: int, axis: str = "data"):
    """1-D data-parallel mesh over the first ``shards`` devices (the
    task-batched meta-training engine shards the task axis over it)."""
    import numpy as np
    from jax.sharding import Mesh

    devices = jax.devices()
    if shards > len(devices):
        raise ValueError(
            f"dp_shards={shards} but only {len(devices)} device(s) are "
            f"visible; use dp_shards <= {len(devices)}, or {_EMULATE_HINT}")
    return Mesh(np.asarray(devices[:shards]), (axis,))


def make_replica_mesh(replicas: int, devices_per_replica: int,
                      axis: str = "serve"):
    """Disjoint per-replica serving meshes for the replicated episodic
    engine (``repro.serve.replica.ReplicatedServeEngine``).

    Returns a list of ``replicas`` 1-D meshes, each over its own
    contiguous ``devices_per_replica``-device group of ``jax.devices()``
    (process-major, so groups align with hosts on a real multi-host
    deployment).  The groups are DISJOINT by construction: weights placed
    on replica r's mesh are stationary within group r, and any collective
    a program compiled on that mesh emits is intra-group — there is no
    axis spanning two groups to communicate over.  This is the serving
    analogue of ``scaling_transformer_inference_efficiency``'s partitioned
    serving groups: weights replicated per group, work (here: the task
    population, routed by uid hash) partitioned across groups."""
    import numpy as np
    from jax.sharding import Mesh

    if replicas < 1:
        raise ValueError(f"replicas must be >= 1, got {replicas}")
    if devices_per_replica < 1:
        raise ValueError(f"devices_per_replica must be >= 1, got "
                         f"{devices_per_replica}")
    devices = jax.devices()
    need = replicas * devices_per_replica
    if need > len(devices):
        raise ValueError(
            f"replicas*devices_per_replica = {replicas}*{devices_per_replica}"
            f" = {need} but only {len(devices)} device(s) are visible; "
            f"{_EMULATE_HINT}")
    grid = np.asarray(devices[:need]).reshape(replicas, devices_per_replica)
    return [Mesh(grid[r], (axis,)) for r in range(replicas)]


def make_two_level_dp_mesh(dcn_shards: int, dp_shards: int,
                           dcn_axis: str = "dcn", axis: str = "data"):
    """Two-level data-parallel mesh for the task-batched engine: an outer
    host-level ``dcn`` axis (slow DCN links — cross-host gradient
    reduction) times an inner ``data`` axis (fast ICI — per-host task
    sharding).  ``jax.devices()`` orders devices process-major, so rows of
    the (dcn, data) grid line up with hosts on a real multi-host
    deployment; on one host (or under emulation) the split is logical but
    exercises the identical collective structure."""
    import numpy as np
    from jax.sharding import Mesh

    devices = jax.devices()
    need = dcn_shards * dp_shards
    if need > len(devices):
        raise ValueError(
            f"dcn_shards*dp_shards = {dcn_shards}*{dp_shards} = {need} but "
            f"only {len(devices)} device(s) are visible; {_EMULATE_HINT}")
    grid = np.asarray(devices[:need]).reshape(dcn_shards, dp_shards)
    return Mesh(grid, (dcn_axis, axis))
