"""Sharding-constraint helper usable from model code that must also run in
un-meshed unit tests.

``constrain(x, spec)`` applies ``with_sharding_constraint`` only when an
ambient mesh is active (the dry-run / trainer wrap lowering in ``with
mesh:``); otherwise it is the identity, so CPU tests and reduced smoke
configs never touch device topology.  Axis names in the spec that the
active mesh does not define, or that do not divide the corresponding array
dimension, are dropped (-> replicated on that dim) so one set of rules
serves the 1-device test mesh, the 16x16 pod, and the 2x16x16 multi-pod
mesh.
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
import numpy as np
from jax.sharding import PartitionSpec

P = PartitionSpec


def _active_mesh():
    # legacy `with mesh:` context (what launch/dryrun uses)
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is None or m.empty:
            return None
        return m
    except Exception:
        return None


def _mesh_axis_sizes(mesh) -> dict:
    if hasattr(mesh, "shape"):
        try:
            return dict(mesh.shape)  # Mesh.shape is OrderedDict name->size
        except Exception:
            pass
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def _sanitize(spec: PartitionSpec, shape: Tuple[int, ...], mesh) -> PartitionSpec:
    sizes = _mesh_axis_sizes(mesh)
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        names = tuple(n for n in names if n in sizes)
        if not names:
            out.append(None)
            continue
        total = int(np.prod([sizes[n] for n in names]))
        if i < len(shape) and shape[i] % total == 0:
            out.append(names if len(names) > 1 else names[0])
        else:
            out.append(None)
    return PartitionSpec(*out)


def constrain(x, spec: PartitionSpec, require_full: bool = False):
    """Mesh-aware, divisibility-safe with_sharding_constraint.

    require_full: if ANY requested axis gets dropped by the divisibility
    sanitizer, skip the constraint entirely instead of pinning the dim to
    REPLICATED — a dropped entry would otherwise force e.g. full k/v
    all-gathers for head counts that don't divide the model axis
    (measured 32x collective regression on minicpm prefill)."""
    mesh = _active_mesh()
    if mesh is None:
        return x
    s = _sanitize(spec, x.shape, mesh)
    if require_full and tuple(s) != tuple(spec):
        return x
    return jax.lax.with_sharding_constraint(x, s)


def sanitize_tree(specs, shapes, mesh):
    """Tree-wise _sanitize: drop undefined / non-dividing axes from a pytree
    of PartitionSpecs given matching ShapeDtypeStructs."""
    return jax.tree.map(
        lambda s, a: _sanitize(s, a.shape, mesh), specs, shapes,
        is_leaf=lambda s: isinstance(s, PartitionSpec))


def residual_spec(cfg):
    """Between-block residual (B, S, D) PartitionSpec per config policy."""
    if not cfg.shard_activations_model:
        return P("data", None, None)
    if getattr(cfg, "activation_layout", "hidden") == "seq":
        return P("data", "model", None)
    return P("data", None, "model")
