"""Partition rules: map parameter/cache/batch pytrees to PartitionSpecs.

Strategy (per DESIGN.md §5):
  * 'model' axis = tensor/expert parallel (attention heads, FFN hidden,
    MoE expert dim, vocab).
  * 'data' (+ 'pod' when present) = data parallel for activations AND the
    second param dim (FSDP / ZeRO-3 style), so no parameter is replicated
    across the data axis — required for the 236B/1T configs.
  * Rules are name+shape based; ``sanitize`` (repro.sharding.ctx) then
    drops any axis that the live mesh lacks or that does not divide the
    dim, so the same rules serve the test mesh, 16x16, and 2x16x16.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import PartitionSpec

from repro.sharding.ctx import sanitize_tree

P = PartitionSpec

FSDP = ("pod", "data")   # sanitize drops 'pod' on single-pod meshes


def _pad(spec: Tuple, ndim: int) -> PartitionSpec:
    """Left-pad a trailing-dims spec with None (layer-stack leading dims)."""
    pad = ndim - len(spec)
    return P(*([None] * pad + list(spec)))


def _param_rule(path: str, ndim: int) -> PartitionSpec:
    """Spec for the TRAILING dims implied by the leaf name."""
    name = path.split("/")[-1]

    # embeddings / unembedding: (V, D) — vocab over model, D over data
    if name in ("embed", "lm_head"):
        return _pad((("model",), FSDP), ndim)

    # MoE shared experts: small (D, F_shared) — keep off the model axis
    # (TP-sharding them costs an (B, S, D) all-reduce per layer fwd+bwd)
    if "shared" in path and name in ("w_gate", "w_up"):
        return _pad((FSDP, None), ndim)
    if "shared" in path and name == "w_down":
        return _pad((None, FSDP), ndim)

    # MoE expert banks: (E, D, F) / (E, F, D) — E over model (EP)
    if "ffn" in path and name in ("w_gate", "w_up") and ndim >= 3:
        return _pad((("model",), FSDP, None), ndim)
    if "ffn" in path and name == "w_down" and ndim >= 3:
        return _pad((("model",), None, FSDP), ndim)
    if name == "router":
        return _pad((FSDP, None), ndim)

    # dense FFN: (D, F) / (F, D)
    if name in ("w_gate", "w_up"):
        return _pad((FSDP, ("model",)), ndim)
    if name == "w_down":
        return _pad((("model",), FSDP), ndim)

    # attention projections
    if name in ("wq", "wk", "wv", "wq_b", "wk_b", "wv_b"):
        return _pad((FSDP, ("model",)), ndim)        # out dim = heads
    if name in ("wq_a", "wkv_a"):
        return _pad((FSDP, None), ndim)              # low-rank out is small
    if name == "wo":
        return _pad((("model",), FSDP), ndim)
    if name in ("bq", "bk", "bv"):
        return _pad((("model",),), ndim)

    # mamba
    if name == "in_proj":
        return _pad((FSDP, ("model",)), ndim)
    if name == "out_proj":
        return _pad((("model",), FSDP), ndim)
    if name == "conv_w":
        return _pad((("model",), None), ndim)
    if name in ("conv_b", "gate_norm"):
        return _pad((("model",),), ndim)

    # everything 1-D-ish (norm scales, dt_bias, A_log, D) replicates
    return P(*([None] * ndim))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(abstract_params: Any) -> Any:
    """Pytree of PartitionSpecs matching ``abstract_params`` (from
    jax.eval_shape on the model init)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_rule(_path_str(path), len(leaf.shape)),
        abstract_params)


def opt_state_specs(abstract_opt: Any, pspecs_example: Any = None) -> Any:
    """Optimizer state mirrors params (mu/nu under dicts; int8 states carry
    a trailing-dim-reduced 'scale' leaf).  Name-based rules still apply —
    the leaf names inside mu/nu are the parameter names, and 'q'/'scale'
    leaves inherit from their parent parameter name."""

    def rule(path, leaf):
        p = _path_str(path)
        name = p.split("/")[-1]
        if name in ("count", "n") or not hasattr(leaf, "shape"):
            # 'n' is the quantized dict's stored trailing dim (a plain
            # python int on the host side — no shape to shard).
            return P()
        if name in ("q", "scale"):
            parent = p.split("/")[-2]
            spec = _param_rule(parent, len(leaf.shape))
            if name == "scale":
                # scale's last dim is blocked; spec entries still apply,
                # sanitize() drops any that no longer divide.
                return spec
            return spec
        if name == "count":
            return P()
        return _param_rule(p, len(leaf.shape))

    return jax.tree_util.tree_map_with_path(rule, abstract_opt)


def batch_specs(abstract_batch: Any) -> Any:
    """Batch: leading dim over (pod, data); tokens replicate over model."""
    def rule(_path, leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        return P(*([FSDP] + [None] * (nd - 1)))
    return jax.tree_util.tree_map_with_path(rule, abstract_batch)


def cache_specs(abstract_cache: Any, batch_size: int, data_size: int,
                model_size: int = 16) -> Any:
    """Decode caches: (L, B, S, H, Dh)-style leaves.

    Placement logic (the KV cache is the decode-memory wall):
      * batch shards over (pod,)data when divisible; else the sequence
        axis takes the data axis (long-context batch=1 cells);
      * heads shard over model when divisible (no attention comm);
        otherwise the SEQUENCE axis shards over model — sequence-parallel
        decode with partial-softmax all-reduces (the gemma2 kv=4 case,
        which would otherwise replicate a 200+GB cache 16x).
    """
    big_batch = batch_size % max(data_size, 1) == 0 and batch_size >= data_size

    def rule(path, leaf):
        p = _path_str(path).split("/")[-1]
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        if p in ("k", "v", "cross_k", "cross_v"):     # (L|G, B, S, H, Dh)
            n_heads = leaf.shape[3]
            b_entry = FSDP if big_batch else None
            s_entry = None if big_batch else FSDP
            if n_heads % model_size == 0:
                return P(None, b_entry, s_entry, "model", None)
            if big_batch:
                return P(None, b_entry, "model", None, None)
            return P(None, None, (FSDP[-1], "model") if s_entry else "model",
                     None, None)
        if p in ("ckv", "krope"):       # (L, B, S, R) — latent: shard S
            b_entry = FSDP if big_batch else None
            return P(None, b_entry, "model" if big_batch else (FSDP[-1], "model"),
                     None)
        if p == "conv":                 # (L, B, C, k-1)
            return P(None, FSDP if big_batch else None, "model", None)
        if p == "ssm":                  # (L, B, H, P, N)
            return P(None, FSDP if big_batch else None, "model", None, None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, abstract_cache)


def sanitize(specs: Any, abstract: Any, mesh) -> Any:
    return sanitize_tree(specs, abstract, mesh)


def strip_axes(specs: Any, axes=("model",)) -> Any:
    """Remove named axes from every spec (tp_enabled=False -> pure DP/FSDP)."""

    def fix(s):
        out = []
        for e in tuple(s):
            if e is None:
                out.append(None)
            elif isinstance(e, tuple):
                kept = tuple(n for n in e if n not in axes)
                out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
            else:
                out.append(None if e in axes else e)
        return P(*out)

    return jax.tree.map(fix, specs, is_leaf=lambda x: isinstance(x, P))
