"""SPMD distribution layer: mesh builders, partition rules, constraints."""
