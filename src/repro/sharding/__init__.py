"""SPMD distribution layer: mesh builders, partition rules, constraints.

Also re-exports ``shard_map`` across the jax relocation (it moved from
``jax.experimental.shard_map`` to top-level ``jax.shard_map``); all repo
code and test snippets import it from here.
"""

try:
    from jax import shard_map  # jax >= 0.6
except ImportError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map

__all__ = ["shard_map"]
