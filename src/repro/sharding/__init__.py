"""SPMD distribution layer: mesh builders, partition rules, constraints.

Also re-exports ``shard_map`` across the jax relocation (it moved from
``jax.experimental.shard_map`` to top-level ``jax.shard_map``); all repo
code and test snippets import it from here — calling ``jax.shard_map``
directly regresses on older jax (that exact drift broke the optimized MoE
dispatch variant; see ROADMAP).

Two-level DP mesh contract (the task-batched meta-training engine,
``repro.core.episodic_train.make_batched_meta_train_step``):

* ``repro.launch.mesh.make_two_level_dp_mesh(dcn, dp)`` builds a
  ``(dcn_axis='dcn', dp_axis='data')`` mesh — the outer ``dcn`` axis is
  the slow cross-host DCN domain (rows align with hosts because
  ``jax.devices()`` orders devices process-major), the inner ``data``
  axis is the fast per-host ICI domain.
* The task axis of a ``TaskBatch`` shards over BOTH axes,
  ``P(('dcn', 'data'))``; params and optimizer state are replicated
  (``P()``), except the compressed-reduction error-feedback residual
  ``opt_state['ef']`` whose leading axis shards ``P('dcn')`` (one
  residual per host; checkpointed like any other opt-state leaf).
* Gradients ``pmean`` first over ``data`` (cheap, per host), then reduce
  once over ``dcn`` — exact ``pmean`` or error-feedback
  ``compressed_psum`` (``repro.optim.compress``).  With ``accum_steps``
  the per-shard tasks are scanned in chunks BEFORE the reduction, so the
  collective count per optimizer step never grows.
* At ``dcn`` size 1 the extra reduction is a singleton all-reduce and the
  engine is bit-identical to the 1-D ``make_dp_mesh`` path (tested in
  tests/test_multihost.py).  Per-step collective wire bytes are
  accounted by ``repro.roofline.hlo.collectives_report`` and tracked in
  ``benchmarks/dp_scaling.py``.

Replica-serving mesh contract (the multi-replica episodic engine,
``repro.serve.replica.ReplicatedServeEngine``):

* ``repro.launch.mesh.make_replica_mesh(replicas, devices_per_replica)``
  builds ``replicas`` DISJOINT 1-D ``('serve',)`` meshes over contiguous
  device groups (process-major, so groups align with hosts).  Each
  replica engine compiles and places its serving weights on its OWN group
  mesh — the compiled program cannot name a device outside the group, so
  every predict-step collective is intra-group by construction and
  per-step wire bytes scale with ``devices_per_replica``, never with the
  deployment size (asserted via ``collectives_report`` in
  tests/test_replica.py).
* Work is partitioned ACROSS groups by data, not by tensor: requests
  route by stable uid hash (``repro.serve.episodic.stable_uid_hash``), so
  the task population — the paper's scaling axis at serving time — splits
  across replicas while weights are simply replicated per group (the
  serving-group discipline of scaling_transformer_inference_efficiency).
* The shared warm tier partitions by the SAME hash into a fixed number of
  shard subdirs independent of the replica count: any replica can locate
  any uid's spilled state (failover rehydration), and resizing the
  deployment re-routes uids without moving their files.
"""

try:
    from jax import shard_map  # jax >= 0.6
except ImportError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map

__all__ = ["shard_map"]
