"""Batched serving driver (deliverable b): continuous-batching KV-cache
decode over the uniform model API — same engine for GQA, MLA-latent,
SSM-state and hybrid caches.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2-2b --requests 6
"""
import argparse

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.models.registry import get_api
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma2-2b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)
    engine = ServeEngine(cfg, params, n_slots=args.slots, max_seq=128)

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, size=8).astype(np.int32),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature)
            for i in range(args.requests)]
    print(f"serving {len(reqs)} requests on {args.slots} slots "
          f"({cfg.name}, {cfg.family} cache)")
    engine.run_to_completion(reqs)
    for r in reqs:
        print(f"  req {r.uid}: prompt={r.prompt.tolist()} -> {r.out_tokens}")
    assert all(r.done for r in reqs)
    print("all requests complete")


if __name__ == "__main__":
    main()
