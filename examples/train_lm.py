"""End-to-end LM training driver (deliverable b): train a ~100M-param
reduced config for a few hundred steps with the full production substrate
— sharded data feed, AdamW + cosine schedule, gradient clipping, atomic
checkpointing with auto-resume, straggler monitoring.

    PYTHONPATH=src python examples/train_lm.py --arch minitron-4b --steps 300

On a pod this same driver runs the FULL config via --full (the mesh and
sharding rules come from repro.launch.mesh / repro.sharding.rules).
"""
import argparse
import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.optim.schedules import cosine_schedule
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import train
from repro.train.step import adamw_for, make_init_state, make_train_step


def scaled_100m(arch: str):
    """A ~100M-param member of the arch's family (CPU-trainable shape)."""
    cfg = get_smoke_config(arch)
    return dataclasses.replace(
        cfg, n_layers=max(cfg.n_layers, 4), d_model=256,
        d_ff=cfg.d_ff * 4 if cfg.d_ff else 0, vocab=8192, max_seq=2048)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="minitron-4b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--full", action="store_true",
                    help="use the full assigned config (pod-scale)")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else scaled_100m(args.arch)
    print(f"arch={cfg.name} layers={cfg.n_layers} d_model={cfg.d_model} "
          f"vocab={cfg.vocab}")

    init = make_init_state(cfg, adamw_for(cfg))
    schedule = functools.partial(cosine_schedule, peak=3e-4, warmup_steps=20,
                                 total_steps=args.steps)
    step = make_train_step(cfg, adamw_for(cfg), schedule=schedule)

    pipe = TokenPipeline(TokenPipelineConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        branching=4))

    def batch_at(s):
        return {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}

    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    template = jax.eval_shape(init, jax.random.key(0))
    result = train(init(jax.random.key(0)), step, batch_at, args.steps,
                   ckpt=ckpt, ckpt_every=100, state_template=template,
                   log_every=25)
    if result.resumed_from is not None:
        print(f"(resumed from checkpointed step {result.resumed_from})")
    print(f"final loss: {result.metrics_history[-1]['loss']:.4f} "
          f"(first: {result.metrics_history[0]['loss']:.4f})")
    if result.straggler_steps:
        print(f"straggler steps flagged: {result.straggler_steps}")


if __name__ == "__main__":
    main()
