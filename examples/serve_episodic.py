"""Episodic serving quickstart: adapt-many-tasks personalization.

Each request is one user's episode — a support set (their labelled
examples) and a query stream (what they want classified).  The engine
adapts newly seen tasks in ONE batched, LITE-chunked, forward-only
dispatch, caches the adapted task state by user id (repeat visitors skip
adaptation entirely), and micro-batches the queries of every live task
into one dispatch per step.

    PYTHONPATH=src python examples/serve_episodic.py --learner protonets

``--replicas R`` serves the same traffic through the replica-aware router
(``repro.serve.replica.ReplicatedServeEngine``): R engines, each with a
full weight copy and its own L1 state cache, with requests routed by a
stable uid hash — the horizontal-scaling story at "millions of users".
On one device the replicas share it (routing/caching semantics are
identical); emulate real disjoint device groups with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``:

    PYTHONPATH=src python examples/serve_episodic.py --replicas 2
"""
import argparse
import time

import jax
import numpy as np

from repro.core.lite import LiteSpec
from repro.core.meta_learners import MetaLearnerConfig, make_learner
from repro.core.set_encoder import SetEncoderConfig
from repro.data.episodic import EpisodicImageConfig, sample_image_task
from repro.models.conv_backbone import ConvBackboneConfig, make_conv_backbone
from repro.serve.episodic import EpisodicRequest, EpisodicServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--learner", default="protonets",
                    choices=["protonets", "cnaps", "simple_cnaps", "fomaml",
                             "finetuner"])
    ap.add_argument("--users", type=int, default=6)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--shot", type=int, default=8)
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through the replica-aware router: uid-hash "
                         "routing over N engines, each with its own weight "
                         "copy and L1 cache (default: 1 — single engine)")
    args = ap.parse_args()

    backbone = make_conv_backbone(ConvBackboneConfig(widths=(8, 16),
                                                     feature_dim=32))
    learner = make_learner(
        MetaLearnerConfig(kind=args.learner, way=5), backbone,
        SetEncoderConfig(kind="conv", conv_blocks=1, conv_width=8,
                         task_dim=16))
    params = learner.init(jax.random.key(0))

    # traffic: a cold wave (every user's first visit, support attached),
    # then a warm wave revisiting users round-robin.  Repeat visitors omit
    # the support set entirely — the engine serves them from the LRU
    # task-state cache (a support-less request therefore requires its
    # user's state to already be cached when it is admitted).
    cfg = EpisodicImageConfig(way=5, shot=args.shot, query_per_class=3,
                              image_size=16)
    tasks = [sample_image_task(jax.random.key(u), cfg)
             for u in range(args.users)]
    cold = [EpisodicRequest(uid=u, support_x=np.asarray(t.support_x),
                            support_y=np.asarray(t.support_y),
                            query_x=np.asarray(t.query_x))
            for u, t in enumerate(tasks)]
    warm = [EpisodicRequest(uid=i % args.users,
                            query_x=np.asarray(tasks[i % args.users].query_x))
            for i in range(max(args.requests - args.users, 0))]

    engine_kw = dict(
        lite=LiteSpec(exact=True, chunk_size=16),   # O(chunk) adapt memory
        n_slots=4, query_chunk=8, support_buckets=(64,),
        cache_capacity=args.users)
    if args.replicas > 1:
        from repro.serve.replica import ReplicatedServeEngine
        engine = ReplicatedServeEngine(learner, params,
                                       replicas=args.replicas, **engine_kw)
    else:
        engine = EpisodicServeEngine(learner, params, **engine_kw)
    t0 = time.time()
    engine.run_to_completion(cold)
    engine.run_to_completion(warm)
    dt = time.time() - t0

    reqs = cold + warm
    assert all(r.done for r in reqs)
    s = engine.stats()
    print(f"{args.learner}: served {len(reqs)} requests "
          f"({s['queries_served']} queries) in {dt:.2f}s")
    print(f"  adapted {s['tasks_adapted']} tasks, cache hit-rate "
          f"{s['hit_rate']:.2f}, compiles adapt={s['adapt_compiles']} "
          f"predict={s['predict_compiles']}")
    print(f"  adapt latency p50/p99 {s['adapt_p50_us']:.0f}/"
          f"{s['adapt_p99_us']:.0f} us, first-logit p50/p99 "
          f"{s['query_p50_us']:.0f}/{s['query_p99_us']:.0f} us "
          f"(set warm_dir= to spill evicted states to disk instead of "
          f"re-adapting)")
    if args.replicas > 1:
        for i, p in enumerate(s["per_replica"]):
            print(f"  replica {i}: adapted={p['tasks_adapted']:.0f} "
                  f"queries={p['queries_served']:.0f} "
                  f"hit_rate={p['hit_rate']:.2f}")
    for r in reqs[: args.users + 2]:
        print(f"  uid={r.uid} cache_hit={r.cache_hit} "
              f"preds={r.predictions().tolist()}")


if __name__ == "__main__":
    main()
