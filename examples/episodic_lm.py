"""The paper's technique as a first-class LM-framework feature: episodic
meta-training (ProtoNets + LITE) wrapped around an assigned LM
architecture — support/query examples are token sequences; FiLM modulates
the residual stream per layer (DESIGN.md §3).

    PYTHONPATH=src python examples/episodic_lm.py --arch minitron-4b
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.core.lite import LiteSpec
from repro.core.meta_learners import MetaLearnerConfig, make_learner
from repro.core.set_encoder import SetEncoderConfig
from repro.data.episodic import EpisodicTokenConfig, sample_token_task
from repro.models.lm_backbone import make_lm_backbone
from repro.optim import clip_by_global_norm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=["minitron-4b", "qwen2-72b",
                                       "gemma2-2b", "mamba2-780m"],
                    default="minitron-4b")
    ap.add_argument("--kind", choices=["protonets", "simple_cnaps"],
                    default="simple_cnaps")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--h", type=int, default=8, help="|H| back-propagated")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    backbone = make_lm_backbone(cfg)
    task_cfg = EpisodicTokenConfig(way=4, shot=8, query_per_class=6,
                                   seq_len=48, vocab=cfg.vocab)
    learner = make_learner(
        MetaLearnerConfig(kind=args.kind, way=4),
        backbone,
        SetEncoderConfig(kind="tokens", in_channels=cfg.vocab, task_dim=32),
    )
    params = learner.init(jax.random.key(0))
    lite = LiteSpec(h=args.h, chunk_size=8)
    n_support = task_cfg.way * task_cfg.shot
    print(f"episodic {args.kind}+LITE over {cfg.name}: "
          f"N={n_support} support sequences, |H|={args.h} back-propagated")

    @jax.jit
    def meta_step(p, task, key):
        (loss, aux), g = jax.value_and_grad(
            lambda pp: learner.meta_loss(pp, task, key, lite), has_aux=True)(p)
        g, _ = clip_by_global_norm(g, 10.0)
        return jax.tree.map(lambda a, b: a - 1e-3 * b, p, g), loss, aux

    key = jax.random.key(1)
    for step in range(args.steps):
        key, kt, kh = jax.random.split(key, 3)
        task = sample_token_task(kt, task_cfg)
        params, loss, aux = meta_step(params, task, kh)
        if step % 10 == 0:
            print(f"step {step:3d}  loss {float(loss):8.4f}  "
                  f"acc {float(aux['accuracy']):.2f}")

    accs = []
    for i in range(10):
        t = sample_token_task(jax.random.fold_in(jax.random.key(5), i), task_cfg)
        st = learner.adapt(params, t.support_x, t.support_y)
        pred = jnp.argmax(learner.predict(params, st, t.query_x), -1)
        accs.append(float(jnp.mean((pred == t.query_y).astype(jnp.float32))))
    print(f"held-out episodic accuracy over {cfg.name}: {np.mean(accs):.3f}")


if __name__ == "__main__":
    main()
