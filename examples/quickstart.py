"""Quickstart: meta-train Simple CNAPs with LITE on synthetic episodic
image tasks, then adapt to a new task at test time with ONE forward pass.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lite import LiteSpec
from repro.core.meta_learners import MetaLearnerConfig, make_learner
from repro.core.set_encoder import SetEncoderConfig
from repro.data.episodic import EpisodicImageConfig, sample_image_task
from repro.models.conv_backbone import ConvBackboneConfig, make_conv_backbone
from repro.optim import clip_by_global_norm


def main() -> None:
    # 1. backbone + meta-learner (the paper's headline instantiation)
    backbone = make_conv_backbone(ConvBackboneConfig(widths=(16, 32),
                                                     feature_dim=64))
    learner = make_learner(
        MetaLearnerConfig(kind="simple_cnaps", way=5),
        backbone,
        SetEncoderConfig(kind="conv", conv_blocks=2, conv_width=16, task_dim=32),
    )
    params = learner.init(jax.random.key(0))

    # 2. LITE: forward the WHOLE support set, back-prop only |H|=8 of 50
    lite = LiteSpec(h=8, chunk_size=16)
    task_cfg = EpisodicImageConfig(way=5, shot=10, query_per_class=6,
                                   image_size=24)

    @jax.jit
    def meta_step(p, task, key):
        (loss, aux), g = jax.value_and_grad(
            lambda pp: learner.meta_loss(pp, task, key, lite), has_aux=True)(p)
        g, _ = clip_by_global_norm(g, 10.0)
        p = jax.tree.map(lambda a, b: a - 1e-3 * b, p, g)
        return p, loss, aux["accuracy"]

    key = jax.random.key(1)
    for step in range(60):
        key, kt, kh = jax.random.split(key, 3)
        task = sample_image_task(kt, task_cfg)
        params, loss, acc = meta_step(params, task, kh)
        if step % 10 == 0:
            print(f"step {step:3d}  meta-loss {float(loss):7.3f}  "
                  f"query-acc {float(acc):.2f}")

    # 3. meta-test: ONE forward pass of the support set adapts the model
    accs = []
    for i in range(10):
        t = sample_image_task(jax.random.fold_in(jax.random.key(2), i), task_cfg)
        state = learner.adapt(params, t.support_x, t.support_y)   # 1F
        pred = jnp.argmax(learner.predict(params, state, t.query_x), -1)
        accs.append(float(jnp.mean((pred == t.query_y).astype(jnp.float32))))
    print(f"\nheld-out task accuracy: {np.mean(accs):.3f} "
          f"(adaptation = single forward pass)")

    # 4. scale it: the TASK-BATCHED engine — many tasks per optimizer step
    # (vmap over the task axis, per-task H draws, one AdamW update; set
    # mesh=make_dp_mesh(n) to shard the task axis across devices).
    from repro.core.episodic_train import make_batched_meta_train_step
    from repro.data.episodic import task_batch_at
    from repro.optim import AdamWConfig, adamw_init

    adamw = AdamWConfig(weight_decay=0.0)
    opt_state = adamw_init(params, adamw)
    batched_step = jax.jit(
        make_batched_meta_train_step(learner, lite, adamw=adamw, lr=1e-3))
    data_key, step_key = jax.random.key(3), jax.random.key(4)
    for step in range(20):
        batch = task_batch_at(data_key, task_cfg, 8, step)   # 8 tasks/step
        params, opt_state, metrics = batched_step(
            params, opt_state, batch, jax.random.fold_in(step_key, step))
        if step % 5 == 0:
            print(f"batched step {step:3d}  loss {float(metrics['loss']):7.3f}"
                  f"  acc {float(metrics['accuracy']):.2f}")


if __name__ == "__main__":
    main()
