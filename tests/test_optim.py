"""Optimizer substrate: AdamW state-dtype policies, schedules, clipping,
int8 quantization, error-feedback compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, cosine_schedule, wsd_schedule)
from repro.optim.compress import ef_compress, zeros_error
from repro.optim.quant import dequantize, quantize


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
def test_adamw_reduces_quadratic(dtype, key):
    cfg = AdamWConfig(state_dtype=dtype, weight_decay=0.0)
    target = jax.random.normal(key, (64, 33))
    params = dict(w=jnp.zeros((64, 33)))
    state = adamw_init(params, cfg)

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state = adamw_update(params, g, state, 0.05, cfg)
    assert float(loss(params)) < 0.15 * l0


def test_quantize_roundtrip_error_bound(key):
    x = 3.0 * jax.random.normal(key, (7, 300))
    q = quantize(x)
    back = dequantize(q, 300)
    scale = np.asarray(q["scale"]).repeat(128, -1)[..., :300]
    assert float(jnp.max(jnp.abs(back - x))) <= float(scale.max()) + 1e-6


def test_ef_compression_error_feedback(key):
    """Error feedback: the accumulated compressed stream tracks the
    accumulated true gradient (long-run bias -> 0)."""
    gs = [0.01 * jax.random.normal(jax.random.fold_in(key, i), (4, 256))
          for i in range(50)]
    err = zeros_error(dict(g=gs[0]))
    acc_hat = jnp.zeros_like(gs[0])
    acc_true = jnp.zeros_like(gs[0])
    for g in gs:
        g_hat, err = ef_compress(dict(g=g), err)
        acc_hat += g_hat["g"]
        acc_true += g
    resid = float(jnp.max(jnp.abs(acc_hat - acc_true)))
    one_step_err = float(jnp.max(jnp.abs(err["g"])))
    # residual stays bounded by one step's quantization error, not 50x it
    assert resid <= one_step_err + 1e-6


def test_clip_by_global_norm(key):
    g = dict(a=jax.random.normal(key, (10,)) * 100)
    clipped, norm = clip_by_global_norm(g, 1.0)
    from repro.common.tree import global_norm
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 1.0


def test_schedules_shape():
    steps = jnp.arange(0, 1000, 50)
    cos = jax.vmap(lambda s: cosine_schedule(s, 1.0, 100, 1000))(steps)
    assert float(cos[0]) < 0.1            # warmup
    assert float(jnp.max(cos)) <= 1.0 + 1e-6
    assert cos[-1] < cos[len(cos) // 2]   # decaying
    wsd = jax.vmap(lambda s: wsd_schedule(s, 1.0, 100, 600, 300))(steps)
    mid = wsd[(steps > 150) & (steps < 650)]
    np.testing.assert_allclose(np.asarray(mid), 1.0, rtol=1e-5)  # stable
    assert float(wsd[-1]) < 0.2           # decayed
