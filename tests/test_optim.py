"""Optimizer substrate: AdamW state-dtype policies, schedules, clipping,
int8 quantization, error-feedback compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, cosine_schedule, wsd_schedule)
from repro.optim.compress import ef_compress, zeros_error
from repro.optim.quant import (BLOCK, _LOG_FLOOR, dequantize, dequantize_log,
                               quantize, quantize_log, resolve_n,
                               zeros_quantized)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
def test_adamw_reduces_quadratic(dtype, key):
    cfg = AdamWConfig(state_dtype=dtype, weight_decay=0.0)
    target = jax.random.normal(key, (64, 33))
    params = dict(w=jnp.zeros((64, 33)))
    state = adamw_init(params, cfg)

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state = adamw_update(params, g, state, 0.05, cfg)
    assert float(loss(params)) < 0.15 * l0


def test_quantize_roundtrip_error_bound(key):
    x = 3.0 * jax.random.normal(key, (7, 300))
    q = quantize(x)
    back = dequantize(q, 300)
    scale = np.asarray(q["scale"]).repeat(128, -1)[..., :300]
    assert float(jnp.max(jnp.abs(back - x))) <= float(scale.max()) + 1e-6


@pytest.mark.quant
@pytest.mark.parametrize("n", [1, 7, BLOCK - 1, BLOCK, BLOCK + 1,
                               2 * BLOCK, 2 * BLOCK + 37])
def test_quantize_roundtrip_stored_n(n, key):
    """Property sweep over non-multiple-of-BLOCK trailing dims: the dict
    carries ``n``, so no-arg dequantize matches the positional path
    bit-for-bit, and the roundtrip error stays within one scale step."""
    x = 2.5 * jax.random.normal(key, (3, n))
    qs = quantize(x)
    assert qs["n"] == n and isinstance(qs["n"], int)
    assert resolve_n(qs) == n
    back = dequantize(qs)                      # stored-n path
    back_pos = dequantize(qs, n)               # back-compat positional path
    np.testing.assert_array_equal(np.asarray(back), np.asarray(back_pos))
    assert back.shape == x.shape
    nb = (n + BLOCK - 1) // BLOCK
    scale = np.asarray(qs["scale"]).repeat(BLOCK, -1)[..., :n]
    assert qs["scale"].shape == (3, nb)
    assert float(jnp.max(jnp.abs(back - x))) <= float(scale.max()) + 1e-6


@pytest.mark.quant
def test_quantize_n_survives_jit_and_legacy_dicts(key):
    """Crossing a jit boundary turns the stored int into a tracer/array;
    resolve_n must fall back to q.shape[-1] (always equal to n).  Legacy
    {q, scale} dicts without ``n`` keep working."""
    x = jax.random.normal(key, (4, 200))
    qs = quantize(x)
    inside = jax.jit(lambda d: dequantize(d))(qs)
    np.testing.assert_array_equal(np.asarray(inside),
                                  np.asarray(dequantize(qs)))
    legacy = dict(q=qs["q"], scale=qs["scale"])        # pre-PR8 form
    np.testing.assert_array_equal(np.asarray(dequantize(legacy)),
                                  np.asarray(dequantize(qs, 200)))


@pytest.mark.quant
def test_quantize_zero_blocks_and_log_floor(key):
    """All-zero input: scale floors at 1e-12 and the roundtrip is exactly
    zero.  Log domain: zeros roundtrip to exactly zero through the
    _LOG_FLOOR clamp, and positive values stay multiplicatively close."""
    z = jnp.zeros((2, BLOCK + 5))
    qz = quantize(z)
    assert float(jnp.max(jnp.abs(dequantize(qz)))) == 0.0
    zq = zeros_quantized((2, BLOCK + 5))
    assert zq["n"] == BLOCK + 5
    assert float(jnp.max(jnp.abs(dequantize(zq)))) == 0.0

    v = jnp.concatenate([jnp.zeros((1, 50)),
                         10.0 ** jax.random.uniform(
                             key, (1, 50), minval=-9.0, maxval=2.0)], axis=-1)
    back = dequantize_log(quantize_log(v))
    np.testing.assert_array_equal(np.asarray(back[:, :50]), 0.0)
    pos = np.asarray(v[:, 50:])
    rel = np.abs(np.asarray(back[:, 50:]) - pos) / np.maximum(pos, _LOG_FLOOR)
    assert rel.max() < 0.25    # log-domain error is multiplicative, bounded


def test_ef_compression_error_feedback(key):
    """Error feedback: the accumulated compressed stream tracks the
    accumulated true gradient (long-run bias -> 0)."""
    gs = [0.01 * jax.random.normal(jax.random.fold_in(key, i), (4, 256))
          for i in range(50)]
    err = zeros_error(dict(g=gs[0]))
    acc_hat = jnp.zeros_like(gs[0])
    acc_true = jnp.zeros_like(gs[0])
    for g in gs:
        g_hat, err = ef_compress(dict(g=g), err)
        acc_hat += g_hat["g"]
        acc_true += g
    resid = float(jnp.max(jnp.abs(acc_hat - acc_true)))
    one_step_err = float(jnp.max(jnp.abs(err["g"])))
    # residual stays bounded by one step's quantization error, not 50x it
    assert resid <= one_step_err + 1e-6


def test_clip_by_global_norm(key):
    g = dict(a=jax.random.normal(key, (10,)) * 100)
    clipped, norm = clip_by_global_norm(g, 1.0)
    from repro.common.tree import global_norm
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 1.0


def test_schedules_shape():
    steps = jnp.arange(0, 1000, 50)
    cos = jax.vmap(lambda s: cosine_schedule(s, 1.0, 100, 1000))(steps)
    assert float(cos[0]) < 0.1            # warmup
    assert float(jnp.max(cos)) <= 1.0 + 1e-6
    assert cos[-1] < cos[len(cos) // 2]   # decaying
    wsd = jax.vmap(lambda s: wsd_schedule(s, 1.0, 100, 600, 300))(steps)
    mid = wsd[(steps > 150) & (steps < 650)]
    np.testing.assert_allclose(np.asarray(mid), 1.0, rtol=1e-5)  # stable
    assert float(wsd[-1]) < 0.2           # decayed
