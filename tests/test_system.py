"""End-to-end behaviour tests: the paper's training scheme drives real
learning, the LM stack trains end-to-end, and the episodic-LM integration
(the paper's technique as a first-class feature of the LM framework) works.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.core.lite import LiteSpec
from repro.core.meta_learners import MetaLearnerConfig, make_learner
from repro.core.set_encoder import SetEncoderConfig
from repro.data.episodic import (EpisodicImageConfig, EpisodicTokenConfig,
                                 sample_image_task, sample_token_task)
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.models.conv_backbone import ConvBackboneConfig, make_conv_backbone
from repro.models.lm_backbone import make_lm_backbone
from repro.train.loop import train
from repro.train.step import adamw_for, make_init_state, make_train_step


def test_lm_loss_decreases_on_learnable_stream(key):
    """A Markov token stream must be learnable by the smoke transformer."""
    cfg = get_smoke_config("minitron-4b")
    init = make_init_state(cfg, adamw_for(cfg))
    step = make_train_step(cfg, adamw_for(cfg), schedule=lambda s: 1e-3)
    pipe = TokenPipeline(TokenPipelineConfig(vocab=cfg.vocab, seq_len=64,
                                             global_batch=8, branching=2))
    batch_at = lambda s: {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
    r = train(init(key), step, batch_at, 40)
    first = np.mean([h["loss"] for h in r.metrics_history[:5]])
    last = np.mean([h["loss"] for h in r.metrics_history[-5:]])
    assert last < first - 0.5, (first, last)


def test_simple_cnaps_lite_end_to_end(key):
    """Paper headline path, deflaked: Simple CNAPs + LITE, meta-trained
    with the task-batched engine, averaged over seeds.

    In this reduced setting (frozen RANDOM backbone + FiLM, synthetic
    tasks, a few dozen steps) held-out accuracy does not reliably RISE
    within a test budget on any seed/lr we measured, so a single-seed
    "+5 points" threshold is pure noise.  What does hold robustly, and is
    asserted here with seed-averaged tolerances, is the paper's qualitative
    claims: (a) one-forward-pass adaptation works — held-out accuracy far
    above chance from random features; (b) LITE meta-training is stable —
    finite losses and no collapse of held-out accuracy.  The STRICT
    improvement assertion lives in
    test_simple_cnaps_training_improves_with_pretrained_stub, which swaps
    in the deterministic pretrained-backbone stub (the paper's actual
    warm-start regime)."""
    from repro.core.episodic_train import make_batched_meta_train_step
    from repro.data.episodic import task_batch_at
    from repro.optim import AdamWConfig, adamw_init

    bb = make_conv_backbone(ConvBackboneConfig(widths=(8, 16), feature_dim=32))
    cfg = MetaLearnerConfig(kind="simple_cnaps", way=5)
    lr = make_learner(cfg, bb, SetEncoderConfig(kind="conv", conv_blocks=2,
                                                conv_width=8, task_dim=16))
    tcfg = EpisodicImageConfig(way=5, shot=10, query_per_class=4, image_size=16)
    spec = LiteSpec(h=10, chunk_size=16)
    adamw = AdamWConfig(weight_decay=0.0)
    step = jax.jit(make_batched_meta_train_step(lr, spec, adamw=adamw,
                                                lr=1e-3))

    def eval_acc(p):
        accs = []
        for i in range(8):
            t = sample_image_task(jax.random.fold_in(jax.random.key(99), i), tcfg)
            st = lr.adapt(p, t.support_x, t.support_y)
            pred = jnp.argmax(lr.predict(p, st, t.query_x), -1)
            accs.append(float(jnp.mean((pred == t.query_y).astype(jnp.float32))))
        return float(np.mean(accs))

    acc0s, acc1s = [], []
    for seed in range(3):
        params = lr.init(jax.random.key(seed))
        opt = adamw_init(params, adamw)
        acc0s.append(eval_acc(params))
        dk, sk = jax.random.key(50 + seed), jax.random.key(150 + seed)
        for s in range(25):
            batch = task_batch_at(dk, tcfg, 4, s)
            params, opt, m = step(params, opt, batch,
                                  jax.random.fold_in(sk, s))
            assert np.isfinite(float(m["loss"])), (seed, s)
        acc1s.append(eval_acc(params))

    # (a) adaptation from a single forward pass beats 5-way chance by far
    assert np.mean(acc0s) > 0.28, acc0s
    # (b) training is stable: seed-mean held-out accuracy within tolerance
    assert np.mean(acc1s) > np.mean(acc0s) - 0.06, (acc0s, acc1s)


def test_simple_cnaps_training_improves_with_pretrained_stub(
        pretrained_stub_backbone):
    """STRICT 'training improves held-out accuracy' for Simple CNAPs
    (ROADMAP open item).  The paper meta-trains FiLM on a frozen
    PRE-TRAINED feature extractor; the deterministic stub backbone
    (tests/conftest.py) reproduces that regime — informative pooled
    features plus noise-dominated distractor dims that the trainable FiLM
    generator learns to suppress.  Unlike the frozen-random-backbone
    setting (previous test), held-out accuracy rises reliably on EVERY
    seed within a small budget (measured: +0.18 to +0.31 over 3 seeds at
    30 steps; asserted at half that margin over 2 seeds)."""
    from repro.core.episodic_train import make_batched_meta_train_step
    from repro.data.episodic import task_batch_at
    from repro.optim import AdamWConfig, adamw_init

    lr = make_learner(MetaLearnerConfig(kind="simple_cnaps", way=5),
                      pretrained_stub_backbone,
                      SetEncoderConfig(kind="conv", conv_blocks=2,
                                       conv_width=8, task_dim=16))
    tcfg = EpisodicImageConfig(way=5, shot=10, query_per_class=4,
                               image_size=16)
    spec = LiteSpec(h=10, chunk_size=16)
    adamw = AdamWConfig(weight_decay=0.0)
    step = jax.jit(make_batched_meta_train_step(lr, spec, adamw=adamw,
                                                lr=2e-3))

    def eval_acc(p):
        accs = []
        for i in range(8):
            t = sample_image_task(jax.random.fold_in(jax.random.key(99), i),
                                  tcfg)
            st = lr.adapt(p, t.support_x, t.support_y)
            pred = jnp.argmax(lr.predict(p, st, t.query_x), -1)
            accs.append(float(jnp.mean((pred == t.query_y)
                                       .astype(jnp.float32))))
        return float(np.mean(accs))

    gains = []
    for seed in range(2):
        params = lr.init(jax.random.key(seed))
        opt = adamw_init(params, adamw)
        acc0 = eval_acc(params)
        dk, sk = jax.random.key(50 + seed), jax.random.key(150 + seed)
        for s in range(30):
            batch = task_batch_at(dk, tcfg, 4, s)
            params, opt, m = step(params, opt, batch,
                                  jax.random.fold_in(sk, s))
            assert np.isfinite(float(m["loss"])), (seed, s)
        acc1 = eval_acc(params)
        gains.append(acc1 - acc0)
        # every seed must strictly improve
        assert acc1 > acc0 + 0.05, (seed, acc0, acc1)
    # and the mean gain must be substantial
    assert np.mean(gains) > 0.10, gains


def test_episodic_lm_with_lite(key):
    """The paper's scheme wrapped around an assigned LM architecture."""
    cfg = get_smoke_config("minitron-4b")
    bb = make_lm_backbone(cfg)
    mcfg = MetaLearnerConfig(kind="protonets", way=4)
    lr = make_learner(mcfg, bb, None)
    params = lr.init(key)
    tcfg = EpisodicTokenConfig(way=4, shot=6, query_per_class=4,
                               seq_len=32, vocab=cfg.vocab)
    task = sample_token_task(jax.random.key(3), tcfg)
    for spec in (LiteSpec(exact=True), LiteSpec(h=6), LiteSpec(h=6, chunk_size=5)):
        loss, aux = lr.meta_loss(params, task, key, spec)
        assert jnp.isfinite(loss)
    g = jax.grad(lambda p: lr.meta_loss(p, task, key, LiteSpec(h=6))[0])(params)
    from repro.common.tree import global_norm
    assert float(global_norm(g)) > 0.0
