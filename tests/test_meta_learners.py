"""Meta-learner behaviour: all kinds run, LITE training works, and the
paper's §5.3 claims hold (unbiasedness; LITE-vs-subsampled RMSE ordering
at small |H| on the set-encoder site)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.diagnostics import gradient_experiment
from repro.core.lite import LiteSpec
from repro.core.meta_learners import MetaLearnerConfig, make_learner
from repro.core.set_encoder import SetEncoderConfig
from repro.data.episodic import EpisodicImageConfig, sample_image_task
from repro.models.conv_backbone import ConvBackboneConfig, make_conv_backbone

BB = make_conv_backbone(ConvBackboneConfig(widths=(8, 16), feature_dim=32))
SET_CFG = SetEncoderConfig(kind="conv", conv_blocks=2, conv_width=8, task_dim=16)
TASK_CFG = EpisodicImageConfig(way=5, shot=10, query_per_class=4, image_size=16)
KINDS = ("protonets", "cnaps", "simple_cnaps", "fomaml", "finetuner")


@pytest.fixture(scope="module")
def task():
    return sample_image_task(jax.random.key(5), TASK_CFG)


@pytest.mark.parametrize("kind", KINDS)
def test_meta_loss_and_adapt(kind, task, key):
    cfg = MetaLearnerConfig(kind=kind, way=5, inner_steps=3)
    lr = make_learner(cfg, BB, SET_CFG)
    params = lr.init(key)
    for spec in (LiteSpec(exact=True), LiteSpec(h=8), LiteSpec(h=8, chunk_size=7)):
        loss, aux = lr.meta_loss(params, task, key, spec)
        assert jnp.isfinite(loss), (kind, spec)
        assert 0.0 <= float(aux["accuracy"]) <= 1.0
    state = lr.adapt(params, task.support_x, task.support_y)
    logits = lr.predict(params, state, task.query_x)
    assert logits.shape == (task.query_x.shape[0], 5)
    assert jnp.all(jnp.isfinite(logits))


@pytest.mark.parametrize("kind", ["protonets"])
def test_lite_training_improves(kind, key):
    """LITE meta-training reduces the meta-loss, averaged over seeds.

    Deflaked from a single-seed accuracy threshold: the synthetic tasks are
    separable enough that query ACCURACY starts near its plateau under
    random features, so the robust cross-seed training signal is the LOSS
    trend.  Trains with the task-batched engine (AdamW, 4 tasks/step — the
    production setting) and asserts the seed-mean first-vs-last ordering
    with a margin."""
    from repro.core.episodic_train import make_batched_meta_train_step
    from repro.data.episodic import task_batch_at
    from repro.optim import AdamWConfig, adamw_init

    cfg = MetaLearnerConfig(kind=kind, way=5)
    lr = make_learner(cfg, BB, SET_CFG)
    spec = LiteSpec(h=10)
    adamw = AdamWConfig(weight_decay=0.0)
    step = jax.jit(make_batched_meta_train_step(lr, spec, adamw=adamw,
                                                lr=1e-3))
    first, last = [], []
    for seed in range(3):
        params = lr.init(jax.random.key(seed))
        opt = adamw_init(params, adamw)
        dk, sk = jax.random.key(50 + seed), jax.random.key(150 + seed)
        losses = []
        for s in range(25):
            batch = task_batch_at(dk, TASK_CFG, 4, s)
            params, opt, m = step(params, opt, batch,
                                  jax.random.fold_in(sk, s))
            losses.append(float(m["loss"]))
        first.append(np.mean(losses[:5]))
        last.append(np.mean(losses[-5:]))
    assert np.mean(last) < np.mean(first) - 0.5, (first, last)


def test_lite_unbiased_on_real_learner(task, key):
    """bias MSE must be explained by sampling variance (var/n_draws)."""
    cfg = MetaLearnerConfig(kind="protonets", way=5)
    lr = make_learner(cfg, BB, SET_CFG)
    params = lr.init(key)
    res = gradient_experiment(lr.meta_loss, params, task, h_values=(10,),
                              n_draws=48, key=jax.random.key(3))
    r = res["lite"][10]
    # E[bias_mse] ~ rmse^2 / n_draws for an unbiased estimator
    assert r["bias_mse"] < 5.0 * (r["rmse"] ** 2) / 48 + 1e-8, r


def test_fig4_ordering_small_h(key):
    """Paper Fig. 4: LITE RMSE < subsampled-task RMSE at small |H| on the
    set-encoder first-layer weights (Simple CNAPs).

    Deflaked: averaged over seeds instead of one draw set, at |H| = way
    (the small-H regime where the paper's ordering is decisive — LITE's
    exact forward vs the naive baseline's 1-example-per-class statistics,
    which are noisy to the point of NaN covariances).  A NaN subsampled
    RMSE counts as a LITE win; the ordering must hold on a majority of
    seeds and every LITE RMSE must stay finite."""
    cfg = MetaLearnerConfig(kind="simple_cnaps", way=5, film_init_std=0.1)
    lr = make_learner(cfg, BB, SET_CFG)
    h = 5
    wins, lite_rmses = 0, []
    for seed in range(3):
        task = sample_image_task(jax.random.key(11 + seed), EpisodicImageConfig(
            way=5, shot=10, query_per_class=4, image_size=16))
        params = lr.init(jax.random.key(1 + seed))
        res = gradient_experiment(
            lr.meta_loss, params, task, h_values=(h,), n_draws=6,
            key=jax.random.key(7 + seed), subsampled_estimator=True,
            param_filter=lambda p: p["enc"]["blocks"][0]["w"])
        lite, sub = res["lite"][h]["rmse"], res["subsampled"][h]["rmse"]
        lite_rmses.append(lite)
        if np.isnan(sub) or lite < sub:
            wins += 1
    assert np.all(np.isfinite(lite_rmses)), lite_rmses
    assert wins >= 2, (wins, lite_rmses)


def test_accuracy_flat_in_h(key):
    """Paper Table 2: accuracy consistent across |H| (trained protonets)."""
    cfg = MetaLearnerConfig(kind="protonets", way=5)
    lr = make_learner(cfg, BB, SET_CFG)
    params = lr.init(key)
    spec = LiteSpec(h=10)

    @jax.jit
    def step(p, t, k):
        _, g = jax.value_and_grad(
            lambda pp: lr.meta_loss(pp, t, k, spec)[0])(p)
        return jax.tree.map(lambda a, b: a - 2e-3 * b, p, g)

    k = jax.random.key(2)
    for i in range(25):
        k, kt, kh = jax.random.split(k, 3)
        params = step(params, sample_image_task(kt, TASK_CFG), kh)

    # eval with exact adaptation on fresh tasks — training H shouldn't matter
    def eval_acc(n_tasks=10):
        accs = []
        for i in range(n_tasks):
            t = sample_image_task(jax.random.fold_in(jax.random.key(9), i),
                                  TASK_CFG)
            st = lr.adapt(params, t.support_x, t.support_y)
            pred = jnp.argmax(lr.predict(params, st, t.query_x), -1)
            accs.append(float(jnp.mean((pred == t.query_y).astype(jnp.float32))))
        return np.mean(accs)

    assert eval_acc() > 0.4


def test_algorithm1_query_microbatching(key):
    """Algorithm 1's M_b loop: microbatched query gradients (same H per
    task) must equal the single-pass gradient exactly."""
    from repro.core.episodic_train import make_meta_train_step
    from repro.optim import AdamWConfig, adamw_init
    cfg = MetaLearnerConfig(kind="protonets", way=5)
    lr = make_learner(cfg, BB, SET_CFG)
    params = lr.init(key)
    task = sample_image_task(jax.random.key(4), TASK_CFG)  # 20 query
    spec = LiteSpec(h=10)
    opt = AdamWConfig(weight_decay=0.0)

    s1 = make_meta_train_step(lr, spec, query_batch=0, adamw=opt)
    s2 = make_meta_train_step(lr, spec, query_batch=5, adamw=opt)
    # 20 queries with batch 8 -> padded tail batch, weighted out
    s3 = make_meta_train_step(lr, spec, query_batch=8, adamw=opt)
    k = jax.random.key(9)
    p1, _, m1 = jax.jit(s1)(params, adamw_init(params, opt), task, k)
    for s in (s2, s3):
        p2, _, m2 = jax.jit(s)(params, adamw_init(params, opt), task, k)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-5)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)
