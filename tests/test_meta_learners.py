"""Meta-learner behaviour: all kinds run, LITE training works, and the
paper's §5.3 claims hold (unbiasedness; LITE-vs-subsampled RMSE ordering
at small |H| on the set-encoder site)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.diagnostics import gradient_experiment
from repro.core.lite import LiteSpec
from repro.core.meta_learners import MetaLearnerConfig, make_learner
from repro.core.set_encoder import SetEncoderConfig
from repro.data.episodic import EpisodicImageConfig, sample_image_task
from repro.models.conv_backbone import ConvBackboneConfig, make_conv_backbone

BB = make_conv_backbone(ConvBackboneConfig(widths=(8, 16), feature_dim=32))
SET_CFG = SetEncoderConfig(kind="conv", conv_blocks=2, conv_width=8, task_dim=16)
TASK_CFG = EpisodicImageConfig(way=5, shot=10, query_per_class=4, image_size=16)
KINDS = ("protonets", "cnaps", "simple_cnaps", "fomaml", "finetuner")


@pytest.fixture(scope="module")
def task():
    return sample_image_task(jax.random.key(5), TASK_CFG)


@pytest.mark.parametrize("kind", KINDS)
def test_meta_loss_and_adapt(kind, task, key):
    cfg = MetaLearnerConfig(kind=kind, way=5, inner_steps=3)
    lr = make_learner(cfg, BB, SET_CFG)
    params = lr.init(key)
    for spec in (LiteSpec(exact=True), LiteSpec(h=8), LiteSpec(h=8, chunk_size=7)):
        loss, aux = lr.meta_loss(params, task, key, spec)
        assert jnp.isfinite(loss), (kind, spec)
        assert 0.0 <= float(aux["accuracy"]) <= 1.0
    state = lr.adapt(params, task.support_x, task.support_y)
    logits = lr.predict(params, state, task.query_x)
    assert logits.shape == (task.query_x.shape[0], 5)
    assert jnp.all(jnp.isfinite(logits))


@pytest.mark.parametrize("kind", ["protonets"])
def test_lite_training_improves(kind, key):
    """A few LITE meta-training steps must beat the untrained accuracy.
    (simple_cnaps' frozen-random-backbone variant improves too slowly for
    an in-training check; its held-out-eval improvement is asserted in
    tests/test_system.py::test_simple_cnaps_lite_end_to_end.)"""
    cfg = MetaLearnerConfig(kind=kind, way=5)
    lr = make_learner(cfg, BB, SET_CFG)
    params = lr.init(key)
    spec = LiteSpec(h=10)
    from repro.optim import clip_by_global_norm

    @jax.jit
    def step(p, t, k):
        (l, aux), g = jax.value_and_grad(
            lambda pp: lr.meta_loss(pp, t, k, spec), has_aux=True)(p)
        # the paper notes LITE's noisier gradients want conservative
        # steps; clip + modest lr is the production setting
        g, _ = clip_by_global_norm(g, 10.0)
        p = jax.tree.map(lambda a, b: a - 1e-3 * b, p, g)
        return p, l, aux["accuracy"]

    k = jax.random.key(1)
    accs = []
    for i in range(50):
        k, kt, kh = jax.random.split(k, 3)
        t = sample_image_task(kt, TASK_CFG)
        params, loss, acc = step(params, t, kh)
        accs.append(float(acc))
    assert np.mean(accs[-15:]) > np.mean(accs[:15]) + 0.05, accs


def test_lite_unbiased_on_real_learner(task, key):
    """bias MSE must be explained by sampling variance (var/n_draws)."""
    cfg = MetaLearnerConfig(kind="protonets", way=5)
    lr = make_learner(cfg, BB, SET_CFG)
    params = lr.init(key)
    res = gradient_experiment(lr.meta_loss, params, task, h_values=(10,),
                              n_draws=48, key=jax.random.key(3))
    r = res["lite"][10]
    # E[bias_mse] ~ rmse^2 / n_draws for an unbiased estimator
    assert r["bias_mse"] < 5.0 * (r["rmse"] ** 2) / 48 + 1e-8, r


def test_fig4_ordering_small_h(key):
    """Paper Fig. 4: LITE RMSE < subsampled-task RMSE at small |H| on the
    set-encoder first-layer weights (Simple CNAPs, 10-way 10-shot)."""
    task = sample_image_task(jax.random.key(11), EpisodicImageConfig(
        way=10, shot=10, query_per_class=4, image_size=16))
    cfg = MetaLearnerConfig(kind="simple_cnaps", way=10, film_init_std=0.1)
    lr = make_learner(cfg, BB, SET_CFG)
    params = lr.init(jax.random.key(1))
    res = gradient_experiment(
        lr.meta_loss, params, task, h_values=(10,), n_draws=10,
        key=jax.random.key(7), subsampled_estimator=True,
        param_filter=lambda p: p["enc"]["blocks"][0]["w"])
    assert res["lite"][10]["rmse"] < res["subsampled"][10]["rmse"], res


def test_accuracy_flat_in_h(key):
    """Paper Table 2: accuracy consistent across |H| (trained protonets)."""
    cfg = MetaLearnerConfig(kind="protonets", way=5)
    lr = make_learner(cfg, BB, SET_CFG)
    params = lr.init(key)
    spec = LiteSpec(h=10)

    @jax.jit
    def step(p, t, k):
        _, g = jax.value_and_grad(
            lambda pp: lr.meta_loss(pp, t, k, spec)[0])(p)
        return jax.tree.map(lambda a, b: a - 2e-3 * b, p, g)

    k = jax.random.key(2)
    for i in range(25):
        k, kt, kh = jax.random.split(k, 3)
        params = step(params, sample_image_task(kt, TASK_CFG), kh)

    # eval with exact adaptation on fresh tasks — training H shouldn't matter
    def eval_acc(n_tasks=10):
        accs = []
        for i in range(n_tasks):
            t = sample_image_task(jax.random.fold_in(jax.random.key(9), i),
                                  TASK_CFG)
            st = lr.adapt(params, t.support_x, t.support_y)
            pred = jnp.argmax(lr.predict(params, st, t.query_x), -1)
            accs.append(float(jnp.mean((pred == t.query_y).astype(jnp.float32))))
        return np.mean(accs)

    assert eval_acc() > 0.4


def test_algorithm1_query_microbatching(key):
    """Algorithm 1's M_b loop: microbatched query gradients (same H per
    task) must equal the single-pass gradient exactly."""
    from repro.core.episodic_train import make_meta_train_step
    from repro.optim import AdamWConfig, adamw_init
    cfg = MetaLearnerConfig(kind="protonets", way=5)
    lr = make_learner(cfg, BB, SET_CFG)
    params = lr.init(key)
    task = sample_image_task(jax.random.key(4), TASK_CFG)  # 20 query
    spec = LiteSpec(h=10)
    opt = AdamWConfig(weight_decay=0.0)

    s1 = make_meta_train_step(lr, spec, query_batch=0, adamw=opt)
    s2 = make_meta_train_step(lr, spec, query_batch=5, adamw=opt)
    k = jax.random.key(9)
    p1, _, m1 = jax.jit(s1)(params, adamw_init(params, opt), task, k)
    p2, _, m2 = jax.jit(s2)(params, adamw_init(params, opt), task, k)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)
