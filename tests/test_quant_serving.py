"""Weight-stationary int8 serving path (quant marker, tier 1).

Covers the three tentpole pieces end to end:

  * ``repro.serve.quant_params``: the frozen slice of each learner kind
    quantizes into the blockwise int8 ``{q, scale, n}`` form, dequantizes
    lazily in-jit, and the measured resident frozen-slice bytes shrink
    >=3x at the launcher's backbone widths;
  * the ``int8_matmul`` kernel dispatch site: Pallas (interpret mode on
    CPU) vs the dequantize-then-dot oracle, all backends, under vmap/jit;
  * fp32-vs-int8 SERVING equivalence per kind through the real engine:
    logit tolerance, >=99% argmax agreement (fomaml bit-identical — its
    frozen slice is empty), and compile-counter flatness across the
    quant flag;
  * the durable warm tier: spilled task states survive an engine restart
    (fresh ``WarmTaskStore`` over the same directory) bit-exactly, and
    quarantine drops the sidecar so restart cannot resurrect a corrupt
    entry;
  * the serving layout chooser on 4 emulated devices: the chosen
    weight-stationary placement moves strictly fewer wire bytes per
    compiled predict step than the training placement.
"""
import os
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.episodic_train import task_key
from repro.core.lite import LiteSpec
from repro.core.meta_learners import MetaLearnerConfig, make_learner
from repro.core.set_encoder import SetEncoderConfig
from repro.data.episodic import EpisodicImageConfig, sample_image_task
from repro.kernels import dispatch
from repro.kernels.int8_matmul import int8_matmul as pallas_int8_matmul
from repro.models.conv_backbone import ConvBackboneConfig, make_conv_backbone
from repro.optim.quant import dequantize, quantize
from repro.serve.episodic import (EpisodicRequest, EpisodicServeEngine,
                                  WarmTaskStore)
from repro.serve.quant_params import (FROZEN_SLICES, ServingWeights,
                                      dequantize_params, is_quantized_leaf,
                                      param_bytes, quantize_frozen)

pytestmark = pytest.mark.quant

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

# the launcher's episodic backbone: realistic widths so the per-block
# scale overhead (4 bytes per 128-block) does not mask the int8 win
BB = make_conv_backbone(ConvBackboneConfig(widths=(16, 32), feature_dim=64))
SET_CFG = SetEncoderConfig(kind="conv", conv_blocks=2, conv_width=16,
                           task_dim=32)
WAY = 3
KINDS = ["protonets", "cnaps", "simple_cnaps", "fomaml", "finetuner"]
LITE = LiteSpec(exact=True, chunk_size=8)


def _learner(kind):
    # film_init_std=0.02: near-identity FiLM modulation at init (the
    # CNAPs-paper initialization).  A LARGE random FiLM generator is an
    # amplifier with no trained structure — int8 backbone noise perturbs
    # the task embedding, which perturbs every query feature through a
    # random map — and that worst case is not what serving quantizes.
    return make_learner(MetaLearnerConfig(kind=kind, way=WAY, inner_steps=2,
                                          film_init_std=0.02), BB, SET_CFG)


def _tasks(n, shot=10, q=8, seed=100):
    # class-separable tasks (class_sep/noise flipped from the training
    # defaults): argmax agreement is measured on decisions the fp32 model
    # actually makes, not on coin-flip queries of an unseparable task
    return [sample_image_task(
        jax.random.key(seed + i),
        EpisodicImageConfig(way=WAY, shot=shot, query_per_class=q,
                            image_size=8, class_sep=2.0, noise=0.5))
            for i in range(n)]


def _serve(lr, params, tasks, **engine_kw):
    eng = EpisodicServeEngine(lr, params, lite=LITE, n_slots=2,
                              query_chunk=8, support_buckets=(32,),
                              **engine_kw)
    reqs = [EpisodicRequest(uid=i, support_x=np.asarray(t.support_x),
                            support_y=np.asarray(t.support_y),
                            query_x=np.asarray(t.query_x), way=WAY)
            for i, t in enumerate(tasks)]
    eng.run_to_completion(reqs)
    return np.concatenate([r.all_logits() for r in reqs]), eng


# ---------------------------------------------------------------------------
# quantize_frozen / dequantize_params / param_bytes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", KINDS)
def test_quantize_frozen_slices_per_kind(kind, key):
    """Only the kind's frozen roots quantize; live tensors stay fp32;
    fomaml (empty frozen slice) degrades to mode='none'."""
    lr = _learner(kind)
    params = lr.init(key)
    sw = quantize_frozen(lr, params, "int8")
    roots = FROZEN_SLICES[kind]
    if not roots:                            # fomaml: principled no-op
        assert sw.mode == "none" and sw.tree is params
        assert sw.quant_paths == ()
        return
    assert sw.mode == "int8" and len(sw.quant_paths) > 0
    for p in sw.quant_paths:
        assert p.split("/", 1)[0] in roots
    # the conv backbone's head matmul is a native int8 site
    assert any(p.endswith("head/w") for p in sw.native_paths)
    # every live (non-frozen) float leaf is untouched fp32
    flat, _ = jax.tree_util.tree_flatten_with_path(
        sw.tree, is_leaf=is_quantized_leaf)
    for path, leaf in flat:
        root = str(getattr(path[0], "key", path[0]))
        if root not in roots:
            assert not is_quantized_leaf(leaf)


def test_dequantize_params_error_bounded_and_native_leaves_stay_int8(key):
    lr = _learner("protonets")
    params = lr.init(key)
    sw = quantize_frozen(lr, params, "int8")
    deq = dequantize_params(sw)
    # native-path leaves remain quantized dicts for the kernel site
    for p in sw.native_paths:
        node = deq
        for k in p.split("/"):
            node = node[k]
        assert is_quantized_leaf(node)
    # every dequantized frozen leaf is within its own block scale
    flat_o = dict(jax.tree_util.tree_flatten_with_path(params)[0])
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            deq, is_leaf=is_quantized_leaf)[0]:
        if is_quantized_leaf(leaf) or path not in flat_o:
            continue
        orig = flat_o[path]
        if orig.shape == leaf.shape and np.any(
                np.asarray(orig) != np.asarray(leaf)):
            err = float(jnp.max(jnp.abs(orig - leaf)))
            assert err <= float(jnp.max(jnp.abs(orig))) / 127.0 + 1e-7


def test_mode_none_is_passthrough_and_bad_mode_raises(key):
    lr = _learner("cnaps")
    params = lr.init(key)
    sw = quantize_frozen(lr, params, "none")
    assert sw.tree is params and sw.mode == "none"
    assert dequantize_params(sw) is params
    with pytest.raises(ValueError, match="serve_quant"):
        quantize_frozen(lr, params, "int4")


def test_serving_weights_is_a_pytree_with_static_aux(key):
    """ServingWeights flows through jit; int8-vs-none trees can never
    collide on a compile-cache entry (aux differs)."""
    lr = _learner("protonets")
    params = lr.init(key)
    a = quantize_frozen(lr, params, "int8")
    b = quantize_frozen(lr, params, "none")
    assert (jax.tree_util.tree_structure(a) !=
            jax.tree_util.tree_structure(b))
    out = jax.jit(lambda sw: jax.tree.reduce(
        lambda x, y: x + jnp.sum(jnp.abs(y).astype(jnp.float32)),
        sw, 0.0))(a)
    assert np.isfinite(float(out))


def test_frozen_resident_bytes_shrink_3x(key):
    """Acceptance: >=3x measured reduction of the resident frozen slice
    at the launcher's widths (the per-block scale overhead is real and
    included — this is accounting over the stored arrays)."""
    lr = _learner("protonets")
    params = lr.init(key)
    b_none = param_bytes(quantize_frozen(lr, params, "none"))
    b_int8 = param_bytes(quantize_frozen(lr, params, "int8"))
    assert b_none["frozen_resident_bytes"] == b_none["frozen_fp32_bytes"]
    ratio = (b_none["frozen_resident_bytes"] /
             b_int8["frozen_resident_bytes"])
    assert ratio >= 3.0, ratio
    # live tensors are identical either way
    live_none = b_none["resident_bytes"] - b_none["frozen_resident_bytes"]
    live_int8 = b_int8["resident_bytes"] - b_int8["frozen_resident_bytes"]
    assert live_none == live_int8


# ---------------------------------------------------------------------------
# int8 matmul kernel: pallas (interpret) vs oracle, all backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [(1, 32, 64), (8, 64, 64), (5, 130, 257),
                                   (128, 256, 128)])
def test_int8_matmul_pallas_matches_oracle(m, k, n, key):
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (k, n), jnp.float32)
    qs = quantize(w)
    want = x @ dequantize(qs)
    got = pallas_int8_matmul(x, qs["q"], qs["scale"], interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("backend", ["naive", "ref", "pallas"])
def test_int8_matmul_dispatch_backends_agree(backend, key):
    x = jax.random.normal(key, (6, 96), jnp.float32)
    qs = quantize(jax.random.normal(jax.random.key(2), (96, 40), jnp.float32))
    with dispatch.use_backend("ref"):
        want = dispatch.int8_matmul(x, qs)
    with dispatch.use_backend(backend):
        got = dispatch.int8_matmul(x, qs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
    assert got.shape == (6, 40)


def test_int8_matmul_handles_leading_dims_and_jit(key):
    """The dispatch wrapper flattens (T, B, k) activations — the shape the
    batched predict path feeds — identically under jit and vmap."""
    x = jax.random.normal(key, (3, 4, 64), jnp.float32)
    qs = quantize(jax.random.normal(jax.random.key(3), (64, 16), jnp.float32))
    with dispatch.use_backend("pallas"):
        got = jax.jit(lambda a, b: dispatch.int8_matmul(a, b))(x, qs)
        vm = jax.vmap(lambda a: dispatch.int8_matmul(a, qs))(x)
    with dispatch.use_backend("ref"):
        want = dispatch.int8_matmul(x, qs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(vm), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# fp32-vs-int8 serving equivalence through the engine, per kind
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", KINDS)
def test_engine_int8_matches_fp32_serving(kind, key):
    """Acceptance: per-kind logit closeness and >=99% argmax agreement
    between a fp32 engine and an int8 engine over the same traffic;
    fomaml is bit-identical (empty frozen slice); compile counters are
    IDENTICAL across the quant flag (same dispatch paths, same buckets).
    """
    lr = _learner(kind)
    params = lr.init(key)
    tasks = _tasks(8)
    lf, ef = _serve(lr, params, tasks, serve_quant="none")
    lq, eq = _serve(lr, params, tasks, serve_quant="int8")
    sf, sq = ef.stats(), eq.stats()
    assert (sf["adapt_compiles"], sf["predict_compiles"]) == \
           (sq["adapt_compiles"], sq["predict_compiles"])
    if kind == "fomaml":
        np.testing.assert_array_equal(lf, lq)
        assert sq["param_bytes_resident"] == sf["param_bytes_resident"]
        return
    agree = float((lf.argmax(-1) == lq.argmax(-1)).mean())
    assert agree >= 0.99, (kind, agree)
    # logits move only by the feature perturbation scale, not wildly:
    # normalize per-row (cnaps-family scores are unnormalized distances)
    denom = np.maximum(np.abs(lf).max(-1, keepdims=True), 1.0)
    rel = np.abs(lf - lq) / denom
    assert float(np.median(rel)) < 0.1, (kind, float(np.median(rel)))
    # and the int8 engine actually holds fewer resident weight bytes
    assert (sq["frozen_param_bytes_resident"] * 3 <=
            sf["frozen_param_bytes_resident"])


def test_engine_stats_report_resident_bytes(key):
    lr = _learner("protonets")
    params = lr.init(key)
    _, eng = _serve(lr, params, _tasks(1), serve_quant="int8")
    s = eng.stats()
    assert s["param_bytes_fp32"] > s["param_bytes_resident"]
    assert s["frozen_param_bytes_fp32"] == 28800      # widths (16,32), f64
    assert s["frozen_param_bytes_resident"] * 3 <= s["frozen_param_bytes_fp32"]


# ---------------------------------------------------------------------------
# durable warm tier: restart rehydration
# ---------------------------------------------------------------------------


@pytest.mark.serve
@pytest.mark.parametrize("kind", KINDS)
def test_warm_tier_survives_restart_bitexact(kind, key, tmp_path):
    """A fresh WarmTaskStore over the same directory (engine restart)
    rescans the template sidecars and serves every spilled uid bit-exactly
    — for every learner kind's state pytree."""
    lr = _learner(kind)
    params = lr.init(key)
    t = _tasks(1)[0]
    st = lr.adapt(params, t.support_x, t.support_y, key=task_key(key, 0),
                  lite=LITE)
    store = WarmTaskStore(tmp_path)
    store.put(0, st)
    del store

    fresh = WarmTaskStore(tmp_path)          # the restart
    assert fresh.template_restores == 1
    back = fresh.get(0)
    assert back is not None
    assert jax.tree.structure(back) == jax.tree.structure(st)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.serve
def test_warm_tier_restart_skips_quarantined_and_orphan_entries(key, tmp_path):
    """Quarantine drops the sidecar (restart cannot resurrect a corrupt
    uid); an orphan npz without a sidecar (crash between the two writes)
    is simply not listed; an unreadable sidecar is dropped, not fatal."""
    lr = _learner("protonets")
    params = lr.init(key)
    t = _tasks(1)[0]
    st = lr.adapt(params, t.support_x, t.support_y, key=task_key(key, 0),
                  lite=LITE)
    store = WarmTaskStore(tmp_path)
    for uid in (0, 1, 2):
        store.put(uid, st)
    # corrupt uid 0 and trigger quarantine in the FIRST store
    with open(tmp_path / "uid_0.npz", "r+b") as f:
        f.truncate(10)
    assert store.get(0) is None and store.quarantined == 1
    assert not (tmp_path / "uid_0.tmpl.pkl").exists()
    # orphan: uid 1's sidecar lost (simulates crash between npz + sidecar)
    (tmp_path / "uid_1.tmpl.pkl").unlink()
    # unreadable sidecar for a uid with no payload at all
    (tmp_path / "uid_9.tmpl.pkl").write_bytes(b"not a pickle")

    fresh = WarmTaskStore(tmp_path)
    assert fresh.template_restores == 1      # only uid 2 survives
    assert fresh.get(2) is not None
    assert fresh.get(0) is None and fresh.get(1) is None
    assert not (tmp_path / "uid_9.tmpl.pkl").exists()


# ---------------------------------------------------------------------------
# layout chooser: wire guard on 4 emulated devices
# ---------------------------------------------------------------------------


_LAYOUT_CODE = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.episodic_train import task_key
    from repro.core.lite import LiteSpec
    from repro.core.meta_learners import MetaLearnerConfig, make_learner
    from repro.core.set_encoder import SetEncoderConfig
    from repro.data.episodic import (EpisodicImageConfig, collate_task_batch,
                                     sample_image_task)
    from repro.models.conv_backbone import (ConvBackboneConfig,
                                            make_conv_backbone)
    from repro.roofline.analysis import choose_serving_layout
    from repro.serve.quant_params import dequantize_params, quantize_frozen

    BB = make_conv_backbone(ConvBackboneConfig(widths=(16, 32),
                                               feature_dim=64))
    SET_CFG = SetEncoderConfig(kind="conv", conv_blocks=2, conv_width=16,
                               task_dim=32)
    lr = make_learner(MetaLearnerConfig(kind="protonets", way=3), BB, SET_CFG)
    params = lr.init(jax.random.key(0))
    sw = quantize_frozen(lr, params, "int8")
    mesh = jax.make_mesh((4,), ("serve",))
    ts = [sample_image_task(jax.random.key(100 + i),
          EpisodicImageConfig(way=3, shot=5, query_per_class=4, image_size=8))
          for i in range(2)]
    batch = collate_task_batch(ts, support_size=16, query_size=12)
    keys = jax.vmap(lambda i: task_key(jax.random.key(0), i))(jnp.arange(2))
    lite = LiteSpec(exact=True, chunk_size=8)
    states = lr.adapt_batch(dequantize_params(sw), batch, keys, lite)

    pick = choose_serving_layout(
        lambda w, st, qx: lr.predict_batch(dequantize_params(w), st, qx),
        sw, (states, batch.query_x), mesh)
    rows = pick["rows"]
    ws, tr = rows["weight_stationary"], rows["training"]
    # acceptance guard: weight-stationary moves STRICTLY less wire than
    # the training placement at serving batch sizes
    assert ws["wire_bytes"] < tr["wire_bytes"], (ws, tr)
    assert ws["wire_bytes"] > 0                 # it is not the replicated row
    assert rows["replicated"]["wire_bytes"] == 0
    # for this weights-dominated predict step the chooser picks it too
    assert pick["choice"] == "weight_stationary", pick["choice"]
    print("WIRE", int(tr["wire_bytes"]), int(ws["wire_bytes"]))
""")


@pytest.mark.serve
def test_weight_stationary_moves_less_wire_than_training():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", _LAYOUT_CODE],
                         capture_output=True, text=True, env=env,
                         timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    tr, ws = [int(v) for v in out.stdout.split("WIRE", 1)[1].split()[:2]]
    assert ws < tr
