"""Serving engines.

LM engine: scheduling, cache splicing, greedy-decode correctness, stacked
batched decode, slot reuse, seeded sampling.

Episodic engine: the uniform batched TaskState contract (adapt_batch /
predict_batch) across all learner kinds, bit-exactness of batched vs
per-task serving under padding, the LRU task-state cache, LITE-chunked
forward-only adaptation, compile-counter flatness, and the tier-1 perf
smoke (micro-batched predict beats the per-task query loop).
"""
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_smoke_config
from repro.core.episodic import index_task_state, stack_task_states
from repro.core.episodic_train import task_key
from repro.core.lite import LiteSpec, lite_sum, serve_sum
from repro.core.meta_learners import MetaLearnerConfig, make_learner
from repro.core.set_encoder import SetEncoderConfig
from repro.data.episodic import (EpisodicImageConfig, collate_task_batch,
                                 iter_query_chunks, sample_image_task)
from repro.models.conv_backbone import ConvBackboneConfig, make_conv_backbone
from repro.models.registry import get_api
from repro.serve.engine import Request, ServeEngine
from repro.serve.episodic import (EpisodicRequest, EpisodicServeEngine,
                                  TaskStateCache)

# ---------------------------------------------------------------------------
# LM engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["minitron-4b", "mamba2-780m", "gemma2-2b"])
def test_engine_completes_requests(arch, key):
    cfg = get_smoke_config(arch)
    api = get_api(cfg)
    params = api.init(key, cfg)
    eng = ServeEngine(cfg, params, n_slots=2, max_seq=64)
    reqs = [Request(uid=i, prompt=np.arange(4, dtype=np.int32) + i,
                    max_new_tokens=5) for i in range(5)]
    out = eng.run_to_completion(reqs)
    assert all(r.done for r in out)
    assert all(len(r.out_tokens) == 5 for r in out)


def test_engine_greedy_matches_full_forward(key):
    """Engine's greedy continuation == argmax over a full re-forward of
    (prompt + generated) at each step — KV-cache correctness end to end."""
    cfg = get_smoke_config("minitron-4b")
    api = get_api(cfg)
    params = api.init(key, cfg)
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)
    eng = ServeEngine(cfg, params, n_slots=1, max_seq=32)
    req = Request(uid=0, prompt=prompt, max_new_tokens=4)
    eng.run_to_completion([req])

    seq = list(prompt)
    want = []
    for _ in range(4):
        logits, _ = api.prefill(params, dict(tokens=jnp.asarray([seq])), cfg)
        nxt = int(jnp.argmax(logits[0]))
        want.append(nxt)
        seq.append(nxt)
    assert req.out_tokens == want, (req.out_tokens, want)


def test_engine_mla_cache_splice(key):
    """MLA latent-cache (ckv/krope) splice path through the engine."""
    import dataclasses
    cfg = get_smoke_config("deepseek-v2-236b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    api = get_api(cfg)
    params = api.init(key, cfg)
    eng = ServeEngine(cfg, params, n_slots=2, max_seq=48)
    reqs = [Request(uid=i, prompt=np.arange(4, dtype=np.int32) + i,
                    max_new_tokens=4) for i in range(3)]
    out = eng.run_to_completion(reqs)
    assert all(r.done and len(r.out_tokens) == 4 for r in out)


def test_prefill_splice_vs_token_by_token_decode(key):
    """The engine's prefill-then-splice continuation must equal an
    uninterrupted decode that fed the prompt token-by-token from an empty
    cache — KV equivalence of the two cache construction paths."""
    cfg = get_smoke_config("minitron-4b")
    api = get_api(cfg)
    params = api.init(key, cfg)
    prompt = np.asarray([7, 2, 9, 4], np.int32)

    eng = ServeEngine(cfg, params, n_slots=1, max_seq=32)
    req = Request(uid=0, prompt=prompt, max_new_tokens=4)
    eng.run_to_completion([req])

    decode = jax.jit(lambda p, c, t: api.decode_step(p, c, t, cfg))
    cache = api.init_cache(cfg, 1, 32)
    logits = None
    for t in prompt:
        logits, cache = decode(params, cache,
                               jnp.asarray([[int(t)]], jnp.int32))
    want = []
    for _ in range(4):
        nxt = int(jnp.argmax(logits[0]))
        want.append(nxt)
        logits, cache = decode(params, cache,
                               jnp.asarray([[nxt]], jnp.int32))
    assert req.out_tokens == want, (req.out_tokens, want)


def test_slot_reuse_after_eos(key):
    """A slot freed by EOS must accept the next pending request, and the
    late joiner's continuation must match a solo run (the splice resets
    the slot's cache region)."""
    cfg = get_smoke_config("minitron-4b")
    api = get_api(cfg)
    params = api.init(key, cfg)
    p0 = np.asarray([3, 1, 4, 1, 5], np.int32)
    p1 = np.asarray([2, 7, 1, 8, 2], np.int32)

    # learn what request 0 greedily emits, then replay with its second
    # token as EOS so the slot frees mid-stream
    probe = Request(uid=0, prompt=p0, max_new_tokens=4)
    ServeEngine(cfg, params, n_slots=1, max_seq=32).run_to_completion([probe])
    eos = probe.out_tokens[1]

    solo = Request(uid=1, prompt=p1, max_new_tokens=4)
    ServeEngine(cfg, params, n_slots=1, max_seq=32).run_to_completion([solo])

    eng = ServeEngine(cfg, params, n_slots=1, max_seq=32, eos_id=eos)
    first = Request(uid=0, prompt=p0, max_new_tokens=4)
    second = Request(uid=1, prompt=p1, max_new_tokens=4)
    eng.run_to_completion([first, second])
    assert first.done and first.out_tokens[-1] == eos
    assert len(first.out_tokens) <= 2
    assert second.done
    # the reused slot serves the second request exactly as a fresh engine
    # would (EOS may truncate it too if it greedily emits the same token)
    want = solo.out_tokens
    if eos in want:
        want = want[: want.index(eos) + 1]
    assert second.out_tokens == want, (second.out_tokens, want)


def test_prefill_token_respects_budget_and_eos(key):
    """The prefill-sampled first token counts against max_new_tokens and
    is checked for EOS: max_new_tokens=1 emits exactly one token and a
    prefill-emitted EOS retires the request before any decode step."""
    cfg = get_smoke_config("minitron-4b")
    api = get_api(cfg)
    params = api.init(key, cfg)
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)

    eng = ServeEngine(cfg, params, n_slots=1, max_seq=32)
    one = Request(uid=0, prompt=prompt, max_new_tokens=1)
    eng.run_to_completion([one])
    assert one.done and len(one.out_tokens) == 1

    eng2 = ServeEngine(cfg, params, n_slots=1, max_seq=32,
                       eos_id=one.out_tokens[0])
    req = Request(uid=1, prompt=prompt, max_new_tokens=8)
    eng2.run_to_completion([req])
    assert req.done and req.out_tokens == one.out_tokens


def test_temperature_sampling_seeded_determinism(key):
    """temperature>0 sampling is a pure function of the engine seed: same
    seed => identical streams, different seed => different draws."""
    cfg = get_smoke_config("minitron-4b")
    api = get_api(cfg)
    params = api.init(key, cfg)

    def run(seed):
        eng = ServeEngine(cfg, params, n_slots=2, max_seq=32, seed=seed)
        reqs = [Request(uid=i, prompt=np.arange(4, dtype=np.int32) + i,
                        max_new_tokens=6, temperature=0.8) for i in range(3)]
        eng.run_to_completion(reqs)
        return [r.out_tokens for r in reqs]

    a, b, c = run(5), run(5), run(6)
    assert a == b
    assert a != c


def test_batched_decode_matches_per_slot_fallback(key):
    """A cohort of equal-length prompts decodes through the stacked path;
    the result must match the engine with batching disabled token for
    token (the stacked dispatch is a pure batching of the same programs)."""
    cfg = get_smoke_config("minitron-4b")
    api = get_api(cfg)
    params = api.init(key, cfg)
    prompts = [np.arange(5, dtype=np.int32) + 3 * i for i in range(2)]

    def run(batched):
        eng = ServeEngine(cfg, params, n_slots=2, max_seq=32,
                          batched_decode=batched)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=5)
                for i, p in enumerate(prompts)]
        eng.run_to_completion(reqs)
        return [r.out_tokens for r in reqs]

    assert run(True) == run(False)


def test_stack_caches_refuses_ragged_positions(key):
    """Slots at different decode positions cannot share one stacked decode
    (``len`` is a scalar shared across the batch) — the engine must fall
    back rather than mis-position a slot."""
    cfg = get_smoke_config("minitron-4b")
    api = get_api(cfg)
    params = api.init(key, cfg)
    eng = ServeEngine(cfg, params, n_slots=2, max_seq=32)
    assert eng.add_request(Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                                   max_new_tokens=8))
    assert eng.add_request(Request(uid=1, prompt=np.arange(6, dtype=np.int32),
                                   max_new_tokens=8))
    caches = [c for c, r in zip(eng._caches, eng._reqs) if r is not None]
    assert eng._stack_caches(caches) is None
    # ...and the engine still completes both through the fallback
    eng.run_to_completion([])
    assert eng.step() == 0


# ---------------------------------------------------------------------------
# episodic engine: the batched TaskState contract
# ---------------------------------------------------------------------------

BB = make_conv_backbone(ConvBackboneConfig(widths=(4,), feature_dim=8))
SET_CFG = SetEncoderConfig(kind="conv", conv_blocks=1, conv_width=4,
                           task_dim=8)
WAY = 3
KINDS = ["protonets", "cnaps", "simple_cnaps", "fomaml", "finetuner"]
SERVE_LITE = LiteSpec(exact=True, chunk_size=8)


def _learner(kind):
    return make_learner(MetaLearnerConfig(kind=kind, way=WAY,
                                          inner_steps=2), BB, SET_CFG)


def _tasks(n, shot=3, image_size=8, q=2, seed=100):
    return [sample_image_task(
        jax.random.key(seed + i),
        EpisodicImageConfig(way=WAY, shot=shot, query_per_class=q,
                            image_size=image_size)) for i in range(n)]


def _max_leaf_diff(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _requests(tasks, uids=None):
    return [EpisodicRequest(uid=i if uids is None else uids[j],
                            support_x=np.asarray(t.support_x),
                            support_y=np.asarray(t.support_y),
                            query_x=np.asarray(t.query_x), way=WAY)
            for j, (i, t) in enumerate(enumerate(tasks))]


# adapt_batch states that are bit-identical to the single-task adapt call;
# fomaml's inner gradient loop and Simple CNAPs' cholesky/solve chain pick
# up f32 reduction-order noise across batch widths (same concession as
# test_padding_invariance_simple_cnaps_loss) — the *engine-level* bit-exact
# guarantee for every kind is test_engine_coscheduling_is_bitexact, where
# dispatch shapes are pinned to n_slots lanes.
STATE_TOL = {"protonets": 0.0, "cnaps": 0.0, "finetuner": 0.0,
             "fomaml": 1e-6, "simple_cnaps": 1e-4}


@pytest.mark.parametrize("kind", KINDS)
def test_adapt_batch_matches_per_task_adapt(kind, key):
    """The uniform contract: vmapped adapt_batch over a PADDED TaskBatch
    reproduces the single-task ``adapt`` on each padded member (state
    bit-exact for the aggregation learners), and predict_batch matches
    per-task predict to XLA batch-width tolerance."""
    lr = _learner(kind)
    params = lr.init(key)
    tasks = _tasks(3, shot=2) + _tasks(1, shot=4, seed=200)
    batch = collate_task_batch(tasks, support_size=16, query_size=8)
    keys = jax.vmap(lambda i: task_key(key, i))(jnp.arange(4))
    states = jax.jit(lambda p, b, k: lr.adapt_batch(p, b, k, SERVE_LITE))(
        params, batch, keys)
    logits = jax.jit(lr.predict_batch)(params, states, batch.query_x)
    assert logits.shape == (4, 8, WAY)
    for i in range(4):
        st = lr.adapt(params, batch.support_x[i], batch.support_y[i],
                      key=keys[i], lite=SERVE_LITE,
                      mask=batch.support_mask[i])
        st_b = index_task_state(states, i)
        assert _max_leaf_diff(st, st_b) <= STATE_TOL[kind]
        lg = lr.predict(params, st, batch.query_x[i])
        np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[i]),
                                   rtol=5e-3, atol=5e-4)


@pytest.mark.parametrize("kind", ["protonets", "simple_cnaps", "fomaml"])
def test_padding_never_changes_adapted_state(kind, key):
    """Same tasks collated to two different pad targets: identical
    states (the mask-aware estimators make padding invisible)."""
    lr = _learner(kind)
    params = lr.init(key)
    tasks = _tasks(2)
    keys = jax.vmap(lambda i: task_key(key, i))(jnp.arange(2))
    s1 = lr.adapt_batch(params, collate_task_batch(tasks, support_size=12,
                                                   query_size=6),
                        keys, SERVE_LITE)
    s2 = lr.adapt_batch(params, collate_task_batch(tasks, support_size=24,
                                                   query_size=6),
                        keys, SERVE_LITE)
    # fomaml's inner gradient loop picks up f32 reduction-order noise
    # across pad widths (same concession as STATE_TOL above)
    tol = {"protonets": 0.0, "fomaml": 1e-6, "simple_cnaps": 1e-4}[kind]
    assert _max_leaf_diff(s1, s2) <= tol


def test_serve_sum_matches_exact_lite_sum(key):
    """serve_sum == exact lite_sum forward bit-for-bit when unchunked;
    chunking only reassociates the accumulation (float tolerance); the
    low-precision complement stays within bf16 rounding of fp32."""
    p = dict(w=jax.random.normal(key, (12, 6)), b=jnp.zeros((6,)))
    xs = jax.random.normal(jax.random.key(1), (20, 12))
    k = jax.random.key(2)
    mask = (jnp.arange(20) < 17).astype(jnp.float32)

    exact = lite_sum(_mlp_encode, p, xs, k, LiteSpec(exact=True), mask=mask)
    unchunked = serve_sum(_mlp_encode, p, xs, k, LiteSpec(exact=True),
                          mask=mask)
    assert _max_leaf_diff(exact, unchunked) == 0.0

    chunked = serve_sum(_mlp_encode, p, xs, k,
                        LiteSpec(exact=True, chunk_size=4), mask=mask)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(exact),
                               rtol=1e-6, atol=1e-6)

    bf16 = serve_sum(_mlp_encode, p, xs, k,
                     LiteSpec(exact=True, chunk_size=4,
                              compute_dtype="bfloat16"), mask=mask)
    assert bf16.dtype == jnp.float32            # fp32 accumulation
    np.testing.assert_allclose(np.asarray(bf16), np.asarray(exact),
                               rtol=2e-2, atol=2e-2)


def _mlp_encode(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])


@pytest.mark.parametrize("kind", ["protonets", "cnaps"])
def test_lite_chunked_serve_adapt_matches_unchunked(kind, key):
    """Serve-time chunked adaptation (the 1000-image-support path) matches
    the single-chunk exact adapt to float accumulation tolerance."""
    lr = _learner(kind)
    params = lr.init(key)
    t = _tasks(1, shot=6)[0]
    st_1 = lr.adapt(params, t.support_x, t.support_y, key=key,
                    lite=LiteSpec(exact=True))
    st_c = lr.adapt(params, t.support_x, t.support_y, key=key,
                    lite=LiteSpec(exact=True, chunk_size=4))
    for a, b in zip(jax.tree.leaves(st_1), jax.tree.leaves(st_c)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_iter_query_chunks_pads_and_masks():
    chunks = list(iter_query_chunks(np.arange(10, dtype=np.float32)
                                    .reshape(5, 2), 2))
    assert len(chunks) == 3
    last_x, last_m, n = chunks[-1]
    assert last_x.shape == (2, 2) and n == 1
    np.testing.assert_array_equal(last_m, [1.0, 0.0])
    np.testing.assert_array_equal(last_x[1], 0.0)
    assert list(iter_query_chunks(np.zeros((0, 2)), 4)) == []
    with pytest.raises(ValueError, match="chunk"):
        list(iter_query_chunks(np.zeros((3, 2)), 0))


def test_task_state_cache_lru_eviction():
    c = TaskStateCache(capacity=2)
    c.put(1, "a"), c.put(2, "b")
    assert c.get(1) == "a"          # 1 becomes most-recent
    c.put(3, "c")                    # evicts 2
    assert 2 not in c and 1 in c and 3 in c
    assert c.get(2) is None
    assert (c.hits, c.misses) == (1, 1)


@pytest.mark.parametrize("kind", KINDS)
def test_engine_serves_all_learner_kinds(kind, key):
    """Acceptance: all four learner kinds (plus the transfer baseline)
    serve through the same adapt_batch/predict_batch contract."""
    lr = _learner(kind)
    params = lr.init(key)
    eng = EpisodicServeEngine(lr, params, lite=SERVE_LITE, n_slots=2,
                              query_chunk=4, support_buckets=(16,))
    reqs = _requests(_tasks(3))
    eng.run_to_completion(reqs)
    assert all(r.done for r in reqs)
    assert all(r.all_logits().shape == (6, WAY) for r in reqs)
    s = eng.stats()
    assert s["tasks_adapted"] == 3 and s["queries_served"] == 18


def test_engine_state_cache_hit_skips_adaptation(key):
    lr = _learner("protonets")
    params = lr.init(key)
    eng = EpisodicServeEngine(lr, params, lite=SERVE_LITE, n_slots=2,
                              query_chunk=4, support_buckets=(16,))
    first = _requests(_tasks(2))
    eng.run_to_completion(first)
    assert eng.stats()["tasks_adapted"] == 2

    # repeat visitor: same uid, NO support set — served from the cache,
    # bit-identical logits, no new adaptation
    rep = EpisodicRequest(uid=0, query_x=np.asarray(first[0].query_x),
                          way=WAY)
    eng.run_to_completion([rep])
    assert rep.done and rep.cache_hit
    assert eng.stats()["tasks_adapted"] == 2
    np.testing.assert_array_equal(rep.all_logits(), first[0].all_logits())

    # unknown uid without support is an explicit error, not a hang
    with pytest.raises(ValueError, match="no cached task state"):
        eng.add_request(EpisodicRequest(uid=99, query_x=np.zeros((2, 8, 8, 3)),
                                        way=WAY))


def test_engine_defers_supportless_repeat_in_same_wave(key):
    """A support-less repeat co-scheduled with its user's FIRST visit must
    be deferred until the state lands — not rejected — so a single
    run_to_completion batch may mix first visits and repeats freely."""
    lr = _learner("protonets")
    params = lr.init(key)
    eng = EpisodicServeEngine(lr, params, lite=SERVE_LITE, n_slots=4,
                              query_chunk=4, support_buckets=(16,))
    first = _requests(_tasks(1))[0]
    repeat = EpisodicRequest(uid=first.uid,
                             query_x=np.asarray(first.query_x), way=WAY)
    eng.run_to_completion([first, repeat])
    assert first.done and repeat.done
    assert repeat.cache_hit
    assert eng.stats()["tasks_adapted"] == 1
    np.testing.assert_array_equal(repeat.all_logits(), first.all_logits())


def test_engine_coscheduling_is_bitexact(key):
    """Serving a task alone vs co-scheduled with strangers must give
    bit-identical logits: every dispatch is padded to the same n_slots
    lanes, and a task's support pad cap comes from its OWN size (one
    adapt dispatch per bucket group), so only lane occupancy differs.
    The tasks here are ragged across TWO planned buckets — the case where
    a shared pad cap would leak co-tenant sizes into fomaml/simple_cnaps
    states."""
    for kind in ("protonets", "simple_cnaps", "fomaml"):
        lr = _learner(kind)
        params = lr.init(key)
        tasks = [_tasks(1, shot=s, seed=400 + 7 * s)[0] for s in (2, 3, 5)]

        eng = EpisodicServeEngine(lr, params, lite=SERVE_LITE, n_slots=3,
                                  query_chunk=4, support_buckets=(8, 16))
        together = _requests(tasks)
        eng.run_to_completion(together)

        for i, t in enumerate(tasks):
            solo_eng = EpisodicServeEngine(lr, params, lite=SERVE_LITE,
                                           n_slots=3, query_chunk=4,
                                           support_buckets=(8, 16))
            solo = _requests([t], uids=[i])
            solo_eng.run_to_completion(solo)
            np.testing.assert_array_equal(solo[0].all_logits(),
                                          together[i].all_logits(),
                                          err_msg=f"{kind} task {i}")


def test_engine_compile_counter_flat_on_ragged_stream(key):
    """A ragged support-size stream against planned buckets: after every
    bucket is warm the compile counters must not move (acceptance: flat
    compile counter, bucketed shapes only)."""
    lr = _learner("protonets")
    params = lr.init(key)
    eng = EpisodicServeEngine(lr, params, lite=SERVE_LITE, n_slots=2,
                              query_chunk=4, support_buckets=(8, 16))
    shots = [2, 4, 3, 5, 2, 4, 5, 3]
    counts = []
    for i, shot in enumerate(shots):
        reqs = _requests(_tasks(1, shot=shot, seed=300 + 10 * i),
                         uids=[1000 + i])
        eng.run_to_completion(reqs)
        s = eng.stats()
        counts.append((s["adapt_compiles"], s["predict_compiles"]))
    # two support buckets, one fixed (n_slots, chunk) predict shape
    assert counts[-1][0] <= 2 and counts[-1][1] == 1
    assert counts[3:] == [counts[3]] * (len(counts) - 3), counts


def test_engine_ragged_query_streams(key):
    """Query counts that don't divide the chunk, including an empty
    stream, all complete with correctly shaped logits."""
    lr = _learner("protonets")
    params = lr.init(key)
    eng = EpisodicServeEngine(lr, params, lite=SERVE_LITE, n_slots=2,
                              query_chunk=4, support_buckets=(16,))
    base = _tasks(3)
    reqs = _requests(base)
    for r, m in zip(reqs, (1, 6, 0)):
        r.query_x = np.asarray(r.query_x)[:m]
    eng.run_to_completion(reqs)
    assert all(r.done for r in reqs)
    assert [r.all_logits().shape[0] for r in reqs] == [1, 6, 0]
    assert eng.stats()["queries_served"] == 7


# ---------------------------------------------------------------------------
# tier-1 perf smoke
# ---------------------------------------------------------------------------


def test_perf_smoke_batched_predict_beats_per_task_loop(key):
    """One micro-batched predict_batch dispatch must beat T per-task
    predict dispatches on the same workload (the dispatch amortization the
    serving engine is built on).  Up to 3 attempts guard against scheduler
    noise on the shared 2-core CPU."""
    lr = _learner("protonets")
    params = lr.init(key)
    t_count = 8
    tasks = _tasks(t_count, shot=2, q=4)
    batch = collate_task_batch(tasks)
    keys = jax.vmap(lambda i: task_key(key, i))(jnp.arange(t_count))
    states = lr.adapt_batch(params, batch, keys, SERVE_LITE)
    per_states = [index_task_state(states, i) for i in range(t_count)]
    stacked = stack_task_states(per_states)

    pred_one = jax.jit(lr.predict)
    pred_b = jax.jit(lr.predict_batch)

    def run_loop():
        jax.block_until_ready([pred_one(params, per_states[i],
                                        batch.query_x[i])
                               for i in range(t_count)])

    def run_batched():
        jax.block_until_ready(pred_b(params, stacked, batch.query_x))

    run_loop(), run_batched()                    # compile both
    ratios = []
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(5):
            run_loop()
        t_loop = time.perf_counter() - t0
        t0 = time.perf_counter()
        for _ in range(5):
            run_batched()
        t_batch = time.perf_counter() - t0
        ratios.append(t_loop / t_batch)
        if ratios[-1] > 1.0:
            break
    assert max(ratios) > 1.0, \
        f"batched predict never beat the per-task loop: {ratios}"


# ---------------------------------------------------------------------------
# production serving: deterministic harness, SLO scheduling, two-tier store
# ---------------------------------------------------------------------------

from conftest import FakeClock, scripted_stream  # noqa: E402
from repro.serve.episodic import (TwoTierTaskStore, WarmTaskStore,  # noqa: E402
                                  stable_uid_hash)


@pytest.mark.serve
def test_task_state_cache_overwrite_and_eviction_stats():
    """The stats contract: hits/misses count ``get`` only; ``put`` on an
    existing uid is an overwrite (recency refresh, ``overwrites`` bumped,
    hits/misses untouched); capacity evictions bump ``evictions`` and
    hand (uid, state) to ``on_evict``."""
    spilled = []
    c = TaskStateCache(capacity=2, on_evict=lambda u, s: spilled.append((u, s)))
    c.put(1, "a")
    c.put(1, "a2")                       # overwrite: not a hit, not a miss
    assert (c.hits, c.misses, c.overwrites, c.evictions) == (0, 0, 1, 0)
    assert len(c) == 1 and c.get(1) == "a2"
    c.put(2, "b")
    c.put(1, "a3")                       # overwrite refreshes recency too
    c.put(3, "c")                        # evicts 2 (LRU), not 1
    assert (c.hits, c.misses, c.overwrites, c.evictions) == (1, 0, 2, 1)
    assert spilled == [(2, "b")]
    assert 2 not in c and 1 in c and 3 in c
    assert c.get(2) is None
    assert (c.hits, c.misses) == (1, 1)


@pytest.mark.serve
def test_warm_store_rescan_on_miss_cross_store(tmp_path):
    """Cross-process safety (the multi-replica contract): a uid spilled by
    store A AFTER store B's startup scan is still found by B — ``get``
    rescans the uid's sidecar path before giving up instead of trusting
    the construction-time index.  This is the post-failover rehydration
    path; without it, replica B could only see spills that predate its
    own start."""
    state = {"w": np.arange(6, dtype=np.float32)}
    b = WarmTaskStore(tmp_path, shards=4)           # scans an empty dir
    a = WarmTaskStore(tmp_path, shards=4)
    a.put(7, state)                                 # after b's scan
    assert 7 in b                                   # rescan via __contains__
    got = b.get(7)
    assert got is not None
    np.testing.assert_array_equal(got["w"], state["w"])
    assert b.rescan_hits == 1
    assert b.get(999) is None                       # a true miss stays a miss
    # corruption found through B quarantines the entry AND its sidecar,
    # so no store — current or future — can resurrect it
    (a._path(7)).write_bytes(b"junk")
    assert b.get(7) is None and b.quarantined == 1
    b2 = WarmTaskStore(tmp_path, shards=4)
    assert b2.get(7) is None and b2.quarantined == 0  # sidecar already gone


@pytest.mark.serve
def test_warm_store_sharded_layout_fixed_by_uid_hash(tmp_path):
    """With ``shards=N`` every uid's files live in the pure-function
    subdir ``shard_{stable_uid_hash(uid) % N}`` (no files at the root),
    independent stores agree on the location, and entries written under a
    DIFFERENT shard count remain loadable (the rescan walks every shard
    subdir) and migrate to the canonical shard on the next put."""
    state = {"w": np.ones((3,), np.float32)}
    s = WarmTaskStore(tmp_path, shards=8)
    for uid in range(12):
        s.put(uid, state)
    assert not list(tmp_path.glob("uid_*"))         # nothing at the root
    for uid in range(12):
        shard = tmp_path / f"shard_{stable_uid_hash(uid) % 8}"
        assert (shard / f"uid_{uid}.npz").exists()
        assert WarmTaskStore(tmp_path, shards=8).get(uid) is not None

    # written under shards=1 (files at the root), read under shards=8
    flat_dir = tmp_path / "flat"
    WarmTaskStore(flat_dir, shards=1).put(3, state)
    resharded = WarmTaskStore(flat_dir, shards=8)
    assert resharded.get(3) is not None             # found despite new layout
    resharded.put(3, state)                         # migrates to canonical
    assert not (flat_dir / "uid_3.npz").exists()
    canon = flat_dir / f"shard_{stable_uid_hash(3) % 8}"
    assert (canon / "uid_3.npz").exists()
    assert WarmTaskStore(flat_dir, shards=8).get(3) is not None


@pytest.mark.serve
@pytest.mark.parametrize("kind", KINDS)
def test_spill_rehydrate_roundtrip_bitexact(kind, key, tmp_path):
    """adapted state -> evict -> spill -> rehydrate is BIT-exact for every
    learner kind (the per-kind parity table): the warm tier writes through
    the checkpoint serialization, so fp arrays roundtrip verbatim."""
    lr = _learner(kind)
    params = lr.init(key)
    t0, t1 = _tasks(2, shot=3)
    st0 = lr.adapt(params, t0.support_x, t0.support_y, key=task_key(key, 0),
                   lite=SERVE_LITE)
    st1 = lr.adapt(params, t1.support_x, t1.support_y, key=task_key(key, 1),
                   lite=SERVE_LITE)
    store = TwoTierTaskStore(capacity=1, warm_dir=tmp_path)
    store.put(0, st0)
    store.put(1, st1)                    # capacity 1: spills uid 0 to disk
    assert store.spills == 1 and len(store.l1) == 1
    back = store.get(0)                  # L1 miss -> warm-tier rehydrate
    assert store.rehydrates == 1
    assert jax.tree.structure(back) == jax.tree.structure(st0)
    for a, b in zip(jax.tree.leaves(st0), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
    assert _max_leaf_diff(st0, back) == 0.0, kind
    # promotion cascaded uid 1 out of the capacity-1 L1 — spilled, not lost
    assert store.spills == 2
    assert _max_leaf_diff(st1, store.get(1)) == 0.0, kind


@pytest.mark.serve
@pytest.mark.parametrize("kind", KINDS)
def test_capacity1_thrash_rehydrates_bitexact(kind, key, tmp_path):
    """Cache-capacity-1 thrash with repeat uids: repeats are served by
    warm-tier rehydration (never re-adapted) and their logits are
    bit-exact to solo serving — the acceptance criterion, per kind."""
    lr = _learner(kind)
    params = lr.init(key)
    tasks = _tasks(2, shot=3)

    def engine():
        return EpisodicServeEngine(lr, params, lite=SERVE_LITE, n_slots=1,
                                   query_chunk=4, support_buckets=(16,),
                                   cache_capacity=1, warm_dir=tmp_path / kind)

    solo = [None, None]
    for u in (0, 1):
        e = EpisodicServeEngine(lr, params, lite=SERVE_LITE, n_slots=1,
                                query_chunk=4, support_buckets=(16,),
                                cache_capacity=1)
        solo[u] = _requests([tasks[u]], uids=[u])[0]
        e.run_to_completion([solo[u]])

    eng = engine()
    cold = _requests(tasks)
    eng.run_to_completion(cold)          # serving uid 1 spills uid 0
    s = eng.stats()
    assert s["tasks_adapted"] == 2 and s["spills"] >= 1

    warm_compiles = (s["adapt_compiles"], s["predict_compiles"])
    repeats = [EpisodicRequest(uid=u, query_x=np.asarray(tasks[u].query_x),
                               way=WAY) for u in (0, 1, 0)]
    eng.run_to_completion(repeats)
    s = eng.stats()
    assert s["tasks_adapted"] == 2       # NEVER re-adapted
    assert s["rehydrates"] >= 2          # thrash served from the warm tier
    # rehydrated avals are identical -> the compiled dispatches are reused
    assert (s["adapt_compiles"], s["predict_compiles"]) == warm_compiles
    assert all(r.done and r.cache_hit for r in repeats)
    for r in repeats:
        np.testing.assert_array_equal(r.all_logits(),
                                      solo[r.uid].all_logits(),
                                      err_msg=f"{kind} uid={r.uid}")


@pytest.mark.serve
def test_rehydrate_keeps_compile_counters_flat(key, tmp_path):
    """A rehydrated state has identical avals to the originally adapted
    one, so the compiled predict dispatch is REUSED — no reshape from the
    warm tier (compile counters flat across the whole thrash)."""
    lr = _learner("protonets")
    params = lr.init(key)
    eng = EpisodicServeEngine(lr, params, lite=SERVE_LITE, n_slots=1,
                              query_chunk=4, support_buckets=(16,),
                              cache_capacity=1, warm_dir=tmp_path)
    tasks = _tasks(3, shot=3)
    eng.run_to_completion(_requests(tasks))
    warm_counts = (eng.stats()["adapt_compiles"],
                   eng.stats()["predict_compiles"])
    repeats = [EpisodicRequest(uid=u, query_x=np.asarray(tasks[u].query_x),
                               way=WAY) for u in (0, 1, 2, 0)]
    eng.run_to_completion(repeats)
    s = eng.stats()
    assert s["rehydrates"] >= 3
    assert (s["adapt_compiles"], s["predict_compiles"]) == warm_counts
    assert all(r.done for r in repeats)


@pytest.mark.serve
def test_same_uid_same_wave_never_double_adapts(key):
    """Two same-uid requests (both carrying supports) offered in one wave:
    the second defers until the first's state lands, then shares it —
    tasks_adapted stays 1 and both streams get identical logits."""
    lr = _learner("protonets")
    params = lr.init(key)
    eng = EpisodicServeEngine(lr, params, lite=SERVE_LITE, n_slots=4,
                              query_chunk=4, support_buckets=(16,))
    t = _tasks(1)[0]
    a, b = _requests([t, t], uids=[7, 7])
    eng.run_to_completion([a, b])
    assert a.done and b.done
    assert eng.stats()["tasks_adapted"] == 1
    assert b.cache_hit
    np.testing.assert_array_equal(a.all_logits(), b.all_logits())


@pytest.mark.serve
def test_oversized_support_is_actionable_admission_error(key):
    """A support set exceeding every planned bucket is rejected AT
    ADMISSION, naming the uid and the caps (stale-histogram contract) —
    not at dispatch time, and never a silent new compiled shape."""
    lr = _learner("protonets")
    params = lr.init(key)
    eng = EpisodicServeEngine(lr, params, lite=SERVE_LITE, n_slots=2,
                              query_chunk=4, support_buckets=(8, 16))
    big = _requests(_tasks(1, shot=6))[0]          # 3-way x 6 = 18 > 16
    with pytest.raises(ValueError, match=r"uid=0.*exceeds every planned "
                                         r"bucket.*re-plan"):
        eng.add_request(big)
    # the queued path surfaces the same error from step()
    eng.submit(_requests(_tasks(1, shot=6), uids=[3])[0])
    with pytest.raises(ValueError, match="uid=3"):
        eng.step()


@pytest.mark.serve
def test_empty_query_stream_completes_without_predict_dispatch(key):
    """An empty query_x stream: the request adapts (its state is cached
    for later visits), completes, and the engine never compiles or
    dispatches predict_batch at all."""
    lr = _learner("protonets")
    params = lr.init(key)
    eng = EpisodicServeEngine(lr, params, lite=SERVE_LITE, n_slots=2,
                              query_chunk=4, support_buckets=(16,))
    r = _requests(_tasks(1))[0]
    r.query_x = np.asarray(r.query_x)[:0]
    eng.run_to_completion([r])
    assert r.done and r.all_logits().shape == (0, WAY)
    s = eng.stats()
    assert s["tasks_adapted"] == 1 and s["queries_served"] == 0
    assert s["predict_compiles"] == 0
    assert r.t_done is not None and r.t_first_logit is None


@pytest.mark.serve
def test_fake_clock_latency_percentiles_exact(key, fake_clock):
    """Latency accounting against a scripted arrival stream: nearest-rank
    p50/p99 adapt and query latencies computed from the injected clock
    are asserted EXACTLY (virtual seconds chosen to be float-exact)."""
    lr = _learner("protonets")
    params = lr.init(key)
    eng = EpisodicServeEngine(lr, params, lite=SERVE_LITE, n_slots=2,
                              query_chunk=4, support_buckets=(16,),
                              clock=fake_clock)
    a, b = _requests(_tasks(2))
    stream = scripted_stream([(1.0, a), (3.0, b)], fake_clock)
    for req in stream:
        eng.submit(req)
    assert (a.t_enqueue, b.t_enqueue) == (1.0, 3.0)
    assert eng.stats()["queue_depth"] == 2
    fake_clock.advance_to(5.0)
    eng.step()                            # both admitted + adapted at t=5
    s = eng.stats()
    assert s["queue_depth"] == 0
    assert (a.t_admit, b.t_admit) == (5.0, 5.0)
    # adapt latencies (enqueue -> state): a=4s, b=2s; first logits land
    # the same virtual instant (the clock was not advanced mid-step)
    assert s["adapt_p50_us"] == 2e6 and s["adapt_p99_us"] == 4e6
    assert s["query_p50_us"] == 2e6 and s["query_p99_us"] == 4e6
    fake_clock.advance(1.0)
    eng.run_to_completion([])
    assert a.done and b.done
    assert a.t_done == 6.0 and b.t_done == 6.0


@pytest.mark.serve
def test_slo_preemption_defers_adapt_wave(key, fake_clock):
    """The SLO scheduler, decision by decision: a pending adapt wave is
    deferred exactly when a live lane's query deadline is ahead but would
    be missed waiting out the estimated adapt dispatch; an already-missed
    deadline no longer preempts (no starvation)."""
    lr = _learner("protonets")
    params = lr.init(key)
    eng = EpisodicServeEngine(lr, params, lite=SERVE_LITE, n_slots=2,
                              query_chunk=2, support_buckets=(16,),
                              clock=fake_clock,
                              query_slo_us=1.5e6,       # 1.5 virtual s
                              adapt_cost_hint_us=1.0e6)  # est. 1 virtual s
    a, b = _requests(_tasks(2))           # 6 queries each, chunk 2
    eng.submit(a)
    eng.step()                            # t=0: no live lanes yet -> adapt
    assert eng.stats()["tasks_adapted"] == 1 and a.served == 2

    fake_clock.advance_to(0.8)
    eng.submit(b)
    eng.step()
    # b's adapt wave would land at ~0.8+1.0 = 1.8s > a's deadline 1.5s,
    # which is still ahead -> preempted; a's chunk goes out instead
    s = eng.stats()
    assert s["slo_preemptions"] == 1 and s["tasks_adapted"] == 1
    assert a.served == 4 and b.served == 0 and b.t_adapt is None

    fake_clock.advance_to(1.6)            # a's deadline now missed
    eng.step()
    s = eng.stats()
    assert s["slo_preemptions"] == 1      # missed deadline never preempts
    assert s["tasks_adapted"] == 2 and b.t_adapt is not None
    assert a.served == 6 and a.done
    eng.run_to_completion([])
    assert b.done
    # control: without an SLO the same schedule never defers
    eng2 = EpisodicServeEngine(lr, params, lite=SERVE_LITE, n_slots=2,
                               query_chunk=2, support_buckets=(16,),
                               clock=FakeClock(),
                               adapt_cost_hint_us=1.0e6)
    a2, b2 = _requests(_tasks(2))
    eng2.submit(a2)
    eng2.step()
    eng2.submit(b2)
    eng2.step()
    assert eng2.stats()["slo_preemptions"] == 0
    assert eng2.stats()["tasks_adapted"] == 2


@pytest.mark.serve
def test_perf_smoke_rehydrate_cheaper_than_readapt_fomaml(key, tmp_path):
    """Tier-1 perf smoke: warm-tier rehydration must be measurably
    cheaper than re-adaptation for fomaml — the expensive re-adapt tail
    (per table1_adaptation_cost.csv) that the two-tier store exists to
    avoid.  3 attempts guard against scheduler noise on the shared CPU."""
    lr = make_learner(MetaLearnerConfig(kind="fomaml", way=WAY,
                                        inner_steps=20), BB, SET_CFG)
    params = lr.init(key)
    t = _tasks(1, shot=4)[0]
    adapt_j = jax.jit(lambda p, sx, sy, k: lr.adapt(p, sx, sy, key=k,
                                                    lite=SERVE_LITE))
    k0 = task_key(key, 0)
    st = jax.block_until_ready(adapt_j(params, t.support_x, t.support_y, k0))
    warm = WarmTaskStore(tmp_path)
    warm.put(0, st)
    jax.block_until_ready(warm.get(0))   # warm the IO path/page cache

    ratios = []
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(adapt_j(params, t.support_x, t.support_y,
                                          k0))
        t_readapt = time.perf_counter() - t0
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(warm.get(0))
        t_rehydrate = time.perf_counter() - t0
        ratios.append(t_readapt / t_rehydrate)
        if ratios[-1] > 1.0:
            break
    assert max(ratios) > 1.0, \
        f"rehydrate never beat fomaml re-adaptation: {ratios}"
    # and it really is the same state, bit for bit
    assert _max_leaf_diff(st, warm.get(0)) == 0.0
