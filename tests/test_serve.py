"""Serving engine: scheduling, cache splicing, greedy-decode correctness."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_smoke_config
from repro.models.registry import get_api
from repro.serve.engine import Request, ServeEngine


@pytest.mark.parametrize("arch", ["minitron-4b", "mamba2-780m", "gemma2-2b"])
def test_engine_completes_requests(arch, key):
    cfg = get_smoke_config(arch)
    api = get_api(cfg)
    params = api.init(key, cfg)
    eng = ServeEngine(cfg, params, n_slots=2, max_seq=64)
    reqs = [Request(uid=i, prompt=np.arange(4, dtype=np.int32) + i,
                    max_new_tokens=5) for i in range(5)]
    out = eng.run_to_completion(reqs)
    assert all(r.done for r in out)
    assert all(len(r.out_tokens) == 5 for r in out)


def test_engine_greedy_matches_full_forward(key):
    """Engine's greedy continuation == argmax over a full re-forward of
    (prompt + generated) at each step — KV-cache correctness end to end."""
    cfg = get_smoke_config("minitron-4b")
    api = get_api(cfg)
    params = api.init(key, cfg)
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)
    eng = ServeEngine(cfg, params, n_slots=1, max_seq=32)
    req = Request(uid=0, prompt=prompt, max_new_tokens=4)
    eng.run_to_completion([req])

    seq = list(prompt)
    want = []
    for _ in range(4):
        logits, _ = api.prefill(params, dict(tokens=jnp.asarray([seq])), cfg)
        nxt = int(jnp.argmax(logits[0]))
        want.append(nxt)
        seq.append(nxt)
    assert req.out_tokens == want, (req.out_tokens, want)


def test_engine_mla_cache_splice(key):
    """MLA latent-cache (ckv/krope) splice path through the engine."""
    import dataclasses
    cfg = get_smoke_config("deepseek-v2-236b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    api = get_api(cfg)
    params = api.init(key, cfg)
    eng = ServeEngine(cfg, params, n_slots=2, max_seq=48)
    reqs = [Request(uid=i, prompt=np.arange(4, dtype=np.int32) + i,
                    max_new_tokens=4) for i in range(3)]
    out = eng.run_to_completion(reqs)
    assert all(r.done and len(r.out_tokens) == 4 for r in out)
