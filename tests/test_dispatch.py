"""Kernel-dispatch subsystem (repro.kernels.dispatch): backend parity
sweeps, custom_vjp gradients, padded-lane invariance, the tier-1 fused-
vs-naive perf smoke, and compile-counter flatness across backend
switches.

Parity contract, stated precisely:
  * ``naive`` IS the pre-dispatch composite — dispatched results on it
    are bit-exact against inline oracles of the old code for every op
    (and, at the learner level, for every learner kind).
  * ``ref`` keeps the naive formula wherever there is no intermediate to
    kill (plain segment sums, the cho_solve Mahalanobis head) — bit-exact
    there — and reassociates ONLY the second moment ("bc,bi,bj->cij"
    contraction instead of materialize-then-reduce).  Dot and reduce
    accumulate fp32 in different orders, so second-moment bits differ at
    the last ulp; asserted to tight tolerance instead.
  * ``pallas`` (interpret off-TPU) agrees with ref to kernel tolerance,
    and its ``custom_vjp`` backward agrees with grad-of-ref.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lite import LiteSpec, lite_class_stats, serve_sum
from repro.core.meta_learners import MetaLearnerConfig, make_learner
from repro.core.set_encoder import SetEncoderConfig
from repro.data.episodic import (EpisodicImageConfig, collate_task_batch,
                                 sample_image_task)
from repro.kernels import dispatch
from repro.models.conv_backbone import ConvBackboneConfig, make_conv_backbone


def _feats_weights(key, b, f, c, frac_masked=0.0):
    x = jax.random.normal(key, (b, f), jnp.float32)
    y = jax.random.randint(jax.random.fold_in(key, 1), (b,), 0, c)
    oh = jax.nn.one_hot(y, c, dtype=jnp.float32)
    if frac_masked:
        m = (jax.random.uniform(jax.random.fold_in(key, 2), (b,))
             > frac_masked).astype(jnp.float32)
        oh = oh * m[:, None]
    return x, oh


# ---------------------------------------------------------------------------
# backend policy
# ---------------------------------------------------------------------------


def test_backend_policy_resolution():
    assert dispatch.resolve_backend("ref") == "ref"
    assert dispatch.resolve_backend("naive") == "naive"
    assert dispatch.resolve_backend("pallas") == "pallas"
    # auto resolves to ref off-TPU (this container), pallas on TPU
    expect = "ref" if jax.default_backend() != "tpu" else "pallas"
    assert dispatch.resolve_backend("auto") == expect
    with pytest.raises(ValueError):
        dispatch.resolve_backend("cuda")
    prev = dispatch.get_default_backend()
    with dispatch.use_backend("naive"):
        assert dispatch.resolve_backend() == "naive"
        with dispatch.use_backend(None):          # None = keep current
            assert dispatch.resolve_backend() == "naive"
    assert dispatch.get_default_backend() == prev


# ---------------------------------------------------------------------------
# op-level parity sweeps
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,f,c", [(64, 32, 5), (257, 48, 7), (100, 64, 10)])
def test_segment_sum_parity(key, b, f, c):
    x, oh = _feats_weights(key, b, f, c, frac_masked=0.3)
    # inline oracle of the pre-dispatch composite: expand + reduce
    want = jnp.sum(jnp.einsum("b...,bc->bc...", x, oh), axis=0)
    got_naive = dispatch.segment_sum(x, oh, backend="naive")
    got_ref = dispatch.segment_sum(x, oh, backend="ref")
    got_pallas = dispatch.segment_sum(x, oh, backend="pallas")
    np.testing.assert_array_equal(np.asarray(got_naive), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got_ref), np.asarray(want))
    np.testing.assert_allclose(np.asarray(got_pallas), np.asarray(want),
                               atol=1e-4, rtol=1e-5)


@pytest.mark.parametrize("b,f,c", [(64, 32, 5), (257, 48, 7), (100, 64, 10)])
def test_class_second_moment_parity(key, b, f, c):
    x, oh = _feats_weights(key, b, f, c, frac_masked=0.3)
    outer = jnp.einsum("bi,bj->bij", x, x)       # inline pre-dispatch oracle
    want = jnp.sum(jnp.einsum("b...,bc->bc...", outer, oh), axis=0)
    got_naive = dispatch.class_second_moment(x, oh, backend="naive")
    got_ref = dispatch.class_second_moment(x, oh, backend="ref")
    got_pallas = dispatch.class_second_moment(x, oh, backend="pallas")
    np.testing.assert_array_equal(np.asarray(got_naive), np.asarray(want))
    # ref reassociates the example-axis contraction: tight tolerance, not
    # bitwise (dot vs reduce accumulation order)
    np.testing.assert_allclose(np.asarray(got_ref), np.asarray(want),
                               atol=1e-4, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(got_pallas), np.asarray(want),
                               atol=1e-4, rtol=1e-5)


def test_segment_sum_higher_rank_leaves(key):
    """Dispatch handles (B, ...) leaves of any rank (set-encoder style)."""
    e = jax.random.normal(key, (40, 3, 5, 2))
    _, oh = _feats_weights(key, 40, 8, 4)
    want = jnp.einsum("bxyz,bc->cxyz", e, oh)
    for bk in ("naive", "ref", "pallas"):
        got = dispatch.segment_sum(e, oh, backend=bk)
        assert got.shape == (4, 3, 5, 2)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, rtol=1e-5)


def test_mahalanobis_head_parity(key):
    b, f, c = 40, 32, 5
    q = jax.random.normal(key, (b, f))
    mu = jax.random.normal(jax.random.fold_in(key, 1), (c, f))
    a = jax.random.normal(jax.random.fold_in(key, 2), (c, f, f))
    sigma = jnp.einsum("cij,ckj->cik", a, a) + 1.0 * jnp.eye(f)
    chol = jax.vmap(jnp.linalg.cholesky)(sigma)
    # inline oracle: the pre-dispatch cho_solve composite
    diff = q[:, None, :] - mu[None, :, :]
    sol = jax.vmap(
        lambda L, d: jax.scipy.linalg.cho_solve((L, True), d.T).T,
        in_axes=(0, 1), out_axes=1)(chol, diff)
    want = jnp.sum(diff * sol, axis=-1)
    got_naive = dispatch.mahalanobis_head(q, mu, chol, backend="naive")
    got_ref = dispatch.mahalanobis_head(q, mu, chol, backend="ref")
    got_pallas = dispatch.mahalanobis_head(q, mu, chol, backend="pallas")
    np.testing.assert_array_equal(np.asarray(got_naive), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got_ref), np.asarray(want))
    np.testing.assert_allclose(np.asarray(got_pallas), np.asarray(want),
                               atol=1e-2, rtol=1e-4)


# ---------------------------------------------------------------------------
# custom_vjp gradients: grad-through-pallas vs grad-of-ref
# ---------------------------------------------------------------------------


def test_segment_sum_grad_through_custom_vjp(key):
    x, oh = _feats_weights(key, 60, 24, 6, frac_masked=0.2)
    g = jax.random.normal(jax.random.fold_in(key, 3), (6, 24))

    def loss(bk):
        return lambda xx, ww: jnp.vdot(
            dispatch.segment_sum(xx, ww, backend=bk), g)

    for wrt in (0, 1):   # both d/dfeat and d/dweights
        want = jax.grad(loss("ref"), argnums=wrt)(x, oh)
        got = jax.grad(loss("pallas"), argnums=wrt)(x, oh)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, rtol=1e-4)


def test_class_second_moment_grad_through_custom_vjp(key):
    x, oh = _feats_weights(key, 60, 24, 6, frac_masked=0.2)
    g = jax.random.normal(jax.random.fold_in(key, 3), (6, 24, 24))

    def loss(bk):
        return lambda xx, ww: jnp.vdot(
            dispatch.class_second_moment(xx, ww, backend=bk), g)

    for wrt in (0, 1):
        naive = jax.grad(loss("naive"), argnums=wrt)(x, oh)
        ref = jax.grad(loss("ref"), argnums=wrt)(x, oh)
        pallas = jax.grad(loss("pallas"), argnums=wrt)(x, oh)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(naive),
                                   atol=1e-3, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(pallas), np.asarray(naive),
                                   atol=1e-3, rtol=1e-4)


def test_mahalanobis_head_grad_through_custom_vjp(key):
    b, f, c = 16, 16, 4
    q = jax.random.normal(key, (b, f))
    mu = jax.random.normal(jax.random.fold_in(key, 1), (c, f))
    a = jax.random.normal(jax.random.fold_in(key, 2), (c, f, f))
    sigma = jnp.einsum("cij,ckj->cik", a, a) + 2.0 * jnp.eye(f)
    chol = jax.vmap(jnp.linalg.cholesky)(sigma)

    def loss(bk):
        return lambda qq, mm, cc: jnp.sum(
            dispatch.mahalanobis_head(qq, mm, cc, backend=bk) ** 2)

    for wrt in (0, 1, 2):   # q, mu, AND chol (through the inverse)
        want = jax.grad(loss("ref"), argnums=wrt)(q, mu, chol)
        got = jax.grad(loss("pallas"), argnums=wrt)(q, mu, chol)
        scale = float(jnp.max(jnp.abs(want))) + 1e-6
        np.testing.assert_allclose(np.asarray(got) / scale,
                                   np.asarray(want) / scale,
                                   atol=5e-3)


# ---------------------------------------------------------------------------
# padded-lane invariance of the masked/weight-aware segment pool
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["naive", "ref", "pallas"])
def test_padded_lane_invariance(key, backend):
    """Appending zero-weight rows (collator padding) changes nothing, on
    every backend — padding works natively, no mask plumbing at the call
    site."""
    x, oh = _feats_weights(key, 50, 16, 5)
    pad_x = jax.random.normal(jax.random.fold_in(key, 9), (14, 16)) * 100.0
    x_p = jnp.concatenate([x, pad_x])
    oh_p = jnp.concatenate([oh, jnp.zeros((14, 5))])
    for op in (dispatch.segment_sum, dispatch.class_second_moment):
        a = op(x, oh, backend=backend)
        b = op(x_p, oh_p, backend=backend)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-6)


# ---------------------------------------------------------------------------
# LITE-estimator level: fused class stats through H-pass + chunked
# complement, padded-batch invariance, grads
# ---------------------------------------------------------------------------


def _toy_features():
    w = jax.random.normal(jax.random.key(7), (12, 10)) * 0.3

    def features_fn(params, x):
        return jnp.tanh(x @ params)

    return w, features_fn


@pytest.mark.parametrize("backend", ["naive", "ref", "pallas"])
def test_lite_class_stats_matches_materializing_oracle(key, backend):
    """lite_class_stats == the literal outer-product encode ridden through
    the generic estimator, per backend tolerance (naive: bitwise)."""
    from repro.core.lite import lite_segment_sum
    w, features_fn = _toy_features()
    xs = jax.random.normal(key, (30, 12))
    ys = jax.random.randint(jax.random.fold_in(key, 1), (30,), 0, 4)
    spec = LiteSpec(h=6, chunk_size=8)

    def outer_encode(p, x):
        f = features_fn(p, x)
        return dict(feat=f, outer=jnp.einsum("bi,bj->bij", f, f))

    want, want_counts = lite_segment_sum(outer_encode, w, xs, ys, 4, key,
                                         spec, backend="naive")
    got, counts = lite_class_stats(features_fn, w, xs, ys, 4, key, spec,
                                   second_moment=True, backend=backend)
    np.testing.assert_array_equal(np.asarray(counts),
                                  np.asarray(want_counts))
    if backend == "naive":
        np.testing.assert_array_equal(np.asarray(got["feat"]),
                                      np.asarray(want["feat"]))
        np.testing.assert_array_equal(np.asarray(got["outer"]),
                                      np.asarray(want["outer"]))
    else:
        for k in ("feat", "outer"):
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(want[k]),
                                       atol=1e-4, rtol=1e-5)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_lite_class_stats_grads_match_naive(key, backend):
    """jax.grad through the fused/custom_vjp H-pass vs grad of the naive
    composite."""
    w, features_fn = _toy_features()
    xs = jax.random.normal(key, (30, 12))
    ys = jax.random.randint(jax.random.fold_in(key, 1), (30,), 0, 4)
    spec = LiteSpec(h=6, chunk_size=8)

    def loss(bk):
        def fn(p):
            stats, _ = lite_class_stats(features_fn, p, xs, ys, 4, key,
                                        spec, second_moment=True,
                                        backend=bk)
            return jnp.sum(stats["feat"] ** 2) + jnp.sum(stats["outer"] ** 2)
        return fn

    g_naive = jax.grad(loss("naive"))(w)
    g = jax.grad(loss(backend))(w)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_naive),
                               atol=1e-3, rtol=1e-4)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_lite_class_stats_padded_batch_invariance(key, backend):
    """A task padded with masked rows produces identical fused stats —
    mask folds into the one-hot weights, per backend."""
    w, features_fn = _toy_features()
    xs = jax.random.normal(key, (20, 12))
    ys = jax.random.randint(jax.random.fold_in(key, 1), (20,), 0, 4)
    spec = LiteSpec(h=5, chunk_size=8)
    got, counts = lite_class_stats(features_fn, w, xs, ys, 4, key, spec,
                                   second_moment=True, backend=backend)
    pad = 12
    xs_p = jnp.concatenate([xs, jnp.ones((pad, 12)) * 50.0])
    ys_p = jnp.concatenate([ys, -jnp.ones((pad,), ys.dtype)])
    mask = jnp.concatenate([jnp.ones((20,)), jnp.zeros((pad,))])
    got_p, counts_p = lite_class_stats(features_fn, w, xs_p, ys_p, 4, key,
                                       spec, mask=mask, second_moment=True,
                                       backend=backend)
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(counts_p))
    for k in ("feat", "outer"):
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(got_p[k]),
                                   atol=1e-5, rtol=1e-6)


def test_serve_class_stats_chunking_reassociates_only(key):
    """Chunked serve-side fused stats == unchunked, to accumulation
    tolerance; and serve_sum-based stats carry no grad."""
    w, features_fn = _toy_features()
    xs = jax.random.normal(key, (40, 12))
    ys = jax.random.randint(jax.random.fold_in(key, 1), (40,), 0, 4)
    unchunked, _ = lite_class_stats(
        features_fn, w, xs, ys, 4, key, LiteSpec(exact=True),
        second_moment=True, sum_fn=serve_sum, backend="ref")
    chunked, _ = lite_class_stats(
        features_fn, w, xs, ys, 4, key, LiteSpec(exact=True, chunk_size=7),
        second_moment=True, sum_fn=serve_sum, backend="ref")
    for k in ("feat", "outer"):
        np.testing.assert_allclose(np.asarray(unchunked[k]),
                                   np.asarray(chunked[k]),
                                   atol=1e-5, rtol=1e-6)
    g = jax.grad(lambda p: jnp.sum(lite_class_stats(
        features_fn, p, xs, ys, 4, key, LiteSpec(exact=True, chunk_size=7),
        second_moment=True, sum_fn=serve_sum, backend="ref")[0]["feat"]))(w)
    assert float(jnp.max(jnp.abs(g))) == 0.0


# ---------------------------------------------------------------------------
# learner level: every kind, train grads + serve outputs per backend
# ---------------------------------------------------------------------------


def _small_learner(kind):
    bb = make_conv_backbone(ConvBackboneConfig(widths=(8, 16),
                                               feature_dim=32))
    set_cfg = SetEncoderConfig(kind="conv", conv_blocks=2, conv_width=8,
                               task_dim=16)
    lr = make_learner(MetaLearnerConfig(kind=kind, way=5), bb, set_cfg)
    return lr, lr.init(jax.random.key(1))


@pytest.mark.parametrize("kind", ["protonets", "cnaps", "simple_cnaps"])
def test_learner_backend_parity(key, kind):
    """ref == naive bitwise for the first-order learners (their dispatch
    sites share the formula); simple_cnaps' reassociated covariance path
    agrees to tolerance; pallas agrees to kernel tolerance — for train
    loss/grads AND serve logits."""
    lr, params = _small_learner(kind)
    tcfg = EpisodicImageConfig(way=5, shot=6, query_per_class=3,
                               image_size=16)
    task = sample_image_task(jax.random.key(3), tcfg)
    spec = LiteSpec(h=8, chunk_size=8)

    def run(bk):
        with dispatch.use_backend(bk):
            loss, grads = jax.value_and_grad(
                lambda p: lr.meta_loss(p, task, key, spec)[0])(params)
            st = lr.adapt(params, task.support_x, task.support_y,
                          key=jax.random.key(4),
                          lite=LiteSpec(exact=True, chunk_size=8))
            logits = lr.predict(params, st, task.query_x)
        return (np.asarray(loss), jax.tree.leaves(grads),
                np.asarray(logits))

    l_naive, g_naive, p_naive = run("naive")
    l_ref, g_ref, p_ref = run("ref")
    l_pal, g_pal, p_pal = run("pallas")
    if kind != "simple_cnaps":
        assert l_naive == l_ref
        np.testing.assert_array_equal(p_naive, p_ref)
        for a, b in zip(g_naive, g_ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    else:
        np.testing.assert_allclose(l_ref, l_naive, rtol=2e-3)
        np.testing.assert_allclose(p_ref, p_naive,
                                   atol=2e-3 * np.abs(p_naive).max())
    np.testing.assert_allclose(l_pal, l_ref, rtol=5e-2)
    assert np.mean(np.argmax(p_pal, -1) == np.argmax(p_ref, -1)) >= 0.9


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_adapt_batch_rides_dispatch(key, backend):
    """The batched TaskBatch serve contract (vmapped adaptation) works on
    every backend and matches per-task adaptation."""
    lr, params = _small_learner("simple_cnaps")
    tcfg = EpisodicImageConfig(way=5, shot=6, query_per_class=3,
                               image_size=16)
    tasks = [sample_image_task(jax.random.key(i), tcfg) for i in (0, 1)]
    batch = collate_task_batch(tasks, support_size=40, query_size=20)
    keys = jnp.stack([jax.random.key(10), jax.random.key(11)])
    lite = LiteSpec(exact=True, chunk_size=8)
    with dispatch.use_backend(backend):
        states = lr.adapt_batch(params, batch, keys, lite)
        logits = lr.predict_batch(params, states, batch.query_x)
        solo = lr.adapt(params, tasks[0].support_x, tasks[0].support_y,
                        key=jax.random.key(10), lite=lite,
                        mask=jnp.ones((tasks[0].support_x.shape[0],)))
        want = lr.predict(params, solo, tasks[0].query_x)
    np.testing.assert_allclose(np.asarray(logits[0, :want.shape[0]]),
                               np.asarray(want), atol=1e-4,
                               rtol=1e-5)


def test_serve_state_carries_precomputed_inverse_on_pallas(key):
    """A simple_cnaps task adapted under the pallas backend carries the
    per-class covariance inverse in its state (computed ONCE at
    adaptation; query dispatches skip the O(C F^3) solves), and predicts
    identically to the inversion-per-call path.  ref-backend states are
    unchanged (no extra leaf)."""
    lr, params = _small_learner("simple_cnaps")
    tcfg = EpisodicImageConfig(way=5, shot=6, query_per_class=3,
                               image_size=16)
    task = sample_image_task(jax.random.key(3), tcfg)
    lite = LiteSpec(exact=True, chunk_size=8)
    with dispatch.use_backend("ref"):
        st_ref = lr.adapt(params, task.support_x, task.support_y,
                          key=key, lite=lite)
    assert "sinv" not in st_ref
    with dispatch.use_backend("pallas"):
        st = lr.adapt(params, task.support_x, task.support_y,
                      key=key, lite=lite)
        assert "sinv" in st
        np.testing.assert_allclose(
            np.asarray(st["sinv"]),
            np.asarray(dispatch.chol_inverse(st["chol"])), rtol=1e-6)
        want = lr.predict(params, st, task.query_x)
        st_no_cache = {k: v for k, v in st.items() if k != "sinv"}
        got = lr.predict(params, st_no_cache, task.query_x)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               atol=1e-5, rtol=1e-6)


# ---------------------------------------------------------------------------
# tier-1 perf smoke: the fused ref path beats the naive outer at N=1000
# ---------------------------------------------------------------------------


def test_perf_smoke_fused_ref_beats_naive_outer(key):
    """Acceptance: fused ref >= 1.5x over the naive outer-product einsum
    at N=1000 on this container (measured ~85x; the generous margin keeps
    this deflaked)."""
    n, f, c = 1000, 64, 10
    x, oh = _feats_weights(key, n, f, c)

    def stats(bk):
        return jax.jit(lambda xx, ww: dispatch.class_second_moment(
            xx, ww, backend=bk))

    def bench(fn):
        jax.block_until_ready(fn(x, oh))        # compile
        ts = []
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x, oh))
            ts.append(time.perf_counter() - t0)
        return sorted(ts)[1]

    t_naive = bench(stats("naive"))
    t_ref = bench(stats("ref"))
    assert t_naive > 1.5 * t_ref, (t_naive, t_ref)


# ---------------------------------------------------------------------------
# compile discipline: backend switches must not leak compiles
# ---------------------------------------------------------------------------


def test_bucketed_cache_flat_across_backend_switch(key):
    """The per-shape compile cache keys on shapes alone; the dispatch
    backend binds at lowering time.  Flipping the ambient default on a
    warm cache therefore adds ZERO compiles (and keeps serving the bound
    backend's executable, bit-for-bit) — the documented 'backend is an
    engine/construction property' semantic."""
    from repro.train.pipeline import BucketedStepCache
    cache = BucketedStepCache(
        lambda x, w: dispatch.class_second_moment(x, w))
    outs = {}
    with dispatch.use_backend("ref"):
        for b in (32, 48):
            x, oh = _feats_weights(jax.random.fold_in(key, b), b, 16, 4)
            outs[b] = np.asarray(cache(x, oh))
    assert cache.compile_count == 2
    with dispatch.use_backend("naive"):
        for b in (32, 48):
            x, oh = _feats_weights(jax.random.fold_in(key, b), b, 16, 4)
            np.testing.assert_array_equal(np.asarray(cache(x, oh)), outs[b])
    assert cache.compile_count == 2               # no leaked compiles


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_serve_engine_kernel_backend_flat_counters(key, backend):
    """An EpisodicServeEngine constructed with an explicit kernel backend
    serves a two-wave stream with flat compile counters, and its results
    agree with the ref engine to kernel tolerance."""
    from repro.serve.episodic import EpisodicRequest, EpisodicServeEngine
    lr, params = _small_learner("simple_cnaps")
    tcfg = EpisodicImageConfig(way=5, shot=6, query_per_class=3,
                               image_size=16)

    def reqs():
        out = []
        for uid in range(4):
            t = sample_image_task(jax.random.key(uid), tcfg)
            out.append(EpisodicRequest(uid=uid,
                                       support_x=np.asarray(t.support_x),
                                       support_y=np.asarray(t.support_y),
                                       query_x=np.asarray(t.query_x)))
        return out

    engine = EpisodicServeEngine(lr, params, n_slots=2, query_chunk=4,
                                 support_buckets=(32,),
                                 kernel_backend=backend)
    assert engine.kernel_backend == backend
    done = engine.run_to_completion(reqs())
    s = engine.stats()
    assert s["adapt_compiles"] == 1 and s["predict_compiles"] == 1
    ref_engine = EpisodicServeEngine(lr, params, n_slots=2, query_chunk=4,
                                     support_buckets=(32,),
                                     kernel_backend="ref")
    ref_done = ref_engine.run_to_completion(reqs())
    for a, b in zip(done, ref_done):
        assert np.mean(a.predictions() == b.predictions()) >= 0.9
