"""Hypothesis property tests on system invariants.

Skips cleanly when hypothesis isn't installed; the two highest-value
properties here (LITE forward exactness, estimator unbiasedness) also have
plain seeded-loop ports in tests/test_lite_estimator.py that always run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed; seeded-loop ports cover the key "
           "properties (see test_lite_estimator.py)")
from hypothesis import given, settings, strategies as st

from repro.core.lite import LiteSpec, lite_sum
from repro.kernels import ops, ref
from repro.optim.quant import dequantize, quantize
from repro.sharding.ctx import _sanitize
from repro.sharding.ctx import P


class _FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(self.shape)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 24), h=st.integers(1, 24), dim=st.integers(1, 8),
       chunk=st.one_of(st.none(), st.integers(1, 8)), seed=st.integers(0, 2**30))
def test_lite_forward_always_exact(n, h, dim, chunk, seed):
    """INVARIANT (paper Eq. 8): LITE's forward value is the exact full sum
    for every (n, h, chunk) combination."""
    key = jax.random.key(seed)
    p = jax.random.normal(key, (dim, dim))
    xs = jax.random.normal(jax.random.fold_in(key, 1), (n, dim))
    enc = lambda pp, x: jnp.tanh(x @ pp)
    got = lite_sum(enc, p, xs, key, LiteSpec(h=h, chunk_size=chunk))
    want = jnp.sum(enc(p, xs), axis=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-5, atol=5e-5)


@settings(max_examples=30, deadline=None)
@given(rows=st.integers(1, 5), n=st.integers(1, 513), seed=st.integers(0, 2**30),
       scale=st.floats(1e-3, 1e3))
def test_quantize_bounded_error(rows, n, seed, scale):
    """INVARIANT: blockwise int8 round-trip error <= per-block scale."""
    x = scale * jax.random.normal(jax.random.key(seed), (rows, n))
    q = quantize(x)
    back = dequantize(q, n)
    per_block_scale = np.asarray(q["scale"])
    err = np.abs(np.asarray(back - x))
    blocks = err.shape[-1]
    for b in range((n + 127) // 128):
        e = err[..., b * 128:(b + 1) * 128].max(-1)
        assert np.all(e <= per_block_scale[..., b] + 1e-6)


@settings(max_examples=40, deadline=None)
@given(dims=st.lists(st.integers(1, 64), min_size=1, max_size=4),
       data=st.integers(1, 8), model=st.integers(1, 8))
def test_sanitize_only_emits_dividing_axes(dims, data, model):
    """INVARIANT: sanitized specs always divide the array dims."""
    mesh = _FakeMesh(dict(data=data, model=model))
    spec = P(*(["data", "model", ("data", "model"), None][:len(dims)]))
    out = _sanitize(spec, tuple(dims), mesh)
    sizes = dict(data=data, model=model)
    for entry, dim in zip(tuple(out), dims):
        if entry is None:
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        total = int(np.prod([sizes[nm] for nm in names]))
        assert dim % total == 0


@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 64), f=st.integers(1, 32), c=st.integers(1, 6),
       seed=st.integers(0, 2**30))
def test_segment_pool_matches_ref(b, f, c, seed):
    key = jax.random.key(seed)
    x = jax.random.normal(key, (b, f))
    y = jax.random.randint(jax.random.fold_in(key, 1), (b,), 0, c)
    s1, c1 = ops.segment_pool(x, y, c)
    s2, c2 = ref.segment_pool_ref(x, y, c)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


@settings(max_examples=10, deadline=None)
@given(seq=st.integers(2, 64), vocab=st.integers(8, 64),
       seed=st.integers(0, 2**30))
def test_token_pipeline_deterministic(seq, vocab, seed):
    """INVARIANT: batch_at(step) is a pure function of (config, step) —
    the property checkpoint-exact resume relies on."""
    from repro.data.tokens import TokenPipeline, TokenPipelineConfig
    cfg = TokenPipelineConfig(vocab=vocab, seq_len=seq, global_batch=2, seed=seed)
    a, b = TokenPipeline(cfg), TokenPipeline(cfg)
    for s in (0, 3, 17):
        np.testing.assert_array_equal(a.batch_at(s)["tokens"],
                                      b.batch_at(s)["tokens"])
    assert not np.array_equal(a.batch_at(0)["tokens"], a.batch_at(1)["tokens"])
