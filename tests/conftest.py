"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the real
1-CPU device; only launch/dryrun.py (and subprocess tests) fake devices."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)


class FakeClock:
    """Deterministic monotonic clock for the serving tests: injectable
    into ``EpisodicServeEngine(clock=...)``, advanced ONLY by the test.
    Calling it returns the current virtual time in seconds (the same
    contract as ``time.monotonic``), so latency percentiles, SLO
    preemption decisions, and timestamp stamping are exact — no sleeps,
    no wall-clock noise."""

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"FakeClock is monotonic; advance({dt})")
        self.t += dt
        return self.t

    def advance_to(self, t: float) -> float:
        if t < self.t:
            raise ValueError(f"FakeClock is monotonic; advance_to({t}) "
                             f"from {self.t}")
        self.t = float(t)
        return self.t


def scripted_stream(arrivals, clock: FakeClock):
    """Scripted-arrival request stream: ``arrivals`` is a sequence of
    ``(t_virtual_seconds, request)`` pairs.  Yields each request after
    advancing ``clock`` to its arrival time (stable order for equal
    times), so ``engine.submit(req)`` stamps exactly the scripted
    ``t_enqueue`` — the generator half of the deterministic serving
    harness."""
    for t, req in sorted(arrivals, key=lambda a: a[0]):
        clock.advance_to(t)
        yield req


@pytest.fixture
def fake_clock():
    return FakeClock()


def make_pretrained_stub_backbone(image_size: int = 16, channels: int = 3,
                                  feature_dim: int = 32, seed: int = 7,
                                  noise_gain: float = 2.0):
    """Tiny DETERMINISTIC 'pretrained' backbone stub (ROADMAP open item).

    The synthetic episodic tasks put the class signal in a low-frequency
    pattern under heavy per-pixel noise, so a fixed 4x4 average-pool +
    seeded random projection is already a decent 'pretrained' feature
    extractor (pooling averages the noise down ~4x).  The second feature
    half projects the RAW pixels — noise-dominated distractor dims that
    dilute the metric head until the (trainable) FiLM generator learns to
    suppress them.  That gives meta-training real, reliable headroom:
    held-out accuracy strictly improves within a small test budget,
    restoring the strict assertion the frozen-random-backbone setting
    could not support (see test_system.py).

    Weights come from a FIXED seed, not from ``init``'s key, so every
    test sees the identical 'pretrained' checkpoint.
    """
    from repro.core.film import apply_film
    from repro.models.backbone import BackboneDef

    assert image_size % 4 == 0, image_size
    half = feature_dim // 2
    k1, k2 = jax.random.split(jax.random.key(seed))
    pooled_dim = 4 * 4 * channels
    flat_dim = image_size * image_size * channels
    w_sig = jax.random.normal(k1, (pooled_dim, half)) / np.sqrt(pooled_dim)
    w_noise = jax.random.normal(k2, (flat_dim, half)) / np.sqrt(flat_dim)

    def init(key):
        return dict(w_sig=w_sig, w_noise=w_noise)

    def features(p, x, film):
        b, h, w, c = x.shape
        f = h // 4
        pooled = x.reshape(b, 4, f, 4, f, c).mean(axis=(2, 4))
        sig = jnp.tanh(pooled.reshape(b, -1) @ p["w_sig"].astype(x.dtype))
        noi = jnp.tanh(x.reshape(b, -1) @ p["w_noise"].astype(x.dtype))
        feats = jnp.concatenate([sig, noise_gain * noi], axis=-1)
        if film is not None:
            feats = apply_film(feats, film[0]["gamma"], film[0]["beta"],
                               channel_axis=-1)
        return feats

    return BackboneDef(init=init, features=features, feature_dim=feature_dim,
                       film_sites=(feature_dim,), name="pretrained_stub")


@pytest.fixture(scope="session")
def pretrained_stub_backbone():
    return make_pretrained_stub_backbone()
