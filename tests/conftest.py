"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the real
1-CPU device; only launch/dryrun.py (and subprocess tests) fake devices."""
import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)
