"""Launcher entry points run end-to-end on the local device (subprocess,
so their arg parsing + mesh/sharding init paths are covered)."""
import os
import pathlib
import subprocess
import sys

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def _run(args, timeout=480):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                       text=True, env=env, timeout=timeout)
    assert r.returncode == 0, r.stderr[-2000:]
    return r.stdout


def test_train_launcher(tmp_path):
    out = _run(["repro.launch.train", "--arch", "gemma2-2b", "--steps", "6",
                "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path),
                "--ckpt-every", "4"])
    assert "done at step 6" in out
    # auto-resume path: run again to a later step
    out2 = _run(["repro.launch.train", "--arch", "gemma2-2b", "--steps", "8",
                 "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path),
                 "--ckpt-every", "4"])
    assert "resumed_from=6" in out2


def test_serve_launcher():
    out = _run(["repro.launch.serve", "--arch", "mamba2-780m",
                "--requests", "3", "--slots", "2", "--max-new", "5",
                "--prompt-len", "4"])
    assert "3 requests" in out
