"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as fa_raw


@pytest.mark.parametrize("causal,window,cap", [
    (True, None, None), (False, None, None), (True, 24, None),
    (True, None, 50.0), (True, 24, 30.0),
])
def test_flash_attention_smoke(key, causal, window, cap):
    """Fast tier-1 reference check: one small shape per masking/capping
    variant (the full shape sweep is the `slow` test below)."""
    s, d, dtype = 64, 32, jnp.float32
    q = jax.random.normal(key, (2, s, d), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, s, d), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, s, d), dtype)
    o = fa_raw(q, k, v, causal=causal, window=window, softcap=cap,
               block_q=32, block_k=32, interpret=True)
    r = ref.attention_ref(q, k, v, causal=causal, window=window, softcap=cap)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=2e-5, rtol=2e-5)


@pytest.mark.slow
@pytest.mark.parametrize("s,d,dtype", [
    (128, 64, jnp.float32), (192, 64, jnp.float32), (256, 128, jnp.float32),
    (128, 64, jnp.bfloat16), (100, 32, jnp.float32),
])
@pytest.mark.parametrize("causal,window,cap", [
    (True, None, None), (False, None, None), (True, 48, None),
    (True, None, 50.0), (True, 32, 30.0),
])
def test_flash_attention_sweep(key, s, d, dtype, causal, window, cap):
    q = jax.random.normal(key, (2, s, d), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, s, d), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, s, d), dtype)
    o = fa_raw(q, k, v, causal=causal, window=window, softcap=cap,
               block_q=64, block_k=64, interpret=True)
    r = ref.attention_ref(q, k, v, causal=causal, window=window, softcap=cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("b,f,c", [(64, 32, 5), (130, 64, 10), (16, 16, 3)])
def test_mahalanobis_sweep(key, b, f, c):
    q = jax.random.normal(key, (b, f))
    mu = jax.random.normal(jax.random.fold_in(key, 1), (c, f))
    a = jax.random.normal(jax.random.fold_in(key, 2), (c, f, f))
    sinv = jnp.einsum("cij,ckj->cik", a, a) + 0.1 * jnp.eye(f)
    got = ops.mahalanobis(q, mu, sinv)
    want = ref.mahalanobis_ref(q, mu, sinv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("b,f,c", [(100, 48, 7), (257, 64, 4), (8, 8, 2)])
def test_segment_pool_sweep(key, b, f, c):
    x = jax.random.normal(key, (b, f))
    y = jax.random.randint(jax.random.fold_in(key, 1), (b,), 0, c)
    s1, c1 = ops.segment_pool(x, y, c)
    s2, c2 = ref.segment_pool_ref(x, y, c)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


@pytest.mark.parametrize("e,c,d,f,dtype", [
    (4, 64, 96, 80, jnp.float32), (2, 130, 64, 64, jnp.float32),
    (3, 32, 48, 40, jnp.bfloat16),
])
def test_gmm_sweep(key, e, c, d, f, dtype):
    x = jax.random.normal(key, (e, c, d), dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (e, d, f), dtype)
    got = ops.gmm(x, w)
    want = ref.gmm_ref(x, w)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("g,q,p,n", [(6, 32, 16, 8), (2, 64, 32, 16)])
def test_ssd_chunk_sweep(key, g, q, p, n):
    x = jax.random.normal(key, (g, q, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (g, q)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (g,)))
    B = jax.random.normal(jax.random.fold_in(key, 3), (g, q, n))
    C = jax.random.normal(jax.random.fold_in(key, 4), (g, q, n))
    y, st, cd, sd = ops.ssd_chunk(x, dt, A, B, C)
    for i in range(g):
        yr, sr, cdr, sdr = ref.ssd_chunk_ref(
            x[i][:, None, :], dt[i][:, None], A[i:i + 1],
            B[i][:, None, :], C[i][:, None, :])
        np.testing.assert_allclose(np.asarray(y[i]), np.asarray(yr[:, 0]),
                                   atol=3e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(st[i]), np.asarray(sr[0]),
                                   atol=3e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(cd[i]), np.asarray(cdr[0]),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(sd[i]), np.asarray(sdr[:, 0]),
                                   atol=1e-5)


def test_ssd_kernel_composes_with_model(key):
    """Kernel-computed chunks + jnp inter-chunk recurrence == model SSD."""
    from repro.models.mamba2 import ssd_chunked
    b, s, h, p, n, chunk = 2, 64, 3, 8, 4, 16
    x = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)))
    B = jax.random.normal(jax.random.fold_in(key, 3), (b, s, h, n))
    C = jax.random.normal(jax.random.fold_in(key, 4), (b, s, h, n))
    y_model, final_model = ssd_chunked(x, dt, A, B, C, chunk)

    nc = s // chunk

    # flatten (b, nc, h) into G for the kernel
    def to_g(t, feat):
        t = t.reshape(b, nc, chunk, h, feat)
        return t.transpose(0, 1, 3, 2, 4).reshape(b * nc * h, chunk, feat)

    xg = to_g(x, p)
    Bg = to_g(B, n)
    Cg = to_g(C, n)
    dtg = dt.reshape(b, nc, chunk, h).transpose(0, 1, 3, 2).reshape(-1, chunk)
    Ag = jnp.tile(A, b * nc)
    yk, stk, cdk, sdk = ops.ssd_chunk(xg, dtg, Ag, Bg, Cg)

    # inter-chunk recurrence in jnp
    stk = stk.reshape(b, nc, h, p, n)
    cdk = cdk.reshape(b, nc, h)
    sdk = sdk.reshape(b, nc, h, chunk).transpose(0, 1, 3, 2)  # (b,nc,chunk,h)
    yk = yk.reshape(b, nc, h, chunk, p).transpose(0, 1, 3, 2, 4)

    state = jnp.zeros((b, h, p, n))
    ys = []
    for ci in range(nc):
        y_off = jnp.einsum("blhn,bhpn,blh->blhp",
                           C.reshape(b, nc, chunk, h, n)[:, ci], state,
                           sdk[:, ci])
        ys.append(yk[:, ci] + y_off)
        state = state * cdk[:, ci][:, :, None, None] + stk[:, ci]
    y_full = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_model),
                               atol=2e-3, rtol=1e-2)
    np.testing.assert_allclose(np.asarray(state), np.asarray(final_model),
                               atol=2e-3, rtol=1e-2)
