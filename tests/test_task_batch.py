"""Task-batched engine correctness: collation, batched==looped equivalence,
padding invariance, per-task key independence, and the shard_map
data-parallel path (subprocess — fake devices must not leak here)."""
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.episodic import TaskBatch, validate_task_batch
from repro.core.episodic_train import (make_batched_meta_grads,
                                       make_batched_meta_train_step,
                                       make_meta_train_step, task_key)
from repro.core.lite import LiteSpec, sample_h_indices
from repro.core.meta_learners import MetaLearnerConfig, make_learner
from repro.core.set_encoder import SetEncoderConfig
from repro.data.episodic import (EpisodicImageConfig, collate_task_batch,
                                 sample_image_task, sample_image_task_batch,
                                 task_batch_at)
from repro.models.conv_backbone import ConvBackboneConfig, make_conv_backbone
from repro.optim import AdamWConfig, adamw_init

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")
BB = make_conv_backbone(ConvBackboneConfig(widths=(8,), feature_dim=16))
SET_CFG = SetEncoderConfig(kind="conv", conv_blocks=1, conv_width=8, task_dim=16)
TCFG = EpisodicImageConfig(way=5, shot=5, query_per_class=3, image_size=12)
SPEC = LiteSpec(h=5)


def _learner(kind="protonets"):
    return make_learner(MetaLearnerConfig(kind=kind, way=5), BB, SET_CFG)


def _tasks(n, shot=5):
    cfg = EpisodicImageConfig(way=5, shot=shot, query_per_class=3,
                              image_size=12)
    return [sample_image_task(jax.random.key(100 + i), cfg) for i in range(n)]


def _max_leaf_diff(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# -- collator ---------------------------------------------------------------


def test_collate_shapes_masks_and_labels():
    tasks = _tasks(3)
    batch = collate_task_batch(tasks, support_size=32, query_size=16)
    validate_task_batch(batch)
    assert batch.num_tasks == 3 and batch.way == 5
    assert batch.support_x.shape[:2] == (3, 32)
    assert batch.query_x.shape[:2] == (3, 16)
    # real prefix is intact, padding is masked and labelled -1
    np.testing.assert_array_equal(np.asarray(batch.support_y[0][:25]),
                                  np.asarray(tasks[0].support_y))
    assert np.all(np.asarray(batch.support_y[0][25:]) == -1)
    np.testing.assert_array_equal(np.asarray(batch.support_mask[0]),
                                  (np.arange(32) < 25).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(batch.query_mask[0]),
                                  (np.arange(16) < 15).astype(np.float32))


def test_collate_ragged_tasks_pad_to_batch_max():
    a, b = _tasks(1, shot=4)[0], _tasks(1, shot=6)[0]
    batch = collate_task_batch([a, b])
    assert batch.support_x.shape[1] == 30      # max(20, 30)
    assert float(batch.support_mask[0].sum()) == 20.0
    assert float(batch.support_mask[1].sum()) == 30.0


def test_collate_bucket_rounding():
    batch = collate_task_batch(_tasks(2), bucket_multiple=16)
    assert batch.support_x.shape[1] == 32      # 25 -> next multiple of 16
    assert batch.query_x.shape[1] == 16        # 15 -> 16


def test_task_batch_at_deterministic():
    b1 = task_batch_at(jax.random.key(3), TCFG, 4, step=7)
    b2 = task_batch_at(jax.random.key(3), TCFG, 4, step=7)
    b3 = task_batch_at(jax.random.key(3), TCFG, 4, step=8)
    assert _max_leaf_diff(b1, b2) == 0.0
    assert _max_leaf_diff(b1, b3) > 0.0


# -- batched == looped ------------------------------------------------------


def test_batched_grads_equal_mean_of_looped(key):
    """Engine contract: vmapped task-batch gradients == the mean of per-task
    gradients computed one task at a time with the same per-task keys."""
    lr = _learner()
    params = lr.init(key)
    tasks = _tasks(4)
    batch = collate_task_batch(tasks)
    k = jax.random.key(9)
    loss_b, acc_b, g_b = jax.jit(make_batched_meta_grads(lr, SPEC))(
        params, batch, k)

    gs, losses = [], []
    for i, t in enumerate(tasks):
        (l, _), g = jax.value_and_grad(
            lambda p: lr.meta_loss(p, t, task_key(k, i), SPEC),
            has_aux=True)(params)
        gs.append(g)
        losses.append(float(l))
    g_mean = jax.tree.map(lambda *a: jnp.mean(jnp.stack(a), 0), *gs)

    np.testing.assert_allclose(float(loss_b), np.mean(losses), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g_b), jax.tree.leaves(g_mean)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_batched_step_equals_looped_step_at_one_task(key):
    """tasks_per_step=1 reproduces paper Algorithm 1's per-task step."""
    lr = _learner()
    params = lr.init(key)
    task = _tasks(1)[0]
    adamw = AdamWConfig(weight_decay=0.0)
    opt = adamw_init(params, adamw)
    k = jax.random.key(4)

    s_loop = jax.jit(make_meta_train_step(lr, SPEC, adamw=adamw))
    p1, o1, m1 = s_loop(params, opt, task, task_key(k, 0))

    s_batch = jax.jit(make_batched_meta_train_step(lr, SPEC, adamw=adamw))
    p2, o2, m2 = s_batch(params, opt, collate_task_batch([task]), k)

    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


# -- padding invariance -----------------------------------------------------


def test_padding_invariance_protonets(key):
    """A padded batch must yield the same loss/grads as the unpadded one —
    the masked estimators re-draw the identical H subset and zero-weight
    every padded row."""
    lr = _learner()
    params = lr.init(key)
    tasks = _tasks(3)
    k = jax.random.key(11)
    gfn = jax.jit(make_batched_meta_grads(lr, SPEC))
    l0, _, g0 = gfn(params, collate_task_batch(tasks), k)
    lp, _, gp = gfn(params, collate_task_batch(tasks, support_size=48,
                                               query_size=24), k)
    np.testing.assert_allclose(float(l0), float(lp), rtol=1e-6)
    gnorm = float(jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(g0))))
    assert _max_leaf_diff(g0, gp) < 1e-4 * max(gnorm, 1.0)


def test_padding_invariance_simple_cnaps_loss(key):
    """Simple CNAPs runs a cholesky/solve chain that amplifies f32
    reduction-order noise, so the invariance contract is checked at the
    loss level with a float tolerance."""
    lr = _learner("simple_cnaps")
    params = lr.init(key)
    tasks = _tasks(2, shot=6)
    k = jax.random.key(13)
    gfn = jax.jit(make_batched_meta_grads(lr, SPEC))
    l0 = gfn(params, collate_task_batch(tasks), k)[0]
    lp = gfn(params, collate_task_batch(tasks, support_size=48,
                                        query_size=24), k)[0]
    np.testing.assert_allclose(float(l0), float(lp), rtol=5e-3)


def test_padded_query_rows_never_move_loss(key):
    """Doubling the query pad alone must not change anything (regression
    guard for the masked cross-entropy denominator)."""
    lr = _learner()
    params = lr.init(key)
    tasks = _tasks(2)
    k = jax.random.key(15)
    gfn = jax.jit(make_batched_meta_grads(lr, SPEC))
    l1 = gfn(params, collate_task_batch(tasks, query_size=16), k)[0]
    l2 = gfn(params, collate_task_batch(tasks, query_size=32), k)[0]
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


# -- per-task key independence ----------------------------------------------


def test_per_task_keys_draw_different_h_subsets():
    """Engine key convention: task i uses task_key(key, i); distinct tasks
    must draw distinct H subsets (Algorithm 1 line 4, independently per
    task in the batch)."""
    key = jax.random.key(0)
    draws = [np.sort(np.asarray(
        sample_h_indices(task_key(key, i), 20, 5)[0])) for i in range(6)]
    distinct = {tuple(d.tolist()) for d in draws}
    assert len(distinct) > 1, draws


def test_identical_tasks_get_independent_gradients(key):
    """Two copies of the SAME task in one batch: exact forward => equal
    losses, but independent H draws => different per-task gradients.  The
    looped reference with the engine's key convention shows both."""
    lr = _learner()
    params = lr.init(key)
    task = _tasks(1, shot=8)[0]
    k = jax.random.key(21)
    grads = []
    for i in range(2):
        g = jax.grad(lambda p: lr.meta_loss(p, task, task_key(k, i), SPEC)[0])(
            params)
        grads.append(g)
    assert _max_leaf_diff(grads[0], grads[1]) > 1e-8

    # and the batched engine's mean over the two slots matches their mean
    batch = collate_task_batch([task, task])
    _, _, g_b = jax.jit(make_batched_meta_grads(lr, SPEC))(params, batch, k)
    g_mean = jax.tree.map(lambda a, b: (a + b) / 2.0, grads[0], grads[1])
    for a, b in zip(jax.tree.leaves(g_b), jax.tree.leaves(g_mean)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


# -- data-parallel shard_map path -------------------------------------------


def test_shard_map_dp_matches_single_device(tmp_path):
    """4 fake CPU devices: the dp-sharded step must reproduce the
    single-device batched step (params replicated, grads pmean'd)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from repro.core.episodic_train import make_batched_meta_train_step
        from repro.core.lite import LiteSpec
        from repro.core.meta_learners import MetaLearnerConfig, make_learner
        from repro.core.set_encoder import SetEncoderConfig
        from repro.data.episodic import (EpisodicImageConfig,
                                         sample_image_task_batch)
        from repro.launch.mesh import make_dp_mesh
        from repro.models.conv_backbone import (ConvBackboneConfig,
                                                make_conv_backbone)
        from repro.optim import AdamWConfig, adamw_init

        assert len(jax.devices()) == 4
        bb = make_conv_backbone(ConvBackboneConfig(widths=(8,), feature_dim=16))
        lr = make_learner(
            MetaLearnerConfig(kind="protonets", way=5), bb,
            SetEncoderConfig(kind="conv", conv_blocks=1, conv_width=4,
                             task_dim=8))
        params = lr.init(jax.random.key(0))
        spec = LiteSpec(h=4)
        adamw = AdamWConfig(weight_decay=0.0)
        opt = adamw_init(params, adamw)
        tcfg = EpisodicImageConfig(way=5, shot=4, query_per_class=2,
                                   image_size=8)
        batch = sample_image_task_batch(jax.random.key(3), tcfg, 8)
        key = jax.random.key(9)

        s1 = jax.jit(make_batched_meta_train_step(lr, spec, adamw=adamw))
        p1, _, m1 = s1(params, opt, batch, key)
        s2 = jax.jit(make_batched_meta_train_step(
            lr, spec, adamw=adamw, mesh=make_dp_mesh(4)))
        p2, _, m2 = s2(params, opt, batch, key)

        err = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                  zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert err < 1e-6, err
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
        print("DP_OK", err)
        """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "DP_OK" in out.stdout


def test_dp_step_rejects_indivisible_batch(key):
    class FakeMesh:
        shape = dict(data=3)

    lr = _learner()
    params = lr.init(key)
    step = make_batched_meta_train_step(lr, SPEC, mesh=FakeMesh())
    batch = collate_task_batch(_tasks(4))
    with pytest.raises(ValueError, match="divisible"):
        step(params, adamw_init(params, AdamWConfig()), batch,
             jax.random.key(0))


# -- two-level engine: config-time validation + local accumulation ----------


def test_meta_train_config_validates_at_construction():
    """Divisibility and reduce-mode errors must fire when the CONFIG is
    built, not at trace time deep inside shard_map."""
    from repro.configs.base import MetaTrainConfig

    MetaTrainConfig(tasks_per_step=8, dp_shards=2, dcn_shards=2,
                    accum_steps=2)      # 8 % (2*2*2) == 0: fine
    with pytest.raises(ValueError, match="divisible"):
        MetaTrainConfig(tasks_per_step=8, dp_shards=3)
    with pytest.raises(ValueError, match="divisible"):
        MetaTrainConfig(tasks_per_step=8, dcn_shards=2, accum_steps=3)
    with pytest.raises(ValueError, match="grad_reduce"):
        MetaTrainConfig(grad_reduce="topk")
    with pytest.raises(ValueError, match=">= 1"):
        MetaTrainConfig(accum_steps=0)


def test_dp_mesh_errors_are_actionable():
    """Oversubscribed meshes must tell the user about CPU device-count
    emulation instead of a bare count mismatch."""
    from repro.launch.mesh import make_dp_mesh, make_two_level_dp_mesh

    n = len(jax.devices())
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        make_dp_mesh(n + 1)
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        make_two_level_dp_mesh(n + 1, 2)


def test_compressed_requires_two_level_mesh(key):
    class FakeMesh:
        shape = dict(data=2)

    with pytest.raises(ValueError, match="two-level"):
        make_batched_meta_train_step(_learner(), SPEC, mesh=FakeMesh(),
                                     grad_reduce="compressed")


def test_local_accumulation_matches_unaccumulated(key):
    """accum_steps chunks the task axis sequentially; per-task keys ride on
    GLOBAL ids so the mean loss/grads match the one-shot step to fp32
    accumulation tolerance (and exactly at accum_steps=1)."""
    lr = _learner()
    params = lr.init(key)
    adamw = AdamWConfig(weight_decay=0.0)
    opt = adamw_init(params, adamw)
    batch = collate_task_batch(_tasks(4))
    k = jax.random.key(5)
    p1, _, m1 = jax.jit(make_batched_meta_train_step(lr, SPEC, adamw=adamw))(
        params, opt, batch, k)
    p2, _, m2 = jax.jit(make_batched_meta_train_step(
        lr, SPEC, adamw=adamw, accum_steps=2))(params, opt, batch, k)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_accum_step_rejects_indivisible_batch(key):
    lr = _learner()
    params = lr.init(key)
    step = make_batched_meta_train_step(lr, SPEC, accum_steps=3)
    with pytest.raises(ValueError, match="divisible"):
        step(params, adamw_init(params, AdamWConfig()),
             collate_task_batch(_tasks(4)), jax.random.key(0))
