"""LITE estimator correctness (paper Eq. 8, §5.3, Tables D.7/D.8)."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lite import (LiteSpec, lite_segment_sum, lite_sum,
                             sample_h_indices, sample_stratified_indices,
                             straight_through, subsampled_task_sum)


def _encode(p, x):
    return jnp.tanh(x @ p)


@pytest.fixture
def setup(key):
    p = jax.random.normal(key, (6, 4))
    xs = jax.random.normal(jax.random.fold_in(key, 1), (20, 6))
    return p, xs


def test_forward_value_is_exact(setup, key):
    """LITE's forward value must equal the full-set sum exactly."""
    p, xs = setup
    exact = jnp.sum(_encode(p, xs), axis=0)
    for h in (1, 5, 19):
        got = lite_sum(_encode, p, xs, key, LiteSpec(h=h))
        np.testing.assert_allclose(np.asarray(got), np.asarray(exact),
                                   rtol=2e-5, atol=2e-6)


def test_forward_value_exact_with_chunking(setup, key):
    p, xs = setup
    exact = jnp.sum(_encode(p, xs), axis=0)
    for chunk in (1, 3, 7, 100):
        got = lite_sum(_encode, p, xs, key, LiteSpec(h=4, chunk_size=chunk))
        np.testing.assert_allclose(np.asarray(got), np.asarray(exact),
                                   rtol=2e-5, atol=2e-6)


def test_gradient_unbiased(setup):
    """Mean of LITE gradients over many draws -> exact gradient."""
    p, xs = setup

    def loss(pp, k, h, exact):
        z = lite_sum(_encode, pp, xs, k, LiteSpec(h=h, exact=exact))
        return jnp.sum(jnp.sin(z) ** 2)

    g_exact = jax.grad(lambda pp: loss(pp, jax.random.key(0), 0, True))(p)
    gfn = jax.jit(jax.grad(loss), static_argnums=(2, 3))
    draws = []
    k = jax.random.key(42)
    for _ in range(300):
        k, sub = jax.random.split(k)
        draws.append(np.asarray(gfn(p, sub, 5, False)))
    draws = np.stack(draws)
    sem = draws.std(0) / np.sqrt(len(draws))
    err = np.abs(draws.mean(0) - np.asarray(g_exact))
    # within 5 standard errors everywhere (unbiasedness)
    assert np.all(err <= 5 * sem + 1e-6), (err / (sem + 1e-12)).max()


def test_gradient_variance_matches_subset_enumeration(key):
    """LITE backward must equal the manual per-subset estimator (N/H sum)."""
    W = jax.random.normal(key, (3, 3))
    xs = jax.random.normal(jax.random.fold_in(key, 1), (4, 3))

    def enc(p, x):
        return x @ p.T

    def loss(p, k):
        z = lite_sum(enc, p, xs, k, LiteSpec(h=2))
        return jnp.sum(z ** 2)

    z_exact = xs.sum(0) @ W.T
    manual = np.stack([
        np.asarray(2.0 * jnp.outer(z_exact, xs[jnp.array(S)].sum(0)) * 2.0)
        for S in itertools.combinations(range(4), 2)])
    gfn = jax.jit(jax.grad(loss))
    draws = np.stack([np.asarray(gfn(W, jax.random.fold_in(key, i)))
                      for i in range(2000)])
    np.testing.assert_allclose(draws.std(0).mean(), manual.std(0).mean(),
                               rtol=0.1)
    # mean within 5 standard errors elementwise (unbiasedness)
    sem = draws.std(0) / np.sqrt(draws.shape[0])
    assert np.all(np.abs(draws.mean(0) - manual.mean(0)) <= 5 * sem + 1e-6)


def test_segment_sum_counts_and_values(setup, key):
    p, xs = setup
    ys = jax.random.randint(jax.random.fold_in(key, 2), (20,), 0, 3)
    sums, counts = lite_segment_sum(_encode, p, xs, ys, 3, key, LiteSpec(h=8))
    enc = _encode(p, xs)
    for c in range(3):
        expect = jnp.sum(jnp.where((ys == c)[:, None], enc, 0), axis=0)
        np.testing.assert_allclose(np.asarray(sums[c]), np.asarray(expect),
                                   rtol=2e-5, atol=2e-6)
        assert counts[c] == jnp.sum(ys == c)


def test_h_geq_n_is_exact_path(setup, key):
    p, xs = setup
    g1 = jax.grad(lambda pp: jnp.sum(
        lite_sum(_encode, pp, xs, key, LiteSpec(h=100)) ** 2))(p)
    g2 = jax.grad(lambda pp: jnp.sum(
        lite_sum(_encode, pp, xs, key, LiteSpec(exact=True)) ** 2))(p)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-6)


def test_straight_through_semantics():
    full = jnp.array([10.0, 20.0])
    grad_val = jnp.array([1.0, 2.0])
    out = straight_through(full, grad_val, 3.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full))
    g = jax.grad(lambda gv: jnp.sum(straight_through(full, gv, 3.0)))(grad_val)
    np.testing.assert_allclose(np.asarray(g), [3.0, 3.0])


def test_sample_h_indices_partition(key):
    h_idx, c_idx = sample_h_indices(key, 10, 4)
    all_idx = np.sort(np.concatenate([np.asarray(h_idx), np.asarray(c_idx)]))
    np.testing.assert_array_equal(all_idx, np.arange(10))


def test_stratified_covers_all_classes(key):
    ys = jnp.repeat(jnp.arange(5), 8)          # 5 classes x 8
    for i in range(20):
        idx = sample_stratified_indices(jax.random.fold_in(key, i), ys, 5, 7)
        classes = set(np.asarray(ys[idx]).tolist())
        assert classes == set(range(5))


def test_lite_forward_exact_grid(key):
    """Seeded-loop port of the hypothesis property (test_property.py::
    test_lite_forward_always_exact): the forward value is the exact full
    sum for every (n, h, chunk) combination — always runs, with or without
    hypothesis installed."""
    for seed, (n, h, chunk) in enumerate(itertools.product(
            (2, 7, 24), (1, 3, 24), (None, 1, 5))):
        k = jax.random.fold_in(key, seed)
        p = jax.random.normal(k, (6, 4))
        xs = jax.random.normal(jax.random.fold_in(k, 1), (n, 6))
        got = lite_sum(_encode, p, xs, k, LiteSpec(h=h, chunk_size=chunk))
        want = jnp.sum(_encode(p, xs), axis=0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=5e-5, atol=5e-5, err_msg=str((n, h, chunk)))


def test_lite_grad_unbiased_grid():
    """Seeded-loop port of the unbiasedness property across several (n, h)
    regimes: mean LITE gradient over draws approaches the exact gradient
    to within sampling error."""
    for n, h in ((6, 2), (12, 5), (20, 13)):
        k0 = jax.random.key(1000 + n)
        p = jax.random.normal(k0, (5, 3))
        xs = jax.random.normal(jax.random.fold_in(k0, 1), (n, 5))

        def loss(pp, k, hh, exact):
            z = lite_sum(_encode, pp, xs, k, LiteSpec(h=hh, exact=exact))
            return jnp.sum(jnp.sin(z) ** 2)

        g_exact = np.asarray(jax.grad(
            lambda pp: loss(pp, k0, 0, True))(p), np.float64)
        gfn = jax.jit(jax.grad(loss), static_argnums=(2, 3))
        draws = np.stack([np.asarray(gfn(p, jax.random.fold_in(k0, 2 + i),
                                         h, False), np.float64)
                          for i in range(200)])
        sem = draws.std(0) / np.sqrt(len(draws))
        err = np.abs(draws.mean(0) - g_exact)
        assert np.all(err <= 5 * sem + 1e-6), (n, h, (err / (sem + 1e-12)).max())


def test_lite_masked_matches_unmasked(key):
    """mask=ones reproduces the unmasked estimator; padded rows with
    mask=0 are invisible to forward AND backward."""
    p = jax.random.normal(key, (6, 4))
    xs = jax.random.normal(jax.random.fold_in(key, 1), (12, 6))
    spec = LiteSpec(h=4)

    def loss(pp, x, m):
        return jnp.sum(lite_sum(_encode, pp, x, key, spec, mask=m) ** 2)

    ones = jnp.ones((12,))
    l_none = jnp.sum(lite_sum(_encode, p, xs, key, spec) ** 2)
    np.testing.assert_allclose(float(loss(p, xs, ones)), float(l_none),
                               rtol=1e-6)
    g_ones = jax.grad(loss)(p, xs, ones)
    g_none = jax.grad(lambda pp: jnp.sum(
        lite_sum(_encode, pp, xs, key, spec) ** 2))(p)
    np.testing.assert_allclose(np.asarray(g_ones), np.asarray(g_none),
                               rtol=1e-5, atol=1e-6)

    # pad with garbage rows, masked out -> same value and gradient
    xs_pad = jnp.concatenate([xs, 100.0 + jnp.zeros((5, 6))])
    m_pad = jnp.concatenate([ones, jnp.zeros((5,))])
    np.testing.assert_allclose(float(loss(p, xs_pad, m_pad)), float(l_none),
                               rtol=1e-5)
    g_pad = jax.grad(loss)(p, xs_pad, m_pad)
    np.testing.assert_allclose(np.asarray(g_pad), np.asarray(g_none),
                               rtol=1e-4, atol=1e-5)


def test_subsampled_task_value_unbiased(setup):
    p, xs = setup
    exact = jnp.sum(_encode(p, xs), axis=0)
    vals = []
    k = jax.random.key(3)
    for _ in range(400):
        k, sub = jax.random.split(k)
        vals.append(np.asarray(
            subsampled_task_sum(_encode, p, xs, sub, LiteSpec(h=5))))
    vals = np.stack(vals)
    sem = vals.std(0) / np.sqrt(len(vals))
    assert np.all(np.abs(vals.mean(0) - np.asarray(exact)) <= 5 * sem + 1e-6)
