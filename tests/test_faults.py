"""Deterministic fault-injection suite (PR7): every fault site in the
stack's tolerance contract reproduces bit-for-bit from a seeded
:class:`repro.faults.FaultPlan` — no real signals, no sleeps (backoffs at
0 / FakeClock), no monkeypatching — and every degradation path proves its
documented behavior:

* non-finite gradients  -> bit-identical skipped step, counted
* consecutive skips     -> rollback to the last committed checkpoint,
                           replay bit-exact with a never-diverged run
* transient data faults -> bounded-backoff retry heals in place (sync and
                           prefetcher paths), exhausted retries propagate
* preemption            -> checkpoint flushed, resume bit-exact
* checkpoint kills      -> previous commit restorable, fresh save recovers
* corrupt warm entries  -> quarantined, engine re-adapts (logits == cold
                           path, compile counters flat)
* vanished warm dir     -> store degrades to L1-only, engine survives
* overload              -> bounded-queue rejection with retry-after; no
                           admitted request is ever lost
* deadlines             -> hopeless requests abandoned, lanes freed
"""
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import FakeClock
from repro.configs.base import MetaTrainConfig
from repro.core.episodic_train import make_batched_meta_train_step
from repro.core.lite import LiteSpec
from repro.core.meta_learners import MetaLearnerConfig, make_learner
from repro.core.set_encoder import SetEncoderConfig
from repro.data.episodic import (EpisodicImageConfig, sample_image_task,
                                 task_batch_at)
from repro.faults import (CKPT_PRE_COMMIT, CKPT_PRE_REPLACE, DATA_NAN,
                          DATA_TRANSIENT, REPLICA_DEAD, TRAIN_PREEMPT,
                          TRAIN_STRAGGLER, WARM_CORRUPT, WARM_VANISH,
                          FaultPlan, FaultSpec, InjectedKill,
                          PreemptionSignal, TransientDataError)
from repro.models.conv_backbone import ConvBackboneConfig, make_conv_backbone
from repro.optim import AdamWConfig, adamw_init
from repro.serve.episodic import (EpisodicRequest, EpisodicServeEngine,
                                  TwoTierTaskStore, WarmTaskStore)
from repro.serve.replica import ReplicatedServeEngine, uid_replica
from repro.train.checkpoint import (CheckpointManager, ChecksumError,
                                    load_array_tree, save_array_tree)
from repro.train.loop import DivergenceError, PreemptedError, train

pytestmark = pytest.mark.faults

BB = make_conv_backbone(ConvBackboneConfig(widths=(4,), feature_dim=8))
SET_CFG = SetEncoderConfig(kind="conv", conv_blocks=1, conv_width=4,
                           task_dim=8)
TCFG = EpisodicImageConfig(way=3, shot=2, query_per_class=2, image_size=8)
SPEC = LiteSpec(h=2)
ADAMW = AdamWConfig(weight_decay=0.0)
SERVE_LITE = LiteSpec(exact=True, chunk_size=8)


def _bit_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x).ravel().view(np.uint8),
                              np.asarray(y).ravel().view(np.uint8))
               for x, y in zip(la, lb))


def _episodic_pieces(tasks_per_step=2):
    lr = make_learner(MetaLearnerConfig(kind="protonets", way=3), BB, SET_CFG)
    params = lr.init(jax.random.key(0))
    inner = make_batched_meta_train_step(lr, SPEC, adamw=ADAMW)

    def train_step(state, batch):
        p, o, m = inner(state["params"], state["opt"], batch["tasks"],
                        batch["key"])
        return dict(params=p, opt=o), m

    dk, sk = jax.random.key(17), jax.random.key(23)

    def batch_at(s):
        return dict(tasks=task_batch_at(dk, TCFG, tasks_per_step, s),
                    key=jax.random.fold_in(sk, s))

    def fresh_state():
        return dict(params=jax.tree.map(jnp.copy, params),
                    opt=adamw_init(params, ADAMW))

    return lr, train_step, batch_at, fresh_state


# ---------------------------------------------------------------------------
# FaultPlan mechanics
# ---------------------------------------------------------------------------


def test_fault_plan_seeded_is_deterministic():
    a = FaultPlan.seeded(7, DATA_NAN, num_steps=100, rate=0.1)
    b = FaultPlan.seeded(7, DATA_NAN, num_steps=100, rate=0.1)
    assert [s.at for s in a.specs] == [s.at for s in b.specs]
    assert a.specs, "rate 0.1 over 100 steps must schedule something"
    c = FaultPlan.seeded(8, DATA_NAN, num_steps=100, rate=0.1)
    assert [s.at for s in a.specs] != [s.at for s in c.specs]


def test_fault_plan_fire_matching_and_counts():
    plan = FaultPlan([FaultSpec(site=DATA_NAN, at=3),
                      FaultSpec(site=DATA_TRANSIENT, at=None, count=2)])
    assert plan.fire(DATA_NAN, 2) is None          # wrong index
    assert plan.fire(TRAIN_PREEMPT, 3) is None     # wrong site
    assert plan.fire(DATA_NAN, 3) is not None
    assert plan.fire(DATA_NAN, 3) is None          # count exhausted
    # any-index spec fires exactly `count` times
    assert plan.fire(DATA_TRANSIENT, 0) is not None
    assert plan.fire(DATA_TRANSIENT, 9) is not None
    assert plan.fire(DATA_TRANSIENT, 9) is None
    assert plan.fired == [(DATA_NAN, 3, "error"), (DATA_TRANSIENT, 0, "error"),
                          (DATA_TRANSIENT, 9, "error")]
    assert plan.fired_count(DATA_TRANSIENT) == 2


# ---------------------------------------------------------------------------
# non-finite guard + divergence rollback
# ---------------------------------------------------------------------------


def test_nonfinite_step_is_bit_identical_skip():
    """A NaN batch through the guarded step must leave params AND opt
    state (count included) bit-identical, reporting nonfinite=1; the next
    clean batch reports 0 and updates."""
    _, train_step, batch_at, fresh_state = _episodic_pieces()
    plan = FaultPlan.single(DATA_NAN, at=0)
    poisoned = plan.wrap_batch_at(batch_at)
    state = fresh_state()
    step = jax.jit(train_step)
    new_state, m = step(state, poisoned(0))
    assert float(m["nonfinite"]) == 1.0
    assert _bit_equal(new_state, state)
    newer, m2 = step(new_state, poisoned(1))       # spec exhausted: clean
    assert float(m2["nonfinite"]) == 0.0
    assert not _bit_equal(newer, new_state)


def test_all_steps_poisoned_leaves_initial_state():
    _, train_step, batch_at, fresh_state = _episodic_pieces()
    plan = FaultPlan.single(DATA_NAN, at=None, count=3)
    ref = fresh_state()
    r = train(fresh_state(), train_step, batch_at, 3, fault_plan=plan,
              max_nonfinite=10)
    assert r.nonfinite_steps == [0, 1, 2]
    assert _bit_equal(r.state, ref)
    assert all(m["nonfinite"] == 1.0 for m in r.metrics_history)


def test_divergence_without_checkpoint_raises():
    _, train_step, batch_at, fresh_state = _episodic_pieces()
    plan = FaultPlan.single(DATA_NAN, at=None, count=10)
    with pytest.raises(DivergenceError, match="consecutive non-finite"):
        train(fresh_state(), train_step, batch_at, 8, fault_plan=plan,
              max_nonfinite=2)


def test_divergence_rolls_back_and_replays_bit_exact(tmp_path):
    """NaNs at steps 2-5, budget 2: skips at 2,3 then the skip at 4 blows
    the budget -> rollback to the committed checkpoint at step 4 (state
    unchanged by the skips) and replay.  The replayed run sees the healed
    stream (specs are one-shot), so the final state must be BIT-EXACT with
    a reference run that skipped only {2,3,5} and never diverged."""
    _, train_step, batch_at, fresh_state = _episodic_pieces()
    template = jax.eval_shape(fresh_state)

    ref_plan = FaultPlan([FaultSpec(site=DATA_NAN, at=s) for s in (2, 3, 5)])
    ref = train(fresh_state(), train_step, batch_at, 8, fault_plan=ref_plan,
                max_nonfinite=10)
    assert ref.nonfinite_steps == [2, 3, 5] and ref.rollbacks == 0

    plan = FaultPlan([FaultSpec(site=DATA_NAN, at=s) for s in (2, 3, 4, 5)])
    ck = CheckpointManager(tmp_path / "ck", keep=5)
    r = train(fresh_state(), train_step, batch_at, 8, fault_plan=plan,
              ckpt=ck, ckpt_every=2, state_template=template,
              max_nonfinite=2, max_rollbacks=1)
    assert r.rollbacks == 1
    assert r.nonfinite_steps == [2, 3, 5]          # 4 replayed clean
    assert len(r.metrics_history) == 8 == len(r.step_times)
    assert _bit_equal(r.state, ref.state)


# ---------------------------------------------------------------------------
# transient data faults: bounded retry
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("prefetch", [0, 2])
def test_transient_data_fault_heals_bit_exact(prefetch):
    """A transient fault that fails twice then heals is absorbed by 2
    retries (backoff 0: no waiting) in BOTH the sync loop and the
    prefetcher worker — the delivered stream, and so the final state, is
    bit-exact with a faultless run."""
    _, train_step, batch_at, fresh_state = _episodic_pieces()
    clean = train(fresh_state(), train_step, batch_at, 4)
    plan = FaultPlan.single(DATA_TRANSIENT, at=2, count=2)
    r = train(fresh_state(), train_step, batch_at, 4, fault_plan=plan,
              prefetch=prefetch, data_retries=2, data_backoff_s=0.0)
    assert r.data_retries == 2
    assert plan.fired_count(DATA_TRANSIENT) == 2
    assert _bit_equal(r.state, clean.state)


def test_transient_fault_outliving_retries_propagates():
    _, train_step, batch_at, fresh_state = _episodic_pieces()
    plan = FaultPlan.single(DATA_TRANSIENT, at=1, count=5)
    with pytest.raises(TransientDataError):
        train(fresh_state(), train_step, batch_at, 4, fault_plan=plan,
              data_retries=1, data_backoff_s=0.0)


# ---------------------------------------------------------------------------
# graceful preemption
# ---------------------------------------------------------------------------


def test_preempt_fault_flushes_and_resumes_bit_exact(tmp_path):
    _, train_step, batch_at, fresh_state = _episodic_pieces()
    template = jax.eval_shape(fresh_state)
    clean = train(fresh_state(), train_step, batch_at, 6)

    ck = CheckpointManager(tmp_path / "ck", keep=5)
    plan = FaultPlan.single(TRAIN_PREEMPT, at=3)
    with pytest.raises(PreemptedError) as ei:
        train(fresh_state(), train_step, batch_at, 6, fault_plan=plan,
              ckpt=ck, ckpt_every=100, state_template=template)
    assert ei.value.step == 3 and ei.value.flushed
    assert ck.latest_step() == 3                   # flushed mid-interval

    r = train(fresh_state(), train_step, batch_at, 6, ckpt=ck,
              ckpt_every=100, state_template=template)
    assert r.resumed_from == 3
    assert _bit_equal(r.state, clean.state)


def test_preemption_signal_polled_and_real_signal_sets_it(tmp_path):
    _, train_step, batch_at, fresh_state = _episodic_pieces()
    template = jax.eval_shape(fresh_state)
    ck = CheckpointManager(tmp_path / "ck", keep=5)
    preempt = PreemptionSignal()

    def hook(s):                                   # a SIGTERM landing at 2
        if s == 2:
            preempt.request()

    with pytest.raises(PreemptedError) as ei:
        train(fresh_state(), train_step, batch_at, 6, ckpt=ck,
              ckpt_every=100, state_template=template, preempt=preempt,
              preemption_hook=hook)
    assert ei.value.step == 2 and ck.latest_step() == 2

    # install() wires a real signal to the flag (SIGUSR1: deliverable
    # to ourselves without killing the test runner)
    sig2 = PreemptionSignal().install(signals=[signal.SIGUSR1])
    assert not sig2.requested
    os.kill(os.getpid(), signal.SIGUSR1)
    assert sig2.requested


# ---------------------------------------------------------------------------
# checkpoint crash consistency
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("site", [CKPT_PRE_COMMIT, CKPT_PRE_REPLACE])
def test_ckpt_kill_leaves_previous_restorable(tmp_path, site):
    """A death between the tmp write and the atomic publish (before OR
    after the COMMIT marker lands in the tmp dir) must leave the previous
    committed step bit-exact and a later save must recover."""
    state1 = dict(w=jnp.arange(4, dtype=jnp.float32), n=jnp.asarray(1))
    state2 = dict(w=jnp.arange(4, dtype=jnp.float32) * 2, n=jnp.asarray(2))
    template = jax.eval_shape(lambda: state1)
    plan = FaultPlan.single(site, at=2)
    ck = CheckpointManager(tmp_path / "ck", keep=3, fault_plan=plan)
    ck.save(1, state1)
    with pytest.raises(InjectedKill):
        ck.save(2, state2)
    # a fresh manager on the same dir (the restarted process)
    ck2 = CheckpointManager(tmp_path / "ck", keep=3)
    assert ck2.all_steps() == [1]                  # partial save invisible
    step, restored, _ = ck2.restore_latest(template)
    assert step == 1 and _bit_equal(restored, state1)
    ck2.save(2, state2)                            # recovery over residue
    assert ck2.all_steps() == [1, 2]
    assert _bit_equal(ck2.restore(2, template)[0], state2)


def test_ckpt_kill_mid_training_then_resume_bit_exact(tmp_path):
    _, train_step, batch_at, fresh_state = _episodic_pieces()
    template = jax.eval_shape(fresh_state)
    clean = train(fresh_state(), train_step, batch_at, 6)

    plan = FaultPlan.single(CKPT_PRE_COMMIT, at=4)
    ck = CheckpointManager(tmp_path / "ck", keep=5, fault_plan=plan)
    with pytest.raises(InjectedKill):
        train(fresh_state(), train_step, batch_at, 6, ckpt=ck,
              ckpt_every=2, state_template=template)
    ck2 = CheckpointManager(tmp_path / "ck", keep=5)
    assert ck2.latest_step() == 2                  # step-4 save died
    r = train(fresh_state(), train_step, batch_at, 6, ckpt=ck2,
              ckpt_every=2, state_template=template)
    assert r.resumed_from == 2
    assert _bit_equal(r.state, clean.state)


def test_checksum_verification_catches_tampering(tmp_path):
    state = dict(a=jnp.arange(8, dtype=jnp.float32),
                 b=jnp.ones((3,), jnp.bfloat16))
    f = tmp_path / "t.npz"
    save_array_tree(f, state)
    template = jax.eval_shape(lambda: state)
    assert _bit_equal(load_array_tree(f, template, verify=True), state)

    # rewrite the npz with one flipped payload byte but the ORIGINAL crc
    # (zipfile's own per-member crc is recomputed by savez, so only our
    # whole-content checksum can notice)
    data = dict(np.load(f).items())
    tampered = np.array(data["a"])
    tampered[3] += 1.0
    data["a"] = tampered
    with open(f, "wb") as fh:
        np.savez(fh, **data)
    with pytest.raises(ChecksumError, match="crc32"):
        load_array_tree(f, template, verify=True)
    load_array_tree(f, template)                   # verify=False: trusted


# ---------------------------------------------------------------------------
# straggler injection under a fake clock
# ---------------------------------------------------------------------------


def test_straggler_fault_detected_and_clean_run_silent():
    """An injected 1s stall at step 4 (virtual: the fault advances the
    loop's FakeClock, zero real sleeping) must be flagged in
    TrainResult.straggler_steps; the same run without the plan flags
    nothing."""
    def step_fn(state, batch):
        return jax.tree.map(lambda p: p + batch["x"], state), \
            dict(loss=batch["x"])

    def run(plan):
        clock = FakeClock()

        def batch_at(s):
            clock.advance(0.01)                    # steady 10ms "work"
            return dict(x=jnp.asarray(float(s)))

        return train(dict(w=jnp.zeros(())), step_fn, batch_at, 8,
                     fault_plan=plan, clock=clock)

    flagged = run(FaultPlan.single(TRAIN_STRAGGLER, at=4, payload=1.0))
    assert flagged.straggler_steps == [4]
    assert run(None).straggler_steps == []


# ---------------------------------------------------------------------------
# warm tier: checksums, quarantine, vanished directory
# ---------------------------------------------------------------------------


def _small_state():
    return dict(a=jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                b=jnp.ones((4,), jnp.bfloat16))


@pytest.mark.parametrize("keep_bytes", [0, 40])
def test_warm_store_truncated_file_quarantined(tmp_path, keep_bytes):
    """Zero-byte and truncated spilled npz files (crash-mid-write residue)
    must quarantine — renamed aside, template dropped, get -> None — not
    crash the reader."""
    w = WarmTaskStore(tmp_path / "warm")
    w.put(5, _small_state())
    with open(w._path(5), "r+b") as f:
        f.truncate(keep_bytes)
    assert w.get(5) is None
    assert w.quarantined == 1
    assert not w._path(5).exists()                 # moved aside
    aside = list((tmp_path / "warm").glob("quarantine_uid_5_*.npz"))
    assert len(aside) == 1
    assert 5 not in w
    assert w.get(5) is None and w.quarantined == 1  # miss now, not re-count


def test_warm_store_corrupt_fault_site(tmp_path):
    plan = FaultPlan.single(WARM_CORRUPT, at=5, payload=32)
    w = WarmTaskStore(tmp_path / "warm", fault_plan=plan)
    w.put(4, _small_state())                       # untargeted uid: intact
    w.put(5, _small_state())
    assert plan.fired_count(WARM_CORRUPT) == 1
    assert w.get(5) is None and w.quarantined == 1
    assert w.get(4) is not None and w.quarantined == 1


def test_spill_survives_vanished_warm_dir(tmp_path):
    """The warm dir disappearing out from under a spill (tmpfs cleanup)
    degrades the store to L1-only: error logged+counted, engine-visible
    behavior is just a cold re-adapt, never a crash."""
    plan = FaultPlan.single(WARM_VANISH)
    store = TwoTierTaskStore(1, warm_dir=tmp_path / "warm", fault_plan=plan)
    store.put(1, _small_state())
    store.put(2, _small_state())                   # evicts 1 -> spill dies
    assert store.spill_errors == 1 and store.warm_disabled
    assert store.get(1) is None                    # discarded, no warm look
    store.put(3, _small_state())                   # further evicts: no crash
    assert store.spill_errors == 1                 # degraded once, silent now
    assert store.get(3) is not None and 2 not in store


# ---------------------------------------------------------------------------
# engine-level degradation
# ---------------------------------------------------------------------------


def _engine(tmp_path=None, **kw):
    lr = make_learner(MetaLearnerConfig(kind="protonets", way=3), BB, SET_CFG)
    params = lr.init(jax.random.key(0))
    kw.setdefault("lite", SERVE_LITE)
    kw.setdefault("n_slots", 2)
    kw.setdefault("query_chunk", 4)
    kw.setdefault("support_buckets", (8,))
    if tmp_path is not None:
        kw.setdefault("warm_dir", tmp_path / "warm")
    return EpisodicServeEngine(lr, params, **kw)


def _request(uid, with_support=True, seed=300):
    t = sample_image_task(jax.random.key(seed + uid), TCFG)
    return EpisodicRequest(
        uid=uid,
        support_x=np.asarray(t.support_x) if with_support else None,
        support_y=np.asarray(t.support_y) if with_support else None,
        query_x=np.asarray(t.query_x), way=3)


def test_corrupt_warm_entry_falls_back_to_readapt(tmp_path):
    """uid 0's spilled state is corrupted on disk; the repeat request
    (support attached) must quarantine it, re-adapt, and produce logits
    bit-equal to a never-cached cold engine — with compile counters flat
    across the degradation (same bucketed shapes, no recompile)."""
    plan = FaultPlan.single(WARM_CORRUPT, at=0)
    eng = _engine(tmp_path, cache_capacity=1, fault_plan=plan)
    r0, r1 = _request(0), _request(1)
    eng.run_to_completion([r0])
    eng.run_to_completion([r1])                    # evicts 0 -> corrupt spill
    compiles = (eng.stats()["adapt_compiles"], eng.stats()["predict_compiles"])

    repeat = _request(0)
    eng.run_to_completion([repeat])
    s = eng.stats()
    assert repeat.done and not repeat.failed
    assert repeat.cache_hit is False               # quarantine forced cold
    assert s["quarantined"] == 1 and s["rehydrates"] == 0
    assert (s["adapt_compiles"], s["predict_compiles"]) == compiles

    cold = _engine(None)                           # no warm tier, fresh
    ref = _request(0)
    cold.run_to_completion([ref])
    assert _bit_equal(repeat.all_logits(), ref.all_logits())


def test_supportless_request_on_quarantined_state_fails_terminal(tmp_path):
    plan = FaultPlan.single(WARM_CORRUPT, at=0)
    eng = _engine(tmp_path, cache_capacity=1, fault_plan=plan)
    eng.run_to_completion([_request(0)])
    eng.run_to_completion([_request(1)])           # spill+corrupt uid 0
    orphan = _request(0, with_support=False)
    healthy = _request(2)
    eng.run_to_completion([orphan, healthy])
    assert orphan.failed and orphan.done and not orphan.logits
    assert healthy.done and not healthy.failed     # engine kept serving
    assert eng.stats()["failed_requests"] == 1


def test_bounded_queue_rejects_with_retry_after(fake_clock):
    """Overload: submits beyond max_queue are rejected (with a re-offer
    estimate from the adapt-cost EWMA), and every ADMITTED request still
    completes with its full logit stream — backpressure never sheds
    accepted work."""
    eng = _engine(None, n_slots=1, clock=fake_clock, max_queue=2,
                  adapt_cost_hint_us=100.0)
    reqs = [_request(i) for i in range(4)]
    assert eng.submit(reqs[0]) and eng.submit(reqs[1])
    assert not eng.submit(reqs[2])                 # queue full
    assert not eng.submit(reqs[3])
    assert reqs[2].rejected and reqs[2].retry_after_us == pytest.approx(300.0)
    assert eng.stats()["rejections"] == 2
    eng.run_to_completion([])
    for r in reqs[:2]:
        assert r.done and r.served == r.n_queries
    assert not reqs[2].done and not reqs[2].logits


def test_deadline_abandons_queued_and_unadapted_requests(fake_clock):
    """With a 1ms deadline, a queued request and an admitted-but-unadapted
    lane both abandon once the (virtual) clock passes it — lanes free up
    and the engine proceeds; a request already streaming is never
    abandoned."""
    eng = _engine(None, n_slots=1, clock=fake_clock, deadline_us=1000.0)
    served = _request(0)
    eng.run_to_completion([served])                # completes pre-deadline
    assert served.done and not served.abandoned

    lane = _request(1)
    queued = _request(2)
    assert eng.add_request(lane)                   # admitted, adapt pending
    eng.submit(queued)
    fake_clock.advance(0.01)                       # 10ms >> deadline
    eng.step()
    assert lane.abandoned and lane.done and not lane.logits
    assert queued.abandoned and queued.done
    assert eng.stats()["deadline_abandoned"] == 2
    late = _request(3)
    eng.run_to_completion([late])                  # lane was freed
    assert late.done and not late.abandoned


def test_stats_exposes_degradation_counters_zero_on_clean_run():
    eng = _engine(None)
    eng.run_to_completion([_request(0), _request(1)])
    s = eng.stats()
    for k in ("quarantined", "spill_errors", "rejections",
              "deadline_abandoned", "failed_requests"):
        assert s[k] == 0, k


# ---------------------------------------------------------------------------
# replica.dead — replica failover in the multi-replica router
# ---------------------------------------------------------------------------


def _router(tmp_path=None, **kw):
    lr = make_learner(MetaLearnerConfig(kind="protonets", way=3), BB, SET_CFG)
    params = lr.init(jax.random.key(0))
    kw.setdefault("lite", SERVE_LITE)
    kw.setdefault("n_slots", 2)
    kw.setdefault("query_chunk", 4)
    kw.setdefault("support_buckets", (8,))
    kw.setdefault("replicas", 2)
    if tmp_path is not None:
        kw.setdefault("warm_dir", tmp_path / "warm")
    return ReplicatedServeEngine(lr, params, **kw)


def _uids_homed(replica, replicas, n, start=0):
    out, u = [], start
    while len(out) < n:
        if uid_replica(u, replicas) == replica:
            out.append(u)
        u += 1
    return out


def test_replica_dead_reroutes_and_rehydrates_bit_exact(tmp_path):
    """A replica injected dead mid-run is quarantined: its queued work is
    re-routed to the survivor by the same hash (linear probe), and uids
    whose state had SPILLED to the shared warm tier rehydrate bit-exactly
    there — replica 0's store never saw them spill (they landed after its
    startup scan), so this exercises rescan-on-miss end to end."""
    router = _router(tmp_path, cache_capacity=1)    # tiny L1: force spills
    u1 = _uids_homed(1, 2, 3)
    first = [_request(u) for u in u1]
    router.run_to_completion(first)
    # evict replica 1's resident state too, so every u1 state is on disk
    router.run_to_completion([_request(u) for u in _uids_homed(1, 2, 1, 100)])
    assert router.stats()["spills"] >= len(u1)

    router.fault_plan = FaultPlan.single(REPLICA_DEAD, at=1)
    repeats = [_request(u, with_support=False) for u in u1]
    router.run_to_completion(repeats)

    s = router.stats()
    assert s["replica_failovers"] == 1 and s["live_replicas"] == 1
    assert s["rerouted_requests"] == len(u1)
    assert router.fault_plan.fired == [(REPLICA_DEAD, 1, "error")]
    assert all(router.route(u) == 0 for u in u1)    # deterministic reroute
    for a, b in zip(first, repeats):
        assert b.done and not b.failed
        assert _bit_equal(a.all_logits(), b.all_logits())
    assert s["tasks_adapted"] == len(u1) + 1        # nothing re-adapted
    assert s["per_replica"][0]["rescan_hits"] >= len(u1)
    assert s["per_replica"][0]["rehydrates"] >= len(u1)


def test_replica_dead_supportless_unspilled_fails_terminal():
    """Without a warm tier, a dead replica's L1 dies with it: a drained
    support-less request whose uid the survivor cannot find anywhere
    fails terminally (counted, never a crash), while drained requests
    WITH support re-adapt cold on the survivor."""
    router = _router(None)                          # no warm tier
    (u,) = _uids_homed(1, 2, 1)
    router.run_to_completion([_request(u)])         # state in replica 1's L1

    router.fault_plan = FaultPlan.single(REPLICA_DEAD, at=1)
    orphan = _request(u, with_support=False)
    healthy = _request(_uids_homed(1, 2, 2)[1])     # support attached
    router.submit(orphan)
    router.submit(healthy)
    router.run_to_completion([])

    assert orphan.failed and orphan.done and not orphan.logits
    assert healthy.done and not healthy.failed      # re-adapted on 0
    s = router.stats()
    assert s["replica_failovers"] == 1
    assert s["failover_failed"] == 1
    assert s["failed_requests"] >= 1
    assert s["per_replica"][0]["queries_served"] > 0


def test_last_replica_cannot_be_quarantined():
    """Failover needs a survivor: quarantining the last live replica
    raises instead of silently dropping the deployment."""
    router = _router(None)
    router.quarantine_replica(0)
    with pytest.raises(RuntimeError, match="last live"):
        router.quarantine_replica(1)
    # routing still works through the survivor
    assert all(router.route(u) == 1 for u in range(8))


# ---------------------------------------------------------------------------
# LM-step guard (the non-episodic path shares the contract)
# ---------------------------------------------------------------------------


def test_lm_train_step_skips_nonfinite_bitwise(key):
    """NaN params make every gradient non-finite; the guarded LM step must
    return params/opt bit-identical (NaN payloads preserved exactly by the
    where-select) with nonfinite=1, and a finite state must update with
    nonfinite=0."""
    from repro.configs.registry import get_smoke_config
    from repro.train.step import adamw_for, make_init_state, make_train_step

    cfg = get_smoke_config("minitron-4b")
    init = make_init_state(cfg, adamw_for(cfg))
    state = init(key)
    step = jax.jit(make_train_step(cfg, adamw_for(cfg)))
    batch = dict(tokens=jnp.zeros((2, 8), jnp.int32))

    poisoned = dict(params=jax.tree.map(
        lambda p: jnp.full_like(p, jnp.nan)
        if jnp.issubdtype(p.dtype, jnp.inexact) else p, state["params"]),
        opt=state["opt"])
    out, m = step(poisoned, batch)
    assert float(m["nonfinite"]) == 1.0
    assert _bit_equal(out, poisoned)

    out2, m2 = step(state, batch)
    assert float(m2["nonfinite"]) == 0.0
    assert not _bit_equal(out2, state)
