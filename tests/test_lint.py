"""repro.lint: per-rule positive/negative fixtures, pragma suppression,
the repo self-scan-clean invariant, the CLI smoke, and the compiled-HLO
contract checker (pure helpers on toy inputs + the real 4-device cells
in a subprocess)."""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

from repro.lint import engine, rules
from repro.lint.contracts import (check_compile_flat, check_inter_group,
                                  check_wire_budget, entry_param_dtypes,
                                  find_outer_tensors, replica_wire_budget,
                                  serve_layout_budgets)

pytestmark = pytest.mark.lint

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def scan(src: str, rel: str):
    return engine.lint_source(textwrap.dedent(src), rel, rules.ALL_RULES)


def hits(src: str, rel: str, rule: str):
    return [f for f in scan(src, rel) if f.rule == rule]


# --------------------------------------------------------- rule fixtures
# one (positive fires, negative clean) pair per rule, at a rel path
# inside the rule's scope

RULE_FIXTURES = {
    "jax-api-drift": dict(
        rel="src/repro/core/x.py",
        positive="""
            import jax
            f = jax.shard_map(g, mesh=m, in_specs=s, out_specs=s)
        """,
        negative="""
            from repro.sharding import shard_map
            f = shard_map(g, mesh=m, in_specs=s, out_specs=s)
        """),
    "raw-cost-analysis": dict(
        rel="src/repro/launch/x.py",
        positive="""
            cost = compiled.cost_analysis() or {}
        """,
        negative="""
            from repro.roofline.hlo import xla_cost_analysis
            cost = xla_cost_analysis(compiled)
        """),
    "clock-discipline": dict(
        rel="src/repro/serve/x.py",
        positive="""
            import time
            def step(self):
                t0 = time.time()
        """,
        negative="""
            import time
            def step(self, clock=time.monotonic):
                t0 = clock()
        """),
    "atomic-publish": dict(
        rel="src/repro/serve/x.py",
        positive="""
            def save(path, data):
                with open(path, "wb") as f:
                    f.write(data)
        """,
        negative="""
            import os
            def save(path, tmp, data):
                with open(tmp, "wb") as f:
                    f.write(data)
                os.replace(tmp, path)
        """),
    "fault-site-registry": dict(
        rel="src/repro/serve/x.py",
        positive="""
            def put(self, uid):
                spec = self.fault_plan.fire("warm.corrupt", uid)
        """,
        negative="""
            from repro.faults.plan import WARM_CORRUPT
            def put(self, uid):
                spec = self.fault_plan.fire(WARM_CORRUPT, uid)
        """),
    "seeded-rng": dict(
        rel="src/repro/data/x.py",
        positive="""
            import numpy as np
            x = np.random.rand(4)
        """,
        negative="""
            import numpy as np
            rng = np.random.default_rng(0)
            x = rng.random(4)
        """),
    "static-aux-hashable": dict(
        rel="src/repro/serve/x.py",
        positive="""
            import jax
            jax.tree_util.register_pytree_node(
                T, lambda t: ((t.x,), [t.a, t.b]), lambda aux, ch: T(*ch))
        """,
        negative="""
            import jax
            jax.tree_util.register_pytree_node(
                T, lambda t: ((t.x,), (t.a, t.b)), lambda aux, ch: T(*ch))
        """),
}


@pytest.mark.parametrize("rule", sorted(RULE_FIXTURES))
def test_rule_fires_on_violation(rule):
    fx = RULE_FIXTURES[rule]
    found = hits(fx["positive"], fx["rel"], rule)
    assert found, f"{rule} missed its positive fixture"
    assert all(f.path == fx["rel"] and f.line > 0 for f in found)


@pytest.mark.parametrize("rule", sorted(RULE_FIXTURES))
def test_rule_quiet_on_clean_code(rule):
    fx = RULE_FIXTURES[rule]
    assert hits(fx["negative"], fx["rel"], rule) == []


@pytest.mark.parametrize("rule", sorted(RULE_FIXTURES))
def test_pragma_suppresses_each_rule(rule):
    fx = RULE_FIXTURES[rule]
    src = textwrap.dedent(fx["positive"])
    line = hits(fx["positive"], fx["rel"], rule)[0].line
    lines = src.splitlines()
    lines[line - 1] += f"  # lint: allow({rule}): fixture"
    assert [f for f in engine.lint_source("\n".join(lines), fx["rel"],
                                          rules.ALL_RULES)
            if f.rule == rule] == []


def test_standalone_pragma_covers_next_line():
    src = """
        import time
        def step(self):
            # lint: allow(clock-discipline): test fixture
            t0 = time.time()
    """
    assert hits(src, "src/repro/serve/x.py", "clock-discipline") == []


def test_pragma_without_reason_is_a_finding():
    # the reasonless pragma is assembled at runtime so THIS file's own
    # self-scan (pragmas are matched line-wise on raw source, strings
    # included) stays clean
    src = textwrap.dedent("""
        import time
        def step(self):
            t0 = time.time()  {} allow(clock-discipline)
    """).format("# lint:")
    found = engine.lint_source(src, "src/repro/serve/x.py", rules.ALL_RULES)
    assert any(f.rule == engine.BAD_PRAGMA_RULE for f in found)
    # and the unreasoned pragma does NOT suppress
    assert any(f.rule == "clock-discipline" for f in found)


def test_pragma_only_suppresses_named_rule():
    src = """
        import time
        def step(self):
            t0 = time.time()  # lint: allow(seeded-rng): wrong rule named
    """
    assert hits(src, "src/repro/serve/x.py", "clock-discipline")


# ------------------------------------------------------------- scoping

def test_clock_rule_ignores_reference_defaults():
    """time.monotonic as an injectable-clock DEFAULT is the contract, not
    a violation (episodic.py:568-style)."""
    src = """
        import time
        class Engine:
            def __init__(self, clock=None):
                self.clock = clock if clock is not None else time.monotonic
    """
    assert hits(src, "src/repro/serve/x.py", "clock-discipline") == []


def test_clock_rule_out_of_scope_elsewhere():
    src = "import time\nt0 = time.time()\n"
    assert hits(src, "src/repro/roofline/x.py", "clock-discipline") == []


def test_atomic_publish_ignores_read_and_update_modes():
    src = """
        def fetch(self, uid):
            with open(self._path(uid), "r+b") as f:
                return f.read()
    """
    assert hits(src, "src/repro/serve/x.py", "atomic-publish") == []


def test_drift_rule_skips_the_shims_themselves():
    src = "import jax\nshard_map = jax.shard_map\n"
    assert hits(src, "src/repro/sharding/__init__.py", "jax-api-drift") == []
    assert hits(src, "src/repro/core/x.py", "jax-api-drift")


def test_fault_site_message_names_the_constant():
    fx = RULE_FIXTURES["fault-site-registry"]
    (f,) = hits(fx["positive"], fx["rel"], "fault-site-registry")
    assert "WARM_CORRUPT" in f.message


def test_unseeded_default_rng_is_a_finding():
    src = "import numpy as np\nrng = np.random.default_rng()\n"
    assert hits(src, "src/repro/data/x.py", "seeded-rng")


# --------------------------------------------------------- repo is clean

def test_repo_self_scan_clean():
    """The merged repo must carry zero findings — the rules describe the
    code as it actually is, with every exception pragma'd and reasoned."""
    root = engine.repo_root()
    findings = engine.lint_paths(engine.default_targets(root), root,
                                 rules.ALL_RULES)
    assert findings == [], "\n".join(f.format() for f in findings)


# ------------------------------------------------------------- CLI smoke

def _cli(args, cwd=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    return subprocess.run([sys.executable, "-m", "repro.lint"] + args,
                          capture_output=True, text=True, env=env,
                          cwd=cwd, timeout=540)


def test_cli_exit_zero_on_repo():
    r = _cli([])
    assert r.returncode == 0, r.stdout + r.stderr


def test_cli_nonzero_names_file_line_and_rule(tmp_path):
    bad = tmp_path / "src" / "repro" / "serve" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import time\n\nt0 = time.time()\n")
    r = _cli([str(bad)])
    assert r.returncode == 1
    assert "bad.py:3" in r.stdout and "clock-discipline" in r.stdout


def test_cli_json_output(tmp_path):
    bad = tmp_path / "src" / "repro" / "data" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import numpy as np\nx = np.random.rand(3)\n")
    r = _cli(["--json", str(bad)])
    assert r.returncode == 1
    (rec,) = json.loads(r.stdout)
    assert rec["rule"] == "seeded-rng" and rec["line"] == 2


def test_cli_rules_filter_and_catalog(tmp_path):
    bad = tmp_path / "src" / "repro" / "data" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import numpy as np\nx = np.random.rand(3)\n")
    assert _cli(["--rules", "clock-discipline", str(bad)]).returncode == 0
    r = _cli(["--list-rules"])
    assert r.returncode == 0
    for rule in rules.ALL_RULES:
        assert rule.name in r.stdout


# ----------------------------------------------- contract checks (pure)

def test_check_inter_group_catches_wide_collective():
    per_kind = {"all-reduce": dict(result_bytes=1.0, wire_bytes=1.0,
                                   count=1.0, max_group=4)}
    assert check_inter_group(per_kind, group_size=2)
    assert check_inter_group(per_kind, group_size=4) == []


def test_check_wire_budget_slack():
    assert check_wire_budget(1000.0, 1000.0, "x") == []
    assert check_wire_budget(1600.0, 1000.0, "x")


def test_check_compile_flat():
    assert check_compile_flat(dict(adapt_compiles=2, predict_compiles=1),
                              n_buckets=2) == []
    bad = check_compile_flat(dict(adapt_compiles=5, predict_compiles=3),
                             n_buckets=2)
    assert len(bad) == 2


_TOY_HLO = textwrap.dedent("""\
    ENTRY %main (p0: {ptype}) -> {ptype} {{
      %p0 = {ptype} parameter(0)
      ROOT %n = {ptype} negate(%p0)
    }}
""")


def test_find_outer_tensors_toy_hlo():
    bad = _TOY_HLO.format(ptype="f32[2,16,16,16]")   # per-example: lead 32
    ok = _TOY_HLO.format(ptype="f32[2,3,16,16]")     # per-class: lead 6
    assert find_outer_tensors(bad, feature_dim=16, max_leading=6)
    assert find_outer_tensors(ok, feature_dim=16, max_leading=6) == []
    # non-square trailing dims are not outer blocks
    other = _TOY_HLO.format(ptype="f32[2,16,16,8]")
    assert find_outer_tensors(other, feature_dim=16, max_leading=6) == []


def test_entry_param_dtypes_toy_hlo():
    assert "s8" in entry_param_dtypes(_TOY_HLO.format(ptype="s8[4,4]"))
    assert "s8" not in entry_param_dtypes(_TOY_HLO.format(ptype="f32[4,4]"))


def test_budget_readers_match_checked_in_csvs():
    budgets = serve_layout_budgets("serve_small")
    assert budgets["weight_stationary"] == 15552.0
    assert budgets["training"] == 117888.0
    assert replica_wire_budget() == 2560.0


# --------------------------------------- contract cells (4 fake devices)

def _run_4dev(args_or_code, timeout=540):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    if isinstance(args_or_code, str):
        cmd = [sys.executable, "-c", textwrap.dedent(args_or_code)]
    else:
        env["REPRO_LINT_CONTRACTS_WORKER"] = "1"
        cmd = [sys.executable, "-m", "repro.lint"] + args_or_code
    return subprocess.run(cmd, capture_output=True, text=True, env=env,
                          timeout=timeout)


def test_contract_cells_pass_on_real_programs():
    """replica_2x2 + int8_ws compile the real serving programs on 4
    emulated devices and must satisfy every structural contract."""
    r = _run_4dev(["--no-ast", "--contracts",
                   "--cells", "replica_2x2", "--cells", "int8_ws"])
    assert r.returncode == 0, r.stdout + r.stderr[-3000:]


def test_contract_cells_engine_and_lite():
    r = _run_4dev(["--no-ast", "--contracts",
                   "--cells", "compile_flat", "--cells", "lite_outer"])
    assert r.returncode == 0, r.stdout + r.stderr[-3000:]


def test_contract_catches_deliberate_inter_group_violation():
    """A predict program deliberately compiled across the FULL 4-device
    mesh, audited as if it were a 2-device replica group: the checker
    must flag the group-spanning collective."""
    r = _run_4dev("""
        import jax, jax.numpy as jnp
        from repro.core.episodic_train import task_key
        from repro.core.lite import LiteSpec
        from repro.core.meta_learners import MetaLearnerConfig, make_learner
        from repro.core.set_encoder import SetEncoderConfig
        from repro.data.episodic import (EpisodicImageConfig,
                                         collate_task_batch,
                                         sample_image_task)
        from repro.models.conv_backbone import (ConvBackboneConfig,
                                                make_conv_backbone)
        from repro.roofline.hlo import collectives_report
        from repro.serve.quant_params import quantize_frozen
        from repro.lint.contracts import _compile_predict, check_inter_group

        lr = make_learner(
            MetaLearnerConfig(kind="protonets", way=3),
            make_conv_backbone(ConvBackboneConfig(widths=(8,),
                                                  feature_dim=16)),
            SetEncoderConfig(kind="conv", conv_blocks=1, conv_width=8,
                             task_dim=16))
        params = lr.init(jax.random.key(0))
        sw = quantize_frozen(lr, params, "none")
        ts = [sample_image_task(jax.random.key(i), EpisodicImageConfig(
            way=3, shot=5, query_per_class=4, image_size=8))
            for i in range(2)]
        batch = collate_task_batch(ts, support_size=16, query_size=12)
        keys = jax.vmap(lambda i: task_key(jax.random.key(0), i))(
            jnp.arange(2))
        states = lr.adapt_batch(params, batch, keys,
                                LiteSpec(exact=True, chunk_size=8))
        mesh = jax.make_mesh((4,), ("serve",))   # spans ALL 4 devices
        text = _compile_predict(lr, sw, states, batch.query_x, mesh,
                                "weight_stationary")
        rep = collectives_report(text)
        msgs = check_inter_group(rep["per_kind"], group_size=2)
        assert msgs, "4-device collective not flagged for a 2-wide group"
        assert "inter-group" in msgs[0]
        assert check_inter_group(rep["per_kind"], group_size=4) == []
        print("VIOLATION_CAUGHT")
        """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "VIOLATION_CAUGHT" in r.stdout


def test_contract_catches_eager_dequantization():
    """Serving weights dequantized OUTSIDE the jitted step (a persistent
    fp32 copy of the frozen slice) must fail the int8 residency check."""
    r = _run_4dev("""
        import jax, jax.numpy as jnp
        from repro.core.episodic_train import task_key
        from repro.core.lite import LiteSpec
        from repro.core.meta_learners import MetaLearnerConfig, make_learner
        from repro.core.set_encoder import SetEncoderConfig
        from repro.data.episodic import (EpisodicImageConfig,
                                         collate_task_batch,
                                         sample_image_task)
        from repro.models.conv_backbone import (ConvBackboneConfig,
                                                make_conv_backbone)
        from repro.serve.quant_params import (dequantize_params, param_bytes,
                                              quantize_frozen,
                                              ServingWeights)
        from repro.lint.contracts import check_int8_residency

        lr = make_learner(
            MetaLearnerConfig(kind="protonets", way=3),
            make_conv_backbone(ConvBackboneConfig(widths=(8,),
                                                  feature_dim=16)),
            SetEncoderConfig(kind="conv", conv_blocks=1, conv_width=8,
                             task_dim=16))
        params = lr.init(jax.random.key(0))
        sw = quantize_frozen(lr, params, "int8")
        # the violation: expand to fp32 eagerly and keep THAT resident
        eager = ServingWeights(tree=dequantize_params(sw),
                               quant_paths=sw.quant_paths,
                               native_paths=(), frozen_roots=sw.frozen_roots,
                               mode="none")
        ts = [sample_image_task(jax.random.key(i), EpisodicImageConfig(
            way=3, shot=5, query_per_class=4, image_size=8))
            for i in range(2)]
        batch = collate_task_batch(ts, support_size=16, query_size=12)
        keys = jax.vmap(lambda i: task_key(jax.random.key(0), i))(
            jnp.arange(2))
        states = lr.adapt_batch(eager.tree, batch, keys,
                                LiteSpec(exact=True, chunk_size=8))
        text = jax.jit(lambda w, st, qx: lr.predict_batch(
            w.tree, st, qx)).lower(eager, states, batch.query_x
                                   ).compile().as_text()
        msgs = check_int8_residency(text, eager, param_bytes(eager))
        assert any("s8" in m or "fp32" in m for m in msgs), msgs
        print("EAGER_CAUGHT")
        """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "EAGER_CAUGHT" in r.stdout
