"""Throughput subsystem (PR2): prefetcher determinism, buffer donation
safety, mixed-precision LITE complement, bucket planning + compiled-step
cache, schedule wiring, async throughput accounting, and the tier-1 perf
smoke (overlapped engine beats the synchronous loop)."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MetaTrainConfig
from repro.core.episodic import Task
from repro.core.episodic_train import (make_batched_meta_train_step,
                                       jit_task_step)
from repro.core.lite import LiteSpec, lite_sum
from repro.core.meta_learners import MetaLearnerConfig, make_learner
from repro.core.set_encoder import SetEncoderConfig
from repro.data.episodic import (EpisodicImageConfig, HostEpisodicConfig,
                                 bucket_for, collate_with_buckets,
                                 host_task_batch_at, plan_buckets,
                                 sample_image_task, task_batch_at)
from repro.models.conv_backbone import ConvBackboneConfig, make_conv_backbone
from repro.optim import AdamWConfig, adamw_init
from repro.optim.schedules import cosine_schedule, schedule_for
from repro.train.loop import train
from repro.train.pipeline import BucketedStepCache, Prefetcher
from repro.train.step import make_episodic_train_step

BB = make_conv_backbone(ConvBackboneConfig(widths=(4,), feature_dim=8))
SET_CFG = SetEncoderConfig(kind="conv", conv_blocks=1, conv_width=4,
                           task_dim=8)
TCFG = EpisodicImageConfig(way=3, shot=3, query_per_class=2, image_size=10)
SPEC = LiteSpec(h=3)
ADAMW = AdamWConfig(weight_decay=0.0)


def _learner(way=3):
    return make_learner(MetaLearnerConfig(kind="protonets", way=way), BB,
                        SET_CFG)


def _max_leaf_diff(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _episodic_pieces(way=3, tasks_per_step=4, lite=SPEC):
    lr = _learner(way)
    params = lr.init(jax.random.key(0))
    inner = make_batched_meta_train_step(lr, lite, adamw=ADAMW)

    def train_step(state, batch):
        p, o, m = inner(state["params"], state["opt"], batch["tasks"],
                        batch["key"])
        return dict(params=p, opt=o), m

    dk, sk = jax.random.key(17), jax.random.key(23)

    def batch_at(s):
        return dict(tasks=task_batch_at(dk, TCFG, tasks_per_step, s),
                    key=jax.random.fold_in(sk, s))

    def fresh_state():
        return dict(params=jax.tree.map(jnp.copy, params),
                    opt=adamw_init(params, ADAMW))

    return lr, train_step, batch_at, fresh_state


# -- prefetcher --------------------------------------------------------------


def test_prefetcher_delivers_batch_at_stream_in_order():
    def batch_at(s):
        return dict(x=jnp.full((3,), float(s)), s=jnp.asarray(s))

    pf = Prefetcher(batch_at, 2, 8, depth=2)
    try:
        for s in range(2, 8):
            b = pf.get(s)
            assert int(b["s"]) == s
            np.testing.assert_array_equal(np.asarray(b["x"]),
                                          np.full((3,), float(s)))
    finally:
        pf.close()


def test_prefetcher_rejects_out_of_order_get():
    pf = Prefetcher(lambda s: jnp.asarray(s), 0, 4, depth=2)
    try:
        pf.get(0)
        with pytest.raises(ValueError, match="sequential"):
            pf.get(2)
    finally:
        pf.close()


def test_prefetcher_propagates_worker_errors():
    def batch_at(s):
        if s == 2:
            raise RuntimeError("loader exploded")
        return jnp.asarray(s)

    pf = Prefetcher(batch_at, 0, 6, depth=1)
    try:
        assert int(pf.get(0)) == 0
        assert int(pf.get(1)) == 1
        with pytest.raises(RuntimeError, match="loader exploded"):
            pf.get(2)
    finally:
        pf.close()


def test_train_prefetch_bit_identical_to_sync(key):
    """Same batch_at stream with and without prefetch => bit-identical
    final params (the prefetcher is only a lookahead evaluator of the
    same pure function)."""
    _, train_step, batch_at, fresh_state = _episodic_pieces()
    r_sync = train(fresh_state(), train_step, batch_at, 5)
    r_pf = train(fresh_state(), train_step, batch_at, 5, prefetch=2)
    assert _max_leaf_diff(r_sync.state, r_pf.state) == 0.0
    assert len(r_pf.step_times) == 5
    # committed metrics identical too
    for a, b in zip(r_sync.metrics_history, r_pf.metrics_history):
        assert a == b


def test_prefetch_preemption_resume_bit_exact(tmp_path, key):
    """Kill an async (prefetch+donate) run mid-span; the resumed async run
    must match an uninterrupted synchronous run bit-for-bit — the
    prefetcher is restarted at the restored step and replays the same
    pure batch_at stream."""
    from repro.train.checkpoint import CheckpointManager

    lr, train_step, batch_at, fresh_state = _episodic_pieces()
    template = jax.eval_shape(fresh_state)
    ck = CheckpointManager(tmp_path / "a", keep=5)

    class Boom(RuntimeError):
        pass

    def preempt_at_5(s):
        if s == 5:
            raise Boom()

    with pytest.raises(Boom):
        train(fresh_state(), train_step, batch_at, 8, ckpt=ck, ckpt_every=2,
              state_template=template, preemption_hook=preempt_at_5,
              prefetch=2, donate=True)
    r = train(fresh_state(), train_step, batch_at, 8, ckpt=ck, ckpt_every=2,
              state_template=template, prefetch=2, donate=True)
    assert r.resumed_from == 5 or r.resumed_from == 4
    r_ref = train(fresh_state(), train_step, batch_at, 8)
    assert _max_leaf_diff(r.state, r_ref.state) == 0.0


# -- buffer donation ---------------------------------------------------------


def test_donated_chain_matches_undonated(key):
    """3 donated steps threaded state-to-state == 3 plain steps, bitwise."""
    lr = _learner()
    params = lr.init(key)
    inner = make_batched_meta_train_step(lr, SPEC, adamw=ADAMW)
    batches = [task_batch_at(jax.random.key(1), TCFG, 4, s) for s in range(3)]
    k = jax.random.key(5)

    plain = jit_task_step(inner, donate=False)
    p1, o1 = params, adamw_init(params, ADAMW)
    for s, b in enumerate(batches):
        p1, o1, m1 = plain(p1, o1, b, jax.random.fold_in(k, s))

    donated = jit_task_step(inner, donate=True)
    p2, o2 = jax.tree.map(jnp.copy, params), adamw_init(params, ADAMW)
    for s, b in enumerate(batches):
        p2, o2, m2 = donated(p2, o2, b, jax.random.fold_in(k, s))

    assert _max_leaf_diff(p1, p2) == 0.0
    assert _max_leaf_diff(o1["mu"], o2["mu"]) == 0.0
    assert float(m1["loss"]) == float(m2["loss"])


def test_donated_buffers_are_consumed(key):
    """No silent use-after-donate: the donated input params are dead after
    the step on backends implementing donation (this CPU backend does)."""
    lr = _learner()
    params = jax.tree.map(jnp.copy, lr.init(key))
    opt = adamw_init(params, ADAMW)
    step = jit_task_step(make_batched_meta_train_step(lr, SPEC, adamw=ADAMW),
                         donate=True)
    batch = task_batch_at(jax.random.key(1), TCFG, 4, 0)
    step(params, opt, batch, jax.random.key(2))
    with pytest.raises((RuntimeError, ValueError),
                       match="deleted|donated"):
        [float(jnp.sum(leaf)) for leaf in jax.tree.leaves(params)]


def test_train_donate_bit_identical_and_loop_safe(key):
    """train(donate=True) threads freshly-donated state through the loop
    (incl. checkpoint boundaries) and reproduces the undonated run."""
    _, train_step, batch_at, fresh_state = _episodic_pieces()
    r0 = train(fresh_state(), train_step, batch_at, 4)
    r1 = train(fresh_state(), train_step, batch_at, 4, donate=True)
    assert _max_leaf_diff(r0.state, r1.state) == 0.0


# -- mixed-precision complement ----------------------------------------------


def _mlp_encode(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])


def test_bf16_complement_forward_close_grads_bitexact(key):
    p = dict(w=jax.random.normal(key, (12, 6)), b=jnp.zeros((6,)))
    xs = jax.random.normal(jax.random.key(1), (32, 12))
    k = jax.random.key(2)
    s32 = LiteSpec(h=4, chunk_size=8)
    s16 = LiteSpec(h=4, chunk_size=8, compute_dtype="bfloat16")

    v32 = lite_sum(_mlp_encode, p, xs, k, s32)
    v16 = lite_sum(_mlp_encode, p, xs, k, s16)
    assert v16.dtype == jnp.float32        # fp32 accumulation
    np.testing.assert_allclose(np.asarray(v16), np.asarray(v32),
                               rtol=2e-2, atol=2e-2)

    # combinator backward flows only through the fp32 H pass => bitwise
    # identical gradients of any linear functional of the output
    g32 = jax.grad(lambda q: jnp.sum(lite_sum(_mlp_encode, q, xs, k, s32)))(p)
    g16 = jax.grad(lambda q: jnp.sum(lite_sum(_mlp_encode, q, xs, k, s16)))(p)
    assert _max_leaf_diff(g32, g16) == 0.0


def test_bf16_complement_masked_matches_unmasked(key):
    """mask=None and an explicit all-ones mask are the same estimator —
    the collapsed single body makes this exact, bf16 path included."""
    p = dict(w=jax.random.normal(key, (12, 6)), b=jnp.zeros((6,)))
    xs = jax.random.normal(jax.random.key(1), (20, 12))
    k = jax.random.key(2)
    for spec in (LiteSpec(h=4, chunk_size=4),
                 LiteSpec(h=4, chunk_size=4, compute_dtype="bfloat16"),
                 LiteSpec(h=4, exact=True)):
        a = lite_sum(_mlp_encode, p, xs, k, spec)
        b = lite_sum(_mlp_encode, p, xs, k, spec,
                     mask=jnp.ones((20,), jnp.float32))
        assert _max_leaf_diff(a, b) == 0.0


def test_bf16_complement_learner_loss_close(key):
    """End-to-end: a meta-loss under the bf16 complement stays within
    float tolerance of fp32 (forward-value rounding only)."""
    lr = _learner()
    params = lr.init(key)
    task = sample_image_task(jax.random.key(3),
                             EpisodicImageConfig(way=3, shot=6,
                                                 query_per_class=2,
                                                 image_size=10))
    k = jax.random.key(4)
    l32 = lr.meta_loss(params, task, k, LiteSpec(h=4, chunk_size=4))[0]
    l16 = lr.meta_loss(params, task, k,
                       LiteSpec(h=4, chunk_size=4,
                                compute_dtype="bfloat16"))[0]
    np.testing.assert_allclose(float(l16), float(l32), rtol=5e-2)


# -- bucket planning + compiled-step cache -----------------------------------


def test_plan_buckets_policy():
    sizes = [15] * 50 + [20] * 30 + [40] * 5 + [37] * 5
    buckets = plan_buckets(sizes, max_buckets=2, multiple=8)
    assert len(buckets) <= 2
    assert buckets[-1] >= 40                  # covers the max
    assert all(b % 8 == 0 for b in buckets)
    assert buckets == tuple(sorted(buckets))
    # common small sizes keep a tight bucket rather than padding to 40
    assert buckets[0] <= 24

    assert bucket_for(15, buckets) == buckets[0]
    assert bucket_for(buckets[-1], buckets) == buckets[-1]
    with pytest.raises(ValueError, match="exceeds every planned bucket"):
        bucket_for(buckets[-1] + 1, buckets)
    with pytest.raises(ValueError):
        plan_buckets([])


def test_bucketed_cache_compile_counter_flat_on_ragged_stream(key):
    """A ragged task stream collated against planned buckets re-uses the
    per-shape compiled steps: the compile counter goes flat after every
    bucket has been seen once."""
    lr = _learner()
    params = lr.init(key)
    opt = adamw_init(params, ADAMW)
    step = BucketedStepCache(make_batched_meta_train_step(lr, SPEC,
                                                          adamw=ADAMW))

    shots = [2, 3, 5, 2, 5, 3, 2, 5, 3, 2]    # ragged stream, 3 size modes
    def task_for(shot, i):
        return sample_image_task(
            jax.random.key(100 + i),
            EpisodicImageConfig(way=3, shot=shot, query_per_class=2,
                                image_size=10))

    s_buckets = plan_buckets([3 * s for s in shots], max_buckets=2,
                             multiple=4)
    q_buckets = plan_buckets([6] * len(shots), max_buckets=1, multiple=4)

    counts = []
    for i, shot in enumerate(shots):
        batch = collate_with_buckets([task_for(shot, i)], s_buckets,
                                     q_buckets)
        step(params, opt, batch, jax.random.fold_in(key, i))
        counts.append(step.compile_count)
    assert counts[-1] <= len(s_buckets) * len(q_buckets)
    # flat tail: nothing new compiles once the buckets are warm
    assert counts[4:] == [counts[4]] * (len(counts) - 4)

    # kernel-dispatch guard: the cache keys on shapes alone and the
    # dispatch backend binds at lowering time, so flipping the ambient
    # backend on the warm cache must not leak a single extra compile
    from repro.kernels import dispatch
    with dispatch.use_backend("naive"):
        for i, shot in enumerate(shots[:4]):
            batch = collate_with_buckets([task_for(shot, i)], s_buckets,
                                         q_buckets)
            step(params, opt, batch, jax.random.fold_in(key, i))
    assert step.compile_count == counts[-1]


# -- schedules in the batched episodic path ----------------------------------


def test_batched_step_follows_schedule(key):
    lr = _learner()
    params = lr.init(key)
    sched = lambda c: cosine_schedule(c, peak=1e-2, warmup_steps=2,
                                      total_steps=10)
    step = jax.jit(make_batched_meta_train_step(lr, SPEC, adamw=ADAMW,
                                                lr=123.0, schedule=sched))
    p, o = params, adamw_init(params, ADAMW)
    batch = task_batch_at(jax.random.key(1), TCFG, 2, 0)
    for count in range(3):
        p, o, m = step(p, o, batch, jax.random.fold_in(key, count))
        np.testing.assert_allclose(float(m["lr"]), float(sched(count)),
                                   rtol=1e-6)


def test_episodic_adapter_wires_schedule_from_config(key):
    lr = _learner()
    meta = MetaTrainConfig(tasks_per_step=2, lr=5e-3, schedule="cosine",
                           warmup_steps=1, total_steps=8)
    step = jax.jit(make_episodic_train_step(lr, SPEC, meta, ADAMW))
    state = dict(params=lr.init(key), opt=adamw_init(lr.init(key), ADAMW))
    batch = dict(tasks=task_batch_at(jax.random.key(1), TCFG, 2, 0),
                 key=jax.random.key(2))
    expected = schedule_for("cosine", 5e-3, 1, 8)
    for count in range(2):
        state, m = step(state, batch)
        np.testing.assert_allclose(float(m["lr"]), float(expected(count)),
                                   rtol=1e-6)


def test_schedule_for_validation():
    assert schedule_for(None, 1e-3, 0, 0) is None
    with pytest.raises(ValueError, match="total_steps"):
        schedule_for("cosine", 1e-3, 0, 0)
    with pytest.raises(ValueError, match="unknown schedule"):
        schedule_for("linear", 1e-3, 1, 10)


# -- host task source + throughput accounting --------------------------------


def test_host_task_batch_at_deterministic_and_shaped():
    cfg = HostEpisodicConfig(way=3, shot=2, query_per_class=1, image_size=8)
    b1 = host_task_batch_at(7, cfg, 4, step=3)
    b2 = host_task_batch_at(7, cfg, 4, step=3)
    b3 = host_task_batch_at(7, cfg, 4, step=4)
    assert b1.support_x.shape == (4, 6, 8, 8, 3)
    assert b1.query_x.shape == (4, 3, 8, 8, 3)
    assert b1.way == 3
    np.testing.assert_array_equal(b1.support_x, b2.support_x)
    assert np.abs(b1.support_x - b3.support_x).max() > 0
    # augmented variant standardizes per image
    aug = host_task_batch_at(7, HostEpisodicConfig(
        way=3, shot=2, query_per_class=1, image_size=8, augment=True), 2, 0)
    np.testing.assert_allclose(
        aug.support_x.mean(axis=(2, 3)), 0.0, atol=1e-4)
    # odd effective sizes work (prototype built at ceil(big/2), cropped)
    for cfg_odd in (HostEpisodicConfig(way=2, shot=1, query_per_class=1,
                                       image_size=9, augment=False),
                    HostEpisodicConfig(way=2, shot=1, query_per_class=1,
                                       image_size=9, augment=True,
                                       crop_pad=4)):
        b = host_task_batch_at(7, cfg_odd, 2, 0)
        assert b.support_x.shape[2:] == (9, 9, 3)


def test_async_step_times_reflect_wall_clock(key):
    """Under prefetch the loop syncs only at span boundaries; step_times
    must still sum to (approximately) the measured wall time — per
    COMMITTED step, not per-dispatch."""
    _, train_step, batch_at, fresh_state = _episodic_pieces()
    t0 = time.time()
    r = train(fresh_state(), train_step, batch_at, 6, prefetch=2)
    wall = time.time() - t0
    assert len(r.step_times) == 6
    assert sum(r.step_times) <= wall + 1e-3
    # dispatch of an async span is microseconds; committed per-step times
    # must be real step durations, far above dispatch latency
    assert all(t > 1e-4 for t in r.step_times)
    assert r.throughput(4) > 0


# -- tier-1 perf smoke -------------------------------------------------------


def test_perf_smoke_overlapped_engine_beats_sync():
    """Tiny batched+donated+prefetched engine run completes and beats the
    synchronous engine's tasks/sec on the same workload.  The comparison
    mirrors the benchmark's engine rows — the PR1 engine as it ran
    (sync loop + on-device sampler) vs the PR2 engine (host stream +
    prefetch + donation), source change included by design; Prefetcher
    correctness in isolation is covered by the bit-exactness tests
    above.  Up to 3 attempts guard against scheduler noise on the
    shared 2-core CPU."""
    way, t = 5, 8
    lr = _learner(way)
    params = lr.init(jax.random.key(0))
    inner = make_batched_meta_train_step(
        lr, LiteSpec(h=8, chunk_size=8), adamw=ADAMW)

    def train_step(state, batch):
        p, o, m = inner(state["params"], state["opt"], batch["tasks"],
                        batch["key"])
        return dict(params=p, opt=o), m

    dcfg = EpisodicImageConfig(way=way, shot=16, query_per_class=3,
                               image_size=16)
    hcfg = HostEpisodicConfig(way=way, shot=16, query_per_class=3,
                              image_size=16, augment=False)
    dk, sk = jax.random.key(31), jax.random.key(37)

    def sync_batch_at(s):
        return dict(tasks=task_batch_at(dk, dcfg, t, s),
                    key=jax.random.fold_in(sk, s))

    def host_batch_at(s):
        return dict(tasks=host_task_batch_at(31, hcfg, t, s),
                    key=jax.random.fold_in(sk, s))

    def fresh_state():
        return dict(params=jax.tree.map(jnp.copy, params),
                    opt=adamw_init(params, ADAMW))

    n = 12
    ratios = []
    for _ in range(3):
        sync = train(fresh_state(), train_step, sync_batch_at, n)
        over = train(fresh_state(), train_step, host_batch_at, n,
                     prefetch=6, donate=True)
        assert over.step == n and len(over.metrics_history) == n
        ratios.append(over.throughput(t) / sync.throughput(t))
        if ratios[-1] > 1.0:
            break
    assert max(ratios) > 1.0, f"overlapped engine never beat sync: {ratios}"
