"""Two-level (DCN x ICI) task-parallel engine, on EMULATED multi-host
topologies: every test here runs its jax code in a fresh subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count`` set (the fake devices
must not leak into this process — same pattern as tests/test_distributed.py),
via the ``run_hosts`` host-count fixture.  All tests carry the ``multihost``
marker (registered in pyproject.toml) and run in tier 1.

Contracts under test (ISSUE-5 acceptance):
  * two-level mesh at dcn_shards=1 is BIT-identical to the 1-D mesh path;
  * dcn_shards=2 pmean matches the unsharded step to fp32 tolerance,
    with or without cross-host gradient accumulation;
  * error-feedback compressed reduction converges (loss decreases, params
    track the exact-reduction path, residual is carried);
  * sharded opt state (incl. the EF residual) round-trips through the
    checkpoint manager bit-exactly;
  * compile counters stay flat across a ragged two-bucket stream under
    the two-level mesh;
  * ``collectives_report`` accounts the step's gradient-reduction wire
    bytes (ring-corrected: ~2x param bytes for a 2x2 two-level mesh).
"""
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.multihost

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

# Shared subprocess preamble: a tiny protonets learner + an 8-task batch on
# 4 fake devices.  Each test appends its scenario code.
_SETUP = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.episodic_train import (init_ef_state,
                                           make_batched_meta_train_step)
    from repro.core.lite import LiteSpec
    from repro.core.meta_learners import MetaLearnerConfig, make_learner
    from repro.core.set_encoder import SetEncoderConfig
    from repro.data.episodic import (EpisodicImageConfig,
                                     sample_image_task_batch)
    from repro.launch.mesh import make_dp_mesh, make_two_level_dp_mesh
    from repro.models.conv_backbone import (ConvBackboneConfig,
                                            make_conv_backbone)
    from repro.optim import AdamWConfig, adamw_init

    bb = make_conv_backbone(ConvBackboneConfig(widths=(8,), feature_dim=16))
    learner = make_learner(
        MetaLearnerConfig(kind="protonets", way=5), bb,
        SetEncoderConfig(kind="conv", conv_blocks=1, conv_width=4,
                         task_dim=8))
    params = learner.init(jax.random.key(0))
    spec = LiteSpec(h=4)
    adamw = AdamWConfig(weight_decay=0.0)
    opt = adamw_init(params, adamw)
    tcfg = EpisodicImageConfig(way=5, shot=4, query_per_class=2,
                               image_size=8)
    batch = sample_image_task_batch(jax.random.key(3), tcfg, 8)
    key = jax.random.key(9)

    def maxdiff(a, b):
        return max(float(jnp.max(jnp.abs(x - y)))
                   for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))
""")


@pytest.fixture
def run_hosts():
    """Host-count fixture: run(code, devices=N) executes ``_SETUP + code``
    in a subprocess emulating N devices and returns its stdout."""

    def run(code: str, devices: int = 4, timeout: int = 540) -> str:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count"
                            f"={devices}")
        env["PYTHONPATH"] = SRC
        out = subprocess.run(
            [sys.executable, "-c", _SETUP + textwrap.dedent(code)],
            capture_output=True, text=True, env=env, timeout=timeout)
        assert out.returncode == 0, out.stderr[-3000:]
        return out.stdout

    return run


def test_two_level_mesh_equivalences(run_hosts):
    """dcn_shards=1 two-level == 1-D mesh BIT-exactly; dcn_shards=2 pmean
    (with and without accumulation) == unsharded to fp32 tolerance."""
    out = run_hosts("""
        s_none = jax.jit(make_batched_meta_train_step(learner, spec,
                                                      adamw=adamw))
        p0, o0, m0 = s_none(params, opt, batch, key)

        s_1d = jax.jit(make_batched_meta_train_step(
            learner, spec, adamw=adamw, mesh=make_dp_mesh(4)))
        p1, o1, m1 = s_1d(params, opt, batch, key)

        s_dcn1 = jax.jit(make_batched_meta_train_step(
            learner, spec, adamw=adamw, mesh=make_two_level_dp_mesh(1, 4)))
        p2, o2, m2 = s_dcn1(params, opt, batch, key)
        assert maxdiff(p1, p2) == 0.0, maxdiff(p1, p2)
        assert float(m1["loss"]) == float(m2["loss"])
        for a, b in zip(jax.tree.leaves(o1), jax.tree.leaves(o2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        s_dcn2 = jax.jit(make_batched_meta_train_step(
            learner, spec, adamw=adamw, mesh=make_two_level_dp_mesh(2, 2)))
        p3, o3, m3 = s_dcn2(params, opt, batch, key)
        assert maxdiff(p0, p3) < 1e-5, maxdiff(p0, p3)
        assert abs(float(m0["loss"]) - float(m3["loss"])) < 1e-5

        s_acc = jax.jit(make_batched_meta_train_step(
            learner, spec, adamw=adamw, mesh=make_two_level_dp_mesh(2, 2),
            accum_steps=2))
        p4, o4, m4 = s_acc(params, opt, batch, key)
        assert maxdiff(p0, p4) < 1e-5, maxdiff(p0, p4)
        print("EQ_OK")
        """)
    assert "EQ_OK" in out


def test_compressed_reduction_error_feedback_converges(run_hosts):
    """grad_reduce='compressed' over dcn=2: the int8 error-feedback
    reduction must (a) carry a nonzero residual in opt_state['ef'],
    (b) keep multi-step training on track with the exact-pmean path
    (error feedback cancels quantization bias across steps), and
    (c) reduce the loss."""
    out = run_hosts("""
        mesh = make_two_level_dp_mesh(2, 2)
        s_exact = jax.jit(make_batched_meta_train_step(
            learner, spec, adamw=adamw, mesh=mesh))
        s_comp = jax.jit(make_batched_meta_train_step(
            learner, spec, adamw=adamw, mesh=mesh,
            grad_reduce="compressed"))

        pe, oe = params, adamw_init(params, adamw)
        pc = params
        oc = dict(adamw_init(params, adamw), ef=init_ef_state(params, 2))
        losses = []
        for s in range(10):
            b = sample_image_task_batch(jax.random.key(100 + s), tcfg, 8)
            k = jax.random.fold_in(key, s)
            pe, oe, me = s_exact(pe, oe, b, k)
            pc, oc, mc = s_comp(pc, oc, b, k)
            losses.append(float(mc["loss"]))
        ef_l1 = sum(float(jnp.sum(jnp.abs(e)))
                    for e in jax.tree.leaves(oc["ef"]))
        assert ef_l1 > 0.0                       # residual is carried
        # compressed path tracks the exact path (relative param drift)
        pnorm = float(jnp.sqrt(sum(jnp.sum(x ** 2)
                                   for x in jax.tree.leaves(pe))))
        drift = maxdiff(pe, pc)
        assert drift < 2e-2 * max(pnorm, 1.0), (drift, pnorm)
        assert losses[-1] < losses[0], losses    # it still learns
        print("EF_OK", drift, ef_l1)
        """)
    assert "EF_OK" in out


def test_sharded_opt_state_checkpoint_roundtrip(run_hosts, tmp_path):
    """opt state with the DCN-sharded EF residual survives save/restore
    bit-exactly, and a step from the restored state equals a step from the
    live state (restart exactness with compressed reduction)."""
    out = run_hosts(f"""
        from repro.train.checkpoint import CheckpointManager
        mesh = make_two_level_dp_mesh(2, 2)
        step = jax.jit(make_batched_meta_train_step(
            learner, spec, adamw=adamw, mesh=mesh,
            grad_reduce="compressed"))
        opt_c = dict(adamw_init(params, adamw), ef=init_ef_state(params, 2))
        p1, o1, _ = step(params, opt_c, batch, key)
        state = dict(params=p1, opt=o1)

        ckpt = CheckpointManager({str(tmp_path)!r}, keep=2)
        ckpt.save(1, state)
        template = jax.eval_shape(lambda: state)
        got, state2, _ = ckpt.restore_latest(template)
        assert got == 1
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        b2 = sample_image_task_batch(jax.random.key(7), tcfg, 8)
        k2 = jax.random.fold_in(key, 1)
        pa, oa, _ = step(state["params"], state["opt"], b2, k2)
        pb, ob, _ = step(state2["params"], state2["opt"], b2, k2)
        assert maxdiff(pa, pb) == 0.0
        assert maxdiff(oa["ef"], ob["ef"]) == 0.0
        print("CKPT_OK")
        """)
    assert "CKPT_OK" in out


def test_compile_counter_flat_and_wire_bytes_two_level(run_hosts):
    """BucketedStepCache over a ragged two-bucket stream compiles exactly
    once per bucket under the two-level mesh, and collectives_report on
    the compiled step accounts the two-stage gradient reduction: ring
    all-reduce over data (group 2) + over dcn (group 2) is ~2x the
    replicated param bytes per step."""
    out = run_hosts("""
        from repro.roofline.hlo import collectives_report
        from repro.train.pipeline import BucketedStepCache
        mesh = make_two_level_dp_mesh(2, 2)
        step = make_batched_meta_train_step(learner, spec, adamw=adamw,
                                            mesh=mesh)
        cache = BucketedStepCache(step)
        small = tcfg
        big = EpisodicImageConfig(way=5, shot=6, query_per_class=2,
                                  image_size=8)
        p, o = params, opt
        for s in range(6):
            cfg_s = small if s % 2 else big
            b = sample_image_task_batch(jax.random.key(s), cfg_s, 8)
            p, o, m = cache(p, o, b, jax.random.fold_in(key, s))
        assert cache.compile_count == 2, cache.compile_count

        compiled = jax.jit(step).lower(params, opt, batch, key).compile()
        rep = collectives_report(compiled)
        pbytes = sum(l.size * l.dtype.itemsize
                     for l in jax.tree.leaves(params))
        assert rep["per_kind"].get("all-reduce"), rep
        ratio = rep["total_wire_bytes"] / pbytes
        # 2(n-1)/n per stage at n=2 -> 1.0 + 1.0 param-multiples, plus
        # a few scalar reductions (loss/acc/grad-norm)
        assert 1.9 < ratio < 2.3, (ratio, rep)
        print("FLAT_OK", cache.compile_count, ratio)
        """)
    assert "FLAT_OK" in out


def test_prefetch_and_donation_survive_sharded_layout(run_hosts):
    """The overlapped pipeline (Prefetcher with a sharded batch_put +
    donated state) over the two-level mesh commits the same final params
    as the synchronous un-prefetched loop, bit-for-bit."""
    out = run_hosts("""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train.loop import train
        mesh = make_two_level_dp_mesh(2, 2)
        step_fn = make_batched_meta_train_step(learner, spec, adamw=adamw,
                                               mesh=mesh)

        def train_step(state, b):
            p, o, m = step_fn(state["params"], state["opt"], b["tasks"],
                              b["key"])
            return dict(params=p, opt=o), m

        def batch_at(s):
            return dict(tasks=sample_image_task_batch(
                            jax.random.key(1000 + s), tcfg, 8),
                        key=jax.random.fold_in(key, s))

        task_sharding = NamedSharding(mesh, P(("dcn", "data")))

        def batch_put(b):
            return dict(tasks=jax.tree.map(
                            lambda a: jax.device_put(a, task_sharding),
                            b["tasks"]),
                        key=b["key"])

        state0 = dict(params=params, opt=adamw_init(params, adamw))
        r_sync = train(state0, train_step, batch_at, 6)
        r_async = train(state0, train_step, batch_at, 6, prefetch=2,
                        donate=True, batch_put=batch_put)
        assert maxdiff(r_sync.state, r_async.state) == 0.0
        print("PIPE_OK")
        """)
    assert "PIPE_OK" in out
