"""Multi-replica episodic serving (PR9): uid-hash routing over N engine
replicas, a shared uid-sharded warm tier, and per-group device isolation.

Contracts under test (ISSUE-9 acceptance):

* routing is a pure function of (uid, replicas): deterministic across
  router restarts, and changing the replica count re-routes uids but
  NEVER loses warm state (fixed shard-subdir layout);
* a mixed-uid workload through the router is BIT-exact with one solo
  engine serving the same requests — which replica adapts a task can
  never change its logits;
* per-replica compile counters stay flat across a ragged mixed-replica
  workload and equal the single-replica count (replication multiplies
  capacity, not compilation);
* overload rejection prices ``retry_after_us`` from the ROUTED replica's
  own adapt-cost EWMA, not a global average;
* int8 x layout composition applies per replica (resident bytes count
  R full copies honestly);
* tier-1 perf smoke: 2 replicas admit >= 1.5x requests per engine step
  vs 1 replica under a FakeClock — zero real sleeps;
* [subprocess, 4 emulated devices] with 2 replicas x 2 devices from
  ``make_replica_mesh``: logits bit-exact vs solo, compile counters flat
  per replica, and ``collectives_report`` proves ZERO inter-group wire —
  per-replica wire bytes equal a solo 2-device engine's (scale with the
  group, not the deployment) and every collective's group fits in the
  replica's devices.
"""
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from conftest import FakeClock
from repro.core.lite import LiteSpec
from repro.core.meta_learners import MetaLearnerConfig, make_learner
from repro.core.set_encoder import SetEncoderConfig
from repro.data.episodic import EpisodicImageConfig, sample_image_task
from repro.models.conv_backbone import ConvBackboneConfig, make_conv_backbone
from repro.serve.episodic import (EpisodicRequest, EpisodicServeEngine,
                                  stable_uid_hash)
from repro.serve.replica import (DEFAULT_WARM_SHARDS, ReplicatedServeEngine,
                                 uid_replica)

pytestmark = pytest.mark.replica

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

BB = make_conv_backbone(ConvBackboneConfig(widths=(4,), feature_dim=8))
SET_CFG = SetEncoderConfig(kind="conv", conv_blocks=1, conv_width=4,
                           task_dim=8)
TCFG = EpisodicImageConfig(way=3, shot=2, query_per_class=2, image_size=8)
SERVE_LITE = LiteSpec(exact=True, chunk_size=8)


def _learner():
    return make_learner(MetaLearnerConfig(kind="protonets", way=3), BB,
                        SET_CFG)


def _router(learner, params, **kw):
    kw.setdefault("lite", SERVE_LITE)
    kw.setdefault("n_slots", 2)
    kw.setdefault("query_chunk", 4)
    kw.setdefault("support_buckets", (8,))
    return ReplicatedServeEngine(learner, params, **kw)


def _solo(learner, params, **kw):
    kw.setdefault("lite", SERVE_LITE)
    kw.setdefault("n_slots", 2)
    kw.setdefault("query_chunk", 4)
    kw.setdefault("support_buckets", (8,))
    return EpisodicServeEngine(learner, params, **kw)


def _request(uid, with_support=True, seed=300):
    t = sample_image_task(jax.random.key(seed + uid), TCFG)
    return EpisodicRequest(
        uid=uid,
        support_x=np.asarray(t.support_x) if with_support else None,
        support_y=np.asarray(t.support_y) if with_support else None,
        query_x=np.asarray(t.query_x), way=3)


def _uids_for(replica, replicas, n, start=0):
    """First ``n`` uids >= start whose hash home is ``replica``."""
    out = []
    u = start
    while len(out) < n:
        if uid_replica(u, replicas) == replica:
            out.append(u)
        u += 1
    return out


# -- routing determinism ------------------------------------------------------


def test_uid_routing_pure_and_restart_stable():
    """Routing is a pure function of (uid, replicas): no process salt
    (crc32, not builtin hash), identical across two independent routers
    over the same config, and ``route`` == ``uid_replica`` while every
    replica is live."""
    import zlib
    for uid in (0, 1, 7, 123456, 2**40 + 17, -3):
        assert stable_uid_hash(uid) == zlib.crc32(
            int(uid).to_bytes(8, "little", signed=True))
    learner = _learner()
    params = learner.init(jax.random.key(0))
    a = _router(learner, params, replicas=3)
    b = _router(learner, params, replicas=3)
    for uid in range(50):
        assert a.route(uid) == b.route(uid) == uid_replica(uid, 3)


def test_mixed_uid_workload_bit_exact_vs_solo():
    """A mixed-uid workload (cold wave + support-less repeats) through 2
    replicas produces BIT-identical logits to one solo engine: adapted
    state is a pure function of (params, support, uid, seed), so the
    partition can never change results."""
    learner = _learner()
    params = learner.init(jax.random.key(0))
    router = _router(learner, params, replicas=2)
    solo = _solo(learner, params)

    uids = list(range(6))
    assert len({uid_replica(u, 2) for u in uids}) == 2  # genuinely mixed
    r_reqs = [_request(u) for u in uids] + \
        [_request(u, with_support=False) for u in uids[:3]]
    s_reqs = [_request(u) for u in uids] + \
        [_request(u, with_support=False) for u in uids[:3]]
    router.run_to_completion(r_reqs)
    solo.run_to_completion(s_reqs)
    for a, b in zip(r_reqs, s_reqs):
        assert a.done and b.done and not a.failed
        np.testing.assert_array_equal(a.all_logits(), b.all_logits())
    # repeats hit the replica that adapted them — no re-adaptation
    assert router.stats()["tasks_adapted"] == len(uids)


def test_compile_counters_flat_and_equal_single_replica():
    """Ragged mixed-replica workload (two support buckets, uneven uid
    split): each replica compiles each bucket's adapt dispatch ONCE and
    the predict dispatch ONCE — exactly the solo engine's counters.
    Replication multiplies serving capacity, never compilation."""
    learner = _learner()
    params = learner.init(jax.random.key(0))
    kw = dict(support_buckets=(4, 8))
    router = _router(learner, params, replicas=2, **kw)
    solo = _solo(learner, params, **kw)

    rng = np.random.default_rng(0)

    def ragged(uid, n_support):
        reps = n_support // 3
        return EpisodicRequest(
            uid=uid,
            support_x=rng.normal(size=(3 * reps, 8, 8, 3)).astype(np.float32),
            support_y=np.tile(np.arange(3, dtype=np.int32), reps),
            query_x=rng.normal(size=(4, 8, 8, 3)).astype(np.float32), way=3)

    # both replicas see both buckets; the split is ragged (3 vs 5 uids)
    sizes = {u: (3 if i % 2 else 6)
             for i, u in enumerate(_uids_for(0, 2, 3) + _uids_for(1, 2, 5))}
    reqs = [ragged(u, n) for u, n in sizes.items()]
    router.run_to_completion(reqs)
    solo.run_to_completion([ragged(u, n) for u, n in sizes.items()])
    ss = solo.stats()
    assert ss["adapt_compiles"] == 2 and ss["predict_compiles"] == 1
    for p in router.stats()["per_replica"]:
        assert p["adapt_compiles"] == ss["adapt_compiles"]
        assert p["predict_compiles"] == ss["predict_compiles"]


# -- warm tier across resizes -------------------------------------------------


def test_resizing_replicas_never_loses_warm_state(tmp_path):
    """The warm shard subdir is a pure function of (uid, shard count) with
    the shard count FIXED (DEFAULT_WARM_SHARDS, independent of replicas):
    a deployment resized 2 -> 4 replicas over the same warm root re-routes
    uids but finds every spilled state where it was left — support-less
    repeats rehydrate bit-exactly instead of failing or re-adapting."""
    assert DEFAULT_WARM_SHARDS % 2 == 0 and DEFAULT_WARM_SHARDS % 4 == 0
    learner = _learner()
    params = learner.init(jax.random.key(0))
    warm = tmp_path / "warm"
    uids = list(range(8))

    first = [_request(u) for u in uids]
    r2 = _router(learner, params, replicas=2, warm_dir=warm,
                 cache_capacity=1)                  # tiny L1: force spills
    r2.run_to_completion(first)
    # evict each replica's last resident state too (capacity-1 L1 keeps
    # the most recent uid; adapting one more per replica spills it)
    r2.run_to_completion([_request(u)
                          for u in _uids_for(0, 2, 1, start=100)
                          + _uids_for(1, 2, 1, start=100)])
    assert r2.stats()["spills"] >= len(uids)
    # the shared root grew uid-hash shard subdirs, no files at the root
    assert sorted(p.name for p in warm.glob("uid_*")) == []
    assert any(warm.glob("shard_*/uid_*.npz"))

    # resized deployment: new router, MORE replicas, same warm root.
    # Support-less repeats must all be served (nothing lost), and uids
    # that changed home rehydrate from the shared warm tier.
    r4 = _router(learner, params, replicas=4, warm_dir=warm,
                 cache_capacity=1)
    moved = [u for u in uids if uid_replica(u, 4) != uid_replica(u, 2)]
    assert moved, "seed produced no re-routed uids; widen the uid range"
    repeats = [_request(u, with_support=False) for u in uids]
    r4.run_to_completion(repeats)
    s4 = r4.stats()
    assert all(r.done and not r.failed for r in repeats)
    assert s4["tasks_adapted"] == 0                  # nothing re-adapted
    assert s4["rehydrates"] == len(uids)             # all from the warm tier
    for a, b in zip(first, repeats):
        np.testing.assert_array_equal(a.all_logits(), b.all_logits())


# -- admission ---------------------------------------------------------------


def test_rejection_priced_by_routed_replica_ewma():
    """Bounded-queue rejection quotes ``retry_after_us`` from the ROUTED
    replica's own adapt-cost EWMA: a hot replica's hint, not a deployment
    average — and a uid routed to the idle replica still admits."""
    learner = _learner()
    params = learner.init(jax.random.key(0))
    router = _router(learner, params, replicas=2, max_queue=1, n_slots=2)
    router.replicas[0]._adapt_cost_est_us = 5000.0   # hot replica
    router.replicas[1]._adapt_cost_est_us = 100.0    # idle replica

    u0a, u0b = _uids_for(0, 2, 2)
    (u1,) = _uids_for(1, 2, 1)
    assert router.submit(_request(u0a))              # fills replica 0's queue
    rej = _request(u0b)
    assert not router.submit(rej)                    # over replica 0's bound
    assert rej.rejected and rej.retry_after_us == 5000.0
    ok = _request(u1)
    assert router.submit(ok)                         # replica 1 is idle
    assert not ok.rejected
    assert router.stats()["rejections"] == 1


def test_throughput_smoke_two_replicas_admit_faster():
    """Tier-1 perf smoke (FakeClock, zero real sleeps): the same 8-request
    workload completes in >= 1.5x fewer router steps on 2 replicas than on
    1 — each router step steps every live replica once, so admitted
    requests per engine step scale with the replica count."""
    learner = _learner()
    params = learner.init(jax.random.key(0))
    # 4 uids homed on each replica: the split is exactly even
    uids = _uids_for(0, 2, 4) + _uids_for(1, 2, 4)

    def run(replicas):
        clk = FakeClock()
        eng = _router(learner, params, replicas=replicas, n_slots=1,
                      clock=clk)
        for u in uids:
            eng.submit(_request(u))
        steps = 0
        while eng.busy:
            eng.step()
            clk.advance(0.001)
            steps += 1
            assert steps < 100
        assert eng.stats()["tasks_adapted"] == len(uids)
        return steps

    steps_1, steps_2 = run(1), run(2)
    assert steps_1 / steps_2 >= 1.5, (steps_1, steps_2)


# -- quantized replicas -------------------------------------------------------


@pytest.mark.quant
def test_int8_composes_per_replica():
    """serve_quant='int8' applies to EVERY replica's weight copy: summed
    resident bytes are R x the solo int8 engine's (the replication cost,
    counted honestly), the frozen slice shrinks below fp32 per copy (the
    >=3x guard at realistic sizes lives in tests/test_quant_serving.py —
    this backbone is too tiny for it), and logits agree with the solo
    int8 engine bit-for-bit."""
    learner = _learner()
    params = learner.init(jax.random.key(0))
    router = _router(learner, params, replicas=2, serve_quant="int8")
    solo = _solo(learner, params, serve_quant="int8")
    reqs = [_request(u) for u in range(4)]
    router.run_to_completion(reqs)
    solo_reqs = [_request(u) for u in range(4)]
    solo.run_to_completion(solo_reqs)
    for a, b in zip(reqs, solo_reqs):
        np.testing.assert_array_equal(a.all_logits(), b.all_logits())
    rs, ss = router.stats(), solo.stats()
    assert rs["param_bytes_resident"] == 2 * ss["param_bytes_resident"]
    assert rs["frozen_param_bytes_resident"] < rs["frozen_param_bytes_fp32"]


# -- device-group isolation (subprocess, 4 emulated devices) ------------------

_SETUP = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.lite import LiteSpec
    from repro.core.meta_learners import MetaLearnerConfig, make_learner
    from repro.core.set_encoder import SetEncoderConfig
    from repro.data.episodic import EpisodicImageConfig, sample_image_task
    from repro.launch.mesh import make_replica_mesh
    from repro.models.conv_backbone import (ConvBackboneConfig,
                                            make_conv_backbone)
    from repro.serve.episodic import EpisodicRequest, EpisodicServeEngine
    from repro.serve.replica import ReplicatedServeEngine

    bb = make_conv_backbone(ConvBackboneConfig(widths=(4,), feature_dim=8))
    learner = make_learner(
        MetaLearnerConfig(kind="protonets", way=3), bb,
        SetEncoderConfig(kind="conv", conv_blocks=1, conv_width=4,
                         task_dim=8))
    params = learner.init(jax.random.key(0))
    tcfg = EpisodicImageConfig(way=3, shot=2, query_per_class=2,
                               image_size=8)
    kw = dict(lite=LiteSpec(exact=True, chunk_size=8), n_slots=2,
              query_chunk=4, support_buckets=(8,))

    def request(uid):
        t = sample_image_task(jax.random.key(300 + uid), tcfg)
        return EpisodicRequest(uid=uid, support_x=np.asarray(t.support_x),
                               support_y=np.asarray(t.support_y),
                               query_x=np.asarray(t.query_x), way=3)
""")


@pytest.fixture
def run_devices():
    """Run ``_SETUP + code`` in a subprocess emulating N CPU devices
    (XLA_FLAGS must be set before jax import — the fake devices must not
    leak into this process; same pattern as tests/test_multihost.py)."""

    def run(code: str, devices: int = 4, timeout: int = 540) -> str:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count"
                            f"={devices}")
        env["PYTHONPATH"] = SRC
        out = subprocess.run(
            [sys.executable, "-c", _SETUP + textwrap.dedent(code)],
            capture_output=True, text=True, env=env, timeout=timeout)
        assert out.returncode == 0, out.stderr[-3000:]
        return out.stdout

    return run


def test_replica_groups_bit_exact_and_counters_flat(run_devices):
    """ISSUE-9 acceptance (i)+(ii) on 2 replicas x 2 devices: a mixed-uid
    workload through device-group replicas is BIT-exact with a solo
    no-mesh engine, and each replica's compile counters equal the solo
    engine's."""
    out = run_devices("""
        assert len(jax.devices()) == 4
        meshes = make_replica_mesh(2, 2)
        assert not (set(meshes[0].devices.flat)
                    & set(meshes[1].devices.flat))
        router = ReplicatedServeEngine(learner, params, replicas=2,
                                       meshes=meshes,
                                       serve_layout="replicated", **kw)
        solo = EpisodicServeEngine(learner, params, **kw)
        reqs = [request(u) for u in range(6)]
        solo_reqs = [request(u) for u in range(6)]
        router.run_to_completion(reqs)
        solo.run_to_completion(solo_reqs)
        for a, b in zip(reqs, solo_reqs):
            assert a.done and b.done
            np.testing.assert_array_equal(a.all_logits(), b.all_logits())
        ss = solo.stats()
        for p in router.stats()["per_replica"]:
            assert p["adapt_compiles"] == ss["adapt_compiles"]
            assert p["predict_compiles"] == ss["predict_compiles"]
        print("BITEXACT_OK")
    """)
    assert "BITEXACT_OK" in out


def test_predict_wire_scales_with_group_not_deployment(run_devices):
    """ISSUE-9 acceptance (iii): compile the predict step weight-stationary
    on one 2-device replica group vs a solo 2-device mesh vs the full
    4-device mesh.  Per-replica wire bytes == the solo 2-device engine's
    (the group IS the collective domain), every collective's group fits in
    the replica's 2 devices (zero inter-group communication is structural:
    the program cannot name an outside device), and the 4-device wire is
    strictly larger.  Under 'replicated' the step has no collectives at
    all."""
    out = run_devices("""
        from jax.sharding import Mesh
        from repro.core.episodic_train import task_key
        from repro.data.episodic import collate_task_batch
        from repro.roofline.analysis import score_serving_layout
        from repro.serve.quant_params import dequantize_params, \\
            quantize_frozen

        sw = quantize_frozen(learner, params, "int8")
        probe = [sample_image_task(jax.random.key(i), tcfg)
                 for i in range(2)]
        batch = collate_task_batch(probe, support_size=8,
                                   query_size=probe[0].query_x.shape[0])
        keys = jax.vmap(lambda i: task_key(jax.random.key(0), i))(
            jnp.arange(2))
        lite = kw["lite"]
        states = learner.adapt_batch(dequantize_params(sw), batch, keys,
                                     lite)
        fn = lambda w, st, qx: learner.predict_batch(
            dequantize_params(w), st, qx)
        args = (states, batch.query_x)

        group = make_replica_mesh(2, 2)[0]            # one replica's mesh
        solo2 = Mesh(np.asarray(jax.devices()[:2]), ("serve",))
        full4 = Mesh(np.asarray(jax.devices()), ("serve",))

        ws_group = score_serving_layout(fn, sw, args, group,
                                        "weight_stationary")
        ws_solo2 = score_serving_layout(fn, sw, args, solo2,
                                        "weight_stationary")
        ws_full4 = score_serving_layout(fn, sw, args, full4,
                                        "weight_stationary")
        rep_group = score_serving_layout(fn, sw, args, group, "replicated")

        assert ws_group["wire_bytes"] == ws_solo2["wire_bytes"], \\
            (ws_group["wire_bytes"], ws_solo2["wire_bytes"])
        assert ws_group["wire_bytes"] > 0
        assert ws_full4["wire_bytes"] > ws_group["wire_bytes"]
        assert rep_group["wire_bytes"] == 0
        assert rep_group["collective_count"] == 0

        # every collective's replica group fits inside the group's 2
        # devices — zero inter-group communication, structurally
        from repro.roofline.analysis import batch_shardings, \\
            serving_shardings
        from repro.roofline.hlo import collectives_report
        in_sh = (serving_shardings(sw, group, "weight_stationary"),) + \\
            tuple(batch_shardings(a, group, "weight_stationary")
                  for a in args)
        compiled = jax.jit(fn, in_shardings=in_sh).lower(
            sw, *args).compile()
        rep = collectives_report(compiled.as_text())
        assert rep["count"] > 0
        for kind, row in rep["per_kind"].items():
            assert row["max_group"] <= 2, (kind, row)
        print("WIRE", ws_group["wire_bytes"], ws_full4["wire_bytes"])
        print("ISOLATION_OK")
    """)
    assert "ISOLATION_OK" in out
