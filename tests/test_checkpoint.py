"""Fault tolerance: atomic checkpoints, keep-N, preemption-exact resume."""
import pathlib
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import StragglerMonitor, train
from repro.train.step import adamw_for, make_init_state, make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("gemma2-2b")
    init = make_init_state(cfg, adamw_for(cfg))
    step = make_train_step(cfg, adamw_for(cfg))
    pipe = TokenPipeline(TokenPipelineConfig(vocab=cfg.vocab, seq_len=32,
                                             global_batch=2))
    batch_at = lambda s: {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
    template = jax.eval_shape(init, jax.random.key(0))
    return cfg, init, step, batch_at, template


def _max_param_diff(a, b):
    d = [float(jnp.max(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32))))
         for x, y in zip(jax.tree.leaves(a["params"]), jax.tree.leaves(b["params"]))]
    return max(d)


def test_save_restore_roundtrip(setup, tmp_path, key):
    cfg, init, step, batch_at, template = setup
    state = init(key)
    ck = CheckpointManager(tmp_path, keep=3)
    ck.save(7, state, extra=dict(note="hello"))
    restored, extra = ck.restore(7, template)
    assert extra["note"] == "hello"
    assert _max_param_diff(state, restored) == 0.0
    # dtypes preserved (incl. int8 quantized opt state if any / bf16)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype


def test_uncommitted_checkpoint_invisible(setup, tmp_path, key):
    cfg, init, step, batch_at, template = setup
    state = init(key)
    ck = CheckpointManager(tmp_path, keep=3)
    ck.save(5, state)
    p = ck.save(9, state)
    (p / "COMMIT").unlink()              # simulate death mid-publish
    assert ck.latest_step() == 5


def test_keep_n_retention(setup, tmp_path, key):
    cfg, init, step, batch_at, template = setup
    state = init(key)
    ck = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, state)
    assert ck.all_steps() == [3, 4]


def test_preemption_resume_bit_exact(setup, tmp_path, key):
    """Kill the loop mid-run; the resumed run must match an uninterrupted
    one bit-for-bit."""
    cfg, init, step, batch_at, template = setup
    ck = CheckpointManager(tmp_path / "a", keep=5)

    class Boom(RuntimeError):
        pass

    def preempt_at_8(s):
        if s == 8:
            raise Boom()

    with pytest.raises(Boom):
        train(init(key), step, batch_at, 12, ckpt=ck, ckpt_every=4,
              state_template=template, preemption_hook=preempt_at_8)
    # resume (fresh process would do exactly this)
    r = train(init(key), step, batch_at, 12, ckpt=ck, ckpt_every=4,
              state_template=template)
    assert r.resumed_from == 8
    r_ref = train(init(key), step, batch_at, 12)
    assert _max_param_diff(r.state, r_ref.state) == 0.0


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(alpha=0.5, ratio=3.0)
    assert not mon.observe(0, 1.0)
    assert not mon.observe(1, 1.1)
    assert mon.observe(2, 10.0)          # 10x the EWMA -> flagged
    assert mon.flagged == [2]
    assert not mon.observe(3, 1.0)       # EWMA not poisoned by the spike


def test_save_load_array_tree_roundtrip_bitexact(tmp_path, key):
    """The standalone npz pytree serialization (the warm task-state
    tier's substrate) roundtrips bit-exactly, including bf16 leaves
    (uint16 views) and integer leaves, against an abstract template."""
    from repro.train.checkpoint import load_array_tree, save_array_tree
    tree = dict(
        w=jax.random.normal(key, (5, 3)),
        nested=dict(b=jnp.arange(4, dtype=jnp.int32),
                    h=jax.random.normal(jax.random.key(1), (2, 2)
                                        ).astype(jnp.bfloat16)),
        scale=jnp.float32(0.5),
    )
    f = tmp_path / "tree.npz"
    save_array_tree(f, tree)
    template = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(jnp.shape(a), a.dtype), tree)
    back = load_array_tree(f, template)
    assert jax.tree.structure(back) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(
            np.asarray(a, np.float32) if a.dtype == jnp.bfloat16
            else np.asarray(a),
            np.asarray(b, np.float32) if b.dtype == jnp.bfloat16
            else np.asarray(b))
