"""Per-arch smoke tests (deliverable f): every assigned architecture at a
reduced config runs one forward/train step on CPU with finite outputs and
correct shapes, and the decode path agrees with prefill."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models.registry import get_api
from repro.train.step import adamw_for, make_init_state, make_train_step

B, S = 2, 32


def _batch(cfg, key):
    batch = dict(tokens=jax.random.randint(key, (B, S), 0, cfg.vocab))
    if cfg.frontend is not None:
        batch["frontend_embeds"] = 0.1 * jnp.ones(
            (B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_is_exact(arch):
    """The full config carries the assigned numbers (spot checks)."""
    cfg = get_config(arch)
    expected = {
        "kimi-k2-1t-a32b": (61, 7168, 163840),
        "deepseek-v2-236b": (60, 5120, 102400),
        "phi-3-vision-4.2b": (32, 3072, 32064),
        "mamba2-780m": (48, 1536, 50280),
        "minicpm-2b": (40, 2304, 122753),
        "minitron-4b": (32, 3072, 256000),
        "qwen2-72b": (80, 8192, 152064),
        "gemma2-2b": (26, 2304, 256000),
        "zamba2-7b": (81, 3584, 32000),
        "whisper-base": (6, 512, 51865),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.vocab) == expected


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch, key):
    cfg = get_smoke_config(arch)
    init = make_init_state(cfg, adamw_for(cfg))
    state = init(key)
    step = jax.jit(make_train_step(cfg, adamw_for(cfg)))
    state2, metrics = step(state, _batch(cfg, key))
    assert jnp.isfinite(metrics["loss"]), (arch, metrics)
    assert jnp.isfinite(metrics["grad_norm"])
    # params actually moved
    a = jax.tree.leaves(state["params"])[0]
    b = jax.tree.leaves(state2["params"])[0]
    assert not jnp.array_equal(a, b)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_shapes_and_finite(arch, key):
    cfg = get_smoke_config(arch)
    api = get_api(cfg)
    params = api.init(key, cfg)
    logits, cache = jax.jit(lambda p, b: api.prefill(p, b, cfg))(
        params, _batch(cfg, key))
    assert logits.shape == (B, cfg.vocab_padded)
    assert jnp.all(jnp.isfinite(logits))
    # vlm frontends prepend patch embeddings to the decoded sequence
    expect = S + (cfg.n_frontend_tokens if cfg.frontend == "vision_stub" else 0)
    assert int(cache["len"]) == expect


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if a != "whisper-base"])
def test_decode_matches_prefill(arch, key):
    """Greedy decode over the same prompt must reproduce the prefill's
    last-token logits (MoE archs get ample capacity so no tokens drop)."""
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    api = get_api(cfg)
    params = api.init(key, cfg)
    batch = _batch(cfg, key)
    logits_p, _ = jax.jit(lambda p, b: api.prefill(p, b, cfg))(params, batch)
    cache = api.init_cache(cfg, B, S + 4)
    dec = jax.jit(lambda p, c, t: api.decode_step(p, c, t, cfg))
    if cfg.frontend is not None:
        pytest.skip("vlm decode-from-scratch differs by frontend positions")
    for i in range(S):
        lg, cache = dec(params, cache, batch["tokens"][:, i:i + 1])
    tol = 0.05 if cfg.family in ("mamba2", "hybrid") else 0.02
    assert float(jnp.max(jnp.abs(lg - logits_p))) < tol


def test_whisper_decode_runs(key):
    cfg = get_smoke_config("whisper-base")
    api = get_api(cfg)
    params = api.init(key, cfg)
    batch = _batch(cfg, key)
    _, cache = jax.jit(lambda p, b: api.prefill(p, b, cfg))(params, batch)
    # continue decoding from the prefilled cache (within capacity)
    cache = jax.tree.map(lambda a: a, cache)
    big = api.init_cache(cfg, B, S + 8)
    for k in ("k", "v"):
        big[k] = jax.lax.dynamic_update_slice(big[k], cache[k], (0, 0, 0, 0, 0))
    big["cross_k"], big["cross_v"] = cache["cross_k"], cache["cross_v"]
    big["len"] = cache["len"]
    lg, big = jax.jit(lambda p, c, t: api.decode_step(p, c, t, cfg))(
        params, big, jnp.zeros((B, 1), jnp.int32))
    assert jnp.all(jnp.isfinite(lg))
    assert int(big["len"]) == S + 1
