"""Distribution layer: sharding rules, HLO analyzer, elastic reshard, and a
subprocess dry-run smoke (these fake multiple devices via XLA_FLAGS, which
must not leak into this process — hence subprocess)."""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import hlo

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def _run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_hlo_analyzer_loop_awareness():
    """Scan vs unrolled FLOPs parity — the analyzer's core guarantee."""
    def scan_model(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=16)
        return jnp.sum(y)

    def unrolled(x, w):
        for _ in range(16):
            x = jnp.tanh(x @ w)
        return jnp.sum(x)

    xs = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    a = hlo.analyze(jax.jit(scan_model).lower(xs, ws).compile().as_text())
    b = hlo.analyze(jax.jit(unrolled).lower(xs, ws).compile().as_text())
    assert a["dot_flops"] == b["dot_flops"] > 0
    # XLA's own count misses the loop factor (documented motivation);
    # the version-drift normalization lives in the one shared shim.
    ca = hlo.xla_cost_analysis(jax.jit(scan_model).lower(xs, ws).compile())
    assert a["dot_flops"] > 4 * ca["flops"]


def test_param_specs_cover_big_leaves():
    """Every >=2D parameter of every arch gets at least one sharded dim."""
    from repro.configs.registry import ARCH_IDS, get_config
    from repro.launch.specs import abstract_params_for
    from repro.sharding import rules
    for arch in ARCH_IDS:
        params = abstract_params_for(get_config(arch))
        specs = rules.param_specs(params)
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, rules.P))
        for leaf, spec in zip(flat_p, flat_s):
            if leaf.size >= 1 << 20:     # every big tensor must shard
                assert any(e is not None for e in tuple(spec)), (arch, leaf.shape)


@pytest.mark.slow
def test_elastic_reshard_roundtrip():
    """8 -> 4 -> 8 devices: state survives re-mesh bit-exactly."""
    _run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh_for
        from repro.train.elastic import choose_mesh_shape, elastic_transition
        from jax.sharding import NamedSharding, PartitionSpec as P

        def specs_for(mesh, abstract):
            return jax.tree.map(lambda a: P("data", None) if len(a.shape) == 2 else P(), abstract)

        state = dict(w=jnp.arange(64.0).reshape(8, 8), step=jnp.asarray(3))
        m8 = make_mesh_for(choose_mesh_shape(8, 2), ("data", "model"))
        s8 = jax.device_put(state, NamedSharding(m8, P()))
        m4 = make_mesh_for(choose_mesh_shape(4, 2), ("data", "model"))
        s4 = elastic_transition(s8, m8, m4, specs_for)
        m8b = make_mesh_for(choose_mesh_shape(8, 2), ("data", "model"))
        s8b = elastic_transition(s4, m4, m8b, specs_for)
        np.testing.assert_array_equal(np.asarray(s8b["w"]), np.asarray(state["w"]))
        assert len(s4["w"].sharding.device_set) == 4
        assert len(s8b["w"].sharding.device_set) == 8
        print("ELASTIC_OK")
        """)


@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    """End-to-end dry-run of one real cell on 512 fake devices."""
    out = tmp_path / "dr.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "whisper-base",
         "--shape", "decode_32k", "--mesh", "multi", "--out", str(out)],
        capture_output=True, text=True, env=env, timeout=540)
    assert r.returncode == 0, r.stderr[-3000:]
    rec = json.loads(out.read_text())["whisper-base/decode_32k/multi"]
    assert rec["status"] == "ok"
    assert rec["chips"] == 512
    assert rec["flops_per_device"] > 0


@pytest.mark.slow
def test_compressed_psum_shard_map():
    """ef-compressed psum under shard_map on 8 fake devices."""
    _run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.optim.compress import compressed_psum, zeros_error
        from repro.sharding import shard_map
        mesh = jax.make_mesh((8,), ("data",))
        g = jnp.arange(8.0 * 16).reshape(8, 16) / 100.0
        err = jnp.zeros((8, 16))

        @partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
                 out_specs=(P("data"), P("data")))
        def body(gs, es):
            s, ne = compressed_psum(dict(g=gs), "data", dict(g=es))
            return s["g"], ne["g"]

        summed, new_err = body(g, err)
        want = jnp.sum(g, axis=0, keepdims=True)
        got = summed[0:1]
        assert float(jnp.max(jnp.abs(got - want))) < 0.05, (got, want)
        print("PSUM_OK")
        """)


@pytest.mark.slow
def test_optimized_variant_reduces_moe_collectives(tmp_path):
    """§Perf regression guard: the shard_map MoE dispatch must keep the
    collective wire bytes far below the GSPMD-scatter baseline (>=3x on
    the deepseek MoE prefill cell)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    outs = {}
    for variant in ("baseline", "optimized"):
        out = tmp_path / f"{variant}.json"
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "deepseek-v2-236b", "--shape", "prefill_32k",
             "--mesh", "single", "--variant", variant, "--out", str(out)],
            capture_output=True, text=True, env=env, timeout=540)
        assert r.returncode == 0, r.stderr[-2000:]
        rec = json.loads(out.read_text())["deepseek-v2-236b/prefill_32k/single"]
        outs[variant] = sum(k["wire_bytes"] for k in rec["collectives"].values())
    assert outs["optimized"] * 3 < outs["baseline"], outs
